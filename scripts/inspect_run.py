#!/usr/bin/env python
"""Live run inspector: summarize an exported tuner trace.

    python scripts/inspect_run.py results/bench/traces/mftune_tpch.json
    python scripts/inspect_run.py run.trace.jsonl --validate

Accepts both exporter formats (JSONL event stream and Chrome/Perfetto
trace_event JSON) — the format is auto-detected. Prints the stage time
breakdown, cache hit rates, rung survival funnel, and budget attribution
(low- vs full-fidelity virtual seconds). ``--validate`` additionally
checks every event against repro/obs/trace_schema.json and exits nonzero
on violations.
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("trace", nargs="+", help="trace file(s): .jsonl or Perfetto .json")
    ap.add_argument("--validate", action="store_true",
                    help="validate every event against the trace schema")
    args = ap.parse_args(argv)

    from repro import obs

    bad = 0
    for path in args.trace:
        if len(args.trace) > 1:
            print(f"=== {path} ===")
        try:
            events = obs.read_events(path)
        except Exception as e:
            print(f"error: cannot read {path}: {type(e).__name__}: {e}")
            bad += 1
            continue
        if args.validate:
            violations = obs.validate_events(events)
            if violations:
                bad += 1
                print(f"schema: {len(violations)} violation(s)")
                for v in violations[:10]:
                    print("  ", v)
            else:
                print(f"schema: all {len(events)} events valid")
        print(obs.summarize(events))
        if len(args.trace) > 1:
            print()
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
