#!/usr/bin/env bash
# Pre-PR gate: tier-1 fast suite + batched-vs-scalar equivalence tests.
#
#   scripts/check.sh          # tier-1 (-m "not slow" via pytest.ini) + equivalence
#   scripts/check.sh --slow   # additionally run the slow tier (system/model tests)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== batched == scalar equivalence gate =="
python -m pytest -x -q tests/test_batch_eval.py

echo "== packed-forest == per-tree-loop equivalence gate =="
python -m pytest -x -q tests/test_surrogate_packed.py

echo "== columnar-space == scalar / frontier == recursive equivalence gate =="
python -m pytest -x -q tests/test_space_plane.py tests/test_tree_frontier.py

echo "== batched-shapley == per-chain-loop equivalence gate =="
python -m pytest -x -q tests/test_shapley_batched.py

echo "== rung-table == scalar-hyperband equivalence gate =="
python -m pytest -x -q tests/test_rung_table.py

echo "== observability gate (span invariants + tracer-on/off bit-identity) =="
python -m pytest -x -q tests/test_obs.py

echo "== hb-schedule bench smoke (promotion equivalence + allocation-growth guard) =="
python -m benchmarks.bench_hb_schedule --smoke > /dev/null

echo "== rank/descent kernel gate (radix == stable argsort; chain-delta identity) =="
python -m pytest -x -q tests/test_rank_kernel.py tests/test_pool_delta.py tests/test_chain_decline.py

echo "== pool-scaling bench smoke (fused-vs-staged identity + jit-cache guard) =="
python -m benchmarks.bench_pool_scaling --smoke > /dev/null

echo "== trace-schema validation (traced end-to-end run, every event checked) =="
python -m repro.obs.selfcheck > /dev/null

echo "== tracer overhead regression gate (on vs off < 1%, identical trajectories) =="
python -m benchmarks.bench_overhead --smoke

echo "== tier-1: pytest -x -q (rest of the fast suite) =="
python -m pytest -x -q --ignore=tests/test_batch_eval.py --ignore=tests/test_surrogate_packed.py \
  --ignore=tests/test_space_plane.py --ignore=tests/test_tree_frontier.py \
  --ignore=tests/test_shapley_batched.py --ignore=tests/test_rung_table.py \
  --ignore=tests/test_obs.py --ignore=tests/test_rank_kernel.py \
  --ignore=tests/test_pool_delta.py --ignore=tests/test_chain_decline.py

if [[ "${1:-}" == "--slow" ]]; then
  echo "== slow tier =="
  python -m pytest -q -m slow
  echo "== surrogate bench smoke (1 repetition) =="
  python -m benchmarks.bench_surrogate --smoke
  echo "== config-space bench smoke (1 repetition) =="
  python -m benchmarks.bench_config_space --smoke
  echo "== pool-scaling full sweep (refreshes results/bench/pool_scaling.json) =="
  python -m benchmarks.bench_pool_scaling > /dev/null
fi
echo "OK"
