"""Shared benchmark machinery.

Every benchmark module exposes ``run(force=False) -> list[row]`` where a
row is ``{"name": str, "us_per_call": float, "derived": str}``. Results are
cached as JSON under results/bench/ so the aggregate ``benchmarks.run``
pass is cheap and reproducible; ``force=True`` recomputes.
"""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CACHE = os.path.join(REPO, "results", "bench")
# _v2: the sparksim noise derivation changed (hash Box-Muller instead of
# per-cell default_rng), so histories generated before that are not
# comparable with new evaluations and must not be reused
KB_ROOT = os.path.join(REPO, ".cache", "sparksim_kb_v2")

os.makedirs(CACHE, exist_ok=True)


CHEAP = {"hb_schedule", "roofline", "batch_eval", "surrogate", "config_space",
         "compression", "pool_scaling"}


def cached(name: str, force: bool, fn: Callable[[], List[dict]]) -> List[dict]:
    path = os.path.join(CACHE, f"{name}.json")
    if not force and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    if os.environ.get("REPRO_BENCH_CACHED_ONLY") == "1" and name not in CHEAP:
        # a long-running suite populates the cache in the background; report
        # in-flight benches instead of recomputing hours of tuning inline
        return [{"name": f"{name}_pending", "us_per_call": 0.0,
                 "derived": "computing in background suite; see results/bench/ when complete"}]
    rows = fn()
    with open(path + ".tmp", "w") as f:
        json.dump(rows, f, indent=1)
    os.replace(path + ".tmp", path)
    return rows


def load_kb(exclude: Optional[List[str]] = None, include_only: Optional[List[str]] = None):
    """Leave-one-out / filtered view of the cached 32-task history."""
    from repro.core import KnowledgeBase
    from repro.sparksim import build_knowledge_base

    kb_full = build_knowledge_base(KB_ROOT)  # cached; generates if missing
    kb = KnowledgeBase()
    for tid, rec in kb_full.tasks.items():
        if exclude and tid in exclude:
            continue
        if include_only is not None and tid not in include_only:
            continue
        kb.tasks[tid] = rec
    return kb


def run_method(method: str, workload, kb, budget_s: float, seed: int,
               mftune_opts: Optional[dict] = None):
    """Instantiate + run one tuner; returns (TuningResult, wall_s).

    When ``REPRO_BENCH_TRACE_DIR`` is set (``benchmarks.run --trace``),
    each run executes under a fresh Tracer and its Perfetto trace is
    persisted to ``$REPRO_BENCH_TRACE_DIR/<method>_<task>_s<seed>.json``
    alongside the results/bench/*.json rows. Off by default — tracing adds
    no RNG draws, so traced and untraced runs are bit-identical anyway.
    """
    from repro.baselines import LOCAT, LOFTune, Rover, Tuneful, TopTune, VanillaBO, RandomSearch
    from repro.core import MFTune, MFTuneOptions
    from repro.tuneapi import Budget

    def go():
        budget = Budget(budget_s)
        if method.startswith("mftune"):
            opts = MFTuneOptions(seed=seed, **(mftune_opts or {}))
            return MFTune(workload, kb, opts).run(budget)
        cls = {
            "locat": LOCAT, "toptune": TopTune, "tuneful": Tuneful,
            "rover": Rover, "loftune": LOFTune, "bo": VanillaBO,
            "random": RandomSearch,
        }[method]
        return cls(workload, kb, seed=seed).run(budget)

    trace_dir = os.environ.get("REPRO_BENCH_TRACE_DIR")
    t0 = time.perf_counter()
    if trace_dir:
        from repro import obs

        tracer = obs.Tracer(f"{method}:{workload.task_id}:s{seed}")
        with obs.tracing(tracer):
            res = go()
        wall = time.perf_counter() - t0
        os.makedirs(trace_dir, exist_ok=True)
        out = os.path.join(trace_dir, f"{method}_{workload.task_id}_s{seed}.json")
        obs.export_perfetto(tracer, out)
        return res, wall
    res = go()
    return res, time.perf_counter() - t0


def stage_summary(res, top: int = 3) -> str:
    """Compact ``stage=secs`` list from a TuningResult's overheads view —
    every method populates it through the shared tracing vocabulary."""
    if not res.overheads:
        return "stages=n/a"
    items = sorted(res.overheads.items(), key=lambda kv: -kv[1])[:top]
    return "stages[" + " ".join(f"{k}={v:.1f}s" for k, v in items) + "]"


def traj_to_curve(res, budget_s: float, n_points: int = 49):
    """Best-so-far latency at evenly spaced times (NaN before first full)."""
    ts = np.linspace(0, budget_s, n_points)
    out = np.full(n_points, np.nan)
    pts = sorted([(p.time, p.best) for p in res.trajectory])
    best = np.nan
    j = 0
    for i, t in enumerate(ts):
        while j < len(pts) and pts[j][0] <= t:
            best = pts[j][1] if not (best == best) else min(best, pts[j][1])
            j += 1
        out[i] = best
    return ts, out


def geomean(xs) -> float:
    xs = [x for x in xs if x == x and x > 0]
    return float(np.exp(np.mean(np.log(xs)))) if xs else float("nan")
