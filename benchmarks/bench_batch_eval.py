"""Scalar vs batched evaluation throughput (configs/sec).

The MFTune bottleneck the batched engine attacks: a Hyperband rung scoring
32 candidate configs over the 99-query TPC-DS workload. Reports configs/sec
for the scalar `SparkCostModel.evaluate` loop and the vectorized
`evaluate_batch` grid, plus the speedup; the cached JSON under
results/bench/ is the baseline later PRs track.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import cached

N_CONFIGS = 32
REPEATS = 5


def _throughput(fn, n_configs: int, repeats: int) -> float:
    fn()  # warm up (hash prefixes, numpy dispatch)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return n_configs / best


def _run():
    from repro.sparksim import SparkWorkload

    wl = SparkWorkload("tpcds", 600, "A")
    rng = np.random.default_rng(0)
    cfgs = [dict(wl.space.default(), **c) for c in wl.space.sample(rng, N_CONFIGS)]
    subset = list(rng.choice(len(wl.queries), size=33, replace=False))

    rows = []
    for name, kwargs in [("full_99q", {}), ("subset_33q", {"query_indices": subset})]:
        scalar = _throughput(
            lambda: [wl.model.evaluate(c, **kwargs) for c in cfgs], N_CONFIGS, REPEATS
        )
        batch = _throughput(
            lambda: wl.model.evaluate_batch(cfgs, **kwargs), N_CONFIGS, REPEATS
        )
        rows.append({
            "name": f"scalar_{name}", "us_per_call": 1e6 / scalar,
            "derived": f"{scalar:.0f} configs/s",
        })
        rows.append({
            "name": f"batch_{name}", "us_per_call": 1e6 / batch,
            "derived": f"{batch:.0f} configs/s; speedup {batch / scalar:.1f}x",
        })
    return rows


def run(force: bool = False):
    return cached("batch_eval", force, _run)


if __name__ == "__main__":
    for r in run(force=True):
        print(r)
