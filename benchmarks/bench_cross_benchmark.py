"""Fig. 3b/3e: cross-benchmark transfer.

Target tpch-600-A gets only the 16 tpcds histories (and vice versa), so
fidelity partitioning cannot run at t=0; MFO activates once the target's
own observations support Alg. 2 (red dashed line in the paper's figure).
Compared against the three history-using baselines.
"""

from __future__ import annotations

import numpy as np

from .common import cached, load_kb, run_method

METHODS = ["mftune", "tuneful", "rover", "loftune"]
SEEDS = [0, 1]
BUDGET = 48 * 3600.0


def run(force: bool = False):
    def compute():
        from repro.sparksim import SparkWorkload, make_task_id

        rows = []
        for bench, other in (("tpch", "tpcds"), ("tpcds", "tpch")):
            include = [make_task_id(other, gb, hw) for gb in (100, 600) for hw in "ABCDEFGH"]
            finals = {}
            act_times = []
            for method in METHODS:
                bests, walls = [], []
                for seed in SEEDS:
                    kb = load_kb(include_only=include)
                    wl = SparkWorkload(bench, 600, "A")
                    res, wall = run_method(method, wl, kb, BUDGET, seed)
                    bests.append(res.best_performance)
                    walls.append(wall)
                    if method == "mftune" and res.mfo_activation_time is not None:
                        act_times.append(res.mfo_activation_time / 3600)
                finals[method] = float(np.mean(bests))
                rows.append({
                    "name": f"fig3cross_{bench}600A_{method}",
                    "us_per_call": float(np.mean(walls)) * 1e6,
                    "derived": f"best_latency_s={np.mean(bests):.0f}",
                })
            mf = finals["mftune"]
            reds = {m: 100 * (1 - mf / finals[m]) for m in METHODS if m != "mftune"}
            rows.append({
                "name": f"fig3cross_{bench}600A_summary",
                "us_per_call": 0.0,
                "derived": (
                    f"reduction={min(reds.values()):.1f}%..{max(reds.values()):.1f}% "
                    f"(paper: {'20.0%..32.5%' if bench == 'tpch' else '35.7%..50.6%'}) "
                    f"mfo_activation_h={np.mean(act_times) if act_times else float('nan'):.1f} (delayed>0)"
                ),
            })
        return rows

    return cached("cross_benchmark", force, compute)
