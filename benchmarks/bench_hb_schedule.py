"""Hyperband scheduling + rung bookkeeping.

Two claims tracked here: (1) Table 1 exactness — ``hb_schedule`` enumerates
the paper's (n_i, r_i) grid bit-for-bit (R=27, eta=3); (2) the array-native
``RungTable`` takes the bracket-bookkeeping stage (per-eval row append +
failure-masked promotion sort + median cost caps) off the Python-bound
profile: >= 3x vs the scalar list-of-dataclass loop at 1024-config rungs.

The table path is equivalence-gated against the loop before timing, and an
allocation-growth guard checks that a reused (cleared) table performs no
further buffer growth across record/promote cycles — the property the
long-running multi-tenant service path depends on.

``--smoke`` (or REPRO_BENCH_SMOKE=1) runs 1 repetition for CI without
overwriting the committed multi-repetition baseline JSON.
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

from benchmarks.common import cached

EXPECTED = {  # s -> [(n_i, r_i), ...] from paper Table 1
    3: [(27, 1), (9, 3), (3, 9), (1, 27)],
    2: [(12, 3), (4, 9), (1, 27)],
    1: [(6, 9), (2, 27)],
    0: [(4, 27)],
}

RUNG_SIZES = [256, 1024, 4096]
FAIL_FRAC = 0.1
ETA = 3
REPEATS = 200
REUSE_CYCLES = 100


def _best(fn, repeats: int) -> float:
    fn()  # warm up
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _schedule_rows():
    from repro.core import hb_schedule

    t0 = time.perf_counter()
    brackets = hb_schedule(R=27, eta=3)
    dt = (time.perf_counter() - t0) * 1e6
    rows = []
    all_match = True
    for b in brackets:
        got = [(r.n, int(r.r)) for r in b.rungs]
        match = got == EXPECTED[b.s]
        all_match &= match
        rows.append({
            "name": f"hb_schedule_s{b.s}",
            "us_per_call": dt / len(brackets),
            "derived": f"rungs={got} match_paper_table1={match}",
        })
    rows.append({
        "name": "hb_schedule_table1",
        "us_per_call": dt,
        "derived": f"all_brackets_match={all_match}",
    })
    assert all_match
    return rows


def _promotion_rows(repeats: int):
    """Scalar list bookkeeping vs RungTable record+promote, per rung size."""
    from repro.core.hyperband import Bracket, EvalOutcome, Rung, RungTable

    rows = []
    rng = np.random.default_rng(0)
    for n in RUNG_SIZES:
        scores = rng.random(n)
        failed = rng.random(n) < FAIL_FRAC
        elapsed = 1.0 + rng.random(n)
        cfg_idx = np.arange(n, dtype=np.int64)
        configs = [{"id": i} for i in range(n)]
        # scalar inputs exactly as the loop backend receives them: one
        # (perf, failed, elapsed) scalar triple per evaluate call
        perf_l = [float(s) for s in scores]
        fail_l = [bool(f) for f in failed]
        elap_l = [float(e) for e in elapsed]

        def loop_promote():
            results = []
            for c, p, f, e in zip(configs, perf_l, fail_l, elap_l):
                results.append(EvalOutcome(c, p, f, e))
            ok = [r for r in results if not r.failed]
            ok.sort(key=lambda r: r.performance)
            keep = max(len(ok) // ETA, 1)
            return [r.config["id"] for r in ok[:keep]]

        bracket = Bracket(s=0, rungs=[Rung(n=n, r=1.0, delta=1.0)])
        table = RungTable(bracket, configs)

        def table_promote():
            table.clear()
            table.record(0, cfg_idx, scores, failed, elapsed)
            return table.promote(0, ETA)

        # equivalence gate before timing: identical survivor sets
        assert table_promote().tolist() == loop_promote()

        t_loop = _best(loop_promote, repeats)
        t_table = _best(table_promote, repeats)
        rows.append({
            "name": f"rung_promote_loop_{n}",
            "us_per_call": t_loop * 1e6,
            "derived": f"list append + filter + stable sort, {FAIL_FRAC:.0%} failed",
        })
        rows.append({
            "name": f"rung_promote_table_{n}",
            "us_per_call": t_table * 1e6,
            "derived": f"record + masked stable top-k; speedup {t_loop / t_table:.1f}x vs loop",
        })
        if n == 1024 and repeats >= REPEATS:
            assert t_loop / t_table >= 3.0, (
                f"rung-promotion target missed: {t_loop / t_table:.2f}x < 3x at {n}"
            )

        # allocation-growth guard: a reused table must not grow its buffers
        cap0 = table.capacity
        for _ in range(REUSE_CYCLES):
            table_promote()
        assert table.capacity == cap0, "reused RungTable grew its buffers"
        rows.append({
            "name": f"rung_table_reuse_guard_{n}",
            "us_per_call": 0.0,
            "derived": f"capacity stable at {cap0} rows over {REUSE_CYCLES} reuse cycles",
        })
    return rows


def _cost_cap_rows(repeats: int):
    """Median cost cap: Python-list np.median vs CostColumns running view."""
    from repro.core.hyperband import CostColumns

    rng = np.random.default_rng(1)
    n = 4096
    vals = rng.random(n)
    as_list = [float(v) for v in vals]
    cc = CostColumns()
    cc.extend(0.111111, vals)

    def list_median():
        return float(np.median(as_list))

    def column_median():
        return cc.median(0.111111)

    assert list_median() == column_median()
    t_list = _best(list_median, repeats)
    t_col = _best(column_median, repeats)
    return [{
        "name": f"cost_cap_list_{n}",
        "us_per_call": t_list * 1e6,
        "derived": "np.median over a Python list (per-call conversion)",
    }, {
        "name": f"cost_cap_columns_{n}",
        "us_per_call": t_col * 1e6,
        "derived": f"vectorized running column; speedup {t_list / t_col:.1f}x vs list",
    }]


def _run():
    repeats = 1 if os.environ.get("REPRO_BENCH_SMOKE") == "1" else REPEATS
    return _schedule_rows() + _promotion_rows(repeats) + _cost_cap_rows(repeats)


def run(force: bool = False):
    return cached("hb_schedule", force, _run)


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        # smoke validates the schedule exactness, promotion equivalence gate
        # and the allocation-growth guard without overwriting the committed
        # multi-repetition baseline JSON
        os.environ["REPRO_BENCH_SMOKE"] = "1"
        for r in _run():
            print(r)
    else:
        for r in run(force=True):
            print(r)
