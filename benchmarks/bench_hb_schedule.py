"""Table 1: Hyperband (n_i, r_i) schedule exactness (R=27, eta=3)."""

from __future__ import annotations

import time

from .common import cached

EXPECTED = {  # s -> [(n_i, r_i), ...] from paper Table 1
    3: [(27, 1), (9, 3), (3, 9), (1, 27)],
    2: [(12, 3), (4, 9), (1, 27)],
    1: [(6, 9), (2, 27)],
    0: [(4, 27)],
}


def run(force: bool = False):
    def compute():
        from repro.core import hb_schedule

        t0 = time.perf_counter()
        brackets = hb_schedule(R=27, eta=3)
        dt = (time.perf_counter() - t0) * 1e6
        rows = []
        all_match = True
        for b in brackets:
            got = [(r.n, int(r.r)) for r in b.rungs]
            match = got == EXPECTED[b.s]
            all_match &= match
            rows.append({
                "name": f"hb_schedule_s{b.s}",
                "us_per_call": dt / len(brackets),
                "derived": f"rungs={got} match_paper_table1={match}",
            })
        rows.append({
            "name": "hb_schedule_table1",
            "us_per_call": dt,
            "derived": f"all_brackets_match={all_match}",
        })
        return rows

    return cached("hb_schedule", force, compute)
