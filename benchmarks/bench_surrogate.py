"""Surrogate/acquisition throughput: per-tree loop vs the packed forest plane.

The acquisition bottleneck PR 2 attacks: ``CandidateGenerator.recommend``
scoring a 256-candidate pool against 8 surrogate sources (MFTune's combined
surrogate — one PRF per source task plus one per fidelity level, §6.2).
Reports per-pass latency for the legacy per-tree loop, the per-forest packed
numpy descent, the fused multi-source ``ForestPlane``, the jax kernel
backend, and the fused EI/rank acquisition program, plus speedups vs the
loop; the cached JSON under results/bench/ is the baseline later PRs track.
Every timed path is equivalence-checked against the loop before timing.

``--smoke`` (or REPRO_BENCH_SMOKE=1) runs 1 repetition for CI.
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

from benchmarks.common import cached

N_SOURCES = 12   # MFTune combined surrogate: source tasks + fidelity levels
N_OBS = 64
D = 16
POOL = 256
REPEATS = 30


def _best(fn, repeats: int) -> float:
    fn()  # warm up (pack, jit, numpy dispatch)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _run():
    from repro.core import ForestPlane, make_forest
    from repro.core.acquisition import aggregate_ranks, ei_scores, score_sources

    repeats = 1 if os.environ.get("REPRO_BENCH_SMOKE") == "1" else REPEATS
    rng = np.random.default_rng(0)
    forests = []
    for s in range(N_SOURCES):
        X = rng.random((N_OBS, D))
        y = 3 * X[:, 0] - X[:, 1] ** 2 + 0.1 * rng.normal(size=N_OBS)
        forests.append(make_forest(seed=s).fit(X, y))
    pool = rng.random((POOL, D))
    incumbents = list(rng.random(N_SOURCES))
    weights = list(rng.random(N_SOURCES))

    def loop():
        return [m.predict_loop(pool) for m in forests]

    def packed_numpy():
        return [m.pack().predict(pool) for m in forests]

    def plane_numpy():
        plane = ForestPlane.from_forests([m.pack() for m in forests])
        return plane.predict(pool)

    def acq_legacy():
        # the pre-refactor acquisition verbatim: per-tree predict loop,
        # EI pushed through np.vectorize(erf), sequential rank aggregation
        import math

        agg = np.zeros(POOL)
        for m, inc, w in zip(forests, incumbents, weights):
            mean, var = m.predict_loop(pool)
            std = np.sqrt(np.maximum(var, 1e-12))
            z = (inc - mean) / std
            phi = np.exp(-0.5 * z**2) / np.sqrt(2 * np.pi)
            Phi = 0.5 * (1.0 + np.vectorize(math.erf)(z / np.sqrt(2.0)))
            scores = np.maximum((inc - mean) * Phi + std * phi, 0.0)
            order = np.argsort(-scores, kind="stable")
            ranks = np.empty(POOL)
            ranks[order] = np.arange(POOL, dtype=float)
            agg += w * ranks
        return agg

    def acq_modern_unfused():  # new EI, per-source loop (exact-equality gate)
        return aggregate_ranks(
            np.stack([ei_scores(m, pool, inc) for m, inc in zip(forests, incumbents)]),
            weights,
        )

    def acq_fused():
        return aggregate_ranks(score_sources(forests, pool, incumbents), weights)

    # equivalence gate before timing
    ref = loop()
    ms, vs = plane_numpy()
    for i, (m_ref, v_ref) in enumerate(ref):
        assert np.array_equal(ms[i], m_ref) and np.array_equal(vs[i], v_ref)
    assert np.array_equal(acq_modern_unfused(), acq_fused())
    # vs the erf-ulp legacy only rank *order* is meaningful (EI clamps at 0,
    # so stable-sort tie blocks shuffle under last-ulp CDF differences)
    agg_legacy, agg_fused = acq_legacy(), acq_fused()
    assert int(np.argmin(agg_legacy)) == int(np.argmin(agg_fused))
    assert np.corrcoef(agg_legacy, agg_fused)[0, 1] > 0.999

    t_loop = _best(loop, repeats)
    rows = [{
        "name": f"loop_{N_SOURCES}src_{POOL}pool", "us_per_call": t_loop * 1e6,
        "derived": f"legacy per-tree loop; {N_SOURCES * POOL / t_loop:.0f} cand-src/s",
    }]
    for name, fn in [("packed_numpy", packed_numpy), ("plane_numpy", plane_numpy)]:
        t = _best(fn, repeats)
        rows.append({
            "name": f"{name}_{N_SOURCES}src_{POOL}pool", "us_per_call": t * 1e6,
            "derived": f"speedup {t_loop / t:.1f}x vs loop",
        })
    try:
        import jax  # noqa: F401

        plane = ForestPlane.from_forests([m.pack() for m in forests])
        mj, vj = plane.predict(pool, backend="jax")
        for i, (m_ref, v_ref) in enumerate(ref):
            assert np.allclose(mj[i], m_ref, atol=1e-9) and np.allclose(vj[i], v_ref, atol=1e-9)
        t = _best(lambda: plane.predict(pool, backend="jax"), repeats)
        rows.append({
            "name": f"plane_jax_{N_SOURCES}src_{POOL}pool", "us_per_call": t * 1e6,
            "derived": f"speedup {t_loop / t:.1f}x vs loop",
        })
        # the pallas kernel path is correctness-tested in interpret mode
        # (tests/test_surrogate_packed.py); timing it only makes sense on a
        # real accelerator, so the row is gated on a non-CPU jax backend
        if jax.default_backend() != "cpu" or os.environ.get("REPRO_BENCH_PALLAS") == "1":
            t = _best(lambda: plane.predict(pool, backend="pallas"), max(1, repeats // 10))
            rows.append({
                "name": f"plane_pallas_{N_SOURCES}src_{POOL}pool", "us_per_call": t * 1e6,
                "derived": f"speedup {t_loop / t:.1f}x vs loop ({jax.default_backend()})",
            })
    except ImportError:
        pass
    t_acq_old = _best(acq_legacy, repeats)
    t_acq = _best(acq_fused, repeats)
    rows.append({
        "name": f"acq_legacy_{N_SOURCES}src_{POOL}pool", "us_per_call": t_acq_old * 1e6,
        "derived": "per-tree loop + np.vectorize(erf) EI + sequential ranks",
    })
    rows.append({
        "name": f"acq_fused_{N_SOURCES}src_{POOL}pool", "us_per_call": t_acq * 1e6,
        "derived": f"score_sources + aggregate_ranks; speedup {t_acq_old / t_acq:.1f}x",
    })
    return rows


def run(force: bool = False):
    return cached("surrogate", force, _run)


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    for r in run(force=True):
        print(r)
