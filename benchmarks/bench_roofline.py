"""§Roofline: the 40-cell table from the dry-run artifacts (ours).

Reads results/dryrun.json (written by repro.launch.dryrun) and emits one
row per successful single-pod cell: the three terms, bottleneck, useful
ratio and roofline fraction. Multi-pod rows prove the pod axis shards.
"""

from __future__ import annotations

import json
import os

from .common import REPO, cached


def run(force: bool = False):
    def compute():
        path = os.path.join(REPO, "results", "dryrun.json")
        if not os.path.exists(path):
            return [{"name": "roofline_missing", "us_per_call": 0.0,
                     "derived": "run: python -m repro.launch.dryrun --all --both-meshes"}]
        with open(path) as f:
            results = json.load(f)
        rows = []
        n_ok = n_skip = n_err = 0
        for r in results:
            tag = f"{r['arch']}__{r['shape']}__{'512' if r['multi_pod'] else '256'}"
            if r["status"] == "skipped":
                n_skip += 1
                if not r["multi_pod"]:
                    rows.append({"name": f"roofline_{tag}", "us_per_call": 0.0,
                                 "derived": f"SKIP: {r['reason']}"})
                continue
            if r["status"] != "ok":
                n_err += 1
                rows.append({"name": f"roofline_{tag}", "us_per_call": 0.0,
                             "derived": f"ERROR: {r.get('error', '?')[:120]}"})
                continue
            n_ok += 1
            rl = r["roofline"]
            if not r["multi_pod"]:
                rows.append({
                    "name": f"roofline_{tag}",
                    "us_per_call": rl["step_time_s"] * 1e6,
                    "derived": (
                        f"compute={rl['compute_s']:.4f}s memory={rl['memory_s']:.4f}s "
                        f"collective={rl['collective_s']:.4f}s bottleneck={rl['bottleneck']} "
                        f"useful={rl['useful_ratio']:.3f} roofline_frac={rl['roofline_fraction']:.4f} "
                        f"temp_gb={r['memory']['temp_gb_per_device']}"
                    ),
                })
            else:
                rows.append({
                    "name": f"dryrun_multipod_{tag}",
                    "us_per_call": r["compile_s"] * 1e6,
                    "derived": f"compiled_ok_512chips temp_gb={r['memory']['temp_gb_per_device']}",
                })
        rows.append({
            "name": "dryrun_sweep_summary",
            "us_per_call": 0.0,
            "derived": f"ok={n_ok} skipped={n_skip} errors={n_err}",
        })
        return rows

    return cached("roofline", force, compute)
