"""Config-space plumbing throughput: scalar reference vs the columnar plane.

The pool-generation bottleneck PR 5 attacks: every tuner in the repo
(MFTune core, the five baselines, sparksim history generation) burns
``sample`` + ``encode_many`` + ``mutate`` on 192-256-config pools per
iteration, and ``RegressionTree`` fits dominate surrogate construction.
Times the full pool path (sample -> unit-cube encode -> mutate) on the
60-knob Spark space at 192 and 1024 configs, scalar-backend reference
(per-knob, per-config loops + dict materialization, the pre-refactor
shape) vs the columnar ConfigBatch path, and regression-tree fits at
n=64/512 for the recursive vs the level-synchronous frontier builder.
Every timed pair is equivalence-checked before timing; the cached JSON
under results/bench/ is the baseline later PRs track.

``--smoke`` (or REPRO_BENCH_SMOKE=1) runs 1 repetition for CI.
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

from benchmarks.common import cached

POOL_SIZES = (192, 1024)
TREE_SIZES = (64, 512)
TREE_DIM = 16
REPEATS = 20


def _best(fn, repeats: int) -> float:
    fn()  # warm up (plane compile, numpy dispatch)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _run():
    from repro.core.space import log_sampling, space_backend
    from repro.core.surrogate import RegressionTree
    from repro.sparksim import spark_space

    repeats = 1 if os.environ.get("REPRO_BENCH_SMOKE") == "1" else REPEATS
    rows = []
    space = spark_space()
    d = space.dim

    for n in POOL_SIZES:
        def pool_columnar(n=n):
            rng = np.random.default_rng(0)
            pool = space.sample(rng, n)
            X = pool.unit()
            muts = space.mutate_many(pool, rng)
            return X, muts.values

        def pool_scalar(n=n):
            # the pre-refactor shape: per-knob per-config loops, dicts at
            # every stage, re-encoding from dicts
            with log_sampling(True), space_backend("scalar"):
                rng = np.random.default_rng(0)
                cfgs = space.sample(rng, n).materialize()
                X = np.stack([space.encode(c) for c in cfgs])
                muts = space.mutate_many(cfgs, rng).materialize()
            return X, muts

        # equivalence gate: same draws => bit-identical pools (the scalar
        # path runs under the same log-space geometry as the columnar one)
        Xc, Vc = pool_columnar()
        Xs, ms = pool_scalar()
        assert np.array_equal(Xc, Xs)
        from repro.core import ConfigBatch

        assert np.array_equal(Vc, ConfigBatch.from_configs(space, ms).values)

        t_scalar = _best(pool_scalar, repeats)
        t_col = _best(pool_columnar, repeats)
        rows.append({
            "name": f"pool_scalar_{n}x{d}", "us_per_call": t_scalar * 1e6,
            "derived": f"sample+encode+mutate, per-knob loops; {n / t_scalar:.0f} cfg/s",
        })
        rows.append({
            "name": f"pool_columnar_{n}x{d}", "us_per_call": t_col * 1e6,
            "derived": f"speedup {t_scalar / t_col:.1f}x vs scalar",
        })

    rng = np.random.default_rng(1)
    for n in TREE_SIZES:
        X = rng.random((n, TREE_DIM))
        y = 3 * X[:, 0] - X[:, 1] ** 2 + 0.1 * rng.normal(size=n)

        def fit(builder):
            return RegressionTree(
                min_samples_leaf=1, rng=np.random.default_rng(7), builder=builder
            ).fit(X, y)

        a, b = fit("recursive"), fit("frontier")
        ma, va = a.predict(X)
        mb, vb = b.predict(X)
        assert np.array_equal(ma, mb) and np.array_equal(va, vb)

        t_rec = _best(lambda: fit("recursive"), repeats)
        t_fro = _best(lambda: fit("frontier"), repeats)
        rows.append({
            "name": f"tree_recursive_{n}x{TREE_DIM}", "us_per_call": t_rec * 1e6,
            "derived": f"node-by-node Python recursion; {len(a.nodes)} nodes",
        })
        rows.append({
            "name": f"tree_frontier_{n}x{TREE_DIM}", "us_per_call": t_fro * 1e6,
            "derived": f"speedup {t_rec / t_fro:.1f}x vs recursive",
        })
    return rows


def run(force: bool = False):
    return cached("config_space", force, _run)


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        # smoke validates the equivalence gates + timing path without
        # overwriting the committed multi-repetition baseline JSON
        os.environ["REPRO_BENCH_SMOKE"] = "1"
        for r in _run():
            print(r)
    else:
        for r in run(force=True):
            print(r)
