"""Fig. 6c: sensitivity of the cumulative density threshold alpha.

Paper: alpha=0.5 over-prunes, 0.8 under-compresses; 0.6-0.7 is a stable
plateau; 0.65 is the default.
"""

from __future__ import annotations

import numpy as np

from .common import cached, load_kb, run_method

ALPHAS = [0.5, 0.6, 0.65, 0.8]
SEEDS = [0]
BUDGET = 48 * 3600.0


def run(force: bool = False):
    def compute():
        from repro.sparksim import SparkWorkload, make_task_id

        target = make_task_id("tpch", 600, "A")
        rows = []
        finals = {}
        for alpha in ALPHAS:
            bests, walls = [], []
            for seed in SEEDS:
                kb = load_kb(exclude=[target])
                wl = SparkWorkload("tpch", 600, "A")
                res, wall = run_method("mftune", wl, kb, BUDGET, seed, mftune_opts={"alpha": alpha})
                bests.append(res.best_performance)
                walls.append(wall)
            finals[alpha] = float(np.mean(bests))
            rows.append({
                "name": f"fig6c_alpha_{alpha}",
                "us_per_call": float(np.mean(walls)) * 1e6,
                "derived": f"best_latency_s={np.mean(bests):.0f}",
            })
        mid = [finals[a] for a in (0.6, 0.65, 0.7)]
        spread = 100 * (max(mid) - min(mid)) / min(mid)
        rows.append({
            "name": "fig6c_summary",
            "us_per_call": 0.0,
            "derived": f"plateau_spread_0.6_to_0.7={spread:.1f}% (paper: comparable/stable)",
        })
        return rows

    return cached("alpha_sensitivity", force, compute)
