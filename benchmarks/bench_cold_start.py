"""Fig. 3c/3f: cold start — no historical data, 96h budget.

MFTune degrades to vanilla BO, then self-transfers: space compression and
MFO activate once its own observations qualify (red dashed line).
Compared against the two history-free baselines.
"""

from __future__ import annotations

import numpy as np

from .common import cached, run_method

METHODS = ["mftune", "locat", "toptune"]
SEEDS = [0]
BUDGET = 96 * 3600.0


def run(force: bool = False):
    def compute():
        from repro.core import KnowledgeBase
        from repro.sparksim import SparkWorkload

        rows = []
        for bench in ("tpch", "tpcds"):
            finals = {}
            act = []
            for method in METHODS:
                bests, walls = [], []
                for seed in SEEDS:
                    wl = SparkWorkload(bench, 600, "A")
                    res, wall = run_method(method, wl, KnowledgeBase(), BUDGET, seed)
                    bests.append(res.best_performance)
                    walls.append(wall)
                    if method == "mftune" and res.mfo_activation_time is not None:
                        act.append(res.mfo_activation_time / 3600)
                finals[method] = float(np.mean(bests))
                rows.append({
                    "name": f"fig3cold_{bench}600A_{method}",
                    "us_per_call": float(np.mean(walls)) * 1e6,
                    "derived": f"best_latency_s={np.mean(bests):.0f}",
                })
            mf = finals["mftune"]
            reds = {m: 100 * (1 - mf / finals[m]) for m in METHODS if m != "mftune"}
            paper = "29.7%/35.4%" if bench == "tpch" else "48.2%/27.4%"
            rows.append({
                "name": f"fig3cold_{bench}600A_summary",
                "us_per_call": 0.0,
                "derived": (
                    f"reduction_vs_locat/toptune="
                    f"{reds.get('locat', float('nan')):.1f}%/{reds.get('toptune', float('nan')):.1f}% "
                    f"(paper: {paper}) mfo_activation_h={np.mean(act) if act else float('nan'):.1f}"
                ),
            })
        return rows

    return cached("cold_start", force, compute)
