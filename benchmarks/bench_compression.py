"""Compression-path throughput: batched Shapley plane vs per-chain loop.

The §5.1 attribution bottleneck this PR attacks: explaining a promising set
of 32 configs at d=24 with 32 antithetic permutations against a 16-row
background is 1024 chains x 25 prefixes x 16 background rows — the legacy
path made one surrogate call per chain; the batched plane builds the whole
composite tensor and pushes it through the packed forest in a few chunked
calls. Both backends consume the same pre-drawn permutations and are
gated bit-identical before timing. Also reports cold/warm
``SpaceCompressor.compress`` latency (region + KDE alpha-mass caches) and
PRF fit throughput under the vectorized splitmix64 seed derivation; the
cached JSON under results/bench/ is the baseline later PRs track.

``--smoke`` (or REPRO_BENCH_SMOKE=1) runs 1 repetition for CI.
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

from benchmarks.common import cached

D = 24            # knobs
N_CONFIGS = 32    # promising set size (extract's max_configs)
N_PERMS = 32
N_BG = 16
N_OBS = 96
REPEATS = 5


def _best(fn, repeats: int) -> float:
    fn()  # warm up (pack, caches, numpy dispatch)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _run():
    from repro.core import (
        ConfigSpace,
        FloatKnob,
        Observation,
        SpaceCompressor,
        TaskRecord,
        make_forest,
        shapley_values_batch,
    )
    from repro.core.similarity import TaskWeights

    repeats = 1 if os.environ.get("REPRO_BENCH_SMOKE") == "1" else REPEATS
    rng = np.random.default_rng(0)

    # PRF surrogate over a synthetic latency surface
    Xt = rng.random((N_OBS, D))
    yt = 3 * Xt[:, 0] - Xt[:, 1] ** 2 + 0.5 * Xt[:, 2] + 0.1 * rng.normal(size=N_OBS)
    forest = make_forest(seed=0).fit(Xt, yt)
    f = forest.predict_mean

    X = rng.random((N_CONFIGS, D))
    background = rng.random((N_BG, D))

    def explain(backend, model=None):
        return shapley_values_batch(
            f, X, background, n_permutations=N_PERMS,
            rng=np.random.default_rng(7), backend=backend, model=model,
        )

    # bit-identity gate before timing: shared permutation draw protocol,
    # across the per-chain loop, the generic composite-tensor plane, and
    # the bitvector chain kernel (model= opt-in)
    phi_loop = explain("loop")
    assert np.array_equal(phi_loop, explain("batched")), "composite plane diverged"
    assert np.array_equal(phi_loop, explain("batched", forest)), "chain kernel diverged"

    t_loop = _best(lambda: explain("loop"), repeats)
    t_plane = _best(lambda: explain("batched"), repeats)
    t_bat = _best(lambda: explain("batched", forest), repeats)
    chains = N_CONFIGS * N_PERMS
    rows = [
        {
            "name": f"shapley_loop_d{D}_p{N_PERMS}_b{N_BG}",
            "us_per_call": t_loop * 1e6,
            "derived": f"per-chain loop; {chains} chains; {N_CONFIGS / t_loop:.0f} cfg/s",
        },
        {
            "name": f"shapley_plane_d{D}_p{N_PERMS}_b{N_BG}",
            "us_per_call": t_plane * 1e6,
            "derived": f"composite tensor via f; speedup {t_loop / t_plane:.1f}x vs loop (bit-identical)",
        },
        {
            "name": f"shapley_batched_d{D}_p{N_PERMS}_b{N_BG}",
            "us_per_call": t_bat * 1e6,
            "derived": f"bitvector chain kernel; speedup {t_loop / t_bat:.1f}x vs loop (bit-identical)",
        },
    ]

    # cold vs warm space compression: cold pays region extraction (Shapley)
    # plus KDE alpha-mass fits; warm re-serves both caches
    space = ConfigSpace([FloatKnob(f"k{i}", 0.0, 1.0) for i in range(D)])
    tasks = {}
    for s in range(4):
        r = np.random.default_rng(100 + s)
        rec = TaskRecord(task_id=f"s{s}", queries=["q"])
        for cfg in space.sample(r, 48):
            z = space.encode_many([cfg])[0]
            perf = float(2.0 + 3 * z[0] - z[1] ** 2 + 0.05 * r.normal())
            rec.observations.append(Observation(config=cfg, performance=perf, fidelity=1.0))
        tasks[f"s{s}"] = rec
    weights = TaskWeights(weights={k: 0.25 for k in tasks}, similarities={}, used_meta=False)

    def compress_cold():
        return SpaceCompressor(space, seed=0).compress(weights, tasks)

    comp_warm = SpaceCompressor(space, seed=0)
    comp_warm.compress(weights, tasks)

    def compress_warm():
        return comp_warm.compress(weights, tasks)

    t_cold = _best(compress_cold, max(1, repeats // 2))
    t_warm = _best(compress_warm, repeats)
    rows.append({
        "name": f"compress_cold_{len(tasks)}task_d{D}",
        "us_per_call": t_cold * 1e6,
        "derived": "region extraction + KDE fits from scratch",
    })
    rows.append({
        "name": f"compress_warm_{len(tasks)}task_d{D}",
        "us_per_call": t_warm * 1e6,
        "derived": f"region + alpha-mass caches hot; speedup {t_cold / t_warm:.1f}x vs cold",
    })

    # PRF fit throughput under the vectorized splitmix64 seed/subset derivation
    t_fit = _best(lambda: make_forest(seed=0).fit(Xt, yt), repeats)
    rows.append({
        "name": f"prf_fit_{N_OBS}obs_d{D}",
        "us_per_call": t_fit * 1e6,
        "derived": f"{forest.n_trees} trees; {forest.n_trees / t_fit:.0f} trees/s",
    })
    return rows


def run(force: bool = False):
    return cached("compression", force, _run)


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    for r in run(force=True):
        print(r)
