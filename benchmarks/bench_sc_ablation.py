"""Fig. 6a/6b: search-space-compression strategy ablation (TPC-H 600GB).

MFTune's density SC vs w/o-SC, Box, Decrease, Project, Vote — each plugged
into MFTune via MFTuneOptions.compressor. 6a = warm start on; 6b = warm
start disabled (stress test; the paper reports the gap widens).
"""

from __future__ import annotations

import numpy as np

from .common import cached, load_kb, run_method

SEEDS = [0]
BUDGET = 48 * 3600.0


def _variants():
    from repro.baselines import BoxCompressor, DecreaseCompressor, ProjectCompressor, VoteCompressor

    return {
        "density": {},
        "wo_sc": {"enable_sc": False},
        "box": {"compressor": BoxCompressor()},
        "decrease": {"compressor": DecreaseCompressor()},
        "project": {"compressor": ProjectCompressor()},
        "vote": {"compressor": VoteCompressor()},
    }


def run(force: bool = False):
    def compute():
        from repro.sparksim import SparkWorkload, make_task_id

        target = make_task_id("tpch", 600, "A")
        rows = []
        for warm, tag in ((True, "fig6a_warm"), (False, "fig6b_cold")):
            finals = {}
            for name, opts in _variants().items():
                full_opts = dict(opts)
                if not warm:
                    full_opts.update(enable_warmstart_p1=False, enable_warmstart_p2=False)
                bests, walls = [], []
                for seed in SEEDS:
                    kb = load_kb(exclude=[target])
                    wl = SparkWorkload("tpch", 600, "A")
                    res, wall = run_method("mftune", wl, kb, BUDGET, seed, mftune_opts=full_opts)
                    bests.append(res.best_performance)
                    walls.append(wall)
                finals[name] = float(np.mean(bests))
                rows.append({
                    "name": f"{tag}_{name}",
                    "us_per_call": float(np.mean(walls)) * 1e6,
                    "derived": f"best_latency_s={np.mean(bests):.0f}",
                })
            d = finals["density"]
            others = {k: 100 * (1 - d / v) for k, v in finals.items() if k != "density"}
            paper = "14.8%..35.7%" if warm else "20.4%..43.0%"
            rows.append({
                "name": f"{tag}_summary",
                "us_per_call": 0.0,
                "derived": (
                    f"density_reduction_vs_variants={min(others.values()):.1f}%..{max(others.values()):.1f}% "
                    f"(paper {paper})"
                ),
            })
        return rows

    return cached("sc_ablation", force, compute)
