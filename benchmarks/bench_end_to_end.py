"""Fig. 3a/3d: end-to-end comparison under the original setting.

Target = {tpch, tpcds} x 600GB x Hardware A, leave-one-out history
(31 source tasks), 48h virtual budget, 3 seeds per method. Reports the
final best latency per method and MFTune's relative reduction (paper:
25.9-43.1% on TPC-H, 37.8-63.1% on TPC-DS).
"""

from __future__ import annotations

import time

import numpy as np

from .common import cached, load_kb, run_method, stage_summary

METHODS = ["mftune", "tuneful", "rover", "loftune", "locat", "toptune"]
SEEDS = [0, 1, 2]
BUDGET = 48 * 3600.0


def run(force: bool = False):
    def compute():
        from repro.sparksim import SparkWorkload, make_task_id

        rows = []
        for bench in ("tpch", "tpcds"):
            target = make_task_id(bench, 600, "A")
            kb_template = load_kb(exclude=[target])
            finals = {}
            evals = {}
            for method in METHODS:
                bests, nevals, walls = [], [], []
                stages = ""
                for seed in SEEDS:
                    kb = load_kb(exclude=[target])  # fresh copy per run
                    wl = SparkWorkload(bench, 600, "A")
                    res, wall = run_method(method, wl, kb, BUDGET, seed)
                    bests.append(res.best_performance)
                    nevals.append(res.n_evaluations)
                    walls.append(wall)
                    if seed == SEEDS[0]:
                        stages = stage_summary(res)
                finals[method] = float(np.mean(bests))
                evals[method] = float(np.mean(nevals))
                rows.append({
                    "name": f"fig3_{bench}600A_{method}",
                    "us_per_call": float(np.mean(walls)) * 1e6,
                    "derived": (
                        f"best_latency_s={np.mean(bests):.0f} (+-{np.std(bests):.0f}) "
                        f"n_evals={np.mean(nevals):.0f} {stages}"
                    ),
                })
            mf = finals["mftune"]
            reds = {m: 100 * (1 - mf / finals[m]) for m in METHODS if m != "mftune"}
            rows.append({
                "name": f"fig3_{bench}600A_mftune_reduction",
                "us_per_call": 0.0,
                "derived": (
                    f"latency_reduction_vs_baselines={min(reds.values()):.1f}%..{max(reds.values()):.1f}% "
                    f"(paper: {'25.9%..43.1%' if bench == 'tpch' else '37.8%..63.1%'}) "
                    f"mftune_evals={evals['mftune']:.0f} vs others={np.mean([evals[m] for m in reds]):.0f}"
                ),
            })
        return rows

    return cached("end_to_end", force, compute)
