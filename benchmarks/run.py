"""Benchmark harness aggregator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Heavy benchmarks cache their
results under results/bench/; pass --force (or REPRO_BENCH_FORCE=1) to
recompute, --only <substr> to run a subset.
"""

from __future__ import annotations

import argparse
import os
import sys

BENCHES = [
    ("hb_schedule", "bench_hb_schedule"),               # Table 1
    ("fidelity_correlation", "bench_fidelity_correlation"),  # Fig 1b / 5b
    ("end_to_end", "bench_end_to_end"),                 # Fig 3a/3d
    ("cross_benchmark", "bench_cross_benchmark"),       # Fig 3b/3e
    ("cold_start", "bench_cold_start"),                 # Fig 3c/3f
    ("generalization", "bench_generalization"),         # Fig 4
    ("mfo_ablation", "bench_mfo_ablation"),             # Fig 5a
    ("sc_ablation", "bench_sc_ablation"),               # Fig 6a/6b
    ("alpha_sensitivity", "bench_alpha_sensitivity"),   # Fig 6c
    ("warmstart", "bench_warmstart"),                   # Table 3
    ("overhead", "bench_overhead"),                     # §7.4.4
    ("roofline", "bench_roofline"),                     # §Roofline (ours)
    ("batch_eval", "bench_batch_eval"),                 # batched engine (ours)
    ("surrogate", "bench_surrogate"),                   # packed forest plane (ours)
    ("config_space", "bench_config_space"),             # columnar space plane (ours)
    ("compression", "bench_compression"),               # batched Shapley plane (ours)
    ("pool_scaling", "bench_pool_scaling"),             # fused propose step (ours)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true",
                    default=os.environ.get("REPRO_BENCH_FORCE") == "1")
    ap.add_argument("--only", type=str, default=None)
    ap.add_argument("--trace", action="store_true",
                    help="persist a Perfetto trace per tuning run under "
                         "results/bench/traces/ (inspect with scripts/inspect_run.py)")
    args = ap.parse_args()

    if args.trace:
        from .common import CACHE

        os.environ["REPRO_BENCH_TRACE_DIR"] = os.path.join(CACHE, "traces")

    import importlib

    print("name,us_per_call,derived")
    failures = 0
    for name, mod_name in BENCHES:
        if args.only and args.only not in name:
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
            rows = mod.run(force=args.force)
        except Exception as e:  # keep the harness running
            print(f"{name},0,ERROR {type(e).__name__}: {e}")
            failures += 1
            continue
        for r in rows:
            derived = str(r["derived"]).replace(",", ";")
            print(f"{r['name']},{r['us_per_call']:.1f},{derived}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
