"""Fig. 5a: MFO mechanism ablation on TPC-DS 600GB / Hardware A.

MFTune vs (w/o MF: full-fidelity only) vs (DV: data-volume proxies).
Paper: 27.8% reduction over w/o-MF, 45.1% over DV; DV underperforms even
the no-MFO variant because its proxies mislead the optimizer.
"""

from __future__ import annotations

import numpy as np

from .common import cached, load_kb, run_method

SEEDS = [0]
BUDGET = 48 * 3600.0

VARIANTS = {
    "mftune": {},
    "mftune_wo_mf": {"enable_mfo": False},
    "mftune_dv": {"fidelity_mode": "data_volume"},
}


def run(force: bool = False):
    def compute():
        from repro.sparksim import SparkWorkload, make_task_id

        target = make_task_id("tpcds", 600, "A")
        rows = []
        finals = {}
        for name, opts in VARIANTS.items():
            bests, walls = [], []
            for seed in SEEDS:
                kb = load_kb(exclude=[target])
                wl = SparkWorkload("tpcds", 600, "A")
                res, wall = run_method("mftune", wl, kb, BUDGET, seed, mftune_opts=opts)
                bests.append(res.best_performance)
                walls.append(wall)
            finals[name] = float(np.mean(bests))
            rows.append({
                "name": f"fig5a_{name}",
                "us_per_call": float(np.mean(walls)) * 1e6,
                "derived": f"best_latency_s={np.mean(bests):.0f} (+-{np.std(bests):.0f})",
            })
        rows.append({
            "name": "fig5a_summary",
            "us_per_call": 0.0,
            "derived": (
                f"reduction_vs_woMF={100 * (1 - finals['mftune'] / finals['mftune_wo_mf']):.1f}% "
                f"(paper 27.8%) vs_DV={100 * (1 - finals['mftune'] / finals['mftune_dv']):.1f}% "
                f"(paper 45.1%) dv_worse_than_woMF={finals['mftune_dv'] > finals['mftune_wo_mf']}"
            ),
        })
        return rows

    return cached("mfo_ablation", force, compute)
