"""Table 3: two-phase warm-start ablation on TPC-H 600GB.

Grid over (P1, P2). The paper reports MFTune's gain over each variant:
5.50% / 2.15x over neither, 5.13% / 1.98x over P1-only, 1.25% / 1.12x over
P2-only — i.e. P2 is the primary driver.
"""

from __future__ import annotations

import numpy as np

from .common import cached, load_kb, run_method, traj_to_curve

SEEDS = [0]
BUDGET = 48 * 3600.0

GRID = {
    "p1p2": (True, True),
    "p1_only": (True, False),
    "p2_only": (False, True),
    "neither": (False, False),
}


def _accel(full_curve, t_full, var_curve, t_var, final_full):
    """Tuning acceleration: time for the variant to reach MFTune's final
    best, divided by the time MFTune took to reach it."""
    import numpy as np

    def first_reach(ts, curve, level):
        for t, v in zip(ts, curve):
            if v == v and v <= level:
                return t
        return float("nan")

    tf = first_reach(t_full, full_curve, final_full * 1.0001)
    tv = first_reach(t_var, var_curve, final_full * 1.0001)
    return tv / tf if tf and tf == tf and tv == tv else float("nan")


def run(force: bool = False):
    def compute():
        from repro.sparksim import SparkWorkload, make_task_id

        target = make_task_id("tpch", 600, "A")
        rows = []
        results = {}
        for name, (p1, p2) in GRID.items():
            bests, curves, walls = [], [], []
            for seed in SEEDS:
                kb = load_kb(exclude=[target])
                wl = SparkWorkload("tpch", 600, "A")
                res, wall = run_method(
                    "mftune", wl, kb, BUDGET, seed,
                    mftune_opts={"enable_warmstart_p1": p1, "enable_warmstart_p2": p2},
                )
                bests.append(res.best_performance)
                ts, curve = traj_to_curve(res, BUDGET)
                curves.append(curve)
                walls.append(wall)
            results[name] = (float(np.mean(bests)), ts, np.nanmean(curves, axis=0))
            rows.append({
                "name": f"table3_{name}",
                "us_per_call": float(np.mean(walls)) * 1e6,
                "derived": f"best_latency_s={np.mean(bests):.0f}",
            })
        full_best, ts, full_curve = results["p1p2"]
        paper = {"neither": "5.50%/2.15x", "p1_only": "5.13%/1.98x", "p2_only": "1.25%/1.12x"}
        for name in ("neither", "p1_only", "p2_only"):
            vb, tv, vc = results[name]
            red = 100 * (1 - full_best / vb)
            acc = _accel(full_curve, ts, vc, tv, full_best)
            rows.append({
                "name": f"table3_gain_over_{name}",
                "us_per_call": 0.0,
                "derived": f"latency_reduction={red:.2f}% accel={acc:.2f}x (paper {paper[name]})",
            })
        return rows

    return cached("warmstart", force, compute)
