"""Fig. 4: generalization across data sizes and hardware (TPC-H).

Cross-scale: 100GB <-> 600GB transfers on Hardware A (16 source tasks of
the other scale). Cross-hardware: 2->3 node transition (target A/600GB,
sources = all 2-node scenarios E-H). Reports speedup of tuned-best vs the
default Spark configuration (paper: up to 3.96x; >=2.18x under hardware
shift).
"""

from __future__ import annotations

import numpy as np

from .common import cached, load_kb, run_method

METHODS = ["mftune", "tuneful", "rover", "loftune"]
SEEDS = [0]
BUDGET = 48 * 3600.0


def _transfer(name, bench, target_args, include, rows):
    from repro.sparksim import SparkWorkload

    wl0 = SparkWorkload(*target_args)
    default = wl0.evaluate(wl0.default_config()).aggregate
    for method in METHODS:
        sp, walls = [], []
        for seed in SEEDS:
            kb = load_kb(include_only=include)
            wl = SparkWorkload(*target_args)
            res, wall = run_method(method, wl, kb, BUDGET, seed)
            sp.append(default / res.best_performance)
            walls.append(wall)
        rows.append({
            "name": f"fig4_{name}_{method}",
            "us_per_call": float(np.mean(walls)) * 1e6,
            "derived": f"speedup_vs_default={np.mean(sp):.2f}x (+-{np.std(sp):.2f})",
        })


def run(force: bool = False):
    def compute():
        from repro.sparksim import make_task_id

        rows = []
        # cross data scale
        for src_gb, tgt_gb in ((100, 600), (600, 100)):
            include = [make_task_id(b, src_gb, hw) for b in ("tpch", "tpcds") for hw in "ABCDEFGH"]
            _transfer(f"scale{src_gb}to{tgt_gb}", "tpch", ("tpch", tgt_gb, "A"), include, rows)
        # cross hardware: 2-node sources -> 3-node target
        include = [make_task_id(b, gb, hw) for b in ("tpch", "tpcds") for gb in (600,) for hw in "EFGH"]
        _transfer("hw2to3nodes", "tpch", ("tpch", 600, "A"), include, rows)
        return rows

    return cached("generalization", force, compute)
