"""Propose-step latency vs candidate-pool size: staged numpy vs fused jax.

The PR 7 headline: one jitted program runs the whole BO propose iteration
(device pool draw, merged-QuickScorer forest descent, per-source combine,
EI, weighted rank aggregation, top-k) against the staged numpy path
(``space.sample`` -> unit encode -> ``score_sources`` ->
``aggregate_ranks`` -> stable argsort), at MFTune's combined-surrogate
scale (12 sources) over pool sizes 256 .. 131072. Both sides draw a fresh
pool per call — the real per-iteration cost, not a cached-pool microloop.

Before timing, host-pool mode is equivalence-gated: the fused program must
select bit-identical candidate indices to the staged numpy path. After the
sweep a jit-cache-growth guard asserts the engine compiled at most one
program per pool bucket (+1 for the host-mode gate) — the bucketed-shape
protocol's contract.

The speedup reported at 131072 is the measured number on the current
host. The 10x target assumes an accelerator; on a single-core CPU the
fused path is sort- and gather-bound, which historically capped the
ratio around 4x there. PR 10 replaced the rank-aggregation stage's
u64 stable sort with a radix-rank kernel (``rank_impl="callback"`` on
CPU: an LSD counting sort behind a raw XLA custom-call), cutting that
stage ~5x at 12 x 131072. The pallas-descent row is gated on a non-CPU
backend.

Per-stage rows decompose the top pool size: the rank-aggregation and
top-k stage programs are timed standalone (they are the exact programs
the engine dispatches), the end-to-end number is the ``propose_step``
span duration captured by a tracer, and the descent+combine+EI residual
is their difference — the fused program is one jit, so there is no
in-program stage boundary to instrument directly.

``--smoke`` (or REPRO_BENCH_SMOKE=1) sweeps two small pools, 1
repetition, and gates the radix rank kernel against the pinned
``np.argsort(-scores, kind="stable")`` permutation on a tie- and
special-heavy fixture.
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

from benchmarks.common import cached

N_SOURCES = 12   # MFTune combined surrogate: source tasks + fidelity levels
N_OBS = 64
D = 16
K = 16           # candidates returned per propose call
POOLS = [256, 1024, 4096, 16384, 65536, 131072]
SMOKE_POOLS = [256, 2048]


def _best(fn, repeats: int) -> float:
    fn()  # warm up (pack, jit, numpy dispatch)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _space():
    from repro.core import ConfigSpace, FloatKnob, IntKnob

    knobs = []
    for j in range(D):
        if j % 4 == 0:
            knobs.append(FloatKnob(f"f{j}", 0.1, 10.0, log=True))
        elif j % 4 == 1:
            knobs.append(FloatKnob(f"f{j}", -5.0, 5.0))
        elif j % 4 == 2:
            knobs.append(IntKnob(f"i{j}", 1, 1024, log=True))
        else:
            knobs.append(IntKnob(f"i{j}", 0, 99))
    return ConfigSpace(knobs)


def _run():
    try:
        import jax  # noqa: F401
    except ImportError:
        return [{"name": "pool_scaling_skipped", "us_per_call": 0.0,
                 "derived": "jax unavailable"}]

    from repro.core import ProposeEngine, make_forest
    from repro.core.acquisition import aggregate_ranks, score_sources

    smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
    pools = SMOKE_POOLS if smoke else POOLS
    rng = np.random.default_rng(0)
    space = _space()
    models = []
    for s in range(N_SOURCES):
        X = rng.random((N_OBS, D))
        y = 3 * X[:, 0] - X[:, 1] ** 2 + 0.1 * rng.normal(size=N_OBS)
        models.append(make_forest(seed=s).fit(X, y))
    assert ProposeEngine.fusable(models)
    incs = list(rng.random(N_SOURCES))
    ws = list(rng.random(N_SOURCES))
    eng = ProposeEngine(space, seed=0)

    seed_ctr = [0]

    def staged(n):
        # fresh pool per call, exactly the staged recommend scoring path
        seed_ctr[0] += 1
        pool = space.sample(np.random.default_rng(seed_ctr[0]), n)
        Xu = space.complete_batch(pool).unit()
        scores = score_sources(models, Xu, incs)
        agg = aggregate_ranks(scores, np.asarray(ws))
        return np.argsort(agg, kind="stable")[:K]

    def fused(n, descent="auto"):
        # fresh device pool per call via the engine's threaded PRNG key
        return eng.propose(models, incs, ws, K, pool_size=n, descent=descent)

    # equivalence gate: host-pool mode must select bit-identical indices
    n_gate = min(4096, max(pools))
    pool = space.sample(np.random.default_rng(99), n_gate)
    Xu = space.complete_batch(pool).unit()
    ref = np.argsort(
        aggregate_ranks(score_sources(models, Xu, incs), np.asarray(ws)),
        kind="stable",
    )[:K]
    got = eng.score_topk(models, Xu, incs, ws, K)
    assert np.array_equal(ref, got), "fused host-mode selection diverged"

    rows = []
    ratios = {}
    for n in pools:
        reps = 1 if smoke else (5 if n <= 16384 else 2)
        t_np = _best(lambda: staged(n), reps)
        t_fx = _best(lambda: fused(n), reps)
        ratios[n] = t_np / t_fx
        rows.append({
            "name": f"staged_numpy_{n}", "us_per_call": t_np * 1e6,
            "derived": f"{n / t_np:.0f} cand/s",
        })
        rows.append({
            "name": f"fused_jax_{n}", "us_per_call": t_fx * 1e6,
            "derived": f"speedup {ratios[n]:.2f}x vs staged; {n / t_fx:.0f} cand/s",
        })
    if jax.default_backend() != "cpu" or os.environ.get("REPRO_BENCH_PALLAS") == "1":
        n = max(pools)
        t = _best(lambda: fused(n, descent="pallas"), 1 if smoke else 2)
        rows.append({
            "name": f"fused_pallas_{n}", "us_per_call": t * 1e6,
            "derived": f"pallas descent ({jax.default_backend()})",
        })

    # ---------------------------------------------------------- per-stage
    # Decompose the top pool size into rank-agg / top-k / descent. Rank
    # aggregation and top-k are timed through the exact stage programs the
    # fused step embeds; the end-to-end number is the propose_step span
    # captured by a tracer (non-compile calls only); descent+combine+EI is
    # the residual. A fresh engine keeps the main engine's jit-cache guard
    # meaningful (per-stage runs compile extra rank_impl signatures).
    from repro import obs
    from repro.kernels.forest_eval import propose as P
    from repro.kernels.forest_eval import rank as R

    n_top = max(pools)
    reps_st = 1 if smoke else 3
    scores_fix = rng.standard_normal((N_SOURCES, n_top))
    scores_fix[rng.random(scores_fix.shape) < 0.1] = 0.0  # tie clusters
    w_fix = np.asarray(ws)

    t_rank = {}
    for impl in ("sort", "callback"):
        t_rank[impl] = _best(
            lambda: P.aggregate_ranks_host(scores_fix, w_fix, rank_impl=impl),
            reps_st,
        )
        rows.append({
            "name": f"stage_rank_{impl}_{n_top}",
            "us_per_call": t_rank[impl] * 1e6,
            "derived": f"rank-aggregation stage alone ({N_SOURCES} x {n_top})",
        })
    rank_speedup = t_rank["sort"] / t_rank["callback"]
    rows.append({
        "name": f"stage_rank_speedup_{n_top}", "us_per_call": rank_speedup,
        "derived": (f"radix-rank callback vs fused stable sort at "
                    f"{N_SOURCES} x {n_top} (PR 10 acceptance: >= 2x on CPU)"),
    })
    if jax.default_backend() == "cpu" and not smoke:
        assert rank_speedup >= 2.0, (
            f"rank-aggregation stage speedup regressed: {rank_speedup:.2f}x"
        )

    import jax.numpy as jnp

    with P._x64():
        topk_fn = jax.jit(lambda a: P._sort_perm_asc1d(a)[:K])
        agg_fix = jnp.asarray(rng.random(n_top))
        t_topk = _best(lambda: np.asarray(topk_fn(agg_fix)), reps_st)
    rows.append({
        "name": f"stage_topk_{n_top}", "us_per_call": t_topk * 1e6,
        "derived": "top-k stage alone (monotone-key argsort, take k)",
    })

    eng_st = ProposeEngine(space, seed=0)
    t_total = {}
    for impl in ("sort", "callback"):
        with obs.tracing() as tr:
            for _ in range(reps_st + 1):
                eng_st.propose(models, incs, ws, K, pool_size=n_top,
                               rank_impl=impl)
        durs = [e["dur"] for e in tr.events
                if e.get("name") == "propose_step"
                and e["args"].get("rank") == impl
                and not e["args"].get("compile")]
        t_total[impl] = min(durs)
        rows.append({
            "name": f"propose_span_{impl}_{n_top}",
            "us_per_call": t_total[impl] * 1e6,
            "derived": f"end-to-end propose_step span, rank_impl={impl}",
        })
    t_resid = min(t_total[i] - t_rank[i] - t_topk for i in t_total)
    rows.append({
        "name": f"stage_descent_residual_{n_top}",
        "us_per_call": max(t_resid, 0.0) * 1e6,
        "derived": ("pool draw + descent + combine + EI residual "
                    "(propose_step span minus rank-agg and top-k stages)"),
    })

    if smoke:
        # radix rank vs pinned stable argsort on a tie/special-heavy fixture
        s = rng.standard_normal((4, 3000))
        s[rng.random(s.shape) < 0.3] = 0.25
        s[0, :8] = [0.0, -0.0, 5e-324, -5e-324, np.inf, -np.inf, 1e-310, 0.0]
        want = np.argsort(-s, axis=-1, kind="stable")
        assert np.array_equal(R.radix_argsort(s), want), (
            "radix rank kernel diverged from the pinned stable argsort"
        )
        rows.append({
            "name": "smoke_radix_identity", "us_per_call": 1.0,
            "derived": "radix_argsort == np.argsort(-s, kind='stable'): OK",
        })

    crossover = next((n for n in pools if ratios[n] >= 1.0), None)
    rows.append({
        "name": "crossover_pool", "us_per_call": float(crossover or 0),
        "derived": ("fused beats staged from this pool size up"
                    if crossover else "fused never crossed staged in sweep"),
    })
    n_top = max(pools)
    rows.append({
        "name": f"headline_speedup_{n_top}", "us_per_call": ratios[n_top],
        "derived": (f"measured fused/staged ratio at {n_top}-candidate pools "
                    f"(single-device {jax.default_backend()}; 10x target assumes "
                    f"an accelerator — XLA:CPU's rank-agg sort and descent "
                    f"gathers are the floor here)"),
    })

    # jit-cache-growth guard: one program per pool bucket, +1 for the
    # host-mode equivalence gate — the bucketed-shape protocol's contract
    n_buckets = len({eng._pow2(max(n, 256)) for n in pools})
    assert len(eng.compiled) <= n_buckets + 1, (
        f"jit cache grew past the bucket bound: {sorted(eng.compiled)}"
    )
    rows.append({
        "name": "jit_cache_guard", "us_per_call": float(len(eng.compiled)),
        "derived": f"compiled signatures <= {n_buckets} buckets + 1 gate: OK",
    })
    return rows


def run(force: bool = False):
    return cached("pool_scaling", force, _run)


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        # smoke validates the selection-identity gates, the radix-rank
        # permutation gate, and the jit-cache guard without overwriting
        # the committed multi-repetition baseline JSON
        os.environ["REPRO_BENCH_SMOKE"] = "1"
        for r in _run():
            print(r)
    else:
        for r in run(force=True):
            print(r)
