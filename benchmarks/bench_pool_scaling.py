"""Propose-step latency vs candidate-pool size: staged numpy vs fused jax.

The PR 7 headline: one jitted program runs the whole BO propose iteration
(device pool draw, merged-QuickScorer forest descent, per-source combine,
EI, weighted rank aggregation, top-k) against the staged numpy path
(``space.sample`` -> unit encode -> ``score_sources`` ->
``aggregate_ranks`` -> stable argsort), at MFTune's combined-surrogate
scale (12 sources) over pool sizes 256 .. 131072. Both sides draw a fresh
pool per call — the real per-iteration cost, not a cached-pool microloop.

Before timing, host-pool mode is equivalence-gated: the fused program must
select bit-identical candidate indices to the staged numpy path. After the
sweep a jit-cache-growth guard asserts the engine compiled at most one
program per pool bucket (+1 for the host-mode gate) — the bucketed-shape
protocol's contract.

The speedup reported at 131072 is the measured number on the current
host. The 10x target assumes an accelerator; on a single-core CPU the
fused path is sort- and gather-bound (rank aggregation's stable sort
~0.6 s, descent + combine ~1.3 s at 12 x 131072), which caps the ratio
around 4x there. The pallas-descent row is gated on a non-CPU backend.

``--smoke`` (or REPRO_BENCH_SMOKE=1) sweeps two small pools, 1 repetition.
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

from benchmarks.common import cached

N_SOURCES = 12   # MFTune combined surrogate: source tasks + fidelity levels
N_OBS = 64
D = 16
K = 16           # candidates returned per propose call
POOLS = [256, 1024, 4096, 16384, 65536, 131072]
SMOKE_POOLS = [256, 2048]


def _best(fn, repeats: int) -> float:
    fn()  # warm up (pack, jit, numpy dispatch)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _space():
    from repro.core import ConfigSpace, FloatKnob, IntKnob

    knobs = []
    for j in range(D):
        if j % 4 == 0:
            knobs.append(FloatKnob(f"f{j}", 0.1, 10.0, log=True))
        elif j % 4 == 1:
            knobs.append(FloatKnob(f"f{j}", -5.0, 5.0))
        elif j % 4 == 2:
            knobs.append(IntKnob(f"i{j}", 1, 1024, log=True))
        else:
            knobs.append(IntKnob(f"i{j}", 0, 99))
    return ConfigSpace(knobs)


def _run():
    try:
        import jax  # noqa: F401
    except ImportError:
        return [{"name": "pool_scaling_skipped", "us_per_call": 0.0,
                 "derived": "jax unavailable"}]

    from repro.core import ProposeEngine, make_forest
    from repro.core.acquisition import aggregate_ranks, score_sources

    smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
    pools = SMOKE_POOLS if smoke else POOLS
    rng = np.random.default_rng(0)
    space = _space()
    models = []
    for s in range(N_SOURCES):
        X = rng.random((N_OBS, D))
        y = 3 * X[:, 0] - X[:, 1] ** 2 + 0.1 * rng.normal(size=N_OBS)
        models.append(make_forest(seed=s).fit(X, y))
    assert ProposeEngine.fusable(models)
    incs = list(rng.random(N_SOURCES))
    ws = list(rng.random(N_SOURCES))
    eng = ProposeEngine(space, seed=0)

    seed_ctr = [0]

    def staged(n):
        # fresh pool per call, exactly the staged recommend scoring path
        seed_ctr[0] += 1
        pool = space.sample(np.random.default_rng(seed_ctr[0]), n)
        Xu = space.complete_batch(pool).unit()
        scores = score_sources(models, Xu, incs)
        agg = aggregate_ranks(scores, np.asarray(ws))
        return np.argsort(agg, kind="stable")[:K]

    def fused(n, descent="auto"):
        # fresh device pool per call via the engine's threaded PRNG key
        return eng.propose(models, incs, ws, K, pool_size=n, descent=descent)

    # equivalence gate: host-pool mode must select bit-identical indices
    n_gate = min(4096, max(pools))
    pool = space.sample(np.random.default_rng(99), n_gate)
    Xu = space.complete_batch(pool).unit()
    ref = np.argsort(
        aggregate_ranks(score_sources(models, Xu, incs), np.asarray(ws)),
        kind="stable",
    )[:K]
    got = eng.score_topk(models, Xu, incs, ws, K)
    assert np.array_equal(ref, got), "fused host-mode selection diverged"

    rows = []
    ratios = {}
    for n in pools:
        reps = 1 if smoke else (5 if n <= 16384 else 2)
        t_np = _best(lambda: staged(n), reps)
        t_fx = _best(lambda: fused(n), reps)
        ratios[n] = t_np / t_fx
        rows.append({
            "name": f"staged_numpy_{n}", "us_per_call": t_np * 1e6,
            "derived": f"{n / t_np:.0f} cand/s",
        })
        rows.append({
            "name": f"fused_jax_{n}", "us_per_call": t_fx * 1e6,
            "derived": f"speedup {ratios[n]:.2f}x vs staged; {n / t_fx:.0f} cand/s",
        })
    if jax.default_backend() != "cpu" or os.environ.get("REPRO_BENCH_PALLAS") == "1":
        n = max(pools)
        t = _best(lambda: fused(n, descent="pallas"), 1 if smoke else 2)
        rows.append({
            "name": f"fused_pallas_{n}", "us_per_call": t * 1e6,
            "derived": f"pallas descent ({jax.default_backend()})",
        })

    crossover = next((n for n in pools if ratios[n] >= 1.0), None)
    rows.append({
        "name": "crossover_pool", "us_per_call": float(crossover or 0),
        "derived": ("fused beats staged from this pool size up"
                    if crossover else "fused never crossed staged in sweep"),
    })
    n_top = max(pools)
    rows.append({
        "name": f"headline_speedup_{n_top}", "us_per_call": ratios[n_top],
        "derived": (f"measured fused/staged ratio at {n_top}-candidate pools "
                    f"(single-device {jax.default_backend()}; 10x target assumes "
                    f"an accelerator — XLA:CPU's rank-agg sort and descent "
                    f"gathers are the floor here)"),
    })

    # jit-cache-growth guard: one program per pool bucket, +1 for the
    # host-mode equivalence gate — the bucketed-shape protocol's contract
    n_buckets = len({eng._pow2(max(n, 256)) for n in pools})
    assert len(eng.compiled) <= n_buckets + 1, (
        f"jit cache grew past the bucket bound: {sorted(eng.compiled)}"
    )
    rows.append({
        "name": "jit_cache_guard", "us_per_call": float(len(eng.compiled)),
        "derived": f"compiled signatures <= {n_buckets} buckets + 1 gate: OK",
    })
    return rows


def run(force: bool = False):
    return cached("pool_scaling", force, _run)


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    for r in run(force=True):
        print(r)
