"""§7.4.4: tuning overhead — real seconds per MFTune component, plus the
observability-plane overhead gate.

Paper: ~15s similarity prediction; fidelity partitioning 21s (TPC-DS) /
0.5s (TPC-H); per-iteration ~0.6s similarity + ~2s compression + ~0.2s BO;
all negligible vs evaluation costs.

This module also owns the tracer-overhead regression gate:

    python -m benchmarks.bench_overhead --smoke

runs the small warm-history TPC-H recipe with the tracer on and off
(interleaved repetitions, min wall per arm), asserts tracer-on wall time
is within 1% of tracer-off (+0.1s absolute slack for timer noise), and
asserts the two runs produce **bit-identical** observation streams and
trajectories — instrumentation must consume no RNG and alter no
computation. Exit code 0 = gate passed; used by scripts/check.sh.
"""

from __future__ import annotations

import sys
import time

import numpy as np

from .common import cached, load_kb, run_method

BUDGET = 48 * 3600.0
SMOKE_REPS = 3
GATE_REL = 0.01     # tracer-on must be within 1% of tracer-off ...
GATE_ABS_S = 0.1    # ... plus absolute slack for scheduler/timer noise


# ------------------------------------------------------------------- smoke
def _smoke_run(traced: bool):
    """One warm-history tpch-100 run; returns (wall_s, obs_sig, traj_sig)."""
    from repro import obs
    from repro.core import MFTune, MFTuneOptions
    from repro.core.knowledge import KnowledgeBase
    from repro.sparksim import SparkWorkload, TaskSpec, generate_history
    from repro.tuneapi import Budget

    kb = KnowledgeBase()
    kb.add_task(
        generate_history(
            TaskSpec("tpch", 100, "A").workload(), n_obs=12, n_init=5, seed=3
        ),
        persist=False,
    )
    wl = SparkWorkload("tpch", 100, "A")
    tuner = MFTune(wl, kb, MFTuneOptions(seed=0))
    budget = Budget(8 * 3600.0)
    t0 = time.perf_counter()
    if traced:
        with obs.tracing(obs.Tracer("overhead-smoke")):
            res = tuner.tune(budget)
    else:
        res = tuner.tune(budget)
    wall = time.perf_counter() - t0
    obs_sig = [
        (o.performance, o.fidelity, tuple(sorted(o.config.items())))
        for o in kb.get(wl.task_id).observations
    ]
    # wall_time is a real-clock stamp and legitimately differs between runs
    traj_sig = [
        (p.time, p.best, p.fidelity, p.rung, tuple(sorted(p.config.items())))
        for p in res.trajectory
    ]
    return wall, obs_sig, traj_sig


def _disabled_path_ns(n: int = 200_000) -> float:
    """ns per obs.span() round-trip with no tracer installed."""
    from repro import obs

    assert obs.get_tracer() is None
    t0 = time.perf_counter()
    for _ in range(n):
        with obs.span("x", a=1):
            pass
        obs.count("c")
    return 1e9 * (time.perf_counter() - t0) / n


def smoke(reps: int = SMOKE_REPS, verbose: bool = True) -> int:
    walls_on, walls_off = [], []
    ref_on = ref_off = None
    for _ in range(reps):  # interleave the arms so drift hits both equally
        w_off, sig_off, traj_off = _smoke_run(traced=False)
        w_on, sig_on, traj_on = _smoke_run(traced=True)
        walls_off.append(w_off)
        walls_on.append(w_on)
        ref_off = ref_off or (sig_off, traj_off)
        ref_on = ref_on or (sig_on, traj_on)

    t_off, t_on = min(walls_off), min(walls_on)
    rel = (t_on - t_off) / t_off if t_off > 0 else 0.0
    identical = ref_on == ref_off
    ns = _disabled_path_ns()

    ok = identical and t_on <= t_off * (1.0 + GATE_REL) + GATE_ABS_S
    if verbose:
        print(f"tracer off : {t_off:.3f}s (min of {reps})")
        print(f"tracer on  : {t_on:.3f}s (min of {reps})  overhead={100 * rel:+.2f}%")
        print(f"disabled-path span+counter: {ns:.0f}ns/call")
        print(f"trajectories bit-identical on vs off: {identical}")
        print("overhead gate:", "OK" if ok else
              f"FAIL (>{100 * GATE_REL:.0f}% + {GATE_ABS_S}s, or trajectory drift)")
    return 0 if ok else 1


# ------------------------------------------------------------- full bench
def run(force: bool = False):
    def compute():
        from repro.sparksim import SparkWorkload, make_task_id

        rows = []
        for bench in ("tpch", "tpcds"):
            target = make_task_id(bench, 600, "A")
            kb = load_kb(exclude=[target])
            wl = SparkWorkload(bench, 600, "A")
            res, wall = run_method("mftune", wl, kb, BUDGET, seed=0)
            iters = max(res.n_evaluations, 1)
            for comp, secs in sorted(res.overheads.items()):
                rows.append({
                    "name": f"overhead_{bench}_{comp}",
                    "us_per_call": 1e6 * secs / iters,
                    "derived": f"total_s={secs:.2f} over {iters} evals (wall={wall:.0f}s)",
                })
            total_oh = sum(res.overheads.values())
            rows.append({
                "name": f"overhead_{bench}_total",
                "us_per_call": 1e6 * total_oh / iters,
                "derived": (
                    f"total_overhead_s={total_oh:.1f} vs virtual_eval_time_h={BUDGET / 3600:.0f} "
                    f"(negligible={total_oh < 0.01 * BUDGET})"
                ),
            })
        # observability-plane overhead: tracer on vs off on the smoke recipe
        w_off, _, traj_off = _smoke_run(traced=False)
        w_on, _, traj_on = _smoke_run(traced=True)
        rows.append({
            "name": "overhead_tracer_smoke",
            "us_per_call": 1e6 * max(w_on - w_off, 0.0),
            "derived": (
                f"tracer_on={w_on:.3f}s tracer_off={w_off:.3f}s "
                f"rel={100 * (w_on - w_off) / max(w_off, 1e-9):+.2f}% "
                f"identical_trajectory={traj_on == traj_off}"
            ),
        })
        ns = _disabled_path_ns()
        rows.append({
            "name": "overhead_tracer_disabled_path",
            "us_per_call": ns / 1e3,
            "derived": f"span+counter round-trip with tracing disabled: {ns:.0f}ns/call",
        })
        return rows

    return cached("overhead", force, compute)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="run the tracer-overhead regression gate and exit")
    ap.add_argument("--reps", type=int, default=SMOKE_REPS)
    args = ap.parse_args()
    if args.smoke:
        sys.exit(smoke(reps=args.reps))
    for r in run(force=True):
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
