"""§7.4.4: tuning overhead — real seconds per MFTune component.

Paper: ~15s similarity prediction; fidelity partitioning 21s (TPC-DS) /
0.5s (TPC-H); per-iteration ~0.6s similarity + ~2s compression + ~0.2s BO;
all negligible vs evaluation costs.
"""

from __future__ import annotations

import numpy as np

from .common import cached, load_kb, run_method

BUDGET = 48 * 3600.0


def run(force: bool = False):
    def compute():
        from repro.sparksim import SparkWorkload, make_task_id

        rows = []
        for bench in ("tpch", "tpcds"):
            target = make_task_id(bench, 600, "A")
            kb = load_kb(exclude=[target])
            wl = SparkWorkload(bench, 600, "A")
            res, wall = run_method("mftune", wl, kb, BUDGET, seed=0)
            iters = max(res.n_evaluations, 1)
            for comp, secs in sorted(res.overheads.items()):
                rows.append({
                    "name": f"overhead_{bench}_{comp}",
                    "us_per_call": 1e6 * secs / iters,
                    "derived": f"total_s={secs:.2f} over {iters} evals (wall={wall:.0f}s)",
                })
            total_oh = sum(res.overheads.values())
            rows.append({
                "name": f"overhead_{bench}_total",
                "us_per_call": 1e6 * total_oh / iters,
                "derived": (
                    f"total_overhead_s={total_oh:.1f} vs virtual_eval_time_h={BUDGET / 3600:.0f} "
                    f"(negligible={total_oh < 0.01 * BUDGET})"
                ),
            })
        return rows

    return cached("overhead", force, compute)
