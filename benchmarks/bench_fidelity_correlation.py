"""Fig. 1b + Fig. 5b: fidelity-proxy correlation with full fidelity.

Samples 50 configurations, evaluates them at full fidelity and under each
proxy, and reports Kendall-tau vs. average latency ratio:
  - Data Volume: data_fraction in {1/27, 1/9, 1/3, 2/3}
  - SQL Early Stop: first ceil(delta*m) queries
  - SQL Selection (ours): Alg. 2 subsets from same-query-set history

Fig. 5b's claim — selection tau > 0.8 at 1/9 while DV is low/volatile —
is summarized over all 16 TPC-DS tasks.
"""

from __future__ import annotations

import time

import numpy as np

from .common import cached, load_kb

DELTAS = [1 / 27, 1 / 9, 1 / 3, 2 / 3]


def _proxy_taus(wl, kb, n_cfg: int = 50, seed: int = 0):
    from repro.core import kendall_tau, collect_query_stats, greedy_query_subset, early_stop_subset

    rng = np.random.default_rng(seed)
    cfgs = wl.space.sample(rng, n_cfg)
    full = []
    ok_cfgs = []
    for c in cfgs:
        r = wl.evaluate(c)
        if not r.failed:
            full.append(r.aggregate)
            ok_cfgs.append(c)
    full = np.array(full)
    full_cost = float(full.mean())
    m = len(wl.queries)

    sources = kb.same_query_sources_list(wl) if hasattr(kb, "same_query_sources_list") else None
    # same-query-set sources for Alg. 2
    from repro.core.knowledge import TaskRecord

    tgt = TaskRecord(task_id=wl.task_id, queries=list(wl.queries))
    srcs = [t for t in kb.tasks.values() if list(t.queries) == list(wl.queries)]
    stats = collect_query_stats(srcs, {t.task_id: 1.0 / max(len(srcs), 1) for t in srcs})

    out = {}
    for d in DELTAS:
        # data volume
        lat = []
        for c in ok_cfgs:
            r = wl.evaluate(c, data_fraction=d)
            lat.append(r.aggregate if not r.failed else np.nan)
        lat = np.array(lat)
        okm = ~np.isnan(lat)
        tau_dv, _ = kendall_tau(lat[okm], full[okm])
        ratio_dv = float(np.nanmean(lat) / full_cost)
        # early stop
        sub = early_stop_subset(m, d)
        lat = np.array([wl.evaluate(c, query_indices=sub).aggregate for c in ok_cfgs])
        tau_es, _ = kendall_tau(lat, full)
        ratio_es = float(lat.mean() / full_cost)
        # SQL selection
        tau_sel = ratio_sel = float("nan")
        if stats:
            subset, _tau_pred, _r = greedy_query_subset(stats, d)
            if subset:
                lat = np.array([wl.evaluate(c, query_indices=subset).aggregate for c in ok_cfgs])
                tau_sel, _ = kendall_tau(lat, full)
                ratio_sel = float(lat.mean() / full_cost)
        out[d] = {
            "data_volume": (tau_dv, ratio_dv),
            "early_stop": (tau_es, ratio_es),
            "sql_selection": (tau_sel, ratio_sel),
        }
    return out


def run(force: bool = False):
    def compute():
        from repro.sparksim import SparkWorkload, make_task_id

        rows = []
        # ---- Fig 1b: TPC-DS 600GB on hardware A
        target = make_task_id("tpcds", 600, "A")
        kb = load_kb(exclude=[target])
        wl = SparkWorkload("tpcds", 600, "A")
        t0 = time.perf_counter()
        taus = _proxy_taus(wl, kb)
        dt = (time.perf_counter() - t0) * 1e6
        for d, r in taus.items():
            for proxy, (tau, ratio) in r.items():
                rows.append({
                    "name": f"fig1b_{proxy}_d{d:.3f}",
                    "us_per_call": dt / (len(taus) * 3),
                    "derived": f"kendall_tau={tau:.3f} latency_ratio={ratio:.3f}",
                })
        # ---- Fig 5b: selection vs DV at 1/9 across all 16 tpcds tasks
        sel_taus, dv_taus = [], []
        for gb in (100, 600):
            for hw in "ABCDEFGH":
                tid = make_task_id("tpcds", gb, hw)
                kb_i = load_kb(exclude=[tid])
                wl_i = SparkWorkload("tpcds", gb, hw)
                r = _proxy_taus(wl_i, kb_i, n_cfg=30, seed=1)[1 / 9]
                sel_taus.append(r["sql_selection"][0])
                dv_taus.append(r["data_volume"][0])
        sel = np.array(sel_taus)
        dv = np.array(dv_taus)
        rows.append({
            "name": "fig5b_selection_tau_1over9_16tasks",
            "us_per_call": dt,
            "derived": (
                f"mean={np.nanmean(sel):.3f} min={np.nanmin(sel):.3f} "
                f"frac_above_0.8={(sel > 0.8).mean():.2f}"
            ),
        })
        rows.append({
            "name": "fig5b_datavolume_tau_1over9_16tasks",
            "us_per_call": dt,
            "derived": (
                f"mean={np.nanmean(dv):.3f} min={np.nanmin(dv):.3f} "
                f"frac_below_0.4={(dv < 0.4).mean():.2f}"
            ),
        })
        return rows

    return cached("fidelity_correlation", force, compute)
