import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "REPRO_XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract memory / cost / collective evidence.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json
  ... add --multi-pod for the 2x16x16 (512-chip) mesh.

Every cell writes incrementally to the output JSON so a long sweep can be
monitored and resumed (--resume skips cells already present).
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import numpy as np


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             runtime_overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    import jax
    import jax.numpy as jnp

    from ..configs import SHAPES, get_arch, shape_applicable
    from ..distributed.sharding import (
        assign_pspec, batch_axes, cache_axes, cache_rules, make_param_rules,
        shardings_for_specs,
    )
    from ..models import Runtime, abstract_params, build_param_specs
    from ..optim import adamw_init_abstract
    from ..tools import analyze_hlo, model_flops, roofline_terms
    from ..train import input_specs, make_decode_step, make_prefill_step, make_train_step
    from .mesh import make_production_mesh

    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch_name, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": reason}

    rt_kw: Dict[str, Any] = dict(
        remat="full" if shape.kind == "train" else "none",
        scan_layers=True,
        attn_chunk=2048 if shape.seq_len >= 32768 else 1024,
        # sequence-parallel residual stream: divides the saved-activation
        # stacks by the model-axis size (measured 49.4 -> 6.6 GB/device on
        # llama3-8b train_4k; see EXPERIMENTS.md §Perf)
        seq_shard=shape.kind == "train",
    )
    if runtime_overrides:
        rt_kw.update(runtime_overrides)
    rt = Runtime(**rt_kw)

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)

    specs = build_param_specs(cfg, rt)
    params = abstract_params(specs)
    rules = make_param_rules(rt, mesh)
    p_shardings = shardings_for_specs(specs, mesh, rules)

    from jax.sharding import NamedSharding, PartitionSpec as P

    dp = batch_axes(mesh)
    tok_sharding = NamedSharding(mesh, P(dp))

    t0 = time.time()
    ins = input_specs(cfg, shape, rt)

    if shape.kind == "train":
        opt = adamw_init_abstract(params, dtype=jnp.dtype(rt.opt_state_dtype))
        opt_shardings = type(opt)(
            NamedSharding(mesh, P()),
            jax.tree.map(lambda s: s, p_shardings),
            jax.tree.map(lambda s: s, p_shardings),
        )
        batch = ins["batch"]
        batch_shardings = {}
        for k, v in batch.items():
            if v.ndim >= 2 and v.shape[0] == shape.global_batch:
                batch_shardings[k] = tok_sharding
            else:
                batch_shardings[k] = NamedSharding(mesh, P())
        step = make_train_step(cfg, rt)
        jitted = jax.jit(
            step,
            in_shardings=(p_shardings, opt_shardings, batch_shardings),
            out_shardings=(p_shardings, opt_shardings, None),
            donate_argnums=(0, 1),
        )
        with jax.set_mesh(mesh):
            lowered = jitted.lower(params, opt, batch)
        trip_hint = cfg.n_layers

    elif shape.kind == "prefill":
        batch = ins["batch"]
        batch_shardings = {
            k: (tok_sharding if v.shape[0] == shape.global_batch else NamedSharding(mesh, P()))
            for k, v in batch.items()
        }
        step = make_prefill_step(cfg, rt)
        jitted = jax.jit(step, in_shardings=(p_shardings, batch_shardings),
                         out_shardings=tok_sharding)
        with jax.set_mesh(mesh):
            lowered = jitted.lower(params, batch)
        trip_hint = cfg.n_layers

    else:  # decode
        cache = ins["cache"]
        tokens = ins["tokens"]
        dp_total = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a] for a in dp]))
        batch_ok = shape.global_batch % dp_total == 0 and shape.global_batch >= dp_total
        crules = cache_rules(rt, mesh, batch_shardable=batch_ok)
        caxes = cache_axes(cfg, cache)
        cache_shardings = {
            k: NamedSharding(mesh, assign_pspec(v.shape, caxes[k], mesh, crules))
            for k, v in cache.items()
        }
        tok_sh = NamedSharding(mesh, P(dp if batch_ok else None))
        step = make_decode_step(cfg, rt)
        jitted = jax.jit(
            step,
            in_shardings=(p_shardings, cache_shardings, tok_sh),
            out_shardings=(None, cache_shardings),
            donate_argnums=(1,),
        )
        with jax.set_mesh(mesh):
            lowered = jitted.lower(params, cache, tokens)
        trip_hint = cfg.n_layers

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    costs = analyze_hlo(hlo_text, trip_hint=trip_hint)
    mf = model_flops(cfg, shape)
    report = roofline_terms(
        arch_name, shape_name, mesh_name, chips, costs, mf,
        raw_flops=float(ca.get("flops", 0.0)), raw_bytes=float(ca.get("bytes accessed", 0.0)),
    )

    out = {
        "arch": arch_name, "shape": shape_name, "multi_pod": multi_pod,
        "mesh": mesh_name, "chips": chips, "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "generated_code_bytes": int(mem.generated_code_size_in_bytes),
            # CPU memory stats are per-device program totals
            "temp_gb_per_device": round(mem.temp_size_in_bytes / 2**30, 3),
            "args_gb_per_device": round(mem.argument_size_in_bytes / 2**30, 3),
        },
        "roofline": report.to_json(),
        "hlo_notes": costs.notes[:5],
        "n_while": costs.n_while,
        "trip_counts": costs.trip_counts,
        "runtime": rt_kw,
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--runtime", type=str, default=None, help="JSON runtime overrides")
    args = ap.parse_args()

    from ..configs import all_cells

    overrides = json.loads(args.runtime) if args.runtime else None
    cells = all_cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    done = set()
    if args.out and args.resume and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
        done = {(r["arch"], r["shape"], r["multi_pod"]) for r in results}

    for arch, shape in cells:
        for mp in meshes:
            if (arch, shape, mp) in done:
                continue
            label = f"{arch} x {shape} ({'512' if mp else '256'} chips)"
            print(f"=== {label}", flush=True)
            try:
                r = run_cell(arch, shape, mp, overrides)
            except Exception as e:
                r = {"arch": arch, "shape": shape, "multi_pod": mp,
                     "status": "error", "error": f"{type(e).__name__}: {e}",
                     "traceback": traceback.format_exc()[-2000:]}
            status = r["status"]
            if status == "ok":
                rl = r["roofline"]
                print(f"    ok  compile={r['compile_s']}s temp/dev={r['memory']['temp_gb_per_device']}GB "
                      f"bottleneck={rl['bottleneck']} step={rl['step_time_s']:.4f}s "
                      f"roofline_frac={rl['roofline_fraction']:.3f}", flush=True)
                print(f"    memory_analysis: {r['memory']}", flush=True)
            else:
                print(f"    {status}: {r.get('reason') or r.get('error')}", flush=True)
            results.append(r)
            if args.out:
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out + ".tmp", "w") as f:
                    json.dump(results, f, indent=1)
                os.replace(args.out + ".tmp", args.out)
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    n_err = sum(1 for r in results if r["status"] == "error")
    print(f"=== done: {n_ok} ok, {n_skip} skipped, {n_err} errors", flush=True)
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
