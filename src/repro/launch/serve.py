"""Serving launcher: batched generation with a reduced config on CPU."""

from __future__ import annotations

import argparse

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax

    from ..configs import get_arch, reduced
    from ..models import Runtime, build_param_specs, init_params
    from ..serving import ServingEngine
    from ..serving.engine import Request

    cfg = reduced(get_arch(args.arch))
    rt = Runtime(remat="none", attn_chunk=64)
    params = init_params(build_param_specs(cfg, rt), jax.random.PRNGKey(args.seed))
    engine = ServingEngine(params, cfg, rt, batch_size=min(args.requests, 4), max_len=128)
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(prompt=rng.integers(2, cfg.vocab, args.prompt_len).astype(np.int32),
                max_new_tokens=args.max_new)
        for _ in range(args.requests)
    ]
    engine.generate(reqs)
    for i, r in enumerate(reqs):
        print(f"req {i}: generated {len(r.generated)} tokens: {r.generated[:12]}...")


if __name__ == "__main__":
    main()
