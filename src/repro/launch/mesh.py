"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is a second-level data-parallel axis whose collectives cross
the inter-pod links (DCN on real deployments); gradient compression
(distributed/compression.py) targets exactly that axis.

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""

from __future__ import annotations

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_mesh_shape"]


def make_mesh_shape(n_devices: int, model: int = 16, multi_pod: bool = False):
    if multi_pod:
        pods = 2
        data = n_devices // (pods * model)
        return (pods, data, model), ("pod", "data", "model")
    data = n_devices // model
    return (data, model), ("data", "model")


def make_production_mesh(*, multi_pod: bool = False, model: int = 16):
    n = 512 if multi_pod else 256
    shape, axes = make_mesh_shape(n, model=model, multi_pod=multi_pod)
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for the {'multi' if multi_pod else 'single'}-pod "
            f"mesh, have {len(devs)} — set XLA_FLAGS=--xla_force_host_platform_device_count={n}"
        )
    # more devices than needed (e.g. 512 forced, single-pod 256): subset mesh
    grid = np.asarray(devs[:n], dtype=object).reshape(shape)
    return jax.sharding.Mesh(grid, axes)
