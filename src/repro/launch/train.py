"""Training launcher: ``python -m repro.launch.train --arch llama3-8b
--reduced --steps 200``. Reduced configs train a real ~small model on CPU;
full configs are for TPU deployments (the dry-run proves they compile on
the production mesh)."""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from ..configs import get_arch, reduced
    from ..models import Runtime
    from ..train.trainer import Trainer

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    rt = Runtime(remat="none", scan_layers=True, attn_chunk=min(256, args.seq_len))
    trainer = Trainer(cfg, rt, seq_len=args.seq_len, global_batch=args.batch,
                      lr=args.lr, seed=args.seed, ckpt_dir=args.ckpt_dir)
    losses = trainer.run(args.steps)
    print(f"final loss: {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
