"""AdamW with dtype-configurable, shardable state.

State moments inherit the parameter sharding (with FSDP that is already
ZeRO-3; without it the caller may extend the sharding over the data axes —
ZeRO-1 — since the update is elementwise and any layout is valid).
Global-norm clipping is fused into the update.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_init_abstract", "adamw_update"]


class AdamWState(NamedTuple):
    step: jax.Array        # () int32
    m: Any                 # pytree like params
    v: Any


def adamw_init(params, dtype=jnp.float32) -> AdamWState:
    z = lambda p: jnp.zeros(p.shape, dtype)
    return AdamWState(jnp.zeros((), jnp.int32), jax.tree.map(z, params), jax.tree.map(z, params))


def adamw_init_abstract(params, dtype=jnp.float32) -> AdamWState:
    z = lambda p: jax.ShapeDtypeStruct(p.shape, dtype)
    return AdamWState(
        jax.ShapeDtypeStruct((), jnp.int32), jax.tree.map(z, params), jax.tree.map(z, params)
    )


def adamw_update(
    params,
    grads,
    state: AdamWState,
    lr: float | jax.Array = 1e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: Optional[float] = 1.0,
) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    if clip_norm is not None:
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    else:
        scale = 1.0

    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + g32 * g32 * (1 - b2)
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in outs])
    return new_p, AdamWState(step, new_m, new_v)
