"""LOFTune (Li et al., TKDE'25) — low-overhead Spark SQL tuning.

Mechanisms reproduced (per §2.1/§7.1/§7.2 of MFTune): similar-workload
identification (we use meta-features in place of its multi-task SQL
representation encoder — see DESIGN.md §9), an aggressive warm start that
deploys the top-k configurations of the most similar tasks at
initialization, and a workload-aware performance simulator fitted on *all*
historical data (a pooled surrogate over [config ++ meta-features]) used
to screen candidates. As MFTune's §7.2 analysis notes, its historical
utilization concentrates in the initialization phase; afterwards it runs
standard BO on its own observations with pooled-simulator screening.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from .. import obs as _obs
from ..core.knowledge import KnowledgeBase
from ..core.surrogate import ProbabilisticRandomForest, make_forest
from .common import BaselineTuner, Budget, Config

__all__ = ["LOFTune"]


class LOFTune(BaselineTuner):
    name = "loftune"

    def __init__(self, workload, kb: Optional[KnowledgeBase] = None, seed: int = 0, warm_k: int = 5):
        super().__init__(workload, kb, seed)
        self.warm_k = warm_k
        self._pooled: Optional[ProbabilisticRandomForest] = None
        self._target_meta = workload.meta_features()

    # ------------------------------------------------- workload-aware simulator
    def _fit_pooled(self) -> None:
        if self._pooled is not None:
            return
        Xs: List[np.ndarray] = []
        ys: List[float] = []
        for t in self.kb.source_tasks(self.wl.task_id):
            if t.meta_features is None:
                continue
            mf = np.asarray(t.meta_features, dtype=float)
            obs = t.full_fidelity()
            if not obs:
                continue
            perf = np.array([o.performance for o in obs])
            # per-task z-normalized target: the simulator predicts *relative*
            # quality so different task scales can pool
            z = (perf - perf.mean()) / (perf.std() + 1e-9)
            Xe = self.space.encode_many([o.config for o in obs])  # one pass
            for xe, zi in zip(Xe, z):
                Xs.append(np.concatenate([xe, mf]))
                ys.append(float(zi))
        if len(ys) >= 10:
            self._pooled = make_forest(seed=self.seed, n_trees=12).fit(
                np.array(Xs), np.array(ys)
            )

    def _meta_distance(self, a, b) -> float:
        a = np.asarray(a, dtype=float)
        b = np.asarray(b, dtype=float)
        return float(np.linalg.norm((a - b) / (np.abs(a) + np.abs(b) + 1e-9)))

    # ------------------------------------------------------------------ warm
    def initialize(self, budget: Budget) -> None:
        with _obs.span("warm_start", tuner=self.name):
            self._initialize(budget)

    def _initialize(self, budget: Budget) -> None:
        sources = [t for t in self.kb.source_tasks(self.wl.task_id) if t.meta_features is not None]
        if self._target_meta is not None and sources:
            sources.sort(key=lambda t: self._meta_distance(self._target_meta, t.meta_features))
            warm: List[Config] = []
            for t in sources[:3]:
                obs = sorted(t.full_fidelity(), key=lambda o: o.performance)
                for o in obs[: self.warm_k]:
                    warm.append(o.config)
            # screen warm candidates with the pooled simulator
            self._fit_pooled()
            if self._pooled is not None and warm and self._target_meta is not None:
                Z = self._with_meta(self.space.encode_many(warm))
                order = np.argsort(self._pooled.predict_mean(Z))
                warm = [warm[i] for i in order]
            for cfg in warm[: self.warm_k]:
                if budget.exhausted:
                    return
                self.evaluate_full(budget, cfg)
        for cfg in self.space.lhs_sample(self.rng, 3):
            if budget.exhausted:
                return
            self.evaluate_full(budget, cfg)

    def _with_meta(self, X: np.ndarray) -> np.ndarray:
        """[config-encoding ++ target meta-features] rows, one broadcast."""
        mf = np.asarray(self._target_meta, dtype=float)
        return np.concatenate([X, np.broadcast_to(mf, (len(X), len(mf)))], axis=1)

    # ------------------------------------------------------------------ loop
    def propose(self, budget: Budget) -> Config:
        model = self.fit_surrogate()
        pool = self.space.sample(self.rng, 192)
        if model is None:
            return pool[0]
        # pooled-simulator pre-screen: keep the better half of the pool
        # (columnar: the pool is encoded once and sliced, never re-encoded)
        self._fit_pooled()
        if self._pooled is not None and self._target_meta is not None:
            Z = self._with_meta(pool.unit())
            order = np.argsort(self._pooled.predict_mean(Z))
            pool = pool.take(order[: len(pool) // 2])
        return self.ei_pick(model, pool)
