"""Shared machinery for the SOTA baseline tuners (paper §7.1).

Every baseline is a full-fidelity iterative tuner: propose a config,
evaluate the entire workload, record. The accounting (budget charging,
best-so-far trajectory of *successful full evaluations*) is identical to
MFTune's so end-to-end comparisons are apples-to-apples.
"""

from __future__ import annotations

import time as _time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from .. import obs as _obs
from ..core.acquisition import ei_scores
from ..core.knowledge import KnowledgeBase, Observation, TaskRecord
from ..core.mftune import TrajectoryPoint, TuningResult
from ..core.space import ConfigSpace
from ..core.surrogate import make_forest
from ..tuneapi import Budget, Workload

Config = Dict[str, Any]

__all__ = ["BaselineTuner", "RandomSearch", "VanillaBO"]


class BaselineTuner:
    name = "baseline"

    def __init__(self, workload: Workload, kb: Optional[KnowledgeBase] = None, seed: int = 0):
        self.wl = workload
        self.kb = kb or KnowledgeBase()
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.space: ConfigSpace = workload.space
        self.obs: List[Observation] = []
        self._trajectory: List[TrajectoryPoint] = []
        # same per-run registry shape as MFTune, so bench_end_to_end can
        # report stage breakdowns for every method through one vocabulary
        self.metrics = _obs.Metrics()

    @contextmanager
    def stage(self, key: str, **args):
        """Span + ``overhead/<key>`` counter around one tuner stage — the
        shared Tracer entry point every baseline proposal routes through."""
        t0 = _time.perf_counter()
        with _obs.span(key, tuner=self.name, **args) as sp:
            try:
                yield sp
            finally:
                self.metrics.counter("overhead/" + key).add(
                    _time.perf_counter() - t0
                )

    # ------------------------------------------------------------- accounting
    def _ok(self) -> List[Observation]:
        return [o for o in self.obs if not o.failed]

    def best(self):
        ok = self._ok()
        return min(ok, key=lambda o: o.performance) if ok else None

    def evaluate_full(self, budget: Budget, cfg: Config, query_indices=None) -> Observation:
        cfg = dict(self.space.default(), **cfg)
        res = self.wl.evaluate(cfg, query_indices=query_indices)
        budget.charge(res.elapsed, label=f"{self.name}-eval")
        o = Observation(
            config=cfg,
            performance=res.aggregate if not res.failed else float("inf"),
            fidelity=1.0 if query_indices is None else 0.0,
            per_query_perf=list(res.per_query_latency) if not res.failed else None,
            per_query_cost=list(res.per_query_cost) if not res.failed else None,
            failed=res.failed,
            elapsed=res.elapsed,
            time=budget.now,
        )
        if query_indices is None:
            m = self.metrics
            m.counter("eval/failed" if o.failed else "eval/ok").add()
            m.counter("budget/full_fidelity_s").add(res.elapsed)
            m.histogram("eval/elapsed_s").observe(res.elapsed)
            self.obs.append(o)
            if not o.failed:
                b = self.best()
                if b is o:
                    self._trajectory.append(
                        TrajectoryPoint(time=budget.now, best=o.performance, config=cfg,
                                        fidelity=1.0, wall_time=_time.time(), rung=None)
                    )
        return o

    # ---------------------------------------------------------------- running
    def initialize(self, budget: Budget) -> None:
        """Default: small LHS init."""
        with _obs.span("cold_start", tuner=self.name):
            for cfg in self.space.lhs_sample(self.rng, 5):
                if budget.exhausted:
                    return
                self.evaluate_full(budget, cfg)

    def propose(self, budget: Budget) -> Optional[Config]:
        raise NotImplementedError

    def step(self, budget: Budget) -> None:
        with self.stage("bo_recommend", mode="baseline"):
            cfg = self.propose(budget)
        if cfg is not None and not budget.exhausted:
            self.evaluate_full(budget, cfg)

    def run(self, budget: Budget) -> TuningResult:
        self.initialize(budget)
        it = 0
        while not budget.exhausted:
            with _obs.span("iteration", tuner=self.name, i=it, mode="full_fidelity"):
                self.step(budget)
            it += 1
        b = self.best()
        m = self.metrics
        tracer = _obs.get_tracer()
        if tracer is not None:
            tracer.emit_metrics(m, scope=f"{self.name}:{self.wl.task_id}")
        return TuningResult(
            best_config=b.config if b else None,
            best_performance=b.performance if b else float("inf"),
            trajectory=self._trajectory,
            n_evaluations=len(self.obs),
            n_full_evaluations=len(self.obs),
            mfo_activation_time=None,
            overheads=m.counters_view("overhead/", coerce_int=False),
            metrics=m.snapshot(),
        )

    # ------------------------------------------------------------------ utils
    def fit_surrogate(self, obs: Optional[Sequence[Observation]] = None, space=None):
        obs = list(obs) if obs is not None else self._ok()
        space = space or self.space
        if len(obs) < 2:
            return None
        with _obs.span("surrogate_fit", source=f"baseline:{self.name}", n_obs=len(obs)):
            X = space.encode_many([o.config for o in obs])
            y = np.array([o.performance for o in obs])
            return make_forest(seed=self.seed).fit(X, y)

    def ei_pick(self, model, pool: Sequence[Config], space=None) -> Config:
        """Best-EI pick; a ConfigBatch pool is scored from its cached unit
        encoding (no dict round-trip), only the winner materializes."""
        space = space or self.space
        ok = self._ok()
        best = min(o.performance for o in ok) if ok else 0.0
        with _obs.span("acquisition", pool=len(pool), sources=1, k=1):
            scores = ei_scores(model, space.encode_many(pool), best)
        return pool[int(np.argmax(scores))]


class RandomSearch(BaselineTuner):
    name = "random"

    def propose(self, budget: Budget) -> Config:
        return self.space.sample(self.rng, 1)[0]


class VanillaBO(BaselineTuner):
    name = "bo"

    def propose(self, budget: Budget) -> Config:
        model = self.fit_surrogate()
        pool = self.space.sample(self.rng, 192)
        if model is None:
            return pool[0]
        return self.ei_pick(model, pool)
