from .common import BaselineTuner, RandomSearch, VanillaBO
from .locat import LOCAT
from .toptune import TopTune
from .tuneful import Tuneful
from .rover import Rover
from .loftune import LOFTune
from .sc_variants import BoxCompressor, DecreaseCompressor, ProjectCompressor, VoteCompressor

__all__ = [
    "BaselineTuner", "RandomSearch", "VanillaBO",
    "LOCAT", "TopTune", "Tuneful", "Rover", "LOFTune",
    "BoxCompressor", "DecreaseCompressor", "ProjectCompressor", "VoteCompressor",
]
