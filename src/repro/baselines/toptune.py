"""TopTune (Wei et al., ICDE'25) — projection-based DBMS tuning.

Mechanisms reproduced (per §2.1/§7.1/§7.4.2 of MFTune): a HeSBO-style
random hash projection embeds the continuous knobs into a low-dimensional
synthetic space where BO runs; categorical and continuous knobs are tuned
*alternately*; bucketization coarsens the projected ranges. History-free.
"""

from __future__ import annotations

from typing import Any, Dict, List

import numpy as np

from ..core.space import BoolKnob, CatKnob, ConfigSpace, FloatKnob, IntKnob
from .common import BaselineTuner, Budget, Config

__all__ = ["TopTune"]


class TopTune(BaselineTuner):
    name = "toptune"

    def __init__(self, workload, kb=None, seed: int = 0, d_low: int = 16, n_buckets: int = 16):
        super().__init__(workload, kb, seed)
        self.d_low = d_low
        self.n_buckets = n_buckets
        rng = np.random.default_rng(seed)
        self.num_names = [k.name for k in self.space.knobs if isinstance(k, (FloatKnob, IntKnob))]
        self.cat_names = [k.name for k in self.space.knobs if isinstance(k, (CatKnob, BoolKnob))]
        names = self.space.names
        self._num_idx = np.array([names.index(n) for n in self.num_names], dtype=np.int64)
        # HeSBO: each original dim hashes to one synthetic dim with a sign
        self.h = rng.integers(0, d_low, len(self.num_names))
        self.sgn = rng.choice([-1.0, 1.0], len(self.num_names))
        self._phase = 0  # alternate: 0 = continuous (projected), 1 = categorical
        self._cat_state: Dict[str, Any] = {
            n: self.space.by_name[n].default_value() for n in self.cat_names
        }
        self._low_obs: List[np.ndarray] = []
        self._low_y: List[float] = []

    # --------------------------------------------------------- projection map
    def _lift(self, z: np.ndarray) -> Config:
        """Synthetic point z in [0,1]^d_low -> full config (continuous part).

        One vectorized hash-gather + bucketization + whole-row decode
        instead of a per-knob from_unit loop; categorical knobs are then
        overwritten from the alternating-phase state.
        """
        u = np.full(self.space.dim, 0.5)
        uz = z[self.h]
        uz = np.where(self.sgn < 0, 1.0 - uz, uz)
        u[self._num_idx] = (np.floor(uz * self.n_buckets) + 0.5) / self.n_buckets
        cfg = self.space.decode(u)
        cfg.update(self._cat_state)
        return cfg

    def propose(self, budget: Budget) -> Config:
        self._phase ^= 1
        if self._phase == 1 and self.cat_names:
            # categorical phase: mutate categorical knobs around incumbent
            best = self.best()
            base = dict(self._cat_state)
            if best is not None:
                base = {n: best.config.get(n, base[n]) for n in self.cat_names}
            name = self.cat_names[int(self.rng.integers(len(self.cat_names)))]
            knob = self.space.by_name[name]
            choices = knob.active_choices() if hasattr(knob, "active_choices") else (False, True)
            base[name] = choices[int(self.rng.integers(len(choices)))]
            self._cat_state = base
            best_cfg = best.config if best is not None else self.space.default()
            cfg = dict(best_cfg)
            cfg.update(base)
            return cfg
        # continuous phase: BO in the synthetic space
        from ..core.surrogate import make_forest
        from ..core.acquisition import ei_scores

        if len(self._low_y) >= 2:
            model = make_forest(seed=self.seed).fit(
                np.array(self._low_obs), np.array(self._low_y)
            )
            pool = self.rng.random((192, self.d_low))
            scores = ei_scores(model, pool, float(np.min(self._low_y)))
            z = pool[int(np.argmax(scores))]
        else:
            z = self.rng.random(self.d_low)
        self._pending_z = z
        return self._lift(z)

    def step(self, budget: Budget) -> None:
        with self.stage("bo_recommend", mode="baseline"):
            cfg = self.propose(budget)
        if cfg is None or budget.exhausted:
            return
        o = self.evaluate_full(budget, cfg)
        if self._phase == 0 and hasattr(self, "_pending_z"):
            if not o.failed:
                self._low_obs.append(self._pending_z)
                self._low_y.append(o.performance)
