"""LOCAT (Xin et al., SIGMOD'22) — low-overhead online configuration tuning.

Key mechanisms reproduced (per its paper and §2.1/§7.1 of MFTune):
  * IICP: iteratively identifies important configuration parameters from
    accumulated observations (permutation importance on the surrogate) and
    shrinks the search space to the top knobs, tightening over time.
  * QCSA: after sufficient observations, compresses the *workload*: selects
    the query subset that preserves the aggregate ranking on observed data,
    then fully replaces the original workload with the compressed one
    (MFTune's §2.1 critique). New compressed-run incumbents trigger one
    full-workload validation run (how a deployment would consume the
    recommendation) — charged to the budget.

No historical-task knowledge is used (history-free method).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional

import numpy as np

from ..core.fidelity import QueryStats, greedy_query_subset
from ..core.knowledge import Observation
from .common import BaselineTuner, Budget, Config

__all__ = ["LOCAT"]


class LOCAT(BaselineTuner):
    name = "locat"

    def __init__(self, workload, kb=None, seed: int = 0,
                 compress_after: int = 12, shrink_every: int = 8, keep_frac: float = 0.6,
                 qcsa_delta: float = 0.4):
        super().__init__(workload, kb, seed)
        self.compress_after = compress_after
        self.shrink_every = shrink_every
        self.keep_frac = keep_frac
        self.qcsa_delta = qcsa_delta
        self.active_space = self.space
        self.query_subset: Optional[List[int]] = None
        self._compressed_best: float = float("inf")

    # ------------------------------------------------------------------ IICP
    def _perm_importance(self, model, X: np.ndarray) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        base = model.predict_mean(X)
        imp = np.zeros(X.shape[1])
        for j in range(X.shape[1]):
            Xp = X.copy()
            Xp[:, j] = rng.permutation(Xp[:, j])
            imp[j] = float(np.abs(model.predict_mean(Xp) - base).mean())
        return imp

    def _maybe_shrink_space(self) -> None:
        ok = self._ok()
        if len(ok) < self.shrink_every or len(ok) % self.shrink_every != 0:
            return
        model = self.fit_surrogate(ok)
        if model is None:
            return
        X = self.space.encode_many([o.config for o in ok])
        imp = self._perm_importance(model, X)
        k = max(int(len(self.space.names) * self.keep_frac), 8)
        order = np.argsort(-imp)
        keep = [self.space.names[i] for i in order[:k]]
        self.active_space = self.space.restrict(keep=keep)

    # ------------------------------------------------------------------ QCSA
    def _maybe_compress_workload(self) -> None:
        if self.query_subset is not None:
            return
        full = [o for o in self._ok() if o.per_query_perf is not None]
        if len(full) < self.compress_after:
            return
        perf = np.array([o.per_query_perf for o in full])
        cost = np.array([o.per_query_cost for o in full])
        stats = [QueryStats(task_id=self.wl.task_id, perf=perf, cost=cost, weight=1.0)]
        subset, _tau, _r = greedy_query_subset(stats, self.qcsa_delta)
        if subset:
            self.query_subset = subset

    # ------------------------------------------------------------------ loop
    def step(self, budget: Budget) -> None:
        with self.stage("bo_recommend", mode="baseline"):
            self._maybe_shrink_space()
            self._maybe_compress_workload()
            model = self.fit_surrogate(space=self.space)
            # columnar: sample the shrunk space, lift into the full space with
            # defaults, and score without materializing dicts
            pool = self.space.complete_batch(self.active_space.sample(self.rng, 192))
            cfg = self.ei_pick(model, pool) if model is not None else pool[0]
        if self.query_subset is None:
            self.evaluate_full(budget, cfg)
            return
        # compressed-workload evaluation (replaces the original workload)
        o = self.evaluate_full(budget, cfg, query_indices=self.query_subset)
        if not o.failed and o.performance < self._compressed_best:
            self._compressed_best = o.performance
            if not budget.exhausted:
                self.evaluate_full(budget, cfg)  # deployment validation run
