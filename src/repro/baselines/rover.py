"""Rover (Shen et al., KDD'23) — generalized transfer learning for Spark.

Mechanisms reproduced (per §2.1/§4.2/§7.1 of MFTune): adaptive similarity
weights over historical workloads (meta-feature prediction early, surrogate
agreement later — MFTune §4.2 explicitly extends Rover's scheme), used to
*weight the BO acquisition function* across source surrogates. No search
space compression, no multi-fidelity, no Phase-2 warm start; the best
historical config seeds the search (Rover's safe exploration).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from .. import obs as _obs
from ..core.acquisition import aggregate_ranks, score_sources
from ..core.knowledge import KnowledgeBase
from ..core.similarity import SimilarityEngine
from .common import BaselineTuner, Budget, Config

__all__ = ["Rover"]


class Rover(BaselineTuner):
    name = "rover"

    def __init__(self, workload, kb: Optional[KnowledgeBase] = None, seed: int = 0):
        super().__init__(workload, kb, seed)
        from ..core.knowledge import TaskRecord

        self.target = TaskRecord(
            task_id=workload.task_id,
            queries=list(workload.queries),
            meta_features=workload.meta_features(),
        )
        self.kb.tasks.setdefault(self.target.task_id, self.target)
        self.sim = SimilarityEngine(self.space, self.kb, seed=seed)
        self._seeded = False

    def initialize(self, budget: Budget) -> None:
        with _obs.span("warm_start", tuner=self.name):
            self._initialize(budget)

    def _initialize(self, budget: Budget) -> None:
        # seed with the best config of the most similar source, then LHS
        weights = self.sim.compute(self.target)
        best_tid = None
        best_w = 0.0
        for tid, w in weights.weights.items():
            if tid != "__target__" and w > best_w:
                best_tid, best_w = tid, w
        if best_tid is not None:
            b = self.kb.get(best_tid).best()
            if b is not None and not budget.exhausted:
                self.evaluate_full(budget, b.config)
        for cfg in self.space.lhs_sample(self.rng, 4):
            if budget.exhausted:
                return
            self.evaluate_full(budget, cfg)

    def evaluate_full(self, budget: Budget, cfg, query_indices=None):
        o = super().evaluate_full(budget, cfg, query_indices)
        # mirror observations into the target record for the similarity engine
        if query_indices is None:
            self.target.observations.append(o)
        return o

    def propose(self, budget: Budget) -> Config:
        pool = self.space.sample(self.rng, 192)
        ok = self._ok()
        if len(ok) < 2:
            return pool[0]
        weights = self.sim.compute(self.target)
        X = self.space.encode_many(pool)
        # target surrogate always participates
        models = [self.fit_surrogate(ok)]
        incs = [min(o.performance for o in ok)]
        wts = [max(weights.weights.get("__target__", 0.0), 0.25)]
        for tid, w in weights.weights.items():
            if tid == "__target__" or w <= 0:
                continue
            sm = self.sim.source_model(tid)
            if sm is None:
                continue
            src_best = self.kb.get(tid).best()
            models.append(sm)
            incs.append(src_best.performance if src_best else 0.0)
            wts.append(w)
        # one fused pass: shared packed-forest descent + EI matrix + ranks
        agg = aggregate_ranks(score_sources(models, X, incs), wts)
        return pool[int(np.argmin(agg))]
