"""Tuneful (Fekry et al., KDD'20).

Mechanisms reproduced (per §2.1/§7.1/§7.4.2 of MFTune):
  * Incremental significance analysis: every ``shrink_every`` iterations,
    remove 40% of the remaining knobs ranked least important (the paper's
    "Decrease" SC baseline is exactly this mechanism).
  * Multi-task GP transfer: a GP is fitted on the observations of the most
    similar historical task and combined with a GP on the current task's
    observations (similarity- and data-weighted posterior mixing).
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from ..core.acquisition import expected_improvement
from ..core.knowledge import KnowledgeBase
from ..core.similarity import kendall_tau
from ..core.surrogate import GaussianProcess
from .common import BaselineTuner, Budget, Config

__all__ = ["Tuneful"]


class Tuneful(BaselineTuner):
    name = "tuneful"

    def __init__(self, workload, kb: Optional[KnowledgeBase] = None, seed: int = 0,
                 shrink_every: int = 10, drop_frac: float = 0.4):
        super().__init__(workload, kb, seed)
        self.shrink_every = shrink_every
        self.drop_frac = drop_frac
        self.active_space = self.space
        self._source_gp: Optional[GaussianProcess] = None
        self._source_tau: float = 0.0
        self._source_fitted = False

    # ----------------------------------------------------------------- MTGP
    def _fit_source(self) -> None:
        """Pick the most similar source task by Kendall tau on current obs."""
        if self._source_fitted:
            return
        ok = self._ok()
        if len(ok) < 5:
            return
        self._source_fitted = True
        X = self.space.encode_many([o.config for o in ok])
        y = np.array([o.performance for o in ok])
        best_tau, best_task = 0.0, None
        for t in self.kb.source_tasks(self.wl.task_id):
            obs = t.full_fidelity()
            if len(obs) < 8:
                continue
            Xs = self.space.encode_many([o.config for o in obs])
            ys = np.array([o.performance for o in obs])
            try:
                gp = GaussianProcess().fit(Xs[:48], ys[:48])
            except RuntimeError:
                continue
            tau, _ = kendall_tau(gp.predict_mean(X), y)
            if tau > best_tau:
                best_tau, best_task = tau, gp
        if best_task is not None:
            self._source_gp = best_task
            self._source_tau = best_tau

    # -------------------------------------------------------- space shrinking
    def _maybe_shrink(self) -> None:
        ok = self._ok()
        if len(ok) < self.shrink_every or len(ok) % self.shrink_every != 0:
            return
        if len(self.active_space.names) <= 10:
            return
        model = self.fit_surrogate(ok)
        if model is None:
            return
        X = self.space.encode_many([o.config for o in ok])
        rng = np.random.default_rng(self.seed)
        base = model.predict_mean(X)
        names = self.active_space.names
        imp = {}
        for name in names:
            j = self.space.names.index(name)
            Xp = X.copy()
            Xp[:, j] = rng.permutation(Xp[:, j])
            imp[name] = float(np.abs(model.predict_mean(Xp) - base).mean())
        keep_n = max(int(len(names) * (1 - self.drop_frac)), 10)
        keep = sorted(imp, key=lambda n: -imp[n])[:keep_n]
        self.active_space = self.space.restrict(keep=keep)

    # ------------------------------------------------------------------ loop
    def propose(self, budget: Budget) -> Config:
        self._maybe_shrink()
        self._fit_source()
        ok = self._ok()
        # columnar: shrunk-space pool lifted to full space, encoded once
        pool = self.space.complete_batch(self.active_space.sample(self.rng, 192))
        if len(ok) < 2:
            return pool[0]
        X = self.space.encode_many([o.config for o in ok])
        y = np.array([o.performance for o in ok])
        try:
            gp_t = GaussianProcess().fit(X, y)
        except RuntimeError:
            return pool[0]
        Xp = self.space.encode_many(pool)
        mu_t, var_t = gp_t.predict(Xp)
        if self._source_gp is not None and self._source_tau > 0:
            # similarity-weighted posterior mixing; target weight grows with data
            w_s = self._source_tau * max(1.0 - len(ok) / 40.0, 0.1)
            mu_s, var_s = self._source_gp.predict(Xp)
            # source predictions are on a different latency scale: rank-match
            # by z-scoring both means before mixing
            zs = (mu_s - mu_s.mean()) / (mu_s.std() + 1e-9)
            zt = (mu_t - mu_t.mean()) / (mu_t.std() + 1e-9)
            z = (1 - w_s) * zt + w_s * zs
            mu = z * (mu_t.std() + 1e-9) + mu_t.mean()
            var = var_t
        else:
            mu, var = mu_t, var_t
        ei = expected_improvement(mu, var, float(y.min()))
        return pool[int(np.argmax(ei))]
