"""Search-space-compression strategy baselines (paper §7.4.2, Fig. 6).

Each is a callable with the ``MFTuneOptions.compressor`` signature
``(space, weights, tasks, target) -> ConfigSpace`` so it can replace
MFTune's density-based SC component in-place:

  Box      (Perrone et al. '19): minimal axis-aligned box containing the
           best observed config of every previous task.
  Decrease (Tuneful): every 10 target observations, drop 40% of remaining
           knobs by importance rank; no range compression.
  Project  (LlamaTune/TopTune): dimensionality reduction to a random knob
           subset with bucketized (quantized) ranges.
  Vote     (OpAdvisor): per knob, each source votes the [min,max] boundary
           box of its better-than-median configs; the range with majority
           weighted votes wins. Sensitive to outliers by construction.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional

import numpy as np

from ..core.knowledge import TaskRecord
from ..core.similarity import TaskWeights
from ..core.space import BoolKnob, CatKnob, ConfigSpace, FloatKnob, IntKnob, Intervals

__all__ = ["BoxCompressor", "DecreaseCompressor", "ProjectCompressor", "VoteCompressor"]


def _good_configs(task: TaskRecord) -> List[dict]:
    obs = task.full_fidelity()
    if len(obs) < 2:
        return []
    perf = np.array([o.performance for o in obs])
    med = float(np.median(perf))
    return [o.config for o in obs if o.performance < med]


class BoxCompressor:
    def __call__(self, space: ConfigSpace, weights: TaskWeights, tasks: Dict[str, TaskRecord],
                 target: Optional[TaskRecord] = None) -> ConfigSpace:
        bests = []
        for t in tasks.values():
            b = t.best()
            if b is not None:
                bests.append(b.config)
        if not bests:
            return space
        ranges: Dict[str, Intervals] = {}
        cat_subsets: Dict[str, List[Any]] = {}
        for knob in space.knobs:
            vals = [c.get(knob.name, knob.default_value()) for c in bests]
            if isinstance(knob, (FloatKnob, IntKnob)):
                ranges[knob.name] = Intervals([(float(min(vals)), float(max(vals)))])
            else:
                cat_subsets[knob.name] = sorted(set(vals), key=repr)
        return space.restrict(ranges=ranges, cat_subsets=cat_subsets)


class DecreaseCompressor:
    def __init__(self, every: int = 10, drop_frac: float = 0.4, min_knobs: int = 10, seed: int = 0):
        self.every = every
        self.drop_frac = drop_frac
        self.min_knobs = min_knobs
        self.seed = seed
        self._keep: Optional[List[str]] = None
        self._last_n = 0

    def __call__(self, space: ConfigSpace, weights: TaskWeights, tasks: Dict[str, TaskRecord],
                 target: Optional[TaskRecord] = None) -> ConfigSpace:
        from ..core.similarity import surrogate_for_task

        if target is None:
            return space
        obs = target.full_fidelity()
        n = len(obs)
        if self._keep is None:
            self._keep = list(space.names)
        if n >= self.every and n // self.every > self._last_n // self.every and len(self._keep) > self.min_knobs:
            model = surrogate_for_task(space, target, seed=self.seed)
            if model is not None:
                X = space.encode_many([o.config for o in obs])
                rng = np.random.default_rng(self.seed)
                base = model.predict_mean(X)
                imp = {}
                for name in self._keep:
                    j = space.names.index(name)
                    Xp = X.copy()
                    Xp[:, j] = rng.permutation(Xp[:, j])
                    imp[name] = float(np.abs(model.predict_mean(Xp) - base).mean())
                keep_n = max(int(len(self._keep) * (1 - self.drop_frac)), self.min_knobs)
                self._keep = sorted(imp, key=lambda k: -imp[k])[:keep_n]
        self._last_n = n
        return space.restrict(keep=self._keep)


class ProjectCompressor:
    def __init__(self, d_low: int = 16, n_buckets: int = 16, seed: int = 0):
        self.d_low = d_low
        self.n_buckets = n_buckets
        self.seed = seed

    def __call__(self, space: ConfigSpace, weights: TaskWeights, tasks: Dict[str, TaskRecord],
                 target: Optional[TaskRecord] = None) -> ConfigSpace:
        rng = np.random.default_rng(self.seed)  # fixed projection across calls
        keep = list(rng.choice(space.names, size=min(self.d_low, len(space.names)), replace=False))
        ranges: Dict[str, Intervals] = {}
        for knob in space.knobs:
            if knob.name not in keep or not isinstance(knob, (FloatKnob, IntKnob)):
                continue
            # bucketized range: quantize into n_buckets cells (keeps full span
            # but coarse — "lacks granularity to exclude low-potential subspaces")
            edges = np.linspace(float(knob.lo), float(knob.hi), self.n_buckets + 1)
            ranges[knob.name] = Intervals([(float(edges[0]), float(edges[-1]))])
        return space.restrict(keep=keep, ranges=ranges)


class VoteCompressor:
    def __init__(self, vote_threshold: float = 0.5):
        self.vote_threshold = vote_threshold

    def __call__(self, space: ConfigSpace, weights: TaskWeights, tasks: Dict[str, TaskRecord],
                 target: Optional[TaskRecord] = None) -> ConfigSpace:
        boxes: List[tuple] = []  # (weight, {knob: (lo, hi) or set})
        for tid, w in weights.weights.items():
            rec = tasks.get(tid) if tid != "__target__" else target
            if rec is None or w <= 0:
                continue
            good = _good_configs(rec)
            if not good:
                continue
            box: Dict[str, Any] = {}
            for knob in space.knobs:
                vals = [c.get(knob.name, knob.default_value()) for c in good]
                if isinstance(knob, (FloatKnob, IntKnob)):
                    box[knob.name] = (float(min(vals)), float(max(vals)))
                else:
                    box[knob.name] = set(map(repr, vals))
            boxes.append((w, box))
        if not boxes:
            return space
        total_w = sum(w for w, _ in boxes)
        ranges: Dict[str, Intervals] = {}
        cat_subsets: Dict[str, List[Any]] = {}
        for knob in space.knobs:
            if isinstance(knob, (FloatKnob, IntKnob)):
                # grid votes: a cell is kept if boxes covering it weigh > threshold
                grid = np.linspace(float(knob.lo), float(knob.hi), 65)
                mids = 0.5 * (grid[:-1] + grid[1:])
                votes = np.zeros(len(mids))
                for w, box in boxes:
                    lo, hi = box[knob.name]
                    votes += w * ((mids >= lo) & (mids <= hi))
                keep_cells = votes / total_w >= self.vote_threshold
                if keep_cells.any():
                    ivs = []
                    i = 0
                    while i < len(mids):
                        if keep_cells[i]:
                            j = i
                            while j + 1 < len(mids) and keep_cells[j + 1]:
                                j += 1
                            ivs.append((float(grid[i]), float(grid[j + 1])))
                            i = j + 1
                        else:
                            i += 1
                    ranges[knob.name] = Intervals(ivs)
            else:
                counts: Dict[str, float] = {}
                for w, box in boxes:
                    for v in box[knob.name]:
                        counts[v] = counts.get(v, 0.0) + w
                kept_reprs = {v for v, cw in counts.items() if cw / total_w >= self.vote_threshold}
                if kept_reprs:
                    choices = knob.active_choices() if hasattr(knob, "active_choices") else (False, True)
                    kept = [c for c in choices if repr(c) in kept_reprs]
                    if kept:
                        cat_subsets[knob.name] = kept
        return space.restrict(ranges=ranges, cat_subsets=cat_subsets)
