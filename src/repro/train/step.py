"""Step factories: train / prefill / decode programs + their input specs.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input of
an (arch x shape) cell — weak-type-correct, shardable, no allocation —
exactly what the multi-pod dry-run lowers against. Modality frontends
(vision/audio) are stubs: the specs carry precomputed patch/frame
embeddings next to the token stream.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from ..models import (
    Runtime,
    abstract_cache,
    build_param_specs,
    decode_step,
    forward,
    loss_fn,
)
from ..optim import adamw_update

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step", "input_specs"]


def input_specs(cfg: ArchConfig, shape: ShapeConfig, rt: Optional[Runtime] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one cell's step inputs."""
    rt = rt or Runtime()
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    if shape.kind == "train":
        batch: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
        if cfg.family == "encdec":
            batch["enc_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), rt.cdtype)
        if cfg.frontend == "vision":
            # M-RoPE 3D position ids from the (stub) vision frontend
            batch["positions"] = jax.ShapeDtypeStruct((B, S, 3), i32)
        return {"batch": batch}

    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.family == "encdec":
            batch["enc_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), rt.cdtype)
        if cfg.frontend == "vision":
            batch["positions"] = jax.ShapeDtypeStruct((B, S, 3), i32)
        return {"batch": batch}

    # decode: one new token against a seq_len cache
    cache = abstract_cache(cfg, rt, B, S, enc_len=(S if cfg.family == "encdec" else 0))
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        "cache": cache,
    }


def make_train_step(cfg: ArchConfig, rt: Runtime, lr: float = 1e-4):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, rt, batch))(params)
        if rt.grad_compression != "none":
            from ..distributed.compression import compress_grads

            grads = compress_grads(grads, rt.grad_compression)
        new_params, new_state = adamw_update(params, grads, opt_state, lr=lr)
        return new_params, new_state, {"loss": loss}

    return train_step


def make_prefill_step(cfg: ArchConfig, rt: Runtime):
    """(params, batch) -> logits; the cache-building pass is the forward."""

    def prefill_step(params, batch):
        logits = forward(
            params, cfg, rt,
            tokens=batch.get("tokens"),
            inputs_embeds=batch.get("inputs_embeds"),
            positions=batch.get("positions"),
            enc_embeds=batch.get("enc_embeds"),
        )
        return logits

    return prefill_step


def make_decode_step(cfg: ArchConfig, rt: Runtime):
    def serve_step(params, cache, tokens):
        return decode_step(params, cfg, rt, cache, tokens)

    return serve_step
