"""Training loop: steps, checkpoints, preemption safety, metrics.

The loop is deliberately boring — all the interesting behavior lives in
the step function (models/, optim/) and the fault-tolerance machinery
(checkpoint.py, data/pipeline.py). ``Trainer.run`` resumes exactly from
the newest checkpoint (params, opt state, data cursor, RNG), saves every
``save_every`` steps asynchronously, and installs a SIGTERM hook that
commits a final checkpoint before exit (preemption safety).
"""

from __future__ import annotations

import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..data import SyntheticTokenPipeline
from ..models import Runtime, build_param_specs, init_params
from ..optim import adamw_init
from .checkpoint import CheckpointManager
from .step import make_train_step

__all__ = ["Trainer"]


class Trainer:
    def __init__(
        self,
        cfg: ArchConfig,
        rt: Runtime,
        seq_len: int = 256,
        global_batch: int = 8,
        lr: float = 3e-4,
        seed: int = 0,
        ckpt_dir: Optional[str] = None,
        save_every: int = 50,
    ):
        self.cfg = cfg
        self.rt = rt
        self.lr = lr
        self.save_every = save_every
        self.pipeline = SyntheticTokenPipeline(cfg.vocab, seq_len, global_batch, seed=seed)
        key = jax.random.PRNGKey(seed)
        self.params = init_params(build_param_specs(cfg, rt), key)
        self.opt = adamw_init(self.params, dtype=jnp.dtype(rt.opt_state_dtype))
        self.step_fn = jax.jit(make_train_step(cfg, rt, lr=lr), donate_argnums=(0, 1))
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.step = 0
        self._preempted = False

    # ----------------------------------------------------------- persistence
    def _state(self) -> Dict[str, Any]:
        return {"params": self.params, "opt": self.opt}

    def maybe_resume(self) -> bool:
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return False
        state, extra = self.ckpt.restore(self._state())
        state = jax.tree.map(jnp.asarray, state)  # numpy -> device arrays
        self.params, self.opt = state["params"], state["opt"]
        self.step = int(extra["step"])
        self.pipeline.restore(extra["data"])
        return True

    def save(self, block: bool = False) -> None:
        if self.ckpt is None:
            return
        self.ckpt.save(
            self.step, self._state(),
            extra={"step": self.step, "data": self.pipeline.state()},
            block=block,
        )

    def _install_preemption_hook(self) -> None:
        def handler(signum, frame):
            self._preempted = True

        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not the main thread

    # ------------------------------------------------------------------ run
    def run(self, steps: int, log_every: int = 10,
            on_metrics: Optional[Callable[[int, Dict[str, float]], None]] = None):
        self._install_preemption_hook()
        self.maybe_resume()
        losses = []
        t0 = time.perf_counter()
        target = self.step + steps
        while self.step < target and not self._preempted:
            batch = next(self.pipeline)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self.params, self.opt, metrics = self.step_fn(self.params, self.opt, batch)
            self.step += 1
            losses.append(float(metrics["loss"]))
            if self.step % log_every == 0:
                dt = (time.perf_counter() - t0) / log_every
                m = {"loss": float(np.mean(losses[-log_every:])), "s_per_step": dt}
                if on_metrics:
                    on_metrics(self.step, m)
                else:
                    print(f"step {self.step}: loss={m['loss']:.4f} ({dt:.2f}s/step)", flush=True)
                t0 = time.perf_counter()
            if self.ckpt is not None and self.step % self.save_every == 0:
                self.save()
        if self.ckpt is not None:
            self.save(block=True)
            self.ckpt.wait()
        return losses
