"""Fault-tolerant sharded checkpointing.

Design for thousands of nodes (adapted to this container's single host):
  * every leaf is written as one .npy per *logical shard group* — on a real
    multi-host deployment each host writes only its addressable shards
    (no gather through host 0);
  * a manifest (JSON) records the pytree structure, every leaf's logical
    axes and global shape — restore onto a DIFFERENT mesh works because
    shardings are re-derived from the logical axes, not stored device ids
    (elastic re-mesh);
  * commits are atomic: write to step_N.tmp/, fsync, rename to step_N/ —
    a preempted writer never corrupts the latest checkpoint;
  * keep_k garbage collection, newest-first restore, async save thread so
    the training loop overlaps the write with the next step;
  * the data-pipeline cursor and the RNG key ride along in the manifest so
    restart is bit-exact.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path
        )
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep_k: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep_k = keep_k
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Dict[str, Any], extra: Optional[Dict[str, Any]] = None,
             block: bool = False) -> None:
        if self._thread is not None:
            self._thread.join()  # one in-flight save at a time
            self._thread = None
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def _write():
            tmp = os.path.join(self.dir, f"step_{step:010d}.tmp")
            final = os.path.join(self.dir, f"step_{step:010d}")
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "extra": extra or {}, "leaves": []}
            for key, leaf in _flatten_with_paths(host_state):
                fn = key.replace("/", "__") + ".npy"
                np.save(os.path.join(tmp, fn), leaf)
                manifest["leaves"].append(
                    {"key": key, "file": fn, "shape": list(np.shape(leaf)),
                     "dtype": str(np.asarray(leaf).dtype)}
                )
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)  # atomic commit
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_k] if self.keep_k else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Dict[str, Any], step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """Restore into the structure of ``template``. If ``shardings`` is
        given (possibly for a different mesh than at save time), leaves are
        device_put with those shardings — the elastic re-mesh path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_key = {m["key"]: m for m in manifest["leaves"]}

        keys = [k for k, _ in _flatten_with_paths(template)]
        leaves_t, treedef = jax.tree_util.tree_flatten(template)
        sh_leaves = jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(leaves_t)
        restored = []
        for key, tmpl, sh in zip(keys, leaves_t, sh_leaves):
            meta = by_key[key]
            arr = np.load(os.path.join(d, meta["file"]))
            if arr.dtype.kind == "V":
                # extended dtypes (bfloat16) round-trip np.save as raw void
                import jax.numpy as jnp

                arr = arr.view(jnp.dtype(meta["dtype"]))
            if sh is not None:
                arr = jax.device_put(arr, sh)
            restored.append(arr)
        return jax.tree_util.tree_unflatten(treedef, restored), manifest["extra"]
