"""Parameter specification pytrees.

Model code declares parameters as ``ParamSpec`` leaves (shape + dtype +
*logical axis names*). One spec tree serves three consumers:

  * ``abstract_params``  -> ShapeDtypeStruct tree (dry-run: no allocation)
  * ``init_params``      -> real arrays (smoke tests / examples)
  * ``spec_shardings``   -> NamedSharding tree via the logical->mesh rules
                            in distributed/sharding.py

Logical axis vocabulary: "layers" (scanned stack), "embed" (d_model),
"vocab", "heads", "kv_heads", "qk" (per-head q/k dims), "mlp" (d_ff),
"experts", "expert_mlp", "ssm_inner", "state", "conv", "rank" (low-rank),
None (never sharded).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ParamSpec", "abstract_params", "init_params", "spec_shardings", "param_bytes"]


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"       # normal | zeros | ones | scaled (1/sqrt(fan_in))
    fan_in_axis: int = -2

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def abstract_params(specs) -> Any:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=_is_spec
    )


def init_params(specs, key: jax.Array, dtype_override=None) -> Any:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for s, k in zip(leaves, keys):
        dt = dtype_override or s.dtype
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, dt))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, dt))
        else:
            fan_in = s.shape[s.fan_in_axis] if len(s.shape) >= 2 else s.shape[-1]
            scale = 1.0 / math.sqrt(max(fan_in, 1)) if s.init == "scaled" else 0.02
            out.append((jax.random.normal(k, s.shape, jnp.float32) * scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def spec_shardings(specs, mesh, rules: Dict[Optional[str], Any]) -> Any:
    """Map logical axes -> NamedSharding using ``rules`` (see distributed)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def one(s: ParamSpec):
        used: set = set()
        parts = []
        for ax in s.axes:
            mesh_axes = rules.get(ax)
            if mesh_axes is None:
                parts.append(None)
                continue
            if isinstance(mesh_axes, str):
                mesh_axes = (mesh_axes,)
            free = tuple(a for a in mesh_axes if a not in used and a in mesh.axis_names)
            if not free:
                parts.append(None)
                continue
            used.update(free)
            parts.append(free if len(free) > 1 else free[0])
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(one, specs, is_leaf=_is_spec)


def param_bytes(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=_is_spec)
    return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize for s in leaves)
