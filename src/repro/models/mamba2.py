"""Mamba2 (SSD) block — chunked state-space dual form (arXiv:2405.21060).

Training/prefill uses the SSD chunked algorithm: within a chunk the output
is computed in quadratic attention-like form with decay masks; states are
passed across chunks with a lax.scan (the TPU-friendly parallel form —
chunk matmuls hit the MXU, the scan carries only the (H, P, N) state).
Decode is the O(1) recurrent update on a cached state.

Layout: d_inner = expand * d_model, heads H = d_inner / head_dim(P),
state N = d_state. Scalar-identity A (Mamba2 simplification): per-head
decay a_t = exp(-softplus(A) * dt_t).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .params import ParamSpec
from .runtime import Runtime

__all__ = ["mamba2_specs", "mamba2_apply", "mamba2_decode_apply", "mamba2_init_state"]


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    return d_inner, H, s.head_dim, s.d_state


def mamba2_specs(cfg: ArchConfig, stacked: Optional[int] = None, dtype=jnp.bfloat16) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    di, H, P, N = _dims(cfg)
    conv = cfg.ssm.conv_dim
    lead = (stacked,) if stacked else ()
    lx = ("layers",) if stacked else ()
    specs = {
        # fused input projection: [z (gate), x, B, C, dt]
        "w_in": ParamSpec(lead + (d, 2 * di + 2 * H * N + H), lx + ("embed", "ssm_inner"), dtype, "scaled"),
        "w_out": ParamSpec(lead + (di, d), lx + ("ssm_inner", "embed"), dtype, "scaled"),
        "A_log": ParamSpec(lead + (H,), lx + (None,), jnp.float32, "zeros"),
        "D": ParamSpec(lead + (H,), lx + (None,), jnp.float32, "zeros"),
        "dt_bias": ParamSpec(lead + (H,), lx + (None,), jnp.float32, "zeros"),
        "norm": ParamSpec(lead + (di,), lx + ("ssm_inner",), dtype, "ones"),
    }
    if conv:
        specs["w_conv"] = ParamSpec(
            lead + (conv, di + 2 * H * N), lx + (None, "ssm_inner"), dtype, "scaled", fan_in_axis=-2
        )
    return specs


def _split_in(y: jax.Array, cfg: ArchConfig):
    di, H, P, N = _dims(cfg)
    z, x, B, C, dt = jnp.split(y, [di, 2 * di, 2 * di + H * N, 2 * di + 2 * H * N], axis=-1)
    return z, x, B, C, dt


def _causal_conv(xbc: jax.Array, w_conv: jax.Array, state: Optional[jax.Array] = None):
    """Depthwise causal conv over time. xbc: (B, S, F), w_conv: (K, F)."""
    K = w_conv.shape[0]
    pad = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[-1]), xbc.dtype) if state is None else state
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i : i + xbc.shape[1], :] * w_conv[i] for i in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else None
    return jax.nn.silu(out), new_state


def _ssd_chunked(xh, Bh, Ch, a, chunk: int):
    """SSD scan. xh: (B,S,H,P), Bh/Ch: (B,S,H,N), a: (B,S,H) log-decay (<=0).
    Returns (B,S,H,P)."""
    Bsz, S, H, P = xh.shape
    N = Bh.shape[-1]
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    nc = S // chunk
    xc = xh.reshape(Bsz, nc, chunk, H, P).transpose(1, 0, 2, 3, 4)
    Bc = Bh.reshape(Bsz, nc, chunk, H, N).transpose(1, 0, 2, 3, 4)
    Cc = Ch.reshape(Bsz, nc, chunk, H, N).transpose(1, 0, 2, 3, 4)
    ac = a.reshape(Bsz, nc, chunk, H).transpose(1, 0, 2, 3)

    def chunk_step(state, inp):
        xk, Bk, Ck, ak = inp                     # (B,c,H,P/N), (B,c,H)
        cs = jnp.cumsum(ak, axis=1)              # (B,c,H) cumulative log decay
        total = cs[:, -1:, :]                    # (B,1,H)
        # intra-chunk (quadratic attention-like with decay mask)
        rel = cs[:, :, None, :] - cs[:, None, :, :]        # (B, q, k, H)
        causal = jnp.tril(jnp.ones((xk.shape[1], xk.shape[1]), bool))
        L = jnp.where(causal[None, :, :, None], jnp.exp(rel), 0.0)
        s = jnp.einsum("bqhn,bkhn->bqkh", Ck, Bk) * L
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", s.astype(xk.dtype), xk)
        # contribution of the carried state
        decay_q = jnp.exp(cs)                    # (B,c,H)
        y_state = jnp.einsum("bqhn,bhpn->bqhp", Ck * decay_q[..., None], state).astype(xk.dtype)
        # state update: state' = exp(total) * state + sum_k exp(total - cs_k) B_k x_k
        w = jnp.exp(total - cs)                  # (B,c,H)
        state_new = jnp.exp(total)[:, 0, :, None, None] * state + jnp.einsum(
            "bkhn,bkhp->bhpn", (Bk * w[..., None]).astype(jnp.float32), xk.astype(jnp.float32)
        )
        return state_new, y_intra + y_state

    state0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, state0, (xc, Bc, Cc, ac))
    return ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, P)


def mamba2_apply(p: Dict[str, jax.Array], u: jax.Array, cfg: ArchConfig, rt: Runtime) -> jax.Array:
    """u: (B, S, D) -> (B, S, D)."""
    from .blocks import rmsnorm

    di, H, P, N = _dims(cfg)
    B_, S, _ = u.shape
    y = u @ p["w_in"]
    z, x, Bv, Cv, dt = _split_in(y, cfg)
    if cfg.ssm.conv_dim:
        xbc = jnp.concatenate([x, Bv, Cv], axis=-1)
        xbc, _ = _causal_conv(xbc, p["w_conv"])
        x, Bv, Cv = jnp.split(xbc, [di, di + H * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])            # (B,S,H)
    a = -jnp.exp(p["A_log"]) * dt                                          # log decay <= 0
    xh = (x * dt.repeat(P, axis=-1)).astype(u.dtype).reshape(B_, S, H, P)
    Bh = Bv.reshape(B_, S, H, N)
    Ch = Cv.reshape(B_, S, H, N)
    yh = _ssd_chunked(xh, Bh, Ch, a, cfg.ssm.chunk)
    yh = yh + x.reshape(B_, S, H, P) * p["D"][None, None, :, None].astype(u.dtype)
    out = yh.reshape(B_, S, di)
    out = rmsnorm(out, p["norm"]) * jax.nn.silu(z)
    return out @ p["w_out"]


def mamba2_init_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    di, H, P, N = _dims(cfg)
    st = {"ssm": jnp.zeros((batch, H, P, N), jnp.float32)}
    if cfg.ssm.conv_dim:
        st["conv"] = jnp.zeros((batch, cfg.ssm.conv_dim - 1, di + 2 * H * N), dtype)
    return st


def mamba2_decode_apply(p, u, state, cfg: ArchConfig, rt: Runtime):
    """Single-token recurrent update. u: (B, 1, D)."""
    from .blocks import rmsnorm

    di, H, P, N = _dims(cfg)
    B_ = u.shape[0]
    y = u @ p["w_in"]
    z, x, Bv, Cv, dt = _split_in(y, cfg)
    new_state = dict(state)
    if cfg.ssm.conv_dim:
        xbc = jnp.concatenate([x, Bv, Cv], axis=-1)
        xbc, conv_state = _causal_conv(xbc, p["w_conv"], state["conv"])
        new_state["conv"] = conv_state
        x, Bv, Cv = jnp.split(xbc, [di, di + H * N], axis=-1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])      # (B,H)
    a = jnp.exp(-jnp.exp(p["A_log"]) * dt)                                 # (B,H)
    xh = (x[:, 0] * dt.repeat(P, axis=-1)).reshape(B_, H, P)
    Bh = Bv[:, 0].reshape(B_, H, N)
    Ch = Cv[:, 0].reshape(B_, H, N)
    s = state["ssm"] * a[:, :, None, None] + jnp.einsum(
        "bhn,bhp->bhpn", Bh.astype(jnp.float32), xh.astype(jnp.float32)
    )
    new_state["ssm"] = s
    yh = jnp.einsum("bhpn,bhn->bhp", s, Ch.astype(jnp.float32)).astype(u.dtype)
    yh = yh + x[:, 0].reshape(B_, H, P) * p["D"][None, :, None].astype(u.dtype)
    out = yh.reshape(B_, 1, di)
    out = rmsnorm(out, p["norm"]) * jax.nn.silu(z)
    return out @ p["w_out"], new_state
