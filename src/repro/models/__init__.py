from .runtime import Runtime
from .params import ParamSpec, abstract_params, init_params, spec_shardings, param_bytes
from .model import (
    build_param_specs,
    forward,
    decode_step,
    init_cache,
    abstract_cache,
    loss_fn,
)

__all__ = [
    "Runtime", "ParamSpec", "abstract_params", "init_params", "spec_shardings",
    "param_bytes", "build_param_specs", "forward", "decode_step", "init_cache",
    "abstract_cache", "loss_fn",
]
