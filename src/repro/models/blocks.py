"""Shared building blocks: norms, FFN variants, rotary embeddings."""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .params import ParamSpec
from .runtime import Runtime

__all__ = [
    "rmsnorm", "ffn_specs", "ffn_apply", "rope_freqs", "apply_rope",
    "mrope_positions", "with_named_precision",
]


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return ((x32 * rms) * w.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------- FFN


def ffn_specs(d_model: int, d_ff: int, act: str, stacked: Optional[int] = None,
              dtype=jnp.bfloat16) -> Dict[str, ParamSpec]:
    lead = (stacked,) if stacked else ()
    lax = ("layers",) if stacked else ()
    if act == "swiglu":
        return {
            "w_gate": ParamSpec(lead + (d_model, d_ff), lax + ("embed", "mlp"), dtype, "scaled"),
            "w_up": ParamSpec(lead + (d_model, d_ff), lax + ("embed", "mlp"), dtype, "scaled"),
            "w_down": ParamSpec(lead + (d_ff, d_model), lax + ("mlp", "embed"), dtype, "scaled"),
        }
    # two-matrix FFNs: squared-ReLU (Primer / Nemotron-4) or GELU (StarCoder2)
    return {
        "w_up": ParamSpec(lead + (d_model, d_ff), lax + ("embed", "mlp"), dtype, "scaled"),
        "w_down": ParamSpec(lead + (d_ff, d_model), lax + ("mlp", "embed"), dtype, "scaled"),
    }


def ffn_apply(p: Dict[str, jax.Array], x: jax.Array, act: str) -> jax.Array:
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif act == "gelu":
        h = jax.nn.gelu(x @ p["w_up"])
    else:
        r = jax.nn.relu(x @ p["w_up"])
        h = r * r
    return h @ p["w_down"]


# --------------------------------------------------------------------- RoPE


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0,
               mrope_sections: Optional[Tuple[int, ...]] = None) -> jax.Array:
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)
    or (..., seq, 3) for M-RoPE (t/h/w position ids, arXiv:2409.12191)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    if mrope_sections is None:
        ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    else:
        # split the rotary dims into (t, h, w) sections, each section driven
        # by its own position id stream
        secs = []
        start = 0
        for i, n in enumerate(mrope_sections):
            f = freqs[start:start + n]
            secs.append(positions[..., i][..., None].astype(jnp.float32) * f)
            start += n
        ang = jnp.concatenate(secs, axis=-1)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope_positions(batch: int, seq: int) -> jax.Array:
    """Stub 3D positions for the VLM backbone: text-linear in all sections.
    The vision frontend would supply true (t, h, w) ids per patch."""
    p = jnp.arange(seq, dtype=jnp.int32)
    return jnp.broadcast_to(p[None, :, None], (batch, seq, 3))


def with_named_precision(rt: Runtime):
    prec = {"default": None, "high": jax.lax.Precision.HIGH, "highest": jax.lax.Precision.HIGHEST}
    return prec[rt.matmul_precision]


def shard_batch(x: jax.Array, rt: Runtime, seq_dim: int = 1) -> jax.Array:
    """Constrain an activation to batch-DP (+ optional sequence-parallel)
    layout. Without this constraint GSPMD has been observed to propagate a
    d_model-sharded / batch-REPLICATED layout from the FSDP-sharded
    embedding into the whole residual stream (a 16x activation-memory
    regression at dp=16). No-op outside a mesh context (smoke tests)."""
    if not rt.act_shard:
        return x
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
        axes = tuple(a for a in ("pod", "data") if a in sizes)
        if not axes:
            return x
        total = 1
        for a in axes:
            total *= sizes[a]
        spec: list = [None] * x.ndim
        if x.shape[0] % total == 0 and x.shape[0] >= total:
            spec[0] = axes if len(axes) > 1 else axes[0]
        if (
            rt.seq_shard and "model" in sizes and x.ndim >= 3
            and x.shape[seq_dim] % sizes["model"] == 0
        ):
            spec[seq_dim] = "model"
        from jax.sharding import PartitionSpec as P

        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x
