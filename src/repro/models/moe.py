"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch.

TPU-native design: expert weights are stacked (E, D, F) and sharded over
the "model" mesh axis (expert parallelism); tokens are dispatched into a
capacity-bounded (E, C, D) buffer via scatter (XLA SPMD turns the
cross-shard movement into all-to-all), processed with a single batched
einsum per projection (MXU-friendly dense grouped matmul), and combined
back with the routing weights. Shared experts (DeepSeek) run densely.

The capacity factor bounds both memory and the dispatch collective —
dropped tokens fall back to the residual path, as in GShard/Switch.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, MoEConfig
from .params import ParamSpec
from .runtime import Runtime

__all__ = ["moe_specs", "moe_apply"]


def moe_specs(cfg: ArchConfig, stacked: Optional[int] = None, dtype=jnp.bfloat16) -> Dict[str, ParamSpec]:
    e = cfg.moe
    d = cfg.d_model
    f = e.d_ff_expert
    lead = (stacked,) if stacked else ()
    lx = ("layers",) if stacked else ()
    glu = cfg.act == "swiglu"
    specs: Dict[str, ParamSpec] = {
        "router": ParamSpec(lead + (d, e.n_experts), lx + ("embed", None), jnp.float32, "scaled"),
        "w_up": ParamSpec(lead + (e.n_experts, d, f), lx + ("experts", "embed", "expert_mlp"), dtype, "scaled"),
        "w_down": ParamSpec(lead + (e.n_experts, f, d), lx + ("experts", "expert_mlp", "embed"), dtype, "scaled"),
    }
    if glu:
        specs["w_gate"] = ParamSpec(lead + (e.n_experts, d, f), lx + ("experts", "embed", "expert_mlp"), dtype, "scaled")
    if e.n_shared:
        fs = f * e.n_shared
        specs["ws_up"] = ParamSpec(lead + (d, fs), lx + ("embed", "mlp"), dtype, "scaled")
        specs["ws_down"] = ParamSpec(lead + (fs, d), lx + ("mlp", "embed"), dtype, "scaled")
        if glu:
            specs["ws_gate"] = ParamSpec(lead + (d, fs), lx + ("embed", "mlp"), dtype, "scaled")
    return specs


def moe_apply(p: Dict[str, jax.Array], x: jax.Array, cfg: ArchConfig, rt: Runtime) -> jax.Array:
    """x: (B, S, D) -> (B, S, D).

    Capacity positions are assigned *per batch row* ("local groups",
    GShard-style): the cumulative-count scan runs over each row's S*K
    slots independently, so it parallelizes over the (data-sharded) batch
    instead of serializing a global (B*S*K, E) cumsum across the whole
    mesh — the global variant measured 3.5x worse on the collective
    roofline term (§Perf mixtral iteration 1).
    """
    e = cfg.moe
    B, S, D = x.shape
    T = B * S
    glu = cfg.act == "swiglu"

    # ---- routing (fp32 for stability)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, e.top_k)                   # (B, S, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    cf = rt.capacity_factor if rt.capacity_factor is not None else e.capacity_factor
    # per-row capacity; the dispatch buffer is (B, E, Cr, D)
    Cr = max(int(S * e.top_k * cf / e.n_experts), 4)

    # ---- per-row capacity assignment
    row_expert = expert_idx.reshape(B, S * e.top_k)                         # (B, SK)
    onehot = jax.nn.one_hot(row_expert, e.n_experts, dtype=jnp.int32)       # (B, SK, E)
    prior = jnp.cumsum(onehot, axis=1) - onehot
    pos_in_expert = jnp.take_along_axis(prior, row_expert[..., None], axis=2)[..., 0]
    keep = pos_in_expert < Cr
    slot = jnp.where(keep, pos_in_expert, Cr)                               # overflow bucket Cr

    # ---- dispatch: (B, E, Cr+1, D); scatter is row-local
    xt = x.reshape(B, S, D)
    tok_idx = jnp.repeat(jnp.arange(S), e.top_k)                            # (SK,)
    buf = jnp.zeros((B, e.n_experts, Cr + 1, D), x.dtype)
    bidx = jnp.arange(B)[:, None]
    buf = buf.at[bidx, row_expert, slot].add(xt[:, tok_idx, :])
    expert_in = buf[:, :, :Cr, :].transpose(1, 0, 2, 3).reshape(e.n_experts, B * Cr, D)
    C = B * Cr

    # ---- expert FFN (batched over E; "experts" axis is model-sharded)
    if glu:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"])) * jnp.einsum(
            "ecd,edf->ecf", expert_in, p["w_up"]
        )
    else:
        r = jax.nn.relu(jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"]))
        h = r * r
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])                 # (E, C, D)

    # ---- combine: gather back per row + weight
    per_row = expert_out.reshape(e.n_experts, B, Cr, D).transpose(1, 0, 2, 3)  # (B, E, Cr, D)
    padded = jnp.concatenate([per_row, jnp.zeros((B, e.n_experts, 1, D), per_row.dtype)], axis=2)
    gathered = padded[bidx, row_expert, slot]                               # (B, SK, D)
    weighted = gathered * gate_vals.reshape(B, S * e.top_k)[..., None].astype(gathered.dtype)
    out = weighted.reshape(B, S, e.top_k, D).sum(axis=2)

    # ---- shared experts (always-on)
    if e.n_shared:
        if glu:
            hs = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["ws_gate"])) * jnp.einsum(
                "bsd,df->bsf", x, p["ws_up"])
        else:
            r = jax.nn.relu(jnp.einsum("bsd,df->bsf", x, p["ws_up"]))
            hs = r * r
        out = out + jnp.einsum("bsf,fd->bsd", hs, p["ws_down"])

    return out.reshape(B, S, D)
