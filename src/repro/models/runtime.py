"""Runtime (system) configuration — the knobs the framework itself exposes.

These are deliberately the same kind of object as sparksim's Spark knobs:
the jaxwl objective tunes them with MFTune. Everything here changes *how*
a model runs, never *what* it computes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["Runtime"]


@dataclass(frozen=True)
class Runtime:
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    softmax_dtype: str = "float32"
    opt_state_dtype: str = "float32"       # bf16 halves optimizer memory
    matmul_precision: str = "default"      # default | high | highest
    # memory/compute scheduling
    remat: str = "none"                    # none | full | dots | attn
    scan_layers: bool = True
    scan_unroll: int = 1
    # attention
    attn_impl: str = "xla"                 # xla | flash (pallas) | chunked
    attn_chunk: int = 2048                 # kv-chunk for chunked attention
    q_block: int = 512                     # pallas flash block sizes
    kv_block: int = 1024
    # MoE
    moe_impl: str = "dense"                # dense (einsum capacity) | ragged
    capacity_factor: Optional[float] = None  # None => arch default
    # distribution
    dp_size: Optional[int] = None          # None => infer from mesh
    act_shard: bool = True                 # constrain activations to batch-DP
    fsdp: bool = True                      # shard params over data axis (ZeRO-3)
    zero1: bool = True                     # shard optimizer state over data axis
    seq_shard: bool = False                # sequence parallelism for long ctx
    grad_compression: str = "none"         # none | int8 | topk
    overlap_collective_matmul: bool = False
    # pipeline (optional; carved from the data axis)
    pp_stages: int = 1
    pp_microbatches: int = 1

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)
