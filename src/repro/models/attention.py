"""Attention: GQA (causal / bidirectional / sliding-window), MLA, decode.

The training/prefill path is a pure-JAX *flash-style* double-blocked
attention (lax.scan over query blocks, inner scan over KV chunks with
running logsumexp) so that S x S score matrices are never materialized —
required for the 32k prefill cells and the memory roofline, and the
direct XLA analogue of the Pallas flash kernel (kernels/flash_attn).

GQA is computed in grouped layout (B, S, Hkv, G, D) so repeated KV heads
are never materialized.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, MLAConfig
from .blocks import apply_rope
from .params import ParamSpec
from .runtime import Runtime

__all__ = [
    "attention_specs", "attention_apply", "attention_decode_apply",
    "mla_specs", "mla_apply", "mla_decode_apply", "flash_attention_xla",
]

NEG_INF = -1e30


def _blk_mask(qpos: jax.Array, kpos: jax.Array, causal: bool, window: Optional[int]) -> jax.Array:
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    return mask


def _blk_bias(qpos: jax.Array, kpos: jax.Array, causal: bool, window: Optional[int], dt) -> jax.Array:
    """Additive (qc, kc) mask bias. Kept 2-D so XLA hoists at most a tiny
    per-block-pair stack instead of materializing broadcast boolean masks
    at the full (B, qc, H, G, kc) score shape (an observed 8+ GB/device
    pitfall with ``where``-style masking inside nested scans)."""
    return jnp.where(_blk_mask(qpos, kpos, causal, window), 0.0, NEG_INF).astype(dt)


def _flash_fwd(q, k, v, causal, window, q_offset, q_chunk, kv_chunk, sm_dt):
    """Returns (out, lse). Shapes: q (B,Sq,Hkv,G,Dqk), k/v (B,Sk,Hkv,D*)."""
    B, Sq, Hkv, G, Dqk = q.shape
    Sk, Dv = k.shape[1], v.shape[-1]
    scale = 1.0 / (Dqk ** 0.5)
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    qb = q.reshape(B, nq, q_chunk, Hkv, G, Dqk).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(B, nk, kv_chunk, Hkv, Dqk).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, kv_chunk, Hkv, Dv).transpose(1, 0, 2, 3, 4)
    qpb = jnp.arange(q_chunk)
    kpb = jnp.arange(kv_chunk)

    def q_block(_, qi_qblk):
        qi, qblk = qi_qblk

        def kv_step(acc, ki_kv):
            ki, kblk, vblk = ki_kv
            m, l, o = acc
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qblk, kblk).astype(sm_dt) * scale
            bias = _blk_bias(q_offset + qi * q_chunk + qpb, ki * kv_chunk + kpb, causal, window, sm_dt)
            s = s + bias[None, :, None, None, :]
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p.astype(vblk.dtype), vblk
            ).astype(sm_dt)
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, q_chunk, Hkv, G), NEG_INF, sm_dt)
        l0 = jnp.zeros((B, q_chunk, Hkv, G), sm_dt)
        o0 = jnp.zeros((B, q_chunk, Hkv, G, Dv), sm_dt)
        (m, l, o), _ = jax.lax.scan(kv_step, (m0, l0, o0), (jnp.arange(nk), kb, vb))
        out = (o / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_block, None, (jnp.arange(nq), qb))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hkv, G, Dv)
    lse = lses.transpose(1, 0, 2, 3, 4).reshape(B, Sq, Hkv, G)
    return out, lse


def _flash_bwd_impl(q, k, v, o, lse, do, causal, window, q_offset, q_chunk, kv_chunk, sm_dt):
    """FlashAttention-2 backward: recompute p per block from lse; two block
    sweeps (dq over q-blocks; dk/dv over kv-blocks). O(block) live memory."""
    B, Sq, Hkv, G, Dqk = q.shape
    Sk, Dv = k.shape[1], v.shape[-1]
    scale = 1.0 / (Dqk ** 0.5)
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    qb = q.reshape(B, nq, q_chunk, Hkv, G, Dqk).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(B, nk, kv_chunk, Hkv, Dqk).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, kv_chunk, Hkv, Dv).transpose(1, 0, 2, 3, 4)
    dob = do.reshape(B, nq, q_chunk, Hkv, G, Dv).transpose(1, 0, 2, 3, 4, 5)
    lseb = lse.reshape(B, nq, q_chunk, Hkv, G).transpose(1, 0, 2, 3, 4)
    # D_i = rowsum(do * o)
    Dfull = jnp.sum(do.astype(sm_dt) * o.astype(sm_dt), axis=-1)
    Db = Dfull.reshape(B, nq, q_chunk, Hkv, G).transpose(1, 0, 2, 3, 4)
    qpb = jnp.arange(q_chunk)
    kpb = jnp.arange(kv_chunk)

    def p_block(qblk, kblk, lse_i, qi, ki):
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qblk, kblk).astype(sm_dt) * scale
        bias = _blk_bias(q_offset + qi * q_chunk + qpb, ki * kv_chunk + kpb, causal, window, sm_dt)
        return jnp.exp(s + bias[None, :, None, None, :] - lse_i[..., None])

    # ---- pass 1: dq, scanning kv blocks inside each q block
    def dq_block(_, inp):
        qi, qblk, do_i, lse_i, D_i = inp

        def kv_step(dq_acc, ki_kv):
            ki, kblk, vblk = ki_kv
            p = p_block(qblk, kblk, lse_i, qi, ki)
            dp = jnp.einsum("bqhgd,bkhd->bqhgk", do_i.astype(sm_dt), vblk.astype(sm_dt))
            ds = p * (dp - D_i[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum("bqhgk,bkhd->bqhgd", ds, kblk.astype(sm_dt))
            return dq_acc, None

        dq0 = jnp.zeros((B, q_chunk, Hkv, G, Dqk), sm_dt)
        dq_i, _ = jax.lax.scan(kv_step, dq0, (jnp.arange(nk), kb, vb))
        return None, dq_i.astype(q.dtype)

    _, dqs = jax.lax.scan(dq_block, None, (jnp.arange(nq), qb, dob, lseb, Db))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hkv, G, Dqk)

    # ---- pass 2: dk/dv, scanning q blocks inside each kv block
    def dkv_block(_, inp):
        ki, kblk, vblk = inp

        def q_step(acc, qinp):
            qi, qblk, do_i, lse_i, D_i = qinp
            dk_acc, dv_acc = acc
            p = p_block(qblk, kblk, lse_i, qi, ki)
            dv_acc = dv_acc + jnp.einsum("bqhgk,bqhgd->bkhd", p, do_i.astype(sm_dt))
            dp = jnp.einsum("bqhgd,bkhd->bqhgk", do_i.astype(sm_dt), vblk.astype(sm_dt))
            ds = p * (dp - D_i[..., None]) * scale
            dk_acc = dk_acc + jnp.einsum("bqhgk,bqhgd->bkhd", ds, qblk.astype(sm_dt))
            return (dk_acc, dv_acc), None

        dk0 = jnp.zeros((B, kv_chunk, Hkv, Dqk), sm_dt)
        dv0 = jnp.zeros((B, kv_chunk, Hkv, Dv), sm_dt)
        (dk_i, dv_i), _ = jax.lax.scan(q_step, (dk0, dv0), (jnp.arange(nq), qb, dob, lseb, Db))
        return None, (dk_i.astype(k.dtype), dv_i.astype(v.dtype))

    _, (dks, dvs) = jax.lax.scan(dkv_block, None, (jnp.arange(nk), kb, vb))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, Sk, Hkv, Dqk)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, Sk, Hkv, Dv)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash_core(q, k, v, causal, window, q_offset, q_chunk, kv_chunk, sm_name):
    out, _ = _flash_fwd(q, k, v, causal, window, q_offset, q_chunk, kv_chunk, jnp.dtype(sm_name))
    return out


def _flash_core_fwd(q, k, v, causal, window, q_offset, q_chunk, kv_chunk, sm_name):
    out, lse = _flash_fwd(q, k, v, causal, window, q_offset, q_chunk, kv_chunk, jnp.dtype(sm_name))
    return out, (q, k, v, out, lse)


def _flash_core_bwd(causal, window, q_offset, q_chunk, kv_chunk, sm_name, res, do):
    q, k, v, out, lse = res
    dq, dk, dv = _flash_bwd_impl(
        q, k, v, out, lse, do, causal, window, q_offset, q_chunk, kv_chunk, jnp.dtype(sm_name)
    )
    return dq, dk, dv


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def flash_attention_xla(
    q: jax.Array,           # (B, Sq, Hkv, G, Dqk)
    k: jax.Array,           # (B, Sk, Hkv, Dqk)
    v: jax.Array,           # (B, Sk, Hkv, Dv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,       # absolute position of q[0] (prefill continuation)
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    softmax_dtype=jnp.float32,
) -> jax.Array:
    """Memory-efficient attention (custom VJP; backward recomputes the
    probability blocks from lse — FlashAttention-2 semantics in XLA).
    Returns (B, Sq, Hkv, G, Dv)."""
    B, Sq = q.shape[:2]
    Sk = k.shape[1]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    while Sq % q_chunk:
        q_chunk //= 2
    while Sk % kv_chunk:
        kv_chunk //= 2
    return _flash_core(q, k, v, causal, window, q_offset, q_chunk, kv_chunk,
                       jnp.dtype(softmax_dtype).name)


# ------------------------------------------------------------------ GQA block


def attention_specs(cfg: ArchConfig, stacked: Optional[int] = None, dtype=jnp.bfloat16,
                    cross: bool = False) -> Dict[str, ParamSpec]:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    lead = (stacked,) if stacked else ()
    lax_ = ("layers",) if stacked else ()
    return {
        "wq": ParamSpec(lead + (d, hq, hd), lax_ + ("embed", "heads", "qk"), dtype, "scaled", fan_in_axis=-3),
        "wk": ParamSpec(lead + (d, hkv, hd), lax_ + ("embed", "kv_heads", "qk"), dtype, "scaled", fan_in_axis=-3),
        "wv": ParamSpec(lead + (d, hkv, hd), lax_ + ("embed", "kv_heads", "qk"), dtype, "scaled", fan_in_axis=-3),
        "wo": ParamSpec(lead + (hq, hd, d), lax_ + ("heads", "qk", "embed"), dtype, "scaled", fan_in_axis=-2),
    }


def _project_qkv(p, x, cfg: ArchConfig, positions, rt: Runtime, kv_x=None, rope=True):
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    g = hq // hkv
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", src, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", src, p["wv"])
    if rope and cfg.rope != "none":
        sections = (16, 24, 24) if cfg.rope == "mrope" else None
        if cfg.rope == "mrope":
            q = apply_rope(q, positions, mrope_sections=sections)
            k = apply_rope(k, positions, mrope_sections=sections)
        else:
            q = apply_rope(q, positions)
            k = apply_rope(k, positions)
    B, S = x.shape[:2]
    q = q.reshape(B, S, hkv, g, cfg.head_dim)
    return q, k, v


def attention_apply(
    p: Dict[str, jax.Array],
    x: jax.Array,
    cfg: ArchConfig,
    rt: Runtime,
    positions: jax.Array,
    causal: bool = True,
    kv_x: Optional[jax.Array] = None,   # cross-attention source
) -> jax.Array:
    use_rope = kv_x is None and cfg.rope != "none"
    if kv_x is None:
        kv_positions = positions
    q, k, v = _project_qkv(p, x, cfg, positions, rt, kv_x=kv_x, rope=use_rope)
    if rt.attn_impl == "flash":
        from ..kernels.flash_attn import ops as flash_ops

        o = flash_ops.flash_attention(
            q, k, v, causal=causal and kv_x is None, window=cfg.window,
            q_block=rt.q_block, kv_block=rt.kv_block,
        )
    else:
        o = flash_attention_xla(
            q, k, v,
            causal=causal and kv_x is None,
            window=cfg.window,
            q_chunk=rt.attn_chunk, kv_chunk=rt.attn_chunk,
            softmax_dtype=jnp.dtype(rt.softmax_dtype),
        )
    B, S = x.shape[:2]
    o = o.reshape(B, S, cfg.n_heads, cfg.head_dim)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"])


def attention_decode_apply(
    p: Dict[str, jax.Array],
    x: jax.Array,                     # (B, 1, D)
    cache: Dict[str, jax.Array],      # {"k": (B, S, Hkv, hd), "v": ..., "pos": (B,)}
    cfg: ArchConfig,
    rt: Runtime,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B = x.shape[0]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = hq // hkv
    pos = cache["pos"]                # (B,) current length
    S = cache["k"].shape[1]
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    if cfg.rope != "none":
        posb = pos[:, None]
        if cfg.rope == "mrope":
            pos3 = jnp.broadcast_to(posb[..., None], (B, 1, 3))
            q = apply_rope(q, pos3, mrope_sections=(16, 24, 24))
            k = apply_rope(k, pos3, mrope_sections=(16, 24, 24))
        else:
            q = apply_rope(q, posb)
            k = apply_rope(k, posb)
    # ring-buffer write (sliding window) or linear write
    if cfg.window is not None and S == cfg.window:
        slot = pos % S
    else:
        slot = jnp.minimum(pos, S - 1)
    bidx = jnp.arange(B)
    knew = cache["k"].at[bidx, slot].set(k[:, 0])
    vnew = cache["v"].at[bidx, slot].set(v[:, 0])
    # attend: q (B,hkv,g,hd) over knew (B,S,hkv,hd)
    qg = q.reshape(B, hkv, g, hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, knew)
    s = (s.astype(jnp.float32)) / (hd ** 0.5)
    kpos = jnp.arange(S)[None, :]                          # (1, S)
    if cfg.window is not None and S == cfg.window:
        valid = kpos < jnp.minimum(pos + 1, S)[:, None]    # ring: all written slots valid
    else:
        valid = kpos <= pos[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1).astype(vnew.dtype)
    o = jnp.einsum("bhgk,bkhd->bhgd", a, vnew).reshape(B, 1, hq, hd)
    out = jnp.einsum("bshe,hed->bsd", o, p["wo"])
    return out, {"k": knew, "v": vnew, "pos": pos + 1}


# ----------------------------------------------------------------------- MLA


def mla_specs(cfg: ArchConfig, stacked: Optional[int] = None, dtype=jnp.bfloat16) -> Dict[str, ParamSpec]:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    lead = (stacked,) if stacked else ()
    lx = ("layers",) if stacked else ()
    return {
        "w_dq": ParamSpec(lead + (d, m.q_lora_rank), lx + ("embed", "rank"), dtype, "scaled"),
        "q_norm": ParamSpec(lead + (m.q_lora_rank,), lx + ("rank",), dtype, "ones"),
        "w_uq": ParamSpec(lead + (m.q_lora_rank, h, m.qk_nope_head_dim + m.qk_rope_head_dim),
                          lx + ("rank", "heads", "qk"), dtype, "scaled", fan_in_axis=-3),
        "w_dkv": ParamSpec(lead + (d, m.kv_lora_rank + m.qk_rope_head_dim), lx + ("embed", "rank"), dtype, "scaled"),
        "kv_norm": ParamSpec(lead + (m.kv_lora_rank,), lx + ("rank",), dtype, "ones"),
        "w_uk": ParamSpec(lead + (m.kv_lora_rank, h, m.qk_nope_head_dim),
                          lx + ("rank", "heads", "qk"), dtype, "scaled", fan_in_axis=-3),
        "w_uv": ParamSpec(lead + (m.kv_lora_rank, h, m.v_head_dim),
                          lx + ("rank", "heads", "qk"), dtype, "scaled", fan_in_axis=-3),
        "wo": ParamSpec(lead + (h, m.v_head_dim, d), lx + ("heads", "qk", "embed"), dtype, "scaled", fan_in_axis=-2),
    }


def _mla_qkv(p, x, cfg: ArchConfig, positions):
    from .blocks import rmsnorm

    m = cfg.mla
    B, S, _ = x.shape
    h = cfg.n_heads
    cq = rmsnorm(x @ p["w_dq"], p["q_norm"])                       # (B,S,rq)
    q = jnp.einsum("bsr,rhe->bshe", cq, p["w_uq"])                 # (B,S,H,nope+rope)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions)
    ckv_full = x @ p["w_dkv"]                                      # (B,S,rkv+rope)
    c_kv, k_rope = jnp.split(ckv_full, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(c_kv, p["kv_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions)          # (B,S,1,rope)
    return q_nope, q_rope, c_kv, k_rope


def mla_apply(p, x, cfg: ArchConfig, rt: Runtime, positions, causal: bool = True) -> jax.Array:
    """Prefill/train MLA: materialize per-head K/V from the latent."""
    m = cfg.mla
    B, S, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uv"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)                 # (B,S,H,192)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, h, m.qk_rope_head_dim))], axis=-1)
    qg = q.reshape(B, S, h, 1, q.shape[-1])                        # Hkv = H (kv=128)
    o = flash_attention_xla(
        qg, k, v, causal=causal, q_chunk=rt.attn_chunk, kv_chunk=rt.attn_chunk,
        softmax_dtype=jnp.dtype(rt.softmax_dtype),
    ).reshape(B, S, h, m.v_head_dim)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"])


def mla_decode_apply(p, x, cache, cfg: ArchConfig, rt: Runtime):
    """Absorbed-matmul MLA decode: attention runs in the 512-d latent space;
    the cache holds only (c_kv, k_rope) — the MLA memory win."""
    m = cfg.mla
    B = x.shape[0]
    h = cfg.n_heads
    pos = cache["pos"]
    S = cache["c_kv"].shape[1]
    posb = pos[:, None]
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(p, x, cfg, posb)
    bidx = jnp.arange(B)
    slot = jnp.minimum(pos, S - 1)
    ckv = cache["c_kv"].at[bidx, slot].set(c_kv_new[:, 0])
    krope = cache["k_rope"].at[bidx, slot].set(k_rope_new[:, 0, 0])
    # absorb W_uk into q: q_lat (B,1,H,rkv)
    q_lat = jnp.einsum("bshe,rhe->bshr", q_nope, p["w_uk"])
    s = jnp.einsum("bhr,bkr->bhk", q_lat[:, 0], ckv)
    s = s + jnp.einsum("bhe,bke->bhk", q_rope[:, 0], krope)
    s = s.astype(jnp.float32) / ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** 0.5)
    valid = jnp.arange(S)[None, :] <= pos[:, None]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1).astype(ckv.dtype)
    ctx = jnp.einsum("bhk,bkr->bhr", a, ckv)                       # latent context
    o = jnp.einsum("bhr,rhe->bhe", ctx, p["w_uv"])                 # (B,H,v_dim)
    out = jnp.einsum("bhe,hed->bd", o, p["wo"])[:, None, :]
    return out, {"c_kv": ckv, "k_rope": krope, "pos": pos + 1}
