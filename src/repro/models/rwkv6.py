"""RWKV-6 "Finch" block (arXiv:2404.05892) — attention-free, data-dependent
per-channel decay.

Time-mixing: r/k/v/g projections with token-shift interpolation; the WKV
recurrence  S_t = diag(w_t) S_{t-1} + k_t^T v_t,  y_t = (r_t S_t) with a
per-head bonus term u for the current token. Computed in chunked parallel
form: within-chunk quadratic form with decay products, state carried
across chunks by lax.scan (same TPU pattern as SSD). Decode is the O(1)
recurrence. Channel-mixing is the RWKV squared-ReLU FFN with token shift.

Simplifications vs. the reference implementation (documented in
DESIGN.md §9): the low-rank LoRA generators for decay/token-shift are
collapsed into single linear maps; per-head LayerNorm on the output is
RMSNorm. The recurrence itself is exact.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .params import ParamSpec
from .runtime import Runtime

__all__ = ["rwkv6_specs", "rwkv6_apply", "rwkv6_decode_apply", "rwkv6_init_state"]


def _dims(cfg: ArchConfig):
    H = cfg.n_heads
    K = cfg.d_model // H
    return H, K


def rwkv6_specs(cfg: ArchConfig, stacked: Optional[int] = None, dtype=jnp.bfloat16) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    H, K = _dims(cfg)
    lead = (stacked,) if stacked else ()
    lx = ("layers",) if stacked else ()
    return {
        "w_r": ParamSpec(lead + (d, d), lx + ("embed", "heads"), dtype, "scaled"),
        "w_k": ParamSpec(lead + (d, d), lx + ("embed", "heads"), dtype, "scaled"),
        "w_v": ParamSpec(lead + (d, d), lx + ("embed", "heads"), dtype, "scaled"),
        "w_g": ParamSpec(lead + (d, d), lx + ("embed", "heads"), dtype, "scaled"),
        "w_decay": ParamSpec(lead + (d, d), lx + ("embed", "heads"), dtype, "scaled"),
        "u_bonus": ParamSpec(lead + (H, K), lx + (None, None), jnp.float32, "zeros"),
        "mix": ParamSpec(lead + (5, d), lx + (None, "embed"), dtype, "zeros"),  # token-shift mixes
        "w_o": ParamSpec(lead + (d, d), lx + ("heads", "embed"), dtype, "scaled"),
        "ln_x": ParamSpec(lead + (d,), lx + ("embed",), dtype, "ones"),
    }


def _token_shift(x: jax.Array, prev: Optional[jax.Array] = None) -> jax.Array:
    """x_{t-1} stream; prev: (B, 1, D) carried last token for decode."""
    if prev is None:
        pad = jnp.zeros_like(x[:, :1])
        return jnp.concatenate([pad, x[:, :-1]], axis=1)
    return prev


def _wkv_chunked(r, k, v, w, u, chunk: int):
    """r,k,v: (B,S,H,K); w: (B,S,H,K) log-decay (<=0); u: (H,K) bonus.
    Returns (B,S,H,K)."""
    B, S, H, K = r.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    nc = S // chunk

    def resh(x):
        return x.reshape(B, nc, chunk, H, K).transpose(1, 0, 2, 3, 4)

    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(w)

    def chunk_step(state, inp):
        rk, kk, vk, wk = inp                           # (B,c,H,K)
        rk32 = rk.astype(jnp.float32)
        kk32 = kk.astype(jnp.float32)
        vk32 = vk.astype(jnp.float32)
        cs = jnp.cumsum(wk, axis=1)                    # cumulative log decay (<= 0)
        total = cs[:, -1, :, :]                        # (B,H,K)
        # state contribution: decay from chunk start to t-1 applied to r
        decay_q = jnp.exp(cs - wk)
        y_state = jnp.einsum("bqhk,bhkv->bqhv", rk32 * decay_q, state)
        # intra-chunk pairwise decay exp(cs_q - w_q - cs_s) is SEPARABLE:
        # fold exp(cs_q - w_q - m) into r and exp(m - cs_s) into k (m = a
        # per-channel midpoint shift keeping both factors in f32 range)
        # instead of materializing a (B,c,c,H,K) tensor.
        m = 0.5 * (total - wk[:, 0])                   # (B,H,K)-ish midpoint
        r_f = rk32 * jnp.exp(cs - wk - m[:, None])
        k_f = kk32 * jnp.exp(m[:, None] - cs)
        # bf16 operands + f32 accumulation: halves the dominant HBM traffic
        # and maps onto the MXU (§Perf rwkv6 iteration 2; decay factors are
        # bounded by the clamp in _time_mix so bf16 range is safe)
        att = jnp.einsum("bqhk,bshk->bqsh", r_f.astype(jnp.bfloat16),
                         k_f.astype(jnp.bfloat16), preferred_element_type=jnp.float32)
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)
        att = att * tri[None, :, :, None]
        y_intra = jnp.einsum("bqsh,bshv->bqhv", att.astype(jnp.bfloat16),
                             vk.astype(jnp.bfloat16), preferred_element_type=jnp.float32)
        # current-token bonus u
        cur = (rk32 * u[None, None] * kk32).sum(-1, keepdims=True)   # (B,c,H,1)
        y_bonus = cur * vk32
        # state update: S' = diag(exp(total)) S + sum_s exp(total - cs_s) k_s v_s
        wts = jnp.exp(total[:, None] - cs)             # (B,c,H,K)
        state_new = jnp.exp(total)[..., None] * state + jnp.einsum(
            "bshk,bshv->bhkv", kk32 * wts, vk32
        )
        return state_new, (y_state + y_intra + y_bonus).astype(r.dtype)

    state0 = jnp.zeros((B, H, K, K), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, state0, (rc, kc, vc, wc))
    return ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, K)


def _time_mix(p, x, cfg: ArchConfig, rt: Runtime, shifted):
    from .blocks import rmsnorm

    H, K = _dims(cfg)
    B, S, D = x.shape
    mix = p["mix"]  # (5, D) in [~0]: learned interpolation toward shifted
    def lerp(i):
        lam = jax.nn.sigmoid(mix[i]).astype(x.dtype)
        return x + (shifted - x) * lam

    r = (lerp(0) @ p["w_r"]).reshape(B, S, H, K)
    kk = (lerp(1) @ p["w_k"]).reshape(B, S, H, K)
    v = (lerp(2) @ p["w_v"]).reshape(B, S, H, K)
    g = jax.nn.silu(lerp(3) @ p["w_g"])
    # data-dependent decay (Finch): w_t = -softplus(decay(x)) (log space).
    # Floored at -2.0/step so the separable intra-chunk factorization stays
    # within f32 range at chunk<=64 (exp(2*64) ~ 1e55 would overflow; the
    # midpoint shift halves the exponent: exp(64) ~ 6e27 is safe).
    w = -jax.nn.softplus((lerp(4) @ p["w_decay"]).astype(jnp.float32)).reshape(B, S, H, K) - 0.1
    w = jnp.maximum(w, -2.0)
    return r, kk, v, g, w


def rwkv6_apply(p: Dict[str, jax.Array], x: jax.Array, cfg: ArchConfig, rt: Runtime) -> jax.Array:
    from .blocks import rmsnorm

    H, K = _dims(cfg)
    B, S, D = x.shape
    shifted = _token_shift(x)
    r, kk, v, g, w = _time_mix(p, x, cfg, rt, shifted)
    y = _wkv_chunked(r, kk, v, w, p["u_bonus"], cfg.ssm.chunk if cfg.ssm else 128)
    y = y.reshape(B, S, D)
    y = rmsnorm(y, p["ln_x"]) * g
    return y @ p["w_o"]


def rwkv6_init_state(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16):
    H, K = _dims(cfg)
    return {
        "wkv": jnp.zeros((batch, H, K, K), jnp.float32),
        "shift": jnp.zeros((batch, 1, cfg.d_model), dtype),
    }


def rwkv6_decode_apply(p, x, state, cfg: ArchConfig, rt: Runtime):
    from .blocks import rmsnorm

    H, K = _dims(cfg)
    B = x.shape[0]
    shifted = _token_shift(x, state["shift"])
    r, kk, v, g, w = _time_mix(p, x, cfg, rt, shifted)
    r1, k1, v1, w1 = r[:, 0], kk[:, 0], v[:, 0], w[:, 0]       # (B,H,K)
    S = state["wkv"]
    # output uses state + bonus on current token
    cur = (r1 * p["u_bonus"][None] * k1).sum(-1, keepdims=True)  # (B,H,1)
    y = jnp.einsum("bhk,bhkv->bhv", r1.astype(jnp.float32), S) + cur.astype(jnp.float32) * v1.astype(jnp.float32)
    S_new = jnp.exp(w1.astype(jnp.float32))[..., None] * S + jnp.einsum(
        "bhk,bhv->bhkv", k1.astype(jnp.float32), v1.astype(jnp.float32)
    )
    y = y.reshape(B, 1, cfg.d_model).astype(x.dtype)
    y = rmsnorm(y, p["ln_x"]) * g
    return y @ p["w_o"], {"wkv": S_new, "shift": x}
