"""Model assembly: param specs, forward, decode, loss for all 10 archs.

Layer stacks are *stacked* (leading "layers" axis) and driven by lax.scan
(compile-time and HLO-size control at 96 layers); remat policy wraps the
scanned body. The hybrid (zamba2) interleaves scanned Mamba2 groups with a
parameter-shared attention block; the enc-dec runs an encoder stack then a
decoder stack with cross-attention.

Decode paths operate on a cache pytree (stacked over layers, scanned) —
KV for attention archs, compressed latents for MLA, O(1) states for
SSM/RWKV, ring buffers for sliding-window.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import (
    attention_apply,
    attention_decode_apply,
    attention_specs,
    mla_apply,
    mla_decode_apply,
    mla_specs,
)
from .blocks import ffn_apply, ffn_specs, mrope_positions, rmsnorm, shard_batch
from .mamba2 import (
    mamba2_apply,
    mamba2_decode_apply,
    mamba2_init_state,
    mamba2_specs,
)
from .moe import moe_apply, moe_specs
from .params import ParamSpec, abstract_params, init_params
from .runtime import Runtime
from .rwkv6 import rwkv6_apply, rwkv6_decode_apply, rwkv6_init_state, rwkv6_specs

__all__ = [
    "build_param_specs", "forward", "decode_step", "init_cache",
    "abstract_cache", "loss_fn",
]


def _ln(stacked: Optional[int], d: int, dtype=jnp.bfloat16) -> ParamSpec:
    lead = (stacked,) if stacked else ()
    lx = ("layers",) if stacked else ()
    return ParamSpec(lead + (d,), lx + ("embed",), dtype, "ones")


def _remat(fn, rt: Runtime):
    if rt.remat == "none":
        return fn
    if rt.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)  # "full"


# =========================================================== param specs


def build_param_specs(cfg: ArchConfig, rt: Optional[Runtime] = None):
    rt = rt or Runtime()
    dt = rt.pdtype
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    specs: Dict[str, Any] = {
        "embed": ParamSpec((V, d), ("vocab", "embed"), dt, "normal"),
        "final_ln": _ln(None, d, dt),
    }
    if not cfg.tie_embeddings:
        specs["out"] = ParamSpec((V, d), ("vocab", "embed"), dt, "scaled", fan_in_axis=-1)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        specs["blocks"] = {
            "attn": attention_specs(cfg, stacked=L, dtype=dt),
            "ffn": ffn_specs(d, cfg.d_ff, cfg.act, stacked=L, dtype=dt),
            "ln1": _ln(L, d, dt),
            "ln2": _ln(L, d, dt),
        }
    elif fam == "moe":
        nd = cfg.moe.first_dense_layers
        nm = L - nd
        attn_fn = mla_specs if cfg.mla is not None else attention_specs
        if nd:
            specs["dense_blocks"] = {
                "attn": attn_fn(cfg, stacked=nd, dtype=dt),
                "ffn": ffn_specs(d, cfg.d_ff, cfg.act, stacked=nd, dtype=dt),
                "ln1": _ln(nd, d, dt),
                "ln2": _ln(nd, d, dt),
            }
        specs["blocks"] = {
            "attn": attn_fn(cfg, stacked=nm, dtype=dt),
            "moe": moe_specs(cfg, stacked=nm, dtype=dt),
            "ln1": _ln(nm, d, dt),
            "ln2": _ln(nm, d, dt),
        }
        if cfg.mtp_depth:
            specs["mtp"] = {
                "proj": ParamSpec((2 * d, d), ("embed", "embed"), dt, "scaled"),
                "attn": attn_fn(cfg, stacked=None, dtype=dt),
                "ffn": ffn_specs(d, cfg.moe.d_ff_expert, cfg.act, stacked=None, dtype=dt),
                "ln1": _ln(None, d, dt),
                "ln2": _ln(None, d, dt),
                "ln_h": _ln(None, d, dt),
                "ln_e": _ln(None, d, dt),
            }
    elif fam == "ssm":  # rwkv6
        specs["blocks"] = {
            "tmix": rwkv6_specs(cfg, stacked=L, dtype=dt),
            "cmix": {
                "w_k": ParamSpec((L, d, cfg.d_ff), ("layers", "embed", "mlp"), dt, "scaled"),
                "w_v": ParamSpec((L, cfg.d_ff, d), ("layers", "mlp", "embed"), dt, "scaled"),
                "w_r": ParamSpec((L, d, d), ("layers", "embed", "heads"), dt, "scaled"),
                "mix": ParamSpec((L, 2, d), ("layers", None, "embed"), dt, "zeros"),
            },
            "ln1": _ln(L, d, dt),
            "ln2": _ln(L, d, dt),
        }
    elif fam == "hybrid":  # zamba2
        specs["blocks"] = {
            "mamba": mamba2_specs(cfg, stacked=L, dtype=dt),
            "ln": _ln(L, d, dt),
        }
        specs["shared_attn"] = {
            "attn": attention_specs(cfg, stacked=None, dtype=dt),
            "ffn": ffn_specs(d, cfg.d_ff, cfg.act, stacked=None, dtype=dt),
            "ln1": _ln(None, d, dt),
            "ln2": _ln(None, d, dt),
        }
    elif fam == "encdec":
        Le = cfg.n_encoder_layers
        specs["enc_blocks"] = {
            "attn": attention_specs(cfg, stacked=Le, dtype=dt),
            "ffn": ffn_specs(d, cfg.d_ff, cfg.act, stacked=Le, dtype=dt),
            "ln1": _ln(Le, d, dt),
            "ln2": _ln(Le, d, dt),
        }
        specs["blocks"] = {
            "attn": attention_specs(cfg, stacked=L, dtype=dt),
            "xattn": attention_specs(cfg, stacked=L, dtype=dt, cross=True),
            "ffn": ffn_specs(d, cfg.d_ff, cfg.act, stacked=L, dtype=dt),
            "ln1": _ln(L, d, dt),
            "ln2": _ln(L, d, dt),
            "ln3": _ln(L, d, dt),
        }
        specs["enc_ln"] = _ln(None, d, dt)
    else:
        raise ValueError(fam)
    return specs


# =============================================================== forward


def _rwkv_cmix(p, x, prev=None):
    from .rwkv6 import _token_shift

    shifted = _token_shift(x, prev)
    lam_k = jax.nn.sigmoid(p["mix"][0]).astype(x.dtype)
    lam_r = jax.nn.sigmoid(p["mix"][1]).astype(x.dtype)
    xk = x + (shifted - x) * lam_k
    xr = x + (shifted - x) * lam_r
    k = jax.nn.relu(xk @ p["w_k"])
    return jax.nn.sigmoid(xr @ p["w_r"]) * ((k * k) @ p["w_v"])


def _scan_stack(fn, x, stacked_params, rt: Runtime):
    def constrained(h, p):
        return shard_batch(fn(shard_batch(h, rt), p), rt)

    body = _remat(constrained, rt)
    if rt.scan_layers:
        x, _ = jax.lax.scan(lambda h, p: (body(h, p), None), x, stacked_params,
                            unroll=rt.scan_unroll)
        return x
    n = jax.tree.leaves(stacked_params)[0].shape[0]
    for i in range(n):
        x = body(x, jax.tree.map(lambda a: a[i], stacked_params))
    return x


def forward(
    params,
    cfg: ArchConfig,
    rt: Runtime,
    tokens: Optional[jax.Array] = None,       # (B, S) int32
    inputs_embeds: Optional[jax.Array] = None, # (B, S, D) modality stub
    positions: Optional[jax.Array] = None,
    enc_embeds: Optional[jax.Array] = None,    # enc-dec encoder input
    causal: bool = True,
    return_hidden: bool = False,
) -> jax.Array:
    """Returns logits (B, S, V). For enc-dec, ``tokens`` are decoder tokens."""
    if inputs_embeds is not None:
        x = inputs_embeds.astype(rt.cdtype)
        B, S = x.shape[:2]
    else:
        x = params["embed"][tokens].astype(rt.cdtype)
        B, S = tokens.shape
    x = shard_batch(x, rt)
    if positions is None:
        if cfg.rope == "mrope":
            positions = mrope_positions(B, S)
        else:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    fam = cfg.family
    if fam in ("dense", "vlm"):
        def blk(h, p):
            h = h + attention_apply(p["attn"], rmsnorm(h, p["ln1"], cfg.norm_eps), cfg, rt, positions, causal)
            h = h + ffn_apply(p["ffn"], rmsnorm(h, p["ln2"], cfg.norm_eps), cfg.act)
            return h
        x = _scan_stack(blk, x, params["blocks"], rt)

    elif fam == "moe":
        attn = mla_apply if cfg.mla is not None else attention_apply
        if "dense_blocks" in params:
            def dblk(h, p):
                h = h + attn(p["attn"], rmsnorm(h, p["ln1"], cfg.norm_eps), cfg, rt, positions, causal)
                h = h + ffn_apply(p["ffn"], rmsnorm(h, p["ln2"], cfg.norm_eps), cfg.act)
                return h
            x = _scan_stack(dblk, x, params["dense_blocks"], rt)

        def mblk(h, p):
            h = h + attn(p["attn"], rmsnorm(h, p["ln1"], cfg.norm_eps), cfg, rt, positions, causal)
            h = h + moe_apply(p["moe"], rmsnorm(h, p["ln2"], cfg.norm_eps), cfg, rt)
            return h
        x = _scan_stack(mblk, x, params["blocks"], rt)

    elif fam == "ssm":
        def blk(h, p):
            h = h + rwkv6_apply(p["tmix"], rmsnorm(h, p["ln1"], cfg.norm_eps), cfg, rt)
            h = h + _rwkv_cmix(p["cmix"], rmsnorm(h, p["ln2"], cfg.norm_eps))
            return h
        x = _scan_stack(blk, x, params["blocks"], rt)

    elif fam == "hybrid":
        every = cfg.attn_every or cfg.n_layers
        groups = cfg.n_layers // every
        gp = jax.tree.map(
            lambda a: a.reshape((groups, every) + a.shape[1:]), params["blocks"]
        )
        sa = params["shared_attn"]

        def mblk(h, p):
            return h + mamba2_apply(p["mamba"], rmsnorm(h, p["ln"], cfg.norm_eps), cfg, rt)

        for g in range(groups):
            x = _scan_stack(mblk, x, jax.tree.map(lambda a: a[g], gp), rt)
            x = x + attention_apply(sa["attn"], rmsnorm(x, sa["ln1"], cfg.norm_eps), cfg, rt, positions, causal)
            x = x + ffn_apply(sa["ffn"], rmsnorm(x, sa["ln2"], cfg.norm_eps), cfg.act)

    elif fam == "encdec":
        assert enc_embeds is not None, "enc-dec needs encoder inputs"
        e = enc_embeds.astype(rt.cdtype)
        Be, Se = e.shape[:2]
        epos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32)[None], (Be, Se))

        def eblk(h, p):
            h = h + attention_apply(p["attn"], rmsnorm(h, p["ln1"], cfg.norm_eps), cfg, rt, epos, causal=False)
            h = h + ffn_apply(p["ffn"], rmsnorm(h, p["ln2"], cfg.norm_eps), cfg.act)
            return h
        e = _scan_stack(eblk, e, params["enc_blocks"], rt)
        e = rmsnorm(e, params["enc_ln"], cfg.norm_eps)

        def dblk(h, p):
            h = h + attention_apply(p["attn"], rmsnorm(h, p["ln1"], cfg.norm_eps), cfg, rt, positions, causal=True)
            h = h + attention_apply(p["xattn"], rmsnorm(h, p["ln3"], cfg.norm_eps), cfg, rt, positions, causal=False, kv_x=e)
            h = h + ffn_apply(p["ffn"], rmsnorm(h, p["ln2"], cfg.norm_eps), cfg.act)
            return h
        x = _scan_stack(dblk, x, params["blocks"], rt)
    else:
        raise ValueError(fam)

    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    if return_hidden:
        return x
    out_w = params["embed"] if cfg.tie_embeddings else params["out"]
    return jnp.einsum("bsd,vd->bsv", x, out_w)


# ================================================================= loss


def chunked_ce(x: jax.Array, out_w: jax.Array, labels: jax.Array,
               chunk: int = 512) -> jax.Array:
    """Cross-entropy over the vocab head without materializing (B, S, V).

    Scans over sequence chunks; each chunk's logits live only inside the
    (rematerialized) chunk body — the head is recomputed in the backward
    pass. This is the difference between O(S*V) and O(chunk*V) live bytes
    per device at 128k-vocab scales.
    """
    B, S, D = x.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    nc = S // chunk
    xc = x.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(tot, inp):
        xk, lk = inp
        # preferred_element_type keeps the cotangent wrt xk in bf16 — without
        # it the f32 cast back-propagates f32 carries through the layer scan
        # (an observed 34 GB/device residual stack).
        lg = jnp.einsum("bsd,vd->bsv", xk, out_w, preferred_element_type=jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        onehot = jax.nn.one_hot(lk, lg.shape[-1], dtype=lg.dtype)
        gold = jnp.sum(lg * onehot, axis=-1)
        return tot + (lse - gold).sum(), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    return tot / (B * S)


def loss_fn(params, cfg: ArchConfig, rt: Runtime, batch: Dict[str, jax.Array]) -> jax.Array:
    """Next-token CE (+ DeepSeek MTP auxiliary loss when configured)."""
    tokens = batch.get("tokens")
    labels = batch["labels"]
    x = forward(
        params, cfg, rt,
        tokens=tokens,
        inputs_embeds=batch.get("inputs_embeds"),
        positions=batch.get("positions"),
        enc_embeds=batch.get("enc_embeds"),
        return_hidden=True,
    )
    out_w = params["embed"] if cfg.tie_embeddings else params["out"]
    loss = chunked_ce(x, out_w, labels)
    if cfg.mtp_depth and "mtp" in params and tokens is not None:
        # Multi-token prediction (depth 1): combine hidden-ish signal with the
        # embedding of the next token, one extra block, predict t+2.
        m = params["mtp"]
        h = params["embed"][tokens].astype(rt.cdtype)
        e_next = params["embed"][jnp.roll(tokens, -1, axis=1)].astype(rt.cdtype)
        hm = jnp.concatenate([rmsnorm(h, m["ln_h"], cfg.norm_eps), rmsnorm(e_next, m["ln_e"], cfg.norm_eps)], axis=-1) @ m["proj"]
        B, S = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        attn = mla_apply if cfg.mla is not None else attention_apply
        hm = hm + attn(m["attn"], rmsnorm(hm, m["ln1"], cfg.norm_eps), cfg, rt, pos, True)
        hm = hm + ffn_apply(m["ffn"], rmsnorm(hm, m["ln2"], cfg.norm_eps), cfg.act)
        labels2 = jnp.roll(labels, -1, axis=1)
        loss = loss + 0.3 * chunked_ce(hm, out_w, labels2)
    return loss


# ================================================================ decode


def _cache_len(cfg: ArchConfig, max_len: int) -> int:
    if cfg.window is not None:
        return min(cfg.window, max_len)
    return max_len


def init_cache(cfg: ArchConfig, rt: Runtime, batch: int, max_len: int,
               enc_len: int = 0, abstract: bool = False):
    """Stacked-over-layers cache pytree. ``pos`` counts tokens generated."""
    dt = rt.cdtype
    L = cfg.n_layers
    S = _cache_len(cfg, max_len)
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    fam = cfg.family

    def Z(shape, dtype=dt):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    pos = Z((batch,), jnp.int32)
    if fam in ("dense", "vlm"):
        return {"k": Z((L, batch, S, hkv, hd)), "v": Z((L, batch, S, hkv, hd)), "pos": pos}
    if fam == "moe":
        if cfg.mla is not None:
            m = cfg.mla
            nd = cfg.moe.first_dense_layers
            c = {
                "c_kv": Z((L, batch, S, m.kv_lora_rank)),
                "k_rope": Z((L, batch, S, m.qk_rope_head_dim)),
                "pos": pos,
            }
            return c
        return {"k": Z((L, batch, S, hkv, hd)), "v": Z((L, batch, S, hkv, hd)), "pos": pos}
    if fam == "ssm":
        H, K = cfg.n_heads, cfg.d_model // cfg.n_heads
        return {
            "wkv": Z((L, batch, H, K, K), jnp.float32),
            "shift1": Z((L, batch, 1, cfg.d_model)),
            "shift2": Z((L, batch, 1, cfg.d_model)),
            "pos": pos,
        }
    if fam == "hybrid":
        di = cfg.ssm.expand * cfg.d_model
        H = di // cfg.ssm.head_dim
        P, N = cfg.ssm.head_dim, cfg.ssm.d_state
        c = {
            "ssm": Z((L, batch, H, P, N), jnp.float32),
            "attn_k": Z((cfg.n_layers // (cfg.attn_every or cfg.n_layers), batch, S, hkv, hd)),
            "attn_v": Z((cfg.n_layers // (cfg.attn_every or cfg.n_layers), batch, S, hkv, hd)),
            "pos": pos,
        }
        if cfg.ssm.conv_dim:
            c["conv"] = Z((L, batch, cfg.ssm.conv_dim - 1, di + 2 * H * N))
        return c
    if fam == "encdec":
        return {
            "k": Z((L, batch, S, hkv, hd)),
            "v": Z((L, batch, S, hkv, hd)),
            "enc_k": Z((L, batch, enc_len, hkv, hd)),
            "enc_v": Z((L, batch, enc_len, hkv, hd)),
            "pos": pos,
        }
    raise ValueError(fam)


def abstract_cache(cfg, rt, batch, max_len, enc_len=0):
    return init_cache(cfg, rt, batch, max_len, enc_len, abstract=True)


def decode_step(params, cfg: ArchConfig, rt: Runtime, cache, tokens: jax.Array):
    """One decode step. tokens: (B, 1) -> logits (B, 1, V), new cache."""
    x = params["embed"][tokens].astype(rt.cdtype)
    B = tokens.shape[0]
    fam = cfg.family
    pos = cache["pos"]

    if fam in ("dense", "vlm") or (fam == "moe" and cfg.mla is None):
        blocks = params["blocks"]
        dense_blocks = params.get("dense_blocks")

        def step(h, layer):
            p, kc, vc = layer
            sub = {"k": kc, "v": vc, "pos": pos}
            a, sub = attention_decode_apply(p["attn"], rmsnorm(h, p["ln1"], cfg.norm_eps), sub, cfg, rt)
            h = h + a
            inner = rmsnorm(h, p["ln2"], cfg.norm_eps)
            if "moe" in p:
                h = h + moe_apply(p["moe"], inner, cfg, rt)
            else:
                h = h + ffn_apply(p["ffn"], inner, cfg.act)
            return h, (sub["k"], sub["v"])

        nd = cfg.moe.first_dense_layers if (fam == "moe" and cfg.moe) else 0
        ks, vs = cache["k"], cache["v"]
        new_k, new_v = [], []
        if dense_blocks is not None and nd:
            def dstep(h, layer):
                return step(h, layer)
            x, (k2, v2) = jax.lax.scan(
                lambda h, l: dstep(h, l), x,
                (dense_blocks, ks[:nd], vs[:nd]),
            )
            new_k.append(k2)
            new_v.append(v2)
            ks, vs = ks[nd:], vs[nd:]
        x, (k2, v2) = jax.lax.scan(lambda h, l: step(h, l), x, (blocks, ks, vs))
        new_k.append(k2)
        new_v.append(v2)
        cache = dict(cache, k=jnp.concatenate(new_k, 0), v=jnp.concatenate(new_v, 0), pos=pos + 1)

    elif fam == "moe":  # MLA
        nd = cfg.moe.first_dense_layers

        def mk_step(has_moe):
            def step(h, layer):
                p, ckv, krope = layer
                sub = {"c_kv": ckv, "k_rope": krope, "pos": pos}
                a, sub = mla_decode_apply(p["attn"], rmsnorm(h, p["ln1"], cfg.norm_eps), sub, cfg, rt)
                h = h + a
                inner = rmsnorm(h, p["ln2"], cfg.norm_eps)
                h = h + (moe_apply(p["moe"], inner, cfg, rt) if has_moe else ffn_apply(p["ffn"], inner, cfg.act))
                return h, (sub["c_kv"], sub["k_rope"])
            return step

        cs, krs = cache["c_kv"], cache["k_rope"]
        outs_c, outs_r = [], []
        if nd:
            x, (c2, r2) = jax.lax.scan(mk_step(False), x, (params["dense_blocks"], cs[:nd], krs[:nd]))
            outs_c.append(c2); outs_r.append(r2)
            cs, krs = cs[nd:], krs[nd:]
        x, (c2, r2) = jax.lax.scan(mk_step(True), x, (params["blocks"], cs, krs))
        outs_c.append(c2); outs_r.append(r2)
        cache = dict(cache, c_kv=jnp.concatenate(outs_c, 0), k_rope=jnp.concatenate(outs_r, 0), pos=pos + 1)

    elif fam == "ssm":
        def step(h, layer):
            p, wkv, s1, s2 = layer
            a, st = rwkv6_decode_apply(p["tmix"], rmsnorm(h, p["ln1"], cfg.norm_eps), {"wkv": wkv, "shift": s1}, cfg, rt)
            h = h + a
            inner = rmsnorm(h, p["ln2"], cfg.norm_eps)
            h = h + _rwkv_cmix(p["cmix"], inner, prev=s2)
            return h, (st["wkv"], st["shift"], inner)

        x, (wkv2, s1n, s2n) = jax.lax.scan(
            step, x, (params["blocks"], cache["wkv"], cache["shift1"], cache["shift2"])
        )
        cache = dict(cache, wkv=wkv2, shift1=s1n, shift2=s2n, pos=pos + 1)

    elif fam == "hybrid":
        every = cfg.attn_every or cfg.n_layers
        groups = cfg.n_layers // every
        gp = jax.tree.map(lambda a: a.reshape((groups, every) + a.shape[1:]), params["blocks"])
        sa = params["shared_attn"]
        ssm_g = cache["ssm"].reshape((groups, every) + cache["ssm"].shape[1:])
        conv_g = cache["conv"].reshape((groups, every) + cache["conv"].shape[1:]) if "conv" in cache else None
        new_ssm, new_conv, new_ak, new_av = [], [], [], []
        for g in range(groups):
            if conv_g is not None:
                def step(h, layer):
                    p, ssm_s, conv_s = layer
                    a, st = mamba2_decode_apply(p["mamba"], rmsnorm(h, p["ln"], cfg.norm_eps),
                                                {"ssm": ssm_s, "conv": conv_s}, cfg, rt)
                    return h + a, (st["ssm"], st["conv"])
                x, (s2, c2) = jax.lax.scan(step, x, (jax.tree.map(lambda a: a[g], gp), ssm_g[g], conv_g[g]))
                new_conv.append(c2)
            else:
                def step(h, layer):
                    p, ssm_s = layer
                    a, st = mamba2_decode_apply(p["mamba"], rmsnorm(h, p["ln"], cfg.norm_eps),
                                                {"ssm": ssm_s}, cfg, rt)
                    return h + a, st["ssm"]
                x, s2 = jax.lax.scan(step, x, (jax.tree.map(lambda a: a[g], gp), ssm_g[g]))
            new_ssm.append(s2)
            sub = {"k": cache["attn_k"][g], "v": cache["attn_v"][g], "pos": pos}
            a, sub = attention_decode_apply(sa["attn"], rmsnorm(x, sa["ln1"], cfg.norm_eps), sub, cfg, rt)
            x = x + a
            x = x + ffn_apply(sa["ffn"], rmsnorm(x, sa["ln2"], cfg.norm_eps), cfg.act)
            new_ak.append(sub["k"])
            new_av.append(sub["v"])
        cache = dict(
            cache,
            ssm=jnp.concatenate(new_ssm, 0).reshape(cache["ssm"].shape),
            attn_k=jnp.stack(new_ak, 0),
            attn_v=jnp.stack(new_av, 0),
            pos=pos + 1,
        )
        if new_conv:
            cache["conv"] = jnp.concatenate(new_conv, 0).reshape(cache["conv"].shape)

    elif fam == "encdec":
        def step(h, layer):
            p, kc, vc, ek, ev = layer
            sub = {"k": kc, "v": vc, "pos": pos}
            a, sub = attention_decode_apply(p["attn"], rmsnorm(h, p["ln1"], cfg.norm_eps), sub, cfg, rt)
            h = h + a
            # cross-attention over precomputed encoder KV
            hn = rmsnorm(h, p["ln3"], cfg.norm_eps)
            q = jnp.einsum("bsd,dhe->bshe", hn, p["xattn"]["wq"])
            Bq = q.shape[0]
            g = cfg.n_heads // cfg.n_kv_heads
            qg = q.reshape(Bq, cfg.n_kv_heads, g, cfg.head_dim)
            s = jnp.einsum("bhgd,bkhd->bhgk", qg, ek).astype(jnp.float32) / (cfg.head_dim ** 0.5)
            att = jax.nn.softmax(s, axis=-1).astype(ev.dtype)
            o = jnp.einsum("bhgk,bkhd->bhgd", att, ev).reshape(Bq, 1, cfg.n_heads, cfg.head_dim)
            h = h + jnp.einsum("bshe,hed->bsd", o, p["xattn"]["wo"])
            h = h + ffn_apply(p["ffn"], rmsnorm(h, p["ln2"], cfg.norm_eps), cfg.act)
            return h, (sub["k"], sub["v"])

        x, (k2, v2) = jax.lax.scan(
            step, x, (params["blocks"], cache["k"], cache["v"], cache["enc_k"], cache["enc_v"])
        )
        cache = dict(cache, k=k2, v=v2, pos=pos + 1)
    else:
        raise ValueError(fam)

    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    out_w = params["embed"] if cfg.tie_embeddings else params["out"]
    logits = jnp.einsum("bsd,vd->bsv", x, out_w)
    return logits, cache
