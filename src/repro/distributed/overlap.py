"""Compute/communication overlap: ring collective-matmul.

``ring_allgather_matmul`` computes x_full @ w where x is sharded over the
given axis — WITHOUT first materializing x_full. Each of the n steps
multiplies the currently-held shard while ppermuting the next one around
the ring, so the interconnect transfer of step i+1 hides behind the matmul
of step i (the classic TPU collective-matmul schedule; on real hardware
XLA's async collective-permute makes the overlap explicit, and the
latency-hiding scheduler flag in launch configs does the rest).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["ring_allgather_matmul"]


def ring_allgather_matmul(x, w, mesh: Mesh, axis: str = "model"):
    """x: (M, K) sharded (axis, None) -> rows; w: (K_total, N) sharded
    (axis, None) -> row-sharded weights. Computes x @ w_full with the ring
    schedule. Returns (M, N) sharded like x's rows."""
    n = mesh.shape[axis]

    def body(x_local, w_local):
        idx = jax.lax.axis_index(axis)
        m = x_local.shape[0]
        acc = jnp.zeros((m, w_local.shape[1]), jnp.float32)
        k_shard = w_local.shape[0]

        def step(i, carry):
            acc, w_cur = carry
            # after i ring hops the shard we hold originated at (idx - i):
            # it covers K rows [src*k_shard, (src+1)*k_shard)
            src = (idx - i) % n
            part = jax.lax.dynamic_slice_in_dim(x_local, src * k_shard, k_shard, 1)
            acc = acc + part.astype(jnp.float32) @ w_cur.astype(jnp.float32)
            # pass our w shard along the ring (overlaps with next matmul)
            w_next = jax.lax.ppermute(
                w_cur, axis, [(j, (j + 1) % n) for j in range(n)]
            )
            return acc, w_next

        acc, _ = jax.lax.fori_loop(0, n, step, (acc, w_local))
        return acc.astype(x_local.dtype)

    f = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None)),
        out_specs=P(axis, None),
        check_vma=False,
    )
    return f(x, w)
