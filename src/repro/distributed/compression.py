"""Gradient compression for cross-pod reduction.

Two schemes, applied *before* the (implicit, XLA-inserted) gradient
all-reduce so the wire format is small:

  int8:  per-tensor symmetric quantization; dequantized immediately so the
         value seen by the optimizer carries quantization error, exactly as
         a quantized all-reduce would. (On real hardware the transport runs
         in int8; XLA:CPU has no int8 all-reduce, so the arithmetic effect
         is modeled and the collective-byte savings are accounted in the
         roofline's collective term via RuntimeConfig.grad_compression.)

  topk:  keep the largest-|g| fraction per tensor with error feedback kept
         in a residual accumulator (stateful variant available through
         ``ErrorFeedback``; the stateless call drops the residual).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_grads", "int8_roundtrip", "topk_mask", "ErrorFeedback"]


def int8_roundtrip(g: jax.Array) -> jax.Array:
    a = jnp.max(jnp.abs(g.astype(jnp.float32)))
    scale = jnp.where(a > 0, a / 127.0, 1.0)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return (q.astype(jnp.float32) * scale).astype(g.dtype)


def topk_mask(g: jax.Array, frac: float = 0.1) -> jax.Array:
    flat = jnp.abs(g.astype(jnp.float32)).reshape(-1)
    k = max(int(flat.size * frac), 1)
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return jnp.where(jnp.abs(g.astype(jnp.float32)) >= thresh, g, 0).astype(g.dtype)


def compress_grads(grads, scheme: str, topk_frac: float = 0.1):
    if scheme == "int8":
        return jax.tree.map(int8_roundtrip, grads)
    if scheme == "topk":
        return jax.tree.map(lambda g: topk_mask(g, topk_frac), grads)
    return grads


class ErrorFeedback:
    """Residual-carrying top-k compression (EF-SGD style)."""

    def init(self, grads):
        return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def compress(self, grads, residual, frac: float = 0.1):
        def one(g, r):
            acc = g.astype(jnp.float32) + r
            kept = topk_mask(acc, frac).astype(jnp.float32)
            return kept.astype(g.dtype), acc - kept

        flat_g, treedef = jax.tree.flatten(grads)
        flat_r = jax.tree.leaves(residual)
        outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
        return (
            jax.tree.unflatten(treedef, [o[0] for o in outs]),
            jax.tree.unflatten(treedef, [o[1] for o in outs]),
        )
