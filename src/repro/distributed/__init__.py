from .sharding import (
    assign_pspec,
    cache_axes,
    make_param_rules,
    shardings_for_specs,
    shardings_for_tree,
)

__all__ = [
    "assign_pspec", "cache_axes", "make_param_rules",
    "shardings_for_specs", "shardings_for_tree",
]
