"""GPipe-style pipeline parallelism via shard_map + collective_permute.

The layer stack is split into ``stages`` contiguous groups; stage s holds
its group's parameters (sharded over a "pipe" mesh axis). A microbatched
forward runs stages in lockstep: at tick t, stage s processes microbatch
(t - s) and ppermutes its activation to stage s+1. The bubble fraction is
(stages - 1) / (microbatches + stages - 1), reported by ``bubble()``.

This module is deliberately self-contained (a composable feature rather
than a default): the dry-run cells use DP/TP/SP/EP; PP is exercised by its
own tests and is available to the tuner as pp_stages / pp_microbatches
knobs for topologies where a model axis of 16 is not enough (e.g. the
340B dense arch on smaller-HBM parts).
"""

from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["pipeline_forward", "bubble"]


def bubble(stages: int, microbatches: int) -> float:
    return (stages - 1) / (microbatches + stages - 1)


def pipeline_forward(
    stage_fn: Callable[[jax.Array, jax.Array], jax.Array],
    stage_params: jax.Array,     # (stages, ...) leading pipe axis, pytree ok
    x: jax.Array,                # (microbatches, mb_size, ...) pre-split
    mesh: Mesh,
    axis: str = "pipe",
) -> jax.Array:
    """Run x through all stages; returns outputs in microbatch order.

    stage_fn(params_slice, h) -> h  — one stage's computation.
    """
    stages = mesh.shape[axis]
    M = x.shape[0]
    assert M >= 1

    def per_stage(params, xs):
        # params: this stage's slice (leading dim 1); xs: full microbatch set
        # (only stage 0 consumes it).
        params = jax.tree.map(lambda a: a[0], params)
        sidx = jax.lax.axis_index(axis)
        n_ticks = M + stages - 1
        out = jnp.zeros_like(xs)

        def tick(t, carry):
            h_in, out = carry
            mb = t - sidx  # microbatch this stage works on at tick t
            active = (mb >= 0) & (mb < M)
            # stage 0 reads a fresh microbatch; others use the permuted input
            src = jnp.where(
                sidx == 0,
                xs[jnp.clip(mb, 0, M - 1)],
                h_in,
            )
            h = stage_fn(params, src)
            h = jnp.where(active, h, h_in)
            # last stage writes its finished microbatch
            out = jax.lax.cond(
                active & (sidx == stages - 1),
                lambda o: o.at[jnp.clip(mb, 0, M - 1)].set(h),
                lambda o: o,
                out,
            )
            # forward the activation ring: stage s -> s+1
            h_next = jax.lax.ppermute(
                h, axis, [(i, (i + 1) % stages) for i in range(stages)]
            )
            return h_next, out

        h0 = jnp.zeros_like(xs[0])
        _, out = jax.lax.fori_loop(0, n_ticks, tick, (h0, out))
        # only the last stage holds real outputs; psum of the masked buffers
        # broadcasts them to every stage
        out = jnp.where(sidx == stages - 1, out, jnp.zeros_like(out))
        return jax.lax.psum(out, axis)

    f = jax.shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False,
    )
    return f(stage_params, x)
