"""Logical-axis -> mesh-axis sharding assignment.

Rules map logical axis names to an ordered tuple of candidate mesh axes.
``assign_pspec`` walks a shape left-to-right and gives each dimension the
first candidate axis (or axis group) that (a) is present in the mesh,
(b) hasn't been used by an earlier dimension of the same tensor, and
(c) divides the dimension evenly. This one function produces every
sharding in the system — params, optimizer states, activations, KV
caches — so TP/FSDP/EP/SP layouts stay mutually consistent.

Default layout:
  model axis: TP (heads / mlp / experts / vocab / ssm_inner)
  data axes (pod, data): batch DP + FSDP parameter sharding (ZeRO-3) +
  sequence sharding for long-context caches whose batch can't split.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..models.params import ParamSpec
from ..models.runtime import Runtime

__all__ = [
    "make_param_rules", "assign_pspec", "shardings_for_specs",
    "shardings_for_tree", "cache_axes", "batch_axes",
]

Rules = Dict[Optional[str], Tuple[str, ...]]


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_param_rules(rt: Runtime, mesh: Mesh) -> Rules:
    d = _data_axes(mesh)
    rules: Rules = {
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "experts": ("model",),
        # fallback TP for MoE weights whose expert count can't divide the
        # model axis (e.g. 8 experts on model=16): shard the FFN width
        "expert_mlp": ("model",),
        "ssm_inner": ("model",),
        "rank": (),
        "qk": (),
        "layers": (),
        "embed": d if rt.fsdp else (),
        None: (),
    }
    return rules


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return _data_axes(mesh)


def assign_pspec(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Rules,
) -> P:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used: set = set()
    parts = []
    for dim, ax in zip(shape, axes):
        cands = rules.get(ax, ())
        if isinstance(cands, str):
            cands = (cands,)
        chosen: Tuple[str, ...] = ()
        # try the full candidate group first (e.g. ("pod","data")), then singles
        groups = [tuple(cands)] + [(c,) for c in cands] if len(cands) > 1 else [tuple(cands)]
        for grp in groups:
            grp = tuple(a for a in grp if a in sizes and a not in used)
            if not grp:
                continue
            total = int(np.prod([sizes[a] for a in grp]))
            if total > 1 and dim % total == 0:
                chosen = grp
                break
        if chosen:
            used.update(chosen)
            parts.append(chosen if len(chosen) > 1 else chosen[0])
        else:
            parts.append(None)
    # drop trailing Nones for tidiness
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def shardings_for_specs(specs, mesh: Mesh, rules: Rules):
    """ParamSpec tree -> NamedSharding tree."""

    def one(s: ParamSpec):
        return NamedSharding(mesh, assign_pspec(s.shape, s.axes, mesh, rules))

    return jax.tree.map(one, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def shardings_for_tree(tree_axes, tree_shapes, mesh: Mesh, rules: Rules):
    """Parallel trees of axis-tuples and shapes -> NamedSharding tree."""

    def one(axes, shaped):
        return NamedSharding(mesh, assign_pspec(shaped.shape, axes, mesh, rules))

    return jax.tree.map(one, tree_axes, tree_shapes,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            a is None or isinstance(a, str) for a in x))


# ------------------------------------------------------------------- caches


def cache_axes(cfg: ArchConfig, cache) -> Any:
    """Logical axes for each cache leaf (parallel tree to init_cache)."""
    fam = cfg.family

    def ax(leaf_name: str, ndim: int) -> Tuple:
        table = {
            # (L, B, S, Hkv, hd)
            "k": ("layers", "batch", "kv_seq", "kv_heads", None),
            "v": ("layers", "batch", "kv_seq", "kv_heads", None),
            "attn_k": ("layers", "batch", "kv_seq", "kv_heads", None),
            "attn_v": ("layers", "batch", "kv_seq", "kv_heads", None),
            "enc_k": ("layers", "batch", "kv_seq", "kv_heads", None),
            "enc_v": ("layers", "batch", "kv_seq", "kv_heads", None),
            "c_kv": ("layers", "batch", "kv_seq", None),
            "k_rope": ("layers", "batch", "kv_seq", None),
            "ssm": ("layers", "batch", "heads", None, None),
            "conv": ("layers", "batch", None, "ssm_inner"),
            "wkv": ("layers", "batch", "heads", None, None),
            "shift1": ("layers", "batch", None, "embed_act"),
            "shift2": ("layers", "batch", None, "embed_act"),
            "pos": ("batch",),
        }
        return table[leaf_name][:ndim]

    return {k: ax(k, np.ndim(v) if not hasattr(v, "shape") else len(v.shape))
            for k, v in cache.items()}


def cache_rules(rt: Runtime, mesh: Mesh, batch_shardable: bool) -> Rules:
    d = _data_axes(mesh)
    return {
        "layers": (),
        "batch": d if batch_shardable else (),
        # KV sequence takes the model axis (ring-decode layout: each model
        # shard holds a slice of the context; softmax reduces across shards).
        # Essential when kv_heads < model-axis size — head sharding can't
        # divide, and a replicated 32k cache is tens of GB/device. When the
        # batch can't shard either (long-context B=1), sequence absorbs the
        # data axes too.
        "kv_seq": ("model",) if batch_shardable else d + ("model",),
        "kv_heads": ("model",),
        "heads": ("model",),
        "ssm_inner": ("model",),
        "embed_act": (),
        None: (),
    }
