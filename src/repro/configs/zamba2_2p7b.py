"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks.

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf]. The shared transformer block (full-attention GQA +
FFN, parameters shared across invocations) fires every 6 Mamba2 layers.
"""

from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, conv_dim=4, chunk=128),
    attn_every=6,
)
