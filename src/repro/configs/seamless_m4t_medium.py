"""seamless-m4t-medium [audio] — enc-dec backbone; speech frontend stub.

12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206 [arXiv:2308.11596; hf].
12 encoder layers (bidirectional) + 12 decoder layers (causal + cross-attn).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    n_encoder_layers=12,
    frontend="audio",
)
