"""qwen2-vl-72b [vlm] — backbone only; M-RoPE (t/h/w sections), dynamic
resolution via the vision-frontend stub [arXiv:2409.12191; hf].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    rope="mrope",
    frontend="vision",
)
