"""deepseek-v3-671b [moe] — MLA + 1 shared + 256 routed experts top-8 + MTP.

61L d_model=7168 128H (GQA kv=128) d_ff=2048(expert) vocab=129280,
MoE 256e top-8 [arXiv:2412.19437; hf]. First 3 layers dense (d_ff 18432).
"""

from .base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,               # dense-layer FFN width
    vocab=129280,
    d_head=128,
    moe=MoEConfig(
        n_experts=256,
        top_k=8,
        n_shared=1,
        d_ff_expert=2048,
        first_dense_layers=3,
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    mtp_depth=1,
)
