"""Architecture and shape configuration.

Every assigned architecture is an ``ArchConfig``; the four input-shape sets
are ``ShapeConfig``s. ``reduced()`` yields the family-preserving smoke-test
variant (small widths/depths/experts) that runs a real forward/train step
on CPU.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "MLAConfig", "MoEConfig", "SSMConfig"]


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention dims (arXiv:2412.19437)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    n_shared: int = 0           # shared (always-on) experts
    d_ff_expert: int = 2048     # per-expert FFN width
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    first_dense_layers: int = 0  # leading layers that stay dense (DeepSeek-V3: 3)


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    conv_dim: int = 4
    chunk: int = 128


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None  # default: d_model // n_heads
    act: str = "swiglu"           # swiglu | sq_relu
    rope: str = "standard"        # standard | mrope | none
    window: Optional[int] = None  # sliding-window attention size
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: Optional[int] = None   # hybrid: shared attn block cadence
    n_encoder_layers: int = 0          # enc-dec only
    mtp_depth: int = 0                 # DeepSeek multi-token prediction heads
    tie_embeddings: bool = False
    frontend: Optional[str] = None     # vision | audio modality stub
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm" and self.attn_every is None

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode at 500k context with bounded state?"""
        return self.family in ("ssm", "hybrid") or self.window is not None

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have decode paths (enc-dec included)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        d, f, L = self.d_model, self.d_ff, self.n_layers
        hd = self.head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer_attn = 0
        if self.mla is not None:
            m = self.mla
            per_layer_attn = (
                d * m.q_lora_rank + m.q_lora_rank * nq * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * nq * (m.qk_nope_head_dim + m.v_head_dim)
                + nq * m.v_head_dim * d
            )
        elif self.family in ("ssm",) and self.ssm is not None:
            pass  # handled below per block type
        else:
            per_layer_attn = d * nq * hd + 2 * d * nkv * hd + nq * hd * d

        def ffn_params(width: int) -> int:
            return d * width * (3 if self.act == "swiglu" else 2)

        total_layers = 0
        for layer in range(L):
            if self.family == "ssm" and self.ssm is not None:
                di = self.ssm.expand * d
                nh = di // self.ssm.head_dim
                total_layers += d * (2 * di + 2 * nh * self.ssm.d_state + nh) + di * d + di * self.ssm.conv_dim
                if self.name.startswith("rwkv"):
                    # rwkv6 block: r,k,v,g,w projections + output + ffn
                    total_layers += 4 * d * d + d * d
                total_layers += ffn_params(f) if f else 0
            elif self.family == "hybrid" and self.ssm is not None:
                di = self.ssm.expand * d
                nh = di // self.ssm.head_dim
                total_layers += d * (2 * di + 2 * nh * self.ssm.d_state + nh) + di * d + di * self.ssm.conv_dim
            else:
                is_moe = (
                    self.moe is not None and layer >= self.moe.first_dense_layers
                )
                total_layers += per_layer_attn
                if is_moe:
                    e = self.moe
                    total_layers += (
                        (e.n_experts + e.n_shared) * d * e.d_ff_expert * (3 if self.act == "swiglu" else 2)
                        + d * e.n_experts
                    )
                else:
                    total_layers += ffn_params(f)
        total += total_layers
        if self.family == "hybrid" and self.attn_every:
            # one shared attention+FFN block
            total += per_layer_attn or (d * nq * hd + 2 * d * nkv * hd + nq * hd * d)
            total += ffn_params(f)
        if self.n_encoder_layers:
            total += self.n_encoder_layers * (per_layer_attn + ffn_params(f))
            total += L * per_layer_attn  # decoder cross-attention
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        d = self.d_model
        glu = 3 if self.act == "swiglu" else 2
        moe_layers = self.n_layers - e.first_dense_layers
        all_experts = moe_layers * e.n_experts * d * e.d_ff_expert * glu
        active = moe_layers * e.top_k * d * e.d_ff_expert * glu
        return self.param_count() - all_experts + active


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
