"""Architecture registry: ``get_arch(id)``, ``reduced(cfg)`` smoke variants,
cell enumeration for the dry-run, and shape applicability rules."""

from __future__ import annotations

from dataclasses import replace
from typing import Dict, List, Optional, Tuple

from .base import ArchConfig, MLAConfig, MoEConfig, SHAPES, ShapeConfig, SSMConfig
from . import (
    zamba2_2p7b,
    rwkv6_7b,
    deepseek_v3_671b,
    mixtral_8x22b,
    nemotron_4_340b,
    llama3_8b,
    starcoder2_7b,
    deepseek_coder_33b,
    qwen2_vl_72b,
    seamless_m4t_medium,
)

__all__ = [
    "ArchConfig", "ShapeConfig", "SHAPES", "ARCHS",
    "get_arch", "reduced", "shape_applicable", "all_cells",
    "MLAConfig", "MoEConfig", "SSMConfig",
]

ARCHS: Dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        zamba2_2p7b, rwkv6_7b, deepseek_v3_671b, mixtral_8x22b, nemotron_4_340b,
        llama3_8b, starcoder2_7b, deepseek_coder_33b, qwen2_vl_72b, seamless_m4t_medium,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Family-preserving smoke-test variant (runs a real step on CPU)."""
    kw: dict = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.family != "hybrid" else 6),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=256,
        vocab=512,
        d_head=32,
    )
    if cfg.moe is not None:
        kw["moe"] = replace(
            cfg.moe, n_experts=4, top_k=2, d_ff_expert=128,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1),
        )
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(
            q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
            qk_rope_head_dim=16, v_head_dim=32,
        )
    if cfg.ssm is not None:
        kw["ssm"] = replace(cfg.ssm, d_state=16, head_dim=32, chunk=32)
    if cfg.attn_every is not None:
        kw["attn_every"] = 3
    if cfg.n_encoder_layers:
        kw["n_encoder_layers"] = 2
        kw["n_layers"] = 2
    return replace(cfg, **kw)


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped). DESIGN.md §7 documents the skips."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, "full quadratic attention cannot decode at 524k context (DESIGN.md §7)"
    return True, ""


def all_cells() -> List[Tuple[str, str]]:
    """The 40 (arch x shape) cells, skips included (marked by dry-run)."""
    return [(a, s) for a in sorted(ARCHS) for s in SHAPES]
