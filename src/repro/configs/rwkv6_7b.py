"""rwkv6-7b [ssm] — Finch, data-dependent decay, attention-free.

32L d_model=4096 (attn-free) d_ff=14336 vocab=65536 [arXiv:2404.05892; hf].
"""

from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,          # wkv heads, head_dim 64
    n_kv_heads=64,
    d_ff=14336,
    vocab=65536,
    rope="none",
    ssm=SSMConfig(d_state=64, expand=1, head_dim=64, conv_dim=0, chunk=64),
)
