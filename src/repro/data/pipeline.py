"""Deterministic, sharded, checkpointable synthetic token pipeline.

Large-scale properties it models faithfully:
  * determinism: batch(step) is a pure function of (seed, step, host) —
    restart at step N reproduces exactly the stream a continuous run saw;
  * host sharding: each host materializes only its slice of the global
    batch (no host-0 fan-out);
  * straggler skip-ahead: ``skip_to(step)`` is O(1) (counter-based PRNG,
    no state to replay) — a restarted/rescheduled worker jumps straight to
    the fleet's current step;
  * checkpoint integration: ``state()`` is just {"step": int}.

The token distribution is Zipfian with a document structure (BOS-separated
segments) so CE losses behave like real text rather than uniform noise.
"""

from __future__ import annotations

from typing import Dict, Iterator, Optional, Tuple

import numpy as np

__all__ = ["SyntheticTokenPipeline"]


class SyntheticTokenPipeline:
    def __init__(
        self,
        vocab: int,
        seq_len: int,
        global_batch: int,
        seed: int = 0,
        host_index: int = 0,
        host_count: int = 1,
        zipf_a: float = 1.2,
        doc_len_mean: int = 512,
    ):
        assert global_batch % host_count == 0
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.local_batch = global_batch // host_count
        self.seed = seed
        self.host_index = host_index
        self.host_count = host_count
        self.zipf_a = zipf_a
        self.doc_len_mean = doc_len_mean
        self._step = 0
        # Zipf over the vocab, renormalized (rank 1 = token id 2; 0=pad, 1=BOS)
        ranks = np.arange(1, vocab - 1, dtype=np.float64)
        p = ranks ** (-zipf_a)
        self._probs = p / p.sum()

    # ------------------------------------------------------------------ state
    def state(self) -> Dict[str, int]:
        return {"step": self._step}

    def restore(self, state: Dict[str, int]) -> None:
        self._step = int(state["step"])

    def skip_to(self, step: int) -> None:
        self._step = int(step)

    # ------------------------------------------------------------------ batch
    def _rng_for(self, step: int) -> np.random.Generator:
        # counter-based: independent stream per (seed, step, host)
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_index])
        )

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng_for(step)
        B, S = self.local_batch, self.seq_len
        toks = rng.choice(self.vocab - 2, size=(B, S + 1), p=self._probs).astype(np.int32) + 2
        # document boundaries: geometric segment lengths, BOS token = 1
        n_docs = max(int((S + 1) / self.doc_len_mean * B), 1)
        rows = rng.integers(0, B, n_docs)
        cols = rng.integers(0, S + 1, n_docs)
        toks[rows, cols] = 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self._step)
        self._step += 1
        return b
