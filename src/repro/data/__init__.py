from .pipeline import SyntheticTokenPipeline

__all__ = ["SyntheticTokenPipeline"]
