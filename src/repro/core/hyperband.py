"""Hyperband / successive-halving scheduling (paper §3.4, Alg. 1, Table 1).

The schedule is computed exactly as in Alg. 1:
    s_max = floor(log_eta(R)),  B = (s_max + 1) * R
    for s in {s_max, ..., 0}:
        n_1 = ceil(B/R * eta^s / (s+1)),  r_1 = R * eta^{-s}
        run SH(n_1, r_1)
Inside SH, after evaluating n_i configs at resource r_i, the top
n_i/eta of the *successful* configs advance to r_{i+1} = eta * r_i until
r = R (failed evaluations occupy a rung slot but never promote and never
count toward the promotion quota).

Resources map to fidelity deltas: delta = r / R (so R=9, eta=3 gives the
paper's default proxy levels 1/9, 1/3, 1).

Evaluation is delegated to a callback so the same scheduler drives the
Spark simulator, the JAX objective and the unit tests. The §6.3 median
early-stop is applied here: an evaluation is capped at the median cost of
historical evaluations at the same fidelity (factor configurable).

Bracket bookkeeping comes in two backends (same pattern as the space /
surrogate / acquisition planes):

``backend="table"`` (default) — array-native :class:`RungTable` state:
    one row per evaluation with config-index / score / failed / elapsed /
    rung-id columns, rung promotion as one masked stable top-k over the
    score column, and per-fidelity cost history in growable
    :class:`CostColumns` buffers (vectorized running medians).
    ``run_bracket`` is a thin driver over ``table.record(...)`` /
    ``table.promote(...)`` steps, and the finished tables are kept on
    ``runner.tables`` so callers (benchmarks, an async-ASHA service layer)
    can read promotion state without re-deriving it.
``backend="loop"`` — the original list-of-dataclass scalar reference.

Both backends replay the same float comparisons (Python's stable
``list.sort`` vs ``np.argsort(kind="stable")`` over float64 scores), so
survivor sets are bit-identical; NaN scores on successful rows are
rejected by the table (they would silently poison either sort order).
"""

from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .. import obs

__all__ = [
    "hb_schedule",
    "sh_schedule",
    "Bracket",
    "Rung",
    "RungTable",
    "CostColumns",
    "HyperbandRunner",
    "get_hyperband_backend",
    "set_hyperband_backend",
    "hyperband_backend",
]


@dataclass
class Rung:
    n: int           # configs evaluated at this rung
    r: float         # resource units
    delta: float     # fidelity r / R


@dataclass
class Bracket:
    s: int
    rungs: List[Rung]


def sh_schedule(n1: int, r1: float, R: float, eta: int) -> List[Rung]:
    rungs = []
    n, r = n1, r1
    while True:
        rungs.append(Rung(n=max(int(n), 1), r=r, delta=min(r / R, 1.0)))
        if r >= R - 1e-9:
            break
        n = max(int(np.floor(n / eta)), 1)
        r = r * eta
    return rungs


def hb_schedule(R: float, eta: int) -> List[Bracket]:
    """Alg. 1 / Table 1 enumeration of (n_i, r_i)."""
    s_max = int(np.floor(np.log(R) / np.log(eta)))
    B = (s_max + 1) * R
    brackets = []
    for s in range(s_max, -1, -1):
        n1 = int(np.ceil(B / R * (eta**s) / (s + 1)))
        r1 = R * (eta ** (-s))
        brackets.append(Bracket(s=s, rungs=sh_schedule(n1, r1, R, eta)))
    return brackets


# ---------------------------------------------------------------------------
# backend selection (module default + context override, like the space /
# forest / acquisition planes)
# ---------------------------------------------------------------------------

_HB_BACKENDS = ("table", "loop")
_HB_BACKEND = "table"


def get_hyperband_backend() -> str:
    return _HB_BACKEND


def set_hyperband_backend(backend: str) -> str:
    """Set the module-default bracket-bookkeeping backend; returns previous."""
    global _HB_BACKEND
    if backend not in _HB_BACKENDS:
        raise ValueError(f"unknown hyperband backend {backend!r}; pick from {_HB_BACKENDS}")
    prev = _HB_BACKEND
    _HB_BACKEND = backend
    return prev


@contextmanager
def hyperband_backend(backend: str):
    prev = set_hyperband_backend(backend)
    try:
        yield
    finally:
        set_hyperband_backend(prev)


# ---------------------------------------------------------------------------
# array-native bookkeeping
# ---------------------------------------------------------------------------


class CostColumns:
    """Per-fidelity running cost buffers with vectorized medians.

    One growable float64 column per fidelity key (amortized-doubling
    appends, contiguous filled views), so the §6.3 median cost cap is one
    ``np.median`` over an existing array instead of a per-call Python-list
    conversion. Values and medians are bit-identical to the list path.
    """

    __slots__ = ("_buf", "_len")

    def __init__(self):
        self._buf: Dict[float, np.ndarray] = {}
        self._len: Dict[float, int] = {}

    def __contains__(self, key: float) -> bool:
        return key in self._buf

    def __setitem__(self, key: float, values) -> None:
        vals = np.asarray(list(values), dtype=np.float64)
        self._buf[key] = vals
        self._len[key] = vals.size

    def keys(self):
        return self._buf.keys()

    def count(self, key: float) -> int:
        return self._len.get(key, 0)

    def values(self, key: float) -> np.ndarray:
        """Contiguous filled view of one fidelity's cost column."""
        return self._buf.get(key, np.empty(0))[: self._len.get(key, 0)]

    def _room(self, key: float, extra: int) -> Tuple[np.ndarray, int]:
        n = self._len.get(key, 0)
        buf = self._buf.get(key)
        if buf is None or n + extra > buf.size:
            cap = max(8, buf.size if buf is not None else 0)
            while cap < n + extra:
                cap *= 2
            grown = np.empty(cap, dtype=np.float64)
            if n:
                grown[:n] = buf[:n]
            self._buf[key] = grown
            buf = grown
        return buf, n

    def append(self, key: float, value: float) -> None:
        buf, n = self._room(key, 1)
        buf[n] = value
        self._len[key] = n + 1

    def extend(self, key: float, values) -> None:
        vals = np.asarray(values, dtype=np.float64)
        buf, n = self._room(key, vals.size)
        buf[n : n + vals.size] = vals
        self._len[key] = n + vals.size

    def median(self, key: float) -> float:
        return float(np.median(self.values(key)))

    def capacity(self) -> int:
        """Total allocated slots across fidelity columns (growth guard)."""
        return int(sum(b.size for b in self._buf.values()))


class RungTable:
    """Array-native successive-halving state for one bracket.

    One row per evaluation, columnar: ``config_idx`` (index into the
    provisioned candidate sequence), ``score`` (performance, lower =
    better), ``failed`` mask, ``elapsed`` cost and ``rung_id``. Promotion
    is a masked stable top-k over the score column — the exact float
    comparisons of the scalar reference's ``sort(key=performance)``, so
    survivor sets are bit-identical — and the promotion quota counts only
    successful rows (top ``len(ok) // eta``).

    Columns grow by amortized doubling and are reusable via ``clear()``
    (buffers are kept), so a long-running service performs no per-bracket
    allocations once warm. ``survivors`` keeps each promotion's surviving
    config indices for introspection (benchmarks / async-ASHA promotion
    state).
    """

    __slots__ = (
        "s",
        "n_rungs",
        "configs",
        "survivors",
        "config_idx",
        "score",
        "failed",
        "elapsed",
        "rung_id",
        "trace_id",
        "_n",
    )

    def __init__(self, bracket: Bracket, configs: Sequence, capacity: Optional[int] = None):
        self.s = bracket.s
        self.n_rungs = len(bracket.rungs)
        self.configs = configs
        self.survivors: List[np.ndarray] = []
        cap = max(
            capacity if capacity is not None else sum(r.n for r in bracket.rungs), 1
        )
        self.config_idx = np.empty(cap, dtype=np.int64)
        self.score = np.empty(cap, dtype=np.float64)
        self.failed = np.empty(cap, dtype=bool)
        self.elapsed = np.empty(cap, dtype=np.float64)
        self.rung_id = np.empty(cap, dtype=np.int32)
        self.trace_id = np.empty(cap, dtype=np.int64)  # rung_eval span id (-1 = untraced)
        self._n = 0

    def __len__(self) -> int:
        return self._n

    @property
    def capacity(self) -> int:
        return self.config_idx.size

    def clear(self, configs: Optional[Sequence] = None) -> None:
        """Reset to empty, keeping the allocated column buffers."""
        self._n = 0
        self.survivors = []
        if configs is not None:
            self.configs = configs

    def _grow(self, need: int) -> None:
        cap = self.capacity
        while cap < need:
            cap *= 2
        for name in ("config_idx", "score", "failed", "elapsed", "rung_id", "trace_id"):
            old = getattr(self, name)
            grown = np.empty(cap, dtype=old.dtype)
            grown[: self._n] = old[: self._n]
            setattr(self, name, grown)

    def record(self, rung_i: int, config_idx, score, failed, elapsed,
               trace_id: int = -1) -> None:
        """Append one rung's evaluation results as columns.

        Non-finite scores on successful rows are rejected: a NaN (or inf)
        ``performance`` that is not marked ``failed`` would silently poison
        the promotion sort (and downstream best-tracking) on either
        backend — callers must coerce such results to failures first.
        """
        idx = np.asarray(config_idx, dtype=np.int64).ravel()
        sc = np.asarray(score, dtype=np.float64).ravel()
        fl = np.asarray(failed, dtype=bool).ravel()
        el = np.asarray(elapsed, dtype=np.float64).ravel()
        if not (idx.size == sc.size == fl.size == el.size):
            raise ValueError("record columns must have equal length")
        if not np.isfinite(sc[~fl]).all():
            raise ValueError(
                "non-finite performance on a successful evaluation; "
                "coerce non-finite aggregates to failed before recording"
            )
        n0, n1 = self._n, self._n + idx.size
        if n1 > self.capacity:
            self._grow(n1)
        self.config_idx[n0:n1] = idx
        self.score[n0:n1] = sc
        self.failed[n0:n1] = fl
        self.elapsed[n0:n1] = el
        self.rung_id[n0:n1] = rung_i
        self.trace_id[n0:n1] = trace_id
        self._n = n1

    def rows(self, rung_i: int) -> np.ndarray:
        """Row indices recorded at rung ``rung_i`` (in evaluation order)."""
        return np.flatnonzero(self.rung_id[: self._n] == rung_i)

    def promote(self, rung_i: int, eta: int) -> np.ndarray:
        """Masked stable top-k: config indices surviving rung ``rung_i``.

        keep = max(len(ok) // eta, 1) successful rows by ascending score;
        ties keep evaluation order (stable sort), replaying the scalar
        reference bit-for-bit.
        """
        rows = self.rows(rung_i)
        ok = rows[~self.failed[rows]]
        if ok.size == 0:
            surv = np.empty(0, dtype=np.int64)
        else:
            keep = max(int(ok.size) // int(eta), 1)
            order = np.argsort(self.score[ok], kind="stable")
            surv = self.config_idx[ok[order[:keep]]]
        self.survivors.append(surv)
        return surv

    def rung_outcomes(self, rung_i: int) -> List["EvalOutcome"]:
        """Materialize one rung's rows as scalar ``EvalOutcome``s."""
        return [
            EvalOutcome(
                config=self.configs[int(self.config_idx[i])],
                performance=float(self.score[i]),
                failed=bool(self.failed[i]),
                elapsed=float(self.elapsed[i]),
            )
            for i in self.rows(rung_i)
        ]


@dataclass
class EvalOutcome:
    config: dict
    performance: float
    failed: bool
    elapsed: float


class HyperbandRunner:
    """Drives one SH inner loop at a time.

    provide_candidates(n, rungs) -> sequence of configs for a new bracket
        (the controller injects warm starts + BO candidates here; the
        table backend accepts any indexable sequence — e.g. a columnar
        ``ConfigBatch`` / ``CandidateColumns`` — and materializes rows
        only when an evaluation needs the dict).
    evaluate(config, delta, cost_cap) -> (performance, failed, elapsed)
        performance must be comparable within a fidelity (lower better).
    on_result(config, delta, performance, failed, elapsed) -> None
        observation hook (knowledge base updates).
    should_stop() -> bool  budget check between evaluations.

    Batched rungs: pass ``evaluate_batch(configs, delta, cost_cap) ->
    list[(performance, failed, elapsed)]`` to ``run_bracket`` and every rung
    evaluates all of its survivors in one call (the vectorized
    ``Workload.evaluate_many`` path). The median-cost cap is computed once
    from the history at rung start and applied to the whole rung (the
    scalar path refreshes it per config — the only semantic difference);
    per-config cost history, on_result hooks and promotion are unchanged.
    The callback may return fewer results than configs (a prefix) when the
    caller's budget runs out mid-rung, mirroring the scalar path's
    between-config should_stop checks.

    ``backend="table"`` (module default) keeps bracket state in an
    array-native :class:`RungTable` (finished/in-flight tables exposed on
    ``self.tables``); ``backend="loop"`` is the pinned scalar reference.
    Survivor sets, outcome order and cost caps are bit-identical across
    backends.
    """

    def __init__(
        self,
        R: float = 9,
        eta: int = 3,
        early_stop_factor: float = 1.0,
        seed: int = 0,
        backend: Optional[str] = None,
    ):
        self.R = R
        self.eta = eta
        self.early_stop_factor = early_stop_factor
        self.brackets = hb_schedule(R, eta)
        self.backend = backend if backend is not None else get_hyperband_backend()
        if self.backend not in _HB_BACKENDS:
            raise ValueError(f"unknown hyperband backend {self.backend!r}")
        self._bracket_idx = 0
        self._cost_history = CostColumns() if self.backend == "table" else {}
        self.tables: List[RungTable] = []
        self.rng = np.random.default_rng(seed)

    def next_bracket(self) -> Bracket:
        b = self.brackets[self._bracket_idx % len(self.brackets)]
        self._bracket_idx += 1
        return b

    def _record_cost(self, delta: float, elapsed: float) -> None:
        key = round(delta, 6)
        if isinstance(self._cost_history, CostColumns):
            self._cost_history.append(key, elapsed)
        else:
            self._cost_history.setdefault(key, []).append(elapsed)

    def _cost_cap(self, delta: float) -> Optional[float]:
        key = round(delta, 6)
        hist = self._cost_history
        if isinstance(hist, CostColumns):
            if hist.count(key) < 3:
                return None
            return self.early_stop_factor * hist.median(key)
        h = hist.get(key, [])
        if len(h) < 3:
            return None
        return self.early_stop_factor * float(np.median(h))

    def run_bracket(
        self,
        bracket: Bracket,
        provide_candidates: Callable[[int, List[Rung]], Sequence[dict]],
        evaluate: Callable[[dict, float, Optional[float]], Tuple[float, bool, float]],
        on_result: Callable[[dict, float, float, bool, float], None],
        should_stop: Callable[[], bool],
        evaluate_batch: Optional[
            Callable[[List[dict], float, Optional[float]], List[Tuple[float, bool, float]]]
        ] = None,
    ) -> List[EvalOutcome]:
        """Run one SH inner loop; returns outcomes of the final rung."""
        args = (bracket, provide_candidates, evaluate, on_result, should_stop, evaluate_batch)
        if self.backend == "table":
            return self._run_bracket_table(*args)
        return self._run_bracket_loop(*args)

    # ------------------------------------------------------- scalar reference
    def _run_bracket_loop(
        self, bracket, provide_candidates, evaluate, on_result, should_stop, evaluate_batch
    ) -> List[EvalOutcome]:
        rungs = bracket.rungs
        configs = provide_candidates(rungs[0].n, rungs)
        outcomes: List[EvalOutcome] = []
        survivors = list(configs)
        for rung_i, rung in enumerate(rungs):
            if should_stop():
                break
            with obs.span(
                "rung_eval", s=bracket.s, rung=rung_i, delta=rung.delta,
                n=min(rung.n, len(survivors)),
            ) as sp:
                results: List[EvalOutcome] = []
                if evaluate_batch is not None:
                    batch = survivors[: rung.n]
                    cap = self._cost_cap(rung.delta)
                    for cfg, (perf, failed, elapsed) in zip(
                        batch, evaluate_batch(batch, rung.delta, cap)
                    ):
                        self._record_cost(rung.delta, elapsed)
                        on_result(cfg, rung.delta, perf, failed, elapsed)
                        results.append(EvalOutcome(cfg, perf, failed, elapsed))
                else:
                    for cfg in survivors[: rung.n]:
                        if should_stop():
                            break
                        cap = self._cost_cap(rung.delta)
                        perf, failed, elapsed = evaluate(cfg, rung.delta, cap)
                        self._record_cost(rung.delta, elapsed)
                        on_result(cfg, rung.delta, perf, failed, elapsed)
                        results.append(EvalOutcome(cfg, perf, failed, elapsed))
                ok = [r for r in results if not r.failed]
                ok.sort(key=lambda r: r.performance)
                sp.set(
                    evaluated=len(results), ok=len(ok),
                    cost=float(sum(r.elapsed for r in results)),
                )
                if rung_i + 1 < len(rungs):
                    # promotion quota over *successful* evaluations: counting
                    # failed rows (the old len(results)) promoted more than the
                    # top n_i/eta of the configs that actually have a score
                    keep = max(len(ok) // self.eta, 1)
                    survivors = [r.config for r in ok[:keep]]
                    sp.set(survivors=len(survivors))
                    if not survivors:
                        break
                else:
                    outcomes = results
        return outcomes

    # ----------------------------------------------------- array-native table
    def _run_bracket_table(
        self, bracket, provide_candidates, evaluate, on_result, should_stop, evaluate_batch
    ) -> List[EvalOutcome]:
        rungs = bracket.rungs
        configs = provide_candidates(rungs[0].n, rungs)
        table = RungTable(bracket, configs)
        self.tables.append(table)
        outcomes: List[EvalOutcome] = []
        survivors = np.arange(len(configs), dtype=np.int64)
        for rung_i, rung in enumerate(rungs):
            if should_stop():
                break
            idxs = survivors[: rung.n]
            with obs.span(
                "rung_eval", s=bracket.s, rung=rung_i, delta=rung.delta, n=len(idxs)
            ) as sp:
                if evaluate_batch is not None:
                    batch = [configs[int(i)] for i in idxs]
                    cap = self._cost_cap(rung.delta)
                    res = evaluate_batch(batch, rung.delta, cap)
                    idxs = idxs[: len(res)]  # budget may truncate to a prefix
                    perf = np.fromiter((r[0] for r in res), dtype=np.float64, count=len(res))
                    fail = np.fromiter((r[1] for r in res), dtype=bool, count=len(res))
                    elap = np.fromiter((r[2] for r in res), dtype=np.float64, count=len(res))
                    if isinstance(self._cost_history, CostColumns):
                        self._cost_history.extend(round(rung.delta, 6), elap)
                    else:
                        for e in elap:
                            self._record_cost(rung.delta, float(e))
                    for i, (p, f, e) in zip(idxs, res):
                        on_result(configs[int(i)], rung.delta, p, f, e)
                else:
                    done, perf_l, fail_l, elap_l = 0, [], [], []
                    for i in idxs:
                        if should_stop():
                            break
                        cfg = configs[int(i)]
                        cap = self._cost_cap(rung.delta)
                        p, f, e = evaluate(cfg, rung.delta, cap)
                        self._record_cost(rung.delta, e)
                        on_result(cfg, rung.delta, p, f, e)
                        perf_l.append(p)
                        fail_l.append(f)
                        elap_l.append(e)
                        done += 1
                    idxs = idxs[:done]
                    perf = np.asarray(perf_l, dtype=np.float64)
                    fail = np.asarray(fail_l, dtype=bool)
                    elap = np.asarray(elap_l, dtype=np.float64)
                table.record(rung_i, idxs, perf, fail, elap, trace_id=sp.id)
                sp.set(
                    evaluated=len(idxs), ok=int(len(idxs) - np.count_nonzero(fail)),
                    cost=float(elap.sum()),
                )
                if rung_i + 1 < len(rungs):
                    survivors = table.promote(rung_i, self.eta)
                    sp.set(survivors=int(survivors.size))
                    if survivors.size == 0:
                        break
                else:
                    outcomes = table.rung_outcomes(rung_i)
        return outcomes
