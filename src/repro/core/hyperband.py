"""Hyperband / successive-halving scheduling (paper §3.4, Alg. 1, Table 1).

The schedule is computed exactly as in Alg. 1:
    s_max = floor(log_eta(R)),  B = (s_max + 1) * R
    for s in {s_max, ..., 0}:
        n_1 = ceil(B/R * eta^s / (s+1)),  r_1 = R * eta^{-s}
        run SH(n_1, r_1)
Inside SH, after evaluating n_i configs at resource r_i, the top n_i/eta
advance to r_{i+1} = eta * r_i until r = R.

Resources map to fidelity deltas: delta = r / R (so R=9, eta=3 gives the
paper's default proxy levels 1/9, 1/3, 1).

Evaluation is delegated to a callback so the same scheduler drives the
Spark simulator, the JAX objective and the unit tests. The §6.3 median
early-stop is applied here: an evaluation is capped at the median cost of
historical evaluations at the same fidelity (factor configurable).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["hb_schedule", "sh_schedule", "Bracket", "Rung", "HyperbandRunner"]


@dataclass
class Rung:
    n: int           # configs evaluated at this rung
    r: float         # resource units
    delta: float     # fidelity r / R


@dataclass
class Bracket:
    s: int
    rungs: List[Rung]


def sh_schedule(n1: int, r1: float, R: float, eta: int) -> List[Rung]:
    rungs = []
    n, r = n1, r1
    while True:
        rungs.append(Rung(n=max(int(n), 1), r=r, delta=min(r / R, 1.0)))
        if r >= R - 1e-9:
            break
        n = max(int(np.floor(n / eta)), 1)
        r = r * eta
    return rungs


def hb_schedule(R: float, eta: int) -> List[Bracket]:
    """Alg. 1 / Table 1 enumeration of (n_i, r_i)."""
    s_max = int(np.floor(np.log(R) / np.log(eta)))
    B = (s_max + 1) * R
    brackets = []
    for s in range(s_max, -1, -1):
        n1 = int(np.ceil(B / R * (eta**s) / (s + 1)))
        r1 = R * (eta ** (-s))
        brackets.append(Bracket(s=s, rungs=sh_schedule(n1, r1, R, eta)))
    return brackets


@dataclass
class EvalOutcome:
    config: dict
    performance: float
    failed: bool
    elapsed: float


class HyperbandRunner:
    """Drives one SH inner loop at a time.

    provide_candidates(n, rungs) -> list of configs for a new bracket
        (the controller injects warm starts + BO candidates here).
    evaluate(config, delta, cost_cap) -> (performance, failed, elapsed)
        performance must be comparable within a fidelity (lower better).
    on_result(config, delta, performance, failed, elapsed) -> None
        observation hook (knowledge base updates).
    should_stop() -> bool  budget check between evaluations.

    Batched rungs: pass ``evaluate_batch(configs, delta, cost_cap) ->
    list[(performance, failed, elapsed)]`` to ``run_bracket`` and every rung
    evaluates all of its survivors in one call (the vectorized
    ``Workload.evaluate_many`` path). The median-cost cap is computed once
    from the history at rung start and applied to the whole rung (the
    scalar path refreshes it per config — the only semantic difference);
    per-config cost history, on_result hooks and promotion are unchanged.
    The callback may return fewer results than configs (a prefix) when the
    caller's budget runs out mid-rung, mirroring the scalar path's
    between-config should_stop checks.
    """

    def __init__(
        self,
        R: float = 9,
        eta: int = 3,
        early_stop_factor: float = 1.0,
        seed: int = 0,
    ):
        self.R = R
        self.eta = eta
        self.early_stop_factor = early_stop_factor
        self.brackets = hb_schedule(R, eta)
        self._bracket_idx = 0
        self._cost_history: Dict[float, List[float]] = {}
        self.rng = np.random.default_rng(seed)

    def next_bracket(self) -> Bracket:
        b = self.brackets[self._bracket_idx % len(self.brackets)]
        self._bracket_idx += 1
        return b

    def _cost_cap(self, delta: float) -> Optional[float]:
        hist = self._cost_history.get(round(delta, 6), [])
        if len(hist) < 3:
            return None
        return self.early_stop_factor * float(np.median(hist))

    def run_bracket(
        self,
        bracket: Bracket,
        provide_candidates: Callable[[int, List[Rung]], List[dict]],
        evaluate: Callable[[dict, float, Optional[float]], Tuple[float, bool, float]],
        on_result: Callable[[dict, float, float, bool, float], None],
        should_stop: Callable[[], bool],
        evaluate_batch: Optional[
            Callable[[List[dict], float, Optional[float]], List[Tuple[float, bool, float]]]
        ] = None,
    ) -> List[EvalOutcome]:
        """Run one SH inner loop; returns outcomes of the final rung."""
        rungs = bracket.rungs
        configs = provide_candidates(rungs[0].n, rungs)
        outcomes: List[EvalOutcome] = []
        survivors = list(configs)
        for rung_i, rung in enumerate(rungs):
            if should_stop():
                break
            results: List[EvalOutcome] = []
            if evaluate_batch is not None:
                batch = survivors[: rung.n]
                cap = self._cost_cap(rung.delta)
                for cfg, (perf, failed, elapsed) in zip(
                    batch, evaluate_batch(batch, rung.delta, cap)
                ):
                    self._cost_history.setdefault(round(rung.delta, 6), []).append(elapsed)
                    on_result(cfg, rung.delta, perf, failed, elapsed)
                    results.append(EvalOutcome(cfg, perf, failed, elapsed))
            else:
                for cfg in survivors[: rung.n]:
                    if should_stop():
                        break
                    cap = self._cost_cap(rung.delta)
                    perf, failed, elapsed = evaluate(cfg, rung.delta, cap)
                    self._cost_history.setdefault(round(rung.delta, 6), []).append(elapsed)
                    on_result(cfg, rung.delta, perf, failed, elapsed)
                    results.append(EvalOutcome(cfg, perf, failed, elapsed))
            ok = [r for r in results if not r.failed]
            ok.sort(key=lambda r: r.performance)
            if rung_i + 1 < len(rungs):
                keep = max(int(np.floor(len(results) / self.eta)), 1)
                survivors = [r.config for r in ok[:keep]]
                if not survivors:
                    break
            else:
                outcomes = results
        return outcomes
