"""Candidate configuration generation (paper §6.2).

BO candidates come from a *combined* surrogate: one PRF per source task
plus one PRF per fidelity level of the current task. Because surrogate
output scales differ across tasks, acquisition (EI) scores are combined by
weighted rank aggregation R(x) = sum_i w_i R_i(x); the top-n by aggregate
rank are recommended. Candidate pool = random samples + mutations of the
current incumbents (OpenBox-style "sampling and mutation").

Two-phase warm start: Phase 1 picks the single best config of the most
similar source task for one immediate full-fidelity evaluation; Phase 2
maintains G_ws = union of better-than-median configs of all sources ranked
by v(.) (Eq. 3) and injects a few of them at the start of each SH inner
loop — as many as will survive to full fidelity, so they cannot evict each
other.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .. import obs as _obs
from .acquisition import (
    aggregate_ranks,
    get_acquisition_backend,
    get_acquisition_pool,
    score_sources,
)
from .knowledge import TaskRecord
from .similarity import TaskWeights, surrogate_for_task
from .space import ConfigBatch, ConfigSpace
from .surrogate import Surrogate, make_forest

Config = Dict[str, Any]

__all__ = [
    "CandidateColumns",
    "CandidateGenerator",
    "SurrogateStore",
    "WarmStartQueue",
    "phase1_config",
]


class CandidateColumns(Sequence):
    """Provisioned candidates: warm-start dicts + one columnar BO batch.

    Indexes like a list of Config dicts (what ``HyperbandRunner`` needs),
    but the BO rows stay columnar until first touched — and each row
    materializes at most once (memoized), so rung bookkeeping can reference
    candidates purely by index column across rungs without re-building
    dicts, and the batch's canonical value matrix / unit encoding remain
    available to downstream consumers (``.batch``).
    """

    __slots__ = ("head", "batch", "_limit", "_memo")

    def __init__(self, head: Sequence[Config], batch: ConfigBatch, limit: Optional[int] = None):
        self.head = list(head)
        self.batch = batch
        n = len(self.head) + len(batch)
        self._limit = n if limit is None else min(int(limit), n)
        self._memo: Dict[int, Config] = {}

    def __len__(self) -> int:
        return self._limit

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self[j] for j in range(*i.indices(len(self)))]
        i = int(i)
        if i < 0:
            i += self._limit
        if not 0 <= i < self._limit:
            raise IndexError(i)
        if i < len(self.head):
            return self.head[i]
        j = i - len(self.head)
        got = self._memo.get(j)
        if got is None:
            got = self.batch[j]
            self._memo[j] = got
        return got


def phase1_config(weights: TaskWeights, tasks: Dict[str, TaskRecord]) -> Optional[Config]:
    """Best config of the best similar source task (Phase 1 warm start)."""
    best_tid, best_sim = None, 0.0
    for tid, w in weights.weights.items():
        if tid != "__target__" and w > best_sim:
            best_tid, best_sim = tid, w
    if best_tid is None:
        return None
    best_obs = tasks[best_tid].best()
    return dict(best_obs.config) if best_obs else None


class WarmStartQueue:
    """Phase 2 warm start: ranked G_ws, consumed a few at a time."""

    def __init__(self):
        self._items: List[Tuple[float, Config]] = []
        self._served: set = set()

    def rebuild(self, weights: TaskWeights, tasks: Dict[str, TaskRecord]) -> None:
        items: List[Tuple[float, Config]] = []
        for tid, w in weights.weights.items():
            if tid == "__target__" or w <= 0 or tid not in tasks:
                continue
            obs = tasks[tid].full_fidelity()
            if len(obs) < 2:
                continue
            perf = np.array([o.performance for o in obs])
            f_med = float(np.median(perf))
            if f_med <= 0:
                continue
            for o in obs:
                if o.performance < f_med:
                    v = w * (f_med - o.performance) / f_med  # Eq. 3 priority
                    items.append((v, dict(o.config)))
        items.sort(key=lambda t: -t[0])
        self._items = items

    def take(self, n: int) -> List[Config]:
        out: List[Config] = []
        for v, cfg in self._items:
            key = tuple(sorted((k, repr(val)) for k, val in cfg.items()))
            if key in self._served:
                continue
            self._served.add(key)
            out.append(cfg)
            if len(out) >= n:
                break
        return out


@dataclass
class SurrogateSource:
    """A weighted surrogate participating in the combined ranking."""

    name: str
    model: Surrogate
    weight: float
    incumbent: float  # best observed value for its own data (EI reference)


class SurrogateStore:
    """Keyed surrogate cache with rung-to-rung reuse and LRU eviction.

    One entry per source name (``task:<tid>`` / ``fid:<delta>:<tid>``),
    fingerprinted by the observation count the model was fitted on: a
    fidelity surrogate is only refit when its rung gained observations, so
    evaluations at one Hyperband rung never invalidate the other rungs'
    models. Replacing a stale fingerprint drops the old model immediately;
    the LRU cap bounds memory across many tasks/brackets.
    """

    def __init__(self, max_entries: int = 64):
        self.max_entries = max_entries
        self._entries: "OrderedDict[str, Tuple[int, Surrogate, float]]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(
        self,
        name: str,
        fingerprint: int,
        build: Callable[[], Optional[Tuple[Surrogate, float]]],
    ) -> Optional[Tuple[Surrogate, float]]:
        """Return the cached (model, incumbent) for ``name`` if its
        fingerprint still matches, else (re)build and cache it."""
        entry = self._entries.get(name)
        if entry is not None and entry[0] == fingerprint:
            self._entries.move_to_end(name)
            self.hits += 1
            return entry[1], entry[2]
        built = build()
        if built is None:
            return None
        self.misses += 1
        self._entries[name] = (fingerprint, built[0], built[1])
        self._entries.move_to_end(name)
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
            self.evictions += 1
        return built


class CandidateGenerator:
    def __init__(
        self,
        space: ConfigSpace,
        seed: int = 0,
        pool_size: int = 256,
        backend: Optional[str] = None,
        cache_entries: int = 64,
    ):
        self.space = space                # full space: defines the surrogate encoding
        self.sample_space = space         # possibly compressed: defines the sampling region
        self.seed = seed
        self.pool_size = pool_size
        self.backend = backend            # packed-forest backend for fitted surrogates
        self._rng = np.random.default_rng(seed)
        self._store = SurrogateStore(max_entries=cache_entries)
        # encoded-exclusion cache: recommend is called once per bracket with
        # the (append-only, heavily overlapping) list of already-evaluated
        # configs; canonical row keys are cached per config-dict identity so
        # each config is encoded once per tuning run instead of per call.
        self._key_cache: Dict[int, bytes] = {}
        self._key_refs: List[Config] = []  # keeps dicts alive => ids stay valid
        self._propose_eng: Any = None  # lazy ProposeEngine; False = unavailable

    def set_sample_space(self, space: ConfigSpace) -> None:
        """Install the compressed space; candidates are sampled from it and
        completed with defaults for dropped knobs before encoding."""
        self.sample_space = space

    @property
    def cache_stats(self) -> Dict[str, int]:
        s = self._store
        return {"hits": s.hits, "misses": s.misses, "evictions": s.evictions, "size": len(s)}

    # ------------------------------------------------------------ surrogates
    def build_sources(
        self,
        weights: TaskWeights,
        tasks: Dict[str, TaskRecord],
        target: TaskRecord,
        fidelities: Sequence[float],
    ) -> List[SurrogateSource]:
        sources: List[SurrogateSource] = []
        # historical tasks (cached: source observations are frozen, so the
        # fingerprint only changes if the task record itself grows)
        for tid, w in weights.weights.items():
            if tid == "__target__" or w <= 0 or tid not in tasks:
                continue

            def build_task(task=tasks[tid], tid=tid):
                with _obs.span("surrogate_fit", source=f"task:{tid}",
                               n_obs=len(task.observations)):
                    m = surrogate_for_task(
                        self.space, task, seed=self.seed, backend=self.backend
                    )
                    if m is None:
                        return None
                    obs = task.full_fidelity()
                    return m, (min(o.performance for o in obs) if obs else 0.0)

            got = self._store.get(f"task:{tid}", len(tasks[tid].observations), build_task)
            if got is None:
                continue
            sources.append(
                SurrogateSource(name=f"task:{tid}", model=got[0], weight=w, incumbent=got[1])
            )
        # current task, one surrogate per fidelity level with observations;
        # rung-to-rung reuse: only the rung whose observation count changed
        # is refit, the other fidelity surrogates come from the store
        w_t = weights.weights.get("__target__", 0.0)
        for d in fidelities:
            all_obs = target.at_fidelity(d, include_failed=True)
            ok_obs = [o for o in all_obs if not o.failed]
            if len(ok_obs) < 2:
                continue

            def build_fid(all_obs=all_obs, ok_obs=ok_obs, d=d):
                # failed evaluations (OOM / early-stop) enter the fit at a
                # crash-cost penalty instead of being hidden: with log-space
                # sampling a large pool fraction can sit in the failure
                # region, and a surrogate that never sees failures keeps
                # recommending into it (SMAC-style imputation)
                with _obs.span("surrogate_fit", source=f"fid:{d:.3f}",
                               n_obs=len(all_obs)):
                    penalty = 2.0 * max(o.performance for o in ok_obs)
                    X = self.space.encode_many([o.config for o in all_obs])
                    y = np.array(
                        [penalty if o.failed else o.performance for o in all_obs]
                    )
                    m = make_forest(seed=self.seed, backend=self.backend).fit(X, y)
                    return m, float(min(o.performance for o in ok_obs))

            got = self._store.get(f"fid:{d:.6f}:{target.task_id}", len(all_obs), build_fid)
            if got is None:
                continue
            # full fidelity of the target carries the target weight; lower
            # fidelities share it, scaled by their level (closer to full =
            # more trustworthy), mirroring MFES-style fidelity weighting.
            wt = w_t * (d if w_t > 0 else 0.0)
            if w_t <= 0:
                # with no established target weight (early phase) the current
                # task's own data is still the only guidance; give it mass.
                wt = d
            sources.append(
                SurrogateSource(name=f"fid:{d:.3f}", model=got[0], weight=wt, incumbent=got[1])
            )
        return sources

    # ------------------------------------------------------------- candidates
    def _candidate_pool(self, incumbents: Sequence[Config]) -> ConfigBatch:
        """Random samples + incumbent mutations as one columnar batch.

        Sampling and mutation run in the (possibly compressed) sample space;
        the batch is then lifted into the full space (dropped knobs take
        full-space defaults) so every candidate is a valid configuration —
        all without materializing Config dicts.
        """
        ss = self.sample_space
        n_mut = min(self.pool_size // 4, 16 * max(len(incumbents), 1))
        with _obs.span("pool_gen", pool_size=self.pool_size,
                       mutations=n_mut if incumbents else 0):
            pool = ss.sample(self._rng, self.pool_size - n_mut if incumbents else self.pool_size)
            proj = None
            if incumbents:
                bases = ConfigBatch.from_configs(
                    ss, [incumbents[i % len(incumbents)] for i in range(n_mut)]
                )
                proj = ss.project_many(bases)
                muts = ss.mutate_many(proj, self._rng)
                pool = ConfigBatch.concat([pool, muts])
            full = self.space.complete_batch(pool)
            if proj is not None and n_mut:
                # mutation provenance: candidate i of the mutation block
                # derives from incumbent i % B — the projected-and-completed
                # base rows are the exact unmutated-coordinate reference, so
                # pool scoring can reuse each base's word ANDs (chain-delta)
                B = min(len(incumbents), n_mut)
                base_full = self.space.complete_batch(proj.take(np.arange(B)))
                base_of = np.concatenate([
                    np.full(len(full) - n_mut, -1, dtype=np.int64),
                    np.arange(n_mut, dtype=np.int64) % B,
                ])
                full.set_delta(base_full.unit(), base_of)
            return full

    def _config_keys(self, cfgs: Sequence[Config]) -> List[bytes]:
        """Canonical row keys for config dicts, cached per dict identity."""
        out: List[Optional[bytes]] = []
        missing: List[Config] = []
        missing_pos: List[int] = []
        for c in cfgs:
            k = self._key_cache.get(id(c))
            if k is None:
                missing.append(c)
                missing_pos.append(len(out))
            out.append(k)
        if missing:
            keys = ConfigBatch.from_configs(self.space, missing).row_keys()
            if len(self._key_refs) > 8192:  # bound memory across long runs
                self._key_cache.clear()
                self._key_refs.clear()
            for c, key, pos in zip(missing, keys, missing_pos):
                self._key_cache[id(c)] = key
                self._key_refs.append(c)
                out[pos] = key
        return out  # type: ignore[return-value]

    def recommend(
        self,
        n: int,
        sources: Sequence[SurrogateSource],
        incumbents: Sequence[Config] = (),
        exclude: Sequence[Config] = (),
    ) -> List[Config]:
        """Top-n candidates by weighted rank-aggregated EI (§6.2).

        The pool stays columnar end-to-end: one unit-cube encoding feeds all
        sources in a fused pass (shared packed-forest descent + EI matrix +
        rank aggregation); only the returned top-n materialize as dicts.
        """
        active = [s for s in sources if s.weight > 0]
        if active and get_acquisition_backend() != "numpy":
            got = self._recommend_fused(n, active, incumbents, exclude)
            if got is not None:
                return got
        return self._recommend_pool_batch(n, active, incumbents, exclude).materialize()

    def recommend_batch(
        self,
        n: int,
        sources: Sequence[SurrogateSource],
        incumbents: Sequence[Config] = (),
        exclude: Sequence[Config] = (),
    ) -> ConfigBatch:
        """``recommend`` returning the top-n as one columnar ``ConfigBatch``.

        Identical selection (materializing the batch yields the same dicts
        in the same order as ``recommend``), but no dict materialization on
        the staged path — rung-table provisioning and the future async-ASHA
        service layer consume the index columns directly.
        """
        active = [s for s in sources if s.weight > 0]
        if active and get_acquisition_backend() != "numpy":
            got = self._recommend_fused(n, active, incumbents, exclude)
            if got is not None:
                return ConfigBatch.from_configs(self.space, got)
        return self._recommend_pool_batch(n, active, incumbents, exclude)

    def _recommend_pool_batch(
        self,
        n: int,
        active: Sequence[SurrogateSource],
        incumbents: Sequence[Config],
        exclude: Sequence[Config],
    ) -> ConfigBatch:
        """Staged numpy path: pool → dedup → score → stable top-n, columnar."""
        pool = self._candidate_pool(incumbents)
        # de-duplicate against already-evaluated configs (exact canonical
        # row match; the exclusion keys are cached across calls)
        if len(exclude):
            seen = set(self._config_keys(exclude))
            keep = np.array([k not in seen for k in pool.row_keys()], dtype=bool)
            if keep.any() and not keep.all():
                pool = pool.take(np.flatnonzero(keep))
        if not active:
            order = self._rng.permutation(len(pool))
            return pool.take(order[:n])
        with _obs.span("acquisition", pool=len(pool), sources=len(active), k=n):
            X = pool.unit()
            scores = score_sources([s.model for s in active], X,
                                   [s.incumbent for s in active],
                                   delta=pool.delta)
            agg = aggregate_ranks(scores, [s.weight for s in active])
            order = np.argsort(agg, kind="stable")
            return pool.take(order[:n])

    # -------------------------------------------------------- fused propose
    @property
    def propose_engine(self):
        """Lazy ProposeEngine (None when jax is unavailable)."""
        if self._propose_eng is None:
            try:
                from .propose import ProposeEngine

                eng = ProposeEngine(
                    self.space, seed=self.seed, pool_size=self.pool_size
                )
                self._propose_eng = eng if eng.available() else False
            except ImportError:
                self._propose_eng = False
        return self._propose_eng or None

    def _recommend_fused(
        self,
        n: int,
        active: Sequence[SurrogateSource],
        incumbents: Sequence[Config],
        exclude: Sequence[Config],
    ) -> Optional[List[Config]]:
        """Route recommend through the fused on-device propose step.

        Returns None when the fused program doesn't apply (no jax, non-PRF
        sources, loop backend, non-uniform tree counts) so the staged numpy
        path takes over. Pool mode "host" scores the generator's own pool
        on device — selections are bit-identical to the numpy path; pool
        mode "device" draws the pool on device from the engine's threaded
        PRNG key (different draws than the host rng — SEED NOTE).
        """
        eng = self.propose_engine
        models = [s.model for s in active]
        if eng is None or not eng.fusable(models):
            return None
        descent = "pallas" if get_acquisition_backend() == "pallas" else "auto"
        incs = [s.incumbent for s in active]
        ws = [s.weight for s in active]
        if get_acquisition_pool() == "host":
            pool = self._candidate_pool(incumbents)
            if len(exclude):
                seen = set(self._config_keys(exclude))
                keep = np.array([k not in seen for k in pool.row_keys()], dtype=bool)
                if keep.any() and not keep.all():
                    pool = pool.take(np.flatnonzero(keep))
            idx = eng.score_topk(models, pool.unit(), incs, ws, n, descent=descent)
            return [pool[int(i)] for i in idx]
        _, units, _ = eng.propose(
            models, incs, ws, n, sample_space=self.sample_space, descent=descent
        )
        batch = self.space.decode_many(units)
        if not len(exclude):
            return [batch[int(i)] for i in range(min(n, len(batch)))]
        seen = set(self._config_keys(exclude))
        out: List[Config] = []
        for i, key in enumerate(batch.row_keys()):
            if key in seen:
                continue
            out.append(batch[int(i)])
            if len(out) >= n:
                break
        return out
