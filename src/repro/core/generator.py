"""Candidate configuration generation (paper §6.2).

BO candidates come from a *combined* surrogate: one PRF per source task
plus one PRF per fidelity level of the current task. Because surrogate
output scales differ across tasks, acquisition (EI) scores are combined by
weighted rank aggregation R(x) = sum_i w_i R_i(x); the top-n by aggregate
rank are recommended. Candidate pool = random samples + mutations of the
current incumbents (OpenBox-style "sampling and mutation").

Two-phase warm start: Phase 1 picks the single best config of the most
similar source task for one immediate full-fidelity evaluation; Phase 2
maintains G_ws = union of better-than-median configs of all sources ranked
by v(.) (Eq. 3) and injects a few of them at the start of each SH inner
loop — as many as will survive to full fidelity, so they cannot evict each
other.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .acquisition import ei_scores, rank_aggregate
from .knowledge import TaskRecord
from .similarity import TaskWeights, surrogate_for_task
from .space import ConfigSpace
from .surrogate import ProbabilisticRandomForest, Surrogate

Config = Dict[str, Any]

__all__ = ["CandidateGenerator", "WarmStartQueue", "phase1_config"]


def phase1_config(weights: TaskWeights, tasks: Dict[str, TaskRecord]) -> Optional[Config]:
    """Best config of the best similar source task (Phase 1 warm start)."""
    best_tid, best_sim = None, 0.0
    for tid, w in weights.weights.items():
        if tid != "__target__" and w > best_sim:
            best_tid, best_sim = tid, w
    if best_tid is None:
        return None
    best_obs = tasks[best_tid].best()
    return dict(best_obs.config) if best_obs else None


class WarmStartQueue:
    """Phase 2 warm start: ranked G_ws, consumed a few at a time."""

    def __init__(self):
        self._items: List[Tuple[float, Config]] = []
        self._served: set = set()

    def rebuild(self, weights: TaskWeights, tasks: Dict[str, TaskRecord]) -> None:
        items: List[Tuple[float, Config]] = []
        for tid, w in weights.weights.items():
            if tid == "__target__" or w <= 0 or tid not in tasks:
                continue
            obs = tasks[tid].full_fidelity()
            if len(obs) < 2:
                continue
            perf = np.array([o.performance for o in obs])
            f_med = float(np.median(perf))
            if f_med <= 0:
                continue
            for o in obs:
                if o.performance < f_med:
                    v = w * (f_med - o.performance) / f_med  # Eq. 3 priority
                    items.append((v, dict(o.config)))
        items.sort(key=lambda t: -t[0])
        self._items = items

    def take(self, n: int) -> List[Config]:
        out: List[Config] = []
        for v, cfg in self._items:
            key = tuple(sorted((k, repr(val)) for k, val in cfg.items()))
            if key in self._served:
                continue
            self._served.add(key)
            out.append(cfg)
            if len(out) >= n:
                break
        return out


@dataclass
class SurrogateSource:
    """A weighted surrogate participating in the combined ranking."""

    name: str
    model: Surrogate
    weight: float
    incumbent: float  # best observed value for its own data (EI reference)


class CandidateGenerator:
    def __init__(self, space: ConfigSpace, seed: int = 0, pool_size: int = 256):
        self.space = space                # full space: defines the surrogate encoding
        self.sample_space = space         # possibly compressed: defines the sampling region
        self.seed = seed
        self.pool_size = pool_size
        self._rng = np.random.default_rng(seed)
        self._model_cache = {}

    def set_sample_space(self, space: ConfigSpace) -> None:
        """Install the compressed space; candidates are sampled from it and
        completed with defaults for dropped knobs before encoding."""
        self.sample_space = space

    _model_cache: Dict[Tuple[str, int], Tuple[Surrogate, float]] = None  # set in __init__

    # ------------------------------------------------------------ surrogates
    def build_sources(
        self,
        weights: TaskWeights,
        tasks: Dict[str, TaskRecord],
        target: TaskRecord,
        fidelities: Sequence[float],
    ) -> List[SurrogateSource]:
        sources: List[SurrogateSource] = []
        # historical tasks (surrogates cached: source observations are frozen)
        for tid, w in weights.weights.items():
            if tid == "__target__" or w <= 0 or tid not in tasks:
                continue
            key = (f"task:{tid}", len(tasks[tid].observations))
            if key not in self._model_cache:
                m = surrogate_for_task(self.space, tasks[tid], seed=self.seed)
                if m is None:
                    continue
                obs = tasks[tid].full_fidelity()
                inc = min(o.performance for o in obs) if obs else 0.0
                self._model_cache[key] = (m, inc)
            m, inc = self._model_cache[key]
            sources.append(SurrogateSource(name=f"task:{tid}", model=m, weight=w, incumbent=inc))
        # current task, one surrogate per fidelity level with observations
        w_t = weights.weights.get("__target__", 0.0)
        for d in fidelities:
            obs = target.at_fidelity(d)
            if len(obs) < 2:
                continue
            key = (f"fid:{d:.6f}:{target.task_id}", len(obs))
            if key in self._model_cache:
                m, _ = self._model_cache[key]
                y = np.array([o.performance for o in obs])
            else:
                X = self.space.encode_many([o.config for o in obs])
                y = np.array([o.performance for o in obs])
                m = ProbabilisticRandomForest(seed=self.seed).fit(X, y)
                self._model_cache[key] = (m, float(y.min()))
            # full fidelity of the target carries the target weight; lower
            # fidelities share it, scaled by their level (closer to full =
            # more trustworthy), mirroring MFES-style fidelity weighting.
            wt = w_t * (d if w_t > 0 else 0.0)
            if w_t <= 0:
                # with no established target weight (early phase) the current
                # task's own data is still the only guidance; give it mass.
                wt = d
            sources.append(
                SurrogateSource(name=f"fid:{d:.3f}", model=m, weight=wt, incumbent=float(y.min()))
            )
        return sources

    # ------------------------------------------------------------- candidates
    def _candidate_pool(self, incumbents: Sequence[Config]) -> List[Config]:
        ss = self.sample_space
        n_mut = min(self.pool_size // 4, 16 * max(len(incumbents), 1))
        pool = ss.sample(self._rng, self.pool_size - n_mut if incumbents else self.pool_size)
        if incumbents:
            for i in range(n_mut):
                base = incumbents[i % len(incumbents)]
                pool.append(ss.mutate(ss.project(base), self._rng))
        # complete dropped knobs with full-space defaults so every candidate
        # is a valid full configuration
        return [dict(self.space.default(), **c) for c in pool]

    def recommend(
        self,
        n: int,
        sources: Sequence[SurrogateSource],
        incumbents: Sequence[Config] = (),
        exclude: Sequence[Config] = (),
    ) -> List[Config]:
        """Top-n candidates by weighted rank-aggregated EI (§6.2)."""
        pool = self._candidate_pool(incumbents)
        # de-duplicate against already-evaluated configs
        seen = {self._key(c) for c in exclude}
        pool = [c for c in pool if self._key(c) not in seen] or pool
        if not sources:
            self._rng.shuffle(pool)
            return pool[:n]
        X = self.space.encode_many(pool)
        score_lists, wts = [], []
        for s in sources:
            if s.weight <= 0:
                continue
            score_lists.append(ei_scores(s.model, X, s.incumbent))
            wts.append(s.weight)
        if not score_lists:
            self._rng.shuffle(pool)
            return pool[:n]
        agg = rank_aggregate(score_lists, wts)
        order = np.argsort(agg, kind="stable")
        return [pool[i] for i in order[:n]]

    @staticmethod
    def _key(cfg: Config) -> tuple:
        return tuple(sorted((k, repr(v)) for k, v in cfg.items()))
