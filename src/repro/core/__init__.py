"""MFTune core: the paper's contribution as a composable library.

Public API:
  ConfigSpace & knobs       — search-space definition with range unions
  ProbabilisticRandomForest — BO surrogate (paper §3.3)
  SimilarityEngine          — §4.2 transfer weights + transition mechanism
  SpaceCompressor           — §5 SHAP+KDE density-based compression
  greedy_query_subset       — §6.1 Alg. 2 fidelity partitioning
  CandidateGenerator        — §6.2 combined-rank BO + two-phase warm start
  HyperbandRunner           — §3.4 HB/SHA scheduling with median early stop
  MFTune                    — §4.1/§6.3 end-to-end controller
"""

from .space import (
    BoolKnob,
    CatKnob,
    ConfigBatch,
    ConfigSpace,
    FloatKnob,
    IntKnob,
    Intervals,
    SpacePlane,
    get_space_backend,
    log_sampling,
    set_log_sampling,
    set_space_backend,
    space_backend,
)
from .surrogate import (
    ForestPlane,
    GaussianProcess,
    PackedForest,
    ProbabilisticRandomForest,
    forest_backend,
    make_forest,
    set_forest_backend,
)
from .acquisition import (
    EI_VAR_FLOOR,
    acquisition_backend,
    acquisition_pool,
    aggregate_ranks,
    aggregate_ranks_jax,
    expected_improvement,
    expected_improvement_jax,
    get_acquisition_backend,
    get_acquisition_pool,
    normal_cdf,
    plane_cache_stats,
    rank_aggregate,
    score_sources,
    set_acquisition_backend,
    set_acquisition_pool,
    set_plane_cache_size,
)
from .propose import ProposeEngine
from .gbm import GradientBoostedTrees
from .kde import WeightedKDE, alpha_mass_categories, alpha_mass_region, silverman_bandwidth
from .shapley import draw_permutations, shapley_values, shapley_values_batch, shapley_values_exact
from .knowledge import KnowledgeBase, Observation, TaskRecord
from .similarity import SimilarityEngine, TaskWeights, kendall_tau, surrogate_for_task
from .compression import SpaceCompressor, compress_space, extract_promising_regions
from .fidelity import (
    FidelityPartition,
    collect_query_stats,
    early_stop_subset,
    greedy_query_subset,
    partition_fidelities,
    subset_correlation,
)
from .generator import (
    CandidateColumns,
    CandidateGenerator,
    SurrogateStore,
    WarmStartQueue,
    phase1_config,
)
from .hyperband import (
    Bracket,
    CostColumns,
    HyperbandRunner,
    Rung,
    RungTable,
    get_hyperband_backend,
    hb_schedule,
    hyperband_backend,
    set_hyperband_backend,
    sh_schedule,
)
from .mftune import MFTune, MFTuneOptions, TuningResult

__all__ = [
    "BoolKnob", "CatKnob", "ConfigSpace", "FloatKnob", "IntKnob", "Intervals",
    "ConfigBatch", "SpacePlane", "get_space_backend", "set_space_backend",
    "space_backend", "set_log_sampling", "log_sampling",
    "GaussianProcess", "ProbabilisticRandomForest",
    "PackedForest", "ForestPlane", "make_forest", "set_forest_backend", "forest_backend",
    "expected_improvement", "rank_aggregate", "aggregate_ranks", "normal_cdf", "score_sources",
    "EI_VAR_FLOOR", "expected_improvement_jax", "aggregate_ranks_jax",
    "set_acquisition_backend", "get_acquisition_backend", "acquisition_backend",
    "set_acquisition_pool", "get_acquisition_pool", "acquisition_pool",
    "set_plane_cache_size", "plane_cache_stats", "ProposeEngine",
    "GradientBoostedTrees",
    "WeightedKDE", "alpha_mass_categories", "alpha_mass_region", "silverman_bandwidth",
    "draw_permutations", "shapley_values", "shapley_values_batch", "shapley_values_exact",
    "KnowledgeBase", "Observation", "TaskRecord",
    "SimilarityEngine", "TaskWeights", "kendall_tau", "surrogate_for_task",
    "SpaceCompressor", "compress_space", "extract_promising_regions",
    "FidelityPartition", "collect_query_stats", "early_stop_subset",
    "greedy_query_subset", "partition_fidelities", "subset_correlation",
    "CandidateColumns", "CandidateGenerator", "SurrogateStore", "WarmStartQueue",
    "phase1_config",
    "Bracket", "HyperbandRunner", "Rung", "RungTable", "CostColumns",
    "hb_schedule", "sh_schedule",
    "get_hyperband_backend", "set_hyperband_backend", "hyperband_backend",
    "MFTune", "MFTuneOptions", "TuningResult",
]
