"""Acquisition functions and rank aggregation (paper §3.3, §6.2).

The acquisition path is a batched array program end-to-end: the normal CDF
is a vectorized ufunc (no per-candidate ``np.vectorize(erf)``),
``score_sources`` computes the EI matrix for *all* surrogate sources in one
fused pass (PRF sources share a single packed-forest descent via
``ForestPlane``), and ``aggregate_ranks`` turns an (S, N) score matrix into
weighted aggregate ranks with one argsort per source row.
"""

from __future__ import annotations

import math
from collections import OrderedDict
from typing import Sequence, Tuple

import numpy as np

from .surrogate import ForestPlane, ProbabilisticRandomForest, Surrogate

try:
    from scipy.special import ndtr as _ndtr
except ImportError:  # pragma: no cover - scipy ships with the image
    _ndtr = None

__all__ = [
    "normal_cdf",
    "expected_improvement",
    "ei_matrix",
    "ei_scores",
    "predict_sources",
    "score_sources",
    "aggregate_ranks",
    "rank_aggregate",
]

_SQRT2 = math.sqrt(2.0)


def normal_cdf(z: np.ndarray) -> np.ndarray:
    """Vectorized standard-normal CDF Phi(z)."""
    z = np.asarray(z, dtype=float)
    if _ndtr is not None:
        return _ndtr(z)
    return 0.5 * (1.0 + np.vectorize(math.erf)(z / _SQRT2))


def expected_improvement(mean: np.ndarray, var: np.ndarray, best: float) -> np.ndarray:
    """EI for *minimization*: E[max(best - y, 0)].

    ``best`` is the incumbent (lowest observed) objective value.
    """
    std = np.sqrt(np.maximum(var, 1e-12))
    z = (best - mean) / std
    # Phi and phi of the standard normal
    phi = np.exp(-0.5 * z**2) / np.sqrt(2 * np.pi)
    ei = (best - mean) * normal_cdf(z) + std * phi
    return np.maximum(ei, 0.0)


def ei_matrix(means: np.ndarray, vars_: np.ndarray, bests: np.ndarray) -> np.ndarray:
    """Row-wise EI: means/vars_ (S, N), bests (S,) -> EI (S, N)."""
    bests = np.asarray(bests, dtype=float)
    return expected_improvement(means, vars_, bests[:, None])


def ei_scores(model: Surrogate, X: np.ndarray, best: float) -> np.ndarray:
    mean, var = model.predict(X)
    return expected_improvement(mean, var, best)


# Fused planes keyed by the identities of their member arenas. PackedForest
# arenas are immutable and cached per PRF fit, so the same source set maps
# to the same key across recommend calls within a rung; the stored pack list
# guards against id() reuse. Small LRU — source sets churn with refits.
_PLANE_CACHE: "OrderedDict[tuple, Tuple[list, ForestPlane]]" = OrderedDict()
_PLANE_CACHE_MAX = 8


def _plane_for(packs: list) -> ForestPlane:
    key = tuple(id(p) for p in packs)
    entry = _PLANE_CACHE.get(key)
    if entry is not None and all(a is b for a, b in zip(entry[0], packs)):
        _PLANE_CACHE.move_to_end(key)
        return entry[1]
    plane = ForestPlane(packs)
    _PLANE_CACHE[key] = (packs, plane)
    while len(_PLANE_CACHE) > _PLANE_CACHE_MAX:
        _PLANE_CACHE.popitem(last=False)
    return plane


def predict_sources(
    models: Sequence[Surrogate], X: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """(means, vars), each (S, N), for all source surrogates on one pool.

    When every source is a fitted PRF on a packed backend, their arenas fuse
    into one :class:`ForestPlane` descent; otherwise each model predicts in
    turn (the GP / legacy-loop fallback).
    """
    X = np.atleast_2d(np.asarray(X, dtype=float))
    fusable = len(models) > 1 and all(
        isinstance(m, ProbabilisticRandomForest) and m.trees and m.backend != "loop"
        for m in models
    )
    if fusable:
        plane = _plane_for([m.pack() for m in models])
        # deterministic backend for a mixed-backend ensemble: an accelerated
        # backend wins over numpy regardless of model order
        backends = {m.backend for m in models}
        backend = next((b for b in ("pallas", "jax", "auto") if b in backends), "numpy")
        return plane.predict(X, backend=backend)
    means = np.empty((len(models), X.shape[0]))
    vars_ = np.empty_like(means)
    for i, m in enumerate(models):
        means[i], vars_[i] = m.predict(X)
    return means, vars_


def score_sources(
    models: Sequence[Surrogate], X: np.ndarray, incumbents: Sequence[float]
) -> np.ndarray:
    """Fused acquisition: EI of every source on every candidate, shape (S, N)."""
    means, vars_ = predict_sources(models, X)
    return ei_matrix(means, vars_, np.asarray(incumbents, dtype=float))


def aggregate_ranks(scores: np.ndarray, weights: Sequence[float]) -> np.ndarray:
    """Weighted rank aggregation R(x) = sum_i w_i * R_i(x)  (paper §6.2).

    ``scores`` is the (S, N) acquisition matrix; each row is converted to
    ranks where rank 0 = best (highest score). Lower aggregate rank = more
    promising. Returns the aggregate rank per candidate, shape (N,).
    """
    scores = np.atleast_2d(np.asarray(scores, dtype=float))
    if scores.size == 0:
        raise ValueError("no scores to aggregate")
    s, n = scores.shape
    order = np.argsort(-scores, axis=1, kind="stable")
    ranks = np.empty((s, n), dtype=float)
    np.put_along_axis(
        ranks, order, np.broadcast_to(np.arange(n, dtype=float), (s, n)), axis=1
    )
    w = np.asarray(weights, dtype=float)
    return (w[:, None] * ranks).sum(axis=0)


def rank_aggregate(score_lists: Sequence[np.ndarray], weights: Sequence[float]) -> np.ndarray:
    """Back-compat wrapper over :func:`aggregate_ranks` for a list of rows."""
    if len(score_lists) == 0:
        raise ValueError("no scores to aggregate")
    return aggregate_ranks(np.asarray(score_lists, dtype=float), weights)
