"""Acquisition functions and rank aggregation (paper §3.3, §6.2)."""

from __future__ import annotations

from typing import Sequence

import numpy as np

from .surrogate import Surrogate

__all__ = ["expected_improvement", "ei_scores", "rank_aggregate"]


def expected_improvement(mean: np.ndarray, var: np.ndarray, best: float) -> np.ndarray:
    """EI for *minimization*: E[max(best - y, 0)].

    ``best`` is the incumbent (lowest observed) objective value.
    """
    std = np.sqrt(np.maximum(var, 1e-12))
    z = (best - mean) / std
    # Phi and phi of the standard normal
    phi = np.exp(-0.5 * z**2) / np.sqrt(2 * np.pi)
    from math import erf

    Phi = 0.5 * (1.0 + np.vectorize(erf)(z / np.sqrt(2.0)))
    ei = (best - mean) * Phi + std * phi
    return np.maximum(ei, 0.0)


def ei_scores(model: Surrogate, X: np.ndarray, best: float) -> np.ndarray:
    mean, var = model.predict(X)
    return expected_improvement(mean, var, best)


def rank_aggregate(score_lists: Sequence[np.ndarray], weights: Sequence[float]) -> np.ndarray:
    """Weighted rank aggregation R(x) = sum_i w_i * R_i(x)  (paper §6.2).

    Each score list is converted to ranks where rank 0 = best (highest
    acquisition score). Lower aggregate rank = more promising. Returns the
    aggregate rank per candidate.
    """
    if not score_lists:
        raise ValueError("no scores to aggregate")
    n = len(score_lists[0])
    agg = np.zeros(n, dtype=float)
    for scores, w in zip(score_lists, weights):
        # argsort of -scores: position in the sorted order = rank
        order = np.argsort(-np.asarray(scores), kind="stable")
        ranks = np.empty(n, dtype=float)
        ranks[order] = np.arange(n, dtype=float)
        agg += float(w) * ranks
    return agg
