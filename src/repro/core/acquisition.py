"""Acquisition functions and rank aggregation (paper §3.3, §6.2).

The acquisition path is a batched array program end-to-end: the normal CDF
is a vectorized ufunc (no per-candidate ``np.vectorize(erf)``),
``score_sources`` computes the EI matrix for *all* surrogate sources in one
fused pass (PRF sources share a single packed-forest descent via
``ForestPlane``), and ``aggregate_ranks`` turns an (S, N) score matrix into
weighted aggregate ranks with one argsort per source row.

Bit-equivalence contract: the numpy EI here is the *reference* for the
on-device fused propose step (``kernels/forest_eval/propose.py``). Both
backends instantiate the same portable Cephes-style ``exp``/``ndtr``
expression tree via :func:`make_portable_kernels`, parameterized over the
array namespace plus a protected-multiply hook (the jax side routes every
product that feeds an add through an XOR-seal so XLA:CPU cannot contract
it into an FMA). Library transcendentals (``np.exp``, ``scipy.ndtr``,
``jax.scipy`` …) are NOT interchangeable at the bit level across backends;
these ports are, by construction. The shared variance floor lives in
:data:`EI_VAR_FLOOR` — one source of truth for both paths.
"""

from __future__ import annotations

import math
from collections import OrderedDict
from contextlib import contextmanager
from typing import Dict, Sequence, Tuple

import numpy as np

from .. import obs
from .surrogate import ForestPlane, ProbabilisticRandomForest, Surrogate

__all__ = [
    "EI_VAR_FLOOR",
    "normal_cdf",
    "expected_improvement",
    "ei_matrix",
    "ei_scores",
    "predict_sources",
    "score_sources",
    "aggregate_ranks",
    "rank_aggregate",
    "make_portable_kernels",
    "set_acquisition_backend",
    "get_acquisition_backend",
    "acquisition_backend",
    "set_acquisition_pool",
    "get_acquisition_pool",
    "acquisition_pool",
    "set_plane_cache_size",
    "plane_cache_stats",
    "expected_improvement_jax",
    "aggregate_ranks_jax",
]

# One variance floor shared by the numpy reference and the jax/pallas
# propose path — the bit-equivalence tests pin both to this constant.
EI_VAR_FLOOR = 1e-12

_SQRT2 = math.sqrt(2.0)
_SQRT2PI = float(np.sqrt(2 * np.pi))

# ---------------------------------------------------------------------------
# Portable Cephes double-precision exp / ndtr (netlib cephes, exp.c + ndtr.c
# coefficient tables). Polynomial ratios + exact power-of-two scaling via
# exponent-field bitcasts: every step is IEEE mul/add/div/sqrt/compare, so
# instantiating the same expression tree under numpy and jax yields
# bit-identical outputs — provided products feeding adds are protected from
# FMA contraction (the ``mul`` hook).
# ---------------------------------------------------------------------------

_MAXLOG = 709.782712893383996843
_MINLOG = -708.396418532264106224
_LOG2E = 1.4426950408889634073599
_EXP_C1 = 6.93145751953125e-1
_EXP_C2 = 1.42860682030941723212e-6
_SQRT1_2 = 0.70710678118654752440
_MIN_NORMAL = 2.2250738585072014e-308  # smallest normal float64 (FTZ cutoff)

_EXP_P = (1.26177193074810590878e-4, 3.02994407707441961300e-2,
          9.99999999999999999910e-1)
_EXP_Q = (3.00198505138664455042e-6, 2.52448340349684104192e-3,
          2.27265548208155028766e-1, 2.00000000000000000005e0)

_ERF_T = (9.60497373987051638749e0, 9.00260197203842689217e1,
          2.23200534594684319226e3, 7.00332514112805075473e3,
          5.55923013010394962768e4)
_ERF_U = (3.35617141647503099647e1, 5.21357949780152679795e2,
          4.59432382970980127987e3, 2.26290000613890934246e4,
          4.92673942608635921086e4)
_ERFC_P = (2.46196981473530512524e-10, 5.64189564831068821977e-1,
           7.46321056442269912687e0, 4.86371970985681366614e1,
           1.96520832956077098242e2, 5.26445194995477358631e2,
           9.34528527171957607540e2, 1.02755188689515710272e3,
           5.57535335369399327526e2)
_ERFC_Q = (1.32281951154744992508e1, 8.67072140885989742329e1,
           3.54937778887819891062e2, 9.75708501743205489753e2,
           1.82390916687909736289e3, 2.24633760818710981792e3,
           1.65666309194161350182e3, 5.57535340817727675546e2)
_ERFC_R = (5.64189583547755073984e-1, 1.27536670759978104416e0,
           5.01905042251180477414e0, 6.16021097993053585195e0,
           7.40974269950448939160e0, 2.97886665372100240670e0)
_ERFC_S = (2.26052863220117276590e0, 9.39603524938001434673e0,
           1.20489539808096656605e1, 3.08326216929483867054e1,
           2.81677489524132947867e1, 7.92101509270425732821e0)


def make_portable_kernels(xp, mul, pow2_bits, div=None) -> Dict[str, callable]:
    """Build exp64 / ndtr64 / EI from one shared IEEE op sequence.

    ``xp``        numpy-compatible namespace (numpy or jax.numpy, x64).
    ``mul``       protected multiply: must not contract into an FMA with a
                  following add (plain ``operator.mul`` for numpy; the
                  XOR-seal under jit).
    ``pow2_bits`` exact 2**k for integral float k via an exponent-field
                  bitcast.
    ``div``       protected divide: XLA rewrites division by a non-power-
                  of-two *constant* into multiplication by its (rounded)
                  reciprocal, a 1-ulp hazard — the jax hook seals the
                  denominator so it is never a constant. Defaults to plain
                  division (numpy).

    Returns {"exp": exp64, "ndtr": ndtr64, "ei": ei}.
    """
    if div is None:
        div = lambda a, b: a / b  # noqa: E731

    def ftz(v):
        # XLA:CPU runs with FTZ/DAZ: products/divisions that underflow come
        # back as (signed) zero, while numpy keeps gradual-underflow
        # denormals. Flushing the few hazard sites (phi, the erfc tail, the
        # EI terms) makes underflow behavior part of the shared contract.
        return xp.where(xp.abs(v) < _MIN_NORMAL, 0.0 * v, v)

    def polevl(x, cs):
        r = xp.full_like(x, cs[0])
        for c in cs[1:]:
            r = mul(r, x) + c
        return r

    def p1evl(x, cs):
        r = x + cs[0]
        for c in cs[1:]:
            r = mul(r, x) + c
        return r

    def exp64(x):
        xs = xp.clip(x, _MINLOG, _MAXLOG)
        k = xp.floor(mul(_LOG2E, xs) + 0.5)
        # r = x - k*ln2, split so the reduction is exact
        r = xs - mul(k, _EXP_C1)
        r = r - mul(k, _EXP_C2)
        xx = mul(r, r)
        p = mul(r, polevl(xx, _EXP_P))
        w = div(p, polevl(xx, _EXP_Q) - p)
        w = 1.0 + mul(2.0, w)
        # two-step 2**k scaling keeps each factor a normal number
        k1 = xp.floor(mul(k, 0.5))
        k2 = k - k1
        out = mul(mul(w, pow2_bits(k1)), pow2_bits(k2))
        out = xp.where(x < _MINLOG, 0.0, out)
        return xp.where(x > _MAXLOG, xp.inf, out)

    def ndtr64(z):
        x = mul(z, _SQRT1_2)
        ax = xp.abs(x)
        # |x| < 1: erf series (clip keeps unselected lanes finite)
        xc = xp.clip(x, -1.0, 1.0)
        zz = mul(xc, xc)
        erf_small = div(mul(xc, polevl(zz, _ERF_T)), p1evl(zz, _ERF_U))
        small = 0.5 + mul(0.5, erf_small)
        # |x| >= 1: erfc tail, two rational regimes around a = 8
        a = xp.clip(ax, 1.0, 100.0)
        ez = exp64(mul(-a, a))
        p_mid = div(polevl(a, _ERFC_P), p1evl(a, _ERFC_Q))
        p_big = div(polevl(a, _ERFC_R), p1evl(a, _ERFC_S))
        ht = ftz(mul(0.5, mul(ez, xp.where(a < 8.0, p_mid, p_big))))
        big = xp.where(x > 0, 1.0 - ht, ht)
        return xp.where(ax < 1.0, small, big)

    def ei(mean, var, best):
        std = xp.sqrt(xp.maximum(var, EI_VAR_FLOOR))
        diff = best - mean
        z = div(diff, std)
        phi = ftz(div(exp64(mul(-0.5, mul(z, z))), _SQRT2PI))
        val = ftz(mul(diff, ndtr64(z))) + ftz(mul(std, phi))
        return ftz(xp.maximum(val, 0.0))

    return {"exp": exp64, "ndtr": ndtr64, "ei": ei}


def _np_pow2(k: np.ndarray) -> np.ndarray:
    """Exact 2**k for integral float k in normal range (numpy bitcast)."""
    return ((np.asarray(k).astype(np.int64) + np.int64(1023))
            << np.int64(52)).view(np.float64)


_NPK = make_portable_kernels(np, lambda a, b: a * b, _np_pow2)


def normal_cdf(z: np.ndarray) -> np.ndarray:
    """Vectorized standard-normal CDF Phi(z) (portable Cephes ndtr)."""
    z = np.asarray(z, dtype=float)
    return _NPK["ndtr"](np.atleast_1d(z)).reshape(z.shape)


def expected_improvement(mean: np.ndarray, var: np.ndarray, best: float) -> np.ndarray:
    """EI for *minimization*: E[max(best - y, 0)].

    ``best`` is the incumbent (lowest observed) objective value. Variance
    is floored at :data:`EI_VAR_FLOOR` — the constant the jax path shares.
    """
    mean = np.asarray(mean, dtype=float)
    var = np.asarray(var, dtype=float)
    best_a = np.asarray(best, dtype=float)
    out = _NPK["ei"](np.atleast_1d(mean), np.atleast_1d(var), best_a)
    shape = np.broadcast_shapes(mean.shape, var.shape, best_a.shape)
    return out.reshape(shape)


def ei_matrix(means: np.ndarray, vars_: np.ndarray, bests: np.ndarray) -> np.ndarray:
    """Row-wise EI: means/vars_ (S, N), bests (S,) -> EI (S, N)."""
    bests = np.asarray(bests, dtype=float)
    return expected_improvement(means, vars_, bests[:, None])


def ei_scores(model: Surrogate, X: np.ndarray, best: float) -> np.ndarray:
    mean, var = model.predict(X)
    return expected_improvement(mean, var, best)


# ---------------------------------------------------------------------------
# Acquisition backend / pool-mode switches (mirrors set_space_backend /
# set_forest_backend). "numpy" keeps the staged host path; "jax"/"pallas"
# route fusable recommend calls through the fused on-device propose step,
# differing only in the descent kernel. Pool mode: "device" draws the
# candidate pool on device from a threaded PRNG key (fast path — changes
# fixed-seed draws, see CHANGES SEED NOTE); "host" uploads the generator's
# numpy pool so selections are bit-identical to the numpy path.
# ---------------------------------------------------------------------------

_ACQ_BACKENDS = ("numpy", "jax", "pallas")
_ACQ_POOLS = ("device", "host")
_ACQ_BACKEND = "numpy"
_ACQ_POOL = "device"


def set_acquisition_backend(backend: str) -> str:
    """Set the module-default acquisition backend; returns the previous."""
    global _ACQ_BACKEND
    if backend not in _ACQ_BACKENDS:
        raise ValueError(f"unknown acquisition backend {backend!r}; "
                         f"expected one of {_ACQ_BACKENDS}")
    prev, _ACQ_BACKEND = _ACQ_BACKEND, backend
    return prev


def get_acquisition_backend() -> str:
    return _ACQ_BACKEND


@contextmanager
def acquisition_backend(backend: str):
    prev = set_acquisition_backend(backend)
    try:
        yield
    finally:
        set_acquisition_backend(prev)


def set_acquisition_pool(mode: str) -> str:
    """Set the pool mode for the fused propose step; returns the previous."""
    global _ACQ_POOL
    if mode not in _ACQ_POOLS:
        raise ValueError(f"unknown acquisition pool mode {mode!r}; "
                         f"expected one of {_ACQ_POOLS}")
    prev, _ACQ_POOL = _ACQ_POOL, mode
    return prev


def get_acquisition_pool() -> str:
    return _ACQ_POOL


@contextmanager
def acquisition_pool(mode: str):
    prev = set_acquisition_pool(mode)
    try:
        yield
    finally:
        set_acquisition_pool(prev)


# ---------------------------------------------------------------------------
# Fused planes keyed by the identities of their member arenas. PackedForest
# arenas are immutable and cached per PRF fit, so the same source set maps
# to the same key across recommend calls within a rung; the stored pack list
# guards against id() reuse. LRU with hit/miss/eviction stats (surfaced via
# TuningResult.plane_cache) and a configurable size — at 100+ sources the
# old hardcoded 8 thrashed silently.
# ---------------------------------------------------------------------------
_PLANE_CACHE: "OrderedDict[tuple, Tuple[list, ForestPlane]]" = OrderedDict()
_PLANE_CACHE_MAX = 8
_PLANE_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def set_plane_cache_size(max_entries: int) -> int:
    """Resize the fused-plane LRU; returns the previous size."""
    global _PLANE_CACHE_MAX
    if max_entries < 1:
        raise ValueError("plane cache needs at least one entry")
    prev, _PLANE_CACHE_MAX = _PLANE_CACHE_MAX, int(max_entries)
    while len(_PLANE_CACHE) > _PLANE_CACHE_MAX:
        _PLANE_CACHE.popitem(last=False)
        _PLANE_STATS["evictions"] += 1
    return prev


def plane_cache_stats() -> Dict[str, int]:
    """Counters in the ``SurrogateStore.cache_stats`` shape."""
    return {**_PLANE_STATS,
            "entries": len(_PLANE_CACHE), "max_entries": _PLANE_CACHE_MAX}


def _plane_for(packs: list) -> ForestPlane:
    key = tuple(id(p) for p in packs)
    entry = _PLANE_CACHE.get(key)
    if entry is not None and all(a is b for a, b in zip(entry[0], packs)):
        _PLANE_CACHE.move_to_end(key)
        _PLANE_STATS["hits"] += 1
        return entry[1]
    _PLANE_STATS["misses"] += 1
    plane = ForestPlane(packs)
    _PLANE_CACHE[key] = (packs, plane)
    while len(_PLANE_CACHE) > _PLANE_CACHE_MAX:
        _PLANE_CACHE.popitem(last=False)
        _PLANE_STATS["evictions"] += 1
    return plane


def predict_sources(
    models: Sequence[Surrogate], X: np.ndarray, delta=None
) -> Tuple[np.ndarray, np.ndarray]:
    """(means, vars), each (S, N), for all source surrogates on one pool.

    When every source is a fitted PRF on a packed backend, their arenas fuse
    into one :class:`ForestPlane` descent; otherwise each model predicts in
    turn (the GP / legacy-loop fallback). ``delta`` is the candidate pool's
    mutation provenance (``(bases, base_of)``) — on the fused host path it
    opts the plane into bitvector delta scoring (bit-identical leaf stats).
    """
    X = np.atleast_2d(np.asarray(X, dtype=float))
    fusable = len(models) > 1 and all(
        isinstance(m, ProbabilisticRandomForest) and m.trees and m.backend != "loop"
        for m in models
    )
    if fusable:
        plane = _plane_for([m.pack() for m in models])
        # deterministic backend for a mixed-backend ensemble: an accelerated
        # backend wins over numpy regardless of model order
        backends = {m.backend for m in models}
        backend = next((b for b in ("pallas", "jax", "auto") if b in backends), "numpy")
        return plane.predict(X, backend=backend, delta=delta)
    means = np.empty((len(models), X.shape[0]))
    vars_ = np.empty_like(means)
    for i, m in enumerate(models):
        means[i], vars_[i] = m.predict(X)
    return means, vars_


def score_sources(
    models: Sequence[Surrogate], X: np.ndarray, incumbents: Sequence[float],
    delta=None,
) -> np.ndarray:
    """Fused acquisition: EI of every source on every candidate, shape (S, N)."""
    with obs.span("surrogate_eval", pool=int(X.shape[0]), sources=len(models)):
        means, vars_ = predict_sources(models, X, delta=delta)
        return ei_matrix(means, vars_, np.asarray(incumbents, dtype=float))


def aggregate_ranks(scores: np.ndarray, weights: Sequence[float]) -> np.ndarray:
    """Weighted rank aggregation R(x) = sum_i w_i * R_i(x)  (paper §6.2).

    ``scores`` is the (S, N) acquisition matrix; each row is converted to
    ranks where rank 0 = best (highest score). Lower aggregate rank = more
    promising. Returns the aggregate rank per candidate, shape (N,).

    The rank matrix comes from ``kernels.forest_eval.rank.rank_rows``: a
    16-bit digit-pass radix over monotone u64 keys above its crossover,
    the stable f64 argsort below — both give the exact ranks of
    ``np.argsort(-scores, kind="stable")``, so this stays the pinned
    numpy reference regardless of dispatch.
    """
    from ..kernels.forest_eval import rank as _rank

    scores = np.atleast_2d(np.asarray(scores, dtype=float))
    if scores.size == 0:
        raise ValueError("no scores to aggregate")
    ranks = _rank.rank_rows(scores)
    w = np.asarray(weights, dtype=float)
    return (w[:, None] * ranks).sum(axis=0)


def rank_aggregate(score_lists: Sequence[np.ndarray], weights: Sequence[float]) -> np.ndarray:
    """Back-compat wrapper over :func:`aggregate_ranks` for a list of rows."""
    if len(score_lists) == 0:
        raise ValueError("no scores to aggregate")
    return aggregate_ranks(np.asarray(score_lists, dtype=float), weights)


def expected_improvement_jax(mean, var, best) -> np.ndarray:
    """Jax-backed EI through the fused kernels (x64, bucket-padded).

    Bit-identical to :func:`expected_improvement`; raises ImportError
    without jax.
    """
    from ..kernels.forest_eval import propose as _propose
    return _propose.ei_host(mean, var, best)


def aggregate_ranks_jax(scores, weights) -> np.ndarray:
    """Jax-backed rank aggregation (x64, bucket-padded), bit-identical to
    :func:`aggregate_ranks`."""
    from ..kernels.forest_eval import propose as _propose
    return _propose.aggregate_ranks_host(scores, weights)
