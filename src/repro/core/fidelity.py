"""Query-based fidelity partitioning (paper §6.1, Algorithm 2).

A delta-fidelity proxy is a subset Q_delta of the workload's queries whose
aggregate latency rank-correlates with the full workload across
configurations, subject to Cost(Q_delta) <= delta * Cost(Q). The greedy
solver starts from the empty set and repeatedly adds the query that
maximizes the weighted Kendall-tau correlation score while respecting the
cost budget. Correlations are computed on historical observations of
source tasks with the *same query set* (Eq. 8), weighted by task
similarity; the current task's own full-fidelity observations can serve as
a source (degradation path, §6.3).

Also provides the two proxy baselines the paper evaluates in Fig. 1b
(data-volume scaling and SQL early stop) so the comparison is reproducible.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .knowledge import TaskRecord
from .similarity import kendall_tau

__all__ = [
    "QueryStats",
    "collect_query_stats",
    "query_cost_ratios",
    "subset_correlation",
    "greedy_query_subset",
    "FidelityPartition",
    "partition_fidelities",
    "early_stop_subset",
]


@dataclass
class QueryStats:
    """Per-source-task observation matrices aligned to the query list.

    perf: (n_configs, n_queries) latency of each query under each config.
    cost: (n_configs, n_queries) evaluation cost (elapsed time here).
    weight: the task's transfer weight w_i.
    """

    task_id: str
    perf: np.ndarray
    cost: np.ndarray
    weight: float


def collect_query_stats(
    tasks: Sequence[TaskRecord], weights: Dict[str, float], min_configs: int = 3
) -> List[QueryStats]:
    out: List[QueryStats] = []
    for t in tasks:
        obs = t.with_query_vectors()
        if len(obs) < min_configs:
            continue
        w = weights.get(t.task_id, 0.0)
        if t.task_id == "__target__":
            w = weights.get("__target__", 0.0)
        if w <= 0:
            continue
        perf = np.array([o.per_query_perf for o in obs], dtype=float)
        cost = np.array(
            [o.per_query_cost if o.per_query_cost is not None else o.per_query_perf for o in obs],
            dtype=float,
        )
        out.append(QueryStats(task_id=t.task_id, perf=perf, cost=cost, weight=w))
    return out


def query_cost_ratios(stats: Sequence[QueryStats]) -> np.ndarray:
    """Weighted average cost ratio c(q) of each query (Alg. 2 line 2)."""
    total_w = sum(s.weight for s in stats)
    m = stats[0].cost.shape[1]
    c = np.zeros(m)
    for s in stats:
        per_cfg_total = s.cost.sum(axis=1, keepdims=True)  # (n,1)
        ratios = (s.cost / np.maximum(per_cfg_total, 1e-12)).mean(axis=0)
        c += (s.weight / total_w) * ratios
    return c


def subset_correlation(stats: Sequence[QueryStats], subset: Sequence[int]) -> float:
    """tau(Q_delta, Q) = sum_i w_i KendallTau(A_i^{Q_delta}, A_i^{Q})  (Eq. 8)."""
    if not subset:
        return 0.0
    idx = np.asarray(list(subset), dtype=int)
    total_w = sum(s.weight for s in stats)
    score = 0.0
    for s in stats:
        agg_sub = s.perf[:, idx].sum(axis=1)
        agg_full = s.perf.sum(axis=1)
        tau, _ = kendall_tau(agg_sub, agg_full)
        score += (s.weight / total_w) * tau
    return score


def greedy_query_subset(
    stats: Sequence[QueryStats], delta: float
) -> Tuple[List[int], float, float]:
    """Algorithm 2. Returns (subset indices, correlation score, cost ratio)."""
    if not stats:
        raise ValueError("no source stats for fidelity partitioning")
    c = query_cost_ratios(stats)
    m = len(c)
    subset: List[int] = []
    r = 0.0
    current_tau = 0.0
    remaining = set(range(m))
    while True:
        best_q, best_tau = None, -np.inf
        for q in sorted(remaining):
            if r + c[q] > delta + 1e-12:
                continue
            tau = subset_correlation(stats, subset + [q])
            if tau > best_tau:
                best_q, best_tau = q, tau
        if best_q is None:
            break
        subset.append(best_q)
        remaining.discard(best_q)
        r += c[best_q]
        current_tau = best_tau
        if current_tau >= 1.0 - 1e-12:
            break
    return subset, current_tau, r


@dataclass
class FidelityPartition:
    """Mapping fidelity delta -> selected query indices (+ diagnostics)."""

    subsets: Dict[float, List[int]]
    scores: Dict[float, float]
    cost_ratios: Dict[float, float]

    def queries_for(self, delta: float) -> List[int]:
        if delta >= 1.0:
            # full fidelity: all queries (total count inferred from any subset)
            return []  # sentinel: empty means "all"
        key = min(self.subsets.keys(), key=lambda d: abs(d - delta))
        return self.subsets[key]


def partition_fidelities(
    stats: Sequence[QueryStats], deltas: Sequence[float]
) -> FidelityPartition:
    subsets: Dict[float, List[int]] = {}
    scores: Dict[float, float] = {}
    ratios: Dict[float, float] = {}
    for d in deltas:
        if d >= 1.0:
            continue
        s, tau, r = greedy_query_subset(stats, d)
        subsets[d] = s
        scores[d] = tau
        ratios[d] = r
    return FidelityPartition(subsets=subsets, scores=scores, cost_ratios=ratios)


def early_stop_subset(n_queries: int, delta: float) -> List[int]:
    """SQL Early Stop baseline: first ceil(delta * m) queries (Fig. 1b)."""
    k = max(1, int(np.ceil(delta * n_queries)))
    return list(range(min(k, n_queries)))
