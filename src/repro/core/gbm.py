"""Gradient-boosted regression trees (LightGBM stand-in, paper §4.2).

Used only for the meta-feature pairwise-similarity regressor that
warm-starts similarity identification. Least-squares boosting with
shallow CART trees and shrinkage; numpy-only.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from .surrogate import RegressionTree

__all__ = ["GradientBoostedTrees"]


class GradientBoostedTrees:
    def __init__(
        self,
        n_estimators: int = 60,
        learning_rate: float = 0.1,
        max_depth: int = 3,
        subsample: float = 0.8,
        min_samples_leaf: int = 2,
        seed: int = 0,
    ):
        self.n_estimators = n_estimators
        self.learning_rate = learning_rate
        self.max_depth = max_depth
        self.subsample = subsample
        self.min_samples_leaf = min_samples_leaf
        self.seed = seed
        self.trees: List[RegressionTree] = []
        self.base_: float = 0.0

    def fit(self, X: np.ndarray, y: np.ndarray) -> "GradientBoostedTrees":
        X = np.atleast_2d(np.asarray(X, dtype=float))
        y = np.asarray(y, dtype=float)
        rng = np.random.default_rng(self.seed)
        self.base_ = float(y.mean()) if len(y) else 0.0
        pred = np.full(len(y), self.base_)
        self.trees = []
        n = len(y)
        for _ in range(self.n_estimators):
            resid = y - pred
            if np.abs(resid).max() < 1e-12:
                break
            m = max(2, int(self.subsample * n))
            idx = rng.choice(n, size=m, replace=False) if m < n else np.arange(n)
            tree = RegressionTree(
                max_depth=self.max_depth,
                min_samples_split=2 * self.min_samples_leaf,
                min_samples_leaf=self.min_samples_leaf,
                max_features=X.shape[1],
                rng=np.random.default_rng(rng.integers(2**63)),
            )
            tree.fit(X[idx], resid[idx])
            step, _ = tree.predict(X)
            pred = pred + self.learning_rate * step
            self.trees.append(tree)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.atleast_2d(np.asarray(X, dtype=float))
        pred = np.full(len(X), self.base_)
        for tree in self.trees:
            step, _ = tree.predict(X)
            pred = pred + self.learning_rate * step
        return pred
