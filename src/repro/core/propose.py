"""Host-side driver for the fused on-device propose step.

:class:`ProposeEngine` owns everything the jitted program in
``repro.kernels.forest_eval.propose`` needs resident on device: the fused
``ForestPlane`` arena (via the acquisition plane LRU, so cache stats stay
in one place), per-source denorm stats, and the sample-space transform
tables — uploaded once per (plane / space) identity and reused across
propose calls. It also threads the JAX PRNG key between steps and tracks
every static jit signature it has launched, which is the jit-cache-growth
guard surface for the pool-scaling bench (compile count must stay bounded
by the number of shape buckets).

Two pool modes (see ``acquisition.set_acquisition_pool``):

* ``device`` — the pool is drawn on device from the threaded key
  (uniform + LHS halves over the sample space's restriction CDFs); only
  the top-k rows come back to the host. Fastest path; changes fixed-seed
  pool draws (SEED NOTE in CHANGES.md).
* ``host`` — the generator's numpy pool is uploaded and only scoring +
  selection run on device, so the chosen indices are bit-identical to the
  staged numpy path (this is what the MFTune trajectory-identity test
  pins).
"""

from __future__ import annotations

from collections import OrderedDict
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .. import obs
from .surrogate import ForestPlane, ProbabilisticRandomForest

__all__ = ["ProposeEngine"]

_CONST_SIG = (4, False, False, False, False, 1)  # dropped knob: unit default

# descent="auto" picks the merged QuickScorer tables at pool buckets >= this
# (measured crossover on XLA:CPU — below it the per-feature table gathers
# cost more than the pointer-chasing they replace), gather descent below
QS_AUTO_MIN = 32768


def _default_rank_impl() -> str:
    from ..kernels.forest_eval import rank as _rank
    return _rank.default_rank_impl()


class ProposeEngine:
    def __init__(self, space, seed: int = 0, pool_size: int = 256,
                 margin: int = 64, arena_cache: int = 8):
        self.space = space
        self.seed = seed
        self.pool_size = pool_size
        self.margin = margin
        self._key = None
        self._zi = None
        self._arena_cache: "OrderedDict[int, tuple]" = OrderedDict()
        self._arena_cache_max = arena_cache
        self._tables_cache: "OrderedDict[int, tuple]" = OrderedDict()
        # every static jit signature launched; the bench asserts this stays
        # <= the number of shape buckets it sweeps (jit-cache-growth guard)
        self.compiled: set = set()

    # ----------------------------------------------------------- availability
    @staticmethod
    def available() -> bool:
        try:
            import jax  # noqa: F401
            return True
        except ImportError:
            return False

    @staticmethod
    def fusable(models: Sequence) -> bool:
        """True when the fused program applies: fitted PRFs on a packed
        backend with a uniform tree count (the per-source slice contract)."""
        if not models:
            return False
        if not all(
            isinstance(m, ProbabilisticRandomForest) and m.trees and m.backend != "loop"
            for m in models
        ):
            return False
        return len({len(m.trees) for m in models}) == 1

    # --------------------------------------------------------------- uploads
    def _x64(self):
        import jax
        return jax.experimental.enable_x64(True)

    def _plane(self, models: Sequence) -> ForestPlane:
        from .acquisition import _plane_for
        return _plane_for([m.pack() for m in models])

    def _arena_for(self, plane: ForestPlane) -> Tuple[tuple, tuple, Optional[tuple], str]:
        """Device-resident (arena, ystats, qs_plan, qs_reason) for a fused
        plane, LRU-cached by plane identity. Unlike ``ops._device_arena``
        this keeps the exact tree set (no power-of-two root padding):
        padded trees would pollute the per-source combine and double the
        descent work. ``qs_plan`` is the uploaded merged QuickScorer table
        set (None when a tree exceeds 128 leaves — gather descent then,
        with the decline cause in ``qs_reason``)."""
        key = id(plane)
        hit = self._arena_cache.get(key)
        if hit is not None and hit[0] is plane:
            self._arena_cache.move_to_end(key)
            return hit[1], hit[2], hit[3], hit[4]
        import jax.numpy as jnp

        from ..kernels.forest_eval.propose import build_qs_plan_ex

        # the upload dtype follows the ambient x64 flag; entering the scope
        # here keeps a direct caller outside propose()/score_topk() from
        # silently caching a float32 arena
        with self._x64():
            return self._arena_upload(plane, jnp, build_qs_plan_ex, key)

    def _arena_upload(self, plane, jnp, build_qs_plan_ex, key):
        arena = tuple(jnp.asarray(a) for a in (
            plane.feat, plane.thr, plane.child, plane.mean, plane.var,
            plane.roots,
        ))
        # y_std**2 on host with the same python-float pow PackedForest.combine
        # uses, so the device denorm replays it exactly
        ystats = (
            jnp.asarray(plane.y_means),
            jnp.asarray(plane.y_stds),
            jnp.asarray(np.array([f.y_std ** 2 for f in plane.forests])),
        )
        qs_host, qs_reason = build_qs_plan_ex(
            plane.feat, plane.thr, plane.child, plane.mean, plane.var,
            plane.roots, self.space.dim,
        )
        qs = None
        if qs_host is not None:
            thrs, tabs, lm, lv, offs = qs_host
            qs = (
                tuple(jnp.asarray(a) for a in thrs),
                tuple(jnp.asarray(a) for a in tabs),
                jnp.asarray(lm), jnp.asarray(lv), jnp.asarray(offs),
            )
        self._arena_cache[key] = (plane, arena, ystats, qs, qs_reason)
        while len(self._arena_cache) > self._arena_cache_max:
            self._arena_cache.popitem(last=False)
        return arena, ystats, qs, qs_reason

    def _tables_for(self, sample_space) -> Tuple[tuple, tuple]:
        """Device transform tables for pool draws over ``sample_space``,
        mapped onto the *full* space's column order (dropped knobs become
        constant unit-default columns). Restrictions don't change a knob's
        lo/hi/log, so the sample space's unit transform is the full space's.
        """
        key = id(sample_space)
        hit = self._tables_cache.get(key)
        if hit is not None and hit[0] is sample_space:
            self._tables_cache.move_to_end(key)
            return hit[1], hit[2]
        import jax.numpy as jnp

        with self._x64():
            return self._tables_upload(sample_space, jnp, key)

    def _tables_upload(self, sample_space, jnp, key):
        ss_plane = sample_space.plane()
        sig_ss, cols_ss = ss_plane.device_tables()
        pos = {name: i for i, name in enumerate(sample_space.names)}
        fplane = self.space.plane()
        unit_default = fplane.encode_values(
            np.atleast_2d(fplane.default_row.copy())
        )[0]
        sig: List[tuple] = []
        cols: List[tuple] = []
        for j, name in enumerate(self.space.names):
            i = pos.get(name)
            if i is None:
                sig.append(_CONST_SIG)
                cols.append((jnp.asarray(np.array([unit_default[j]])),))
            else:
                sig.append(sig_ss[i])
                cols.append(tuple(jnp.asarray(a) for a in cols_ss[i]))
        entry = (sample_space, tuple(sig), tuple(cols))
        self._tables_cache[key] = entry
        while len(self._tables_cache) > self._arena_cache_max:
            self._tables_cache.popitem(last=False)
        return entry[1], entry[2]

    def _next_key(self):
        import jax
        if self._key is None:
            self._key = jax.random.PRNGKey(self.seed)
        self._key, sub = jax.random.split(self._key)
        return sub

    def _zero(self):
        import jax.numpy as jnp
        if self._zi is None:
            self._zi = jnp.zeros((), dtype=jnp.uint64)
        return self._zi

    @staticmethod
    def _pow2(n: int) -> int:
        return 1 << (max(int(n), 1) - 1).bit_length()

    # ---------------------------------------------------------------- propose
    def propose(
        self,
        models: Sequence,
        incumbents: Sequence[float],
        weights: Sequence[float],
        n: int,
        sample_space=None,
        descent: str = "auto",
        rank_impl: Optional[str] = None,
        pool_size: Optional[int] = None,
        steps: Optional[int] = None,
    ):
        """Device-pool mode: draw a fresh on-device pool from the threaded
        key and return the fused top-k as ``(idx, unit_rows, agg)`` numpy
        arrays (k = n + margin rows for host-side exclusion dedup). With
        ``steps`` set, runs that many iterations under one ``lax.scan`` and
        returns stacked outputs with a leading steps axis."""
        from ..kernels.forest_eval import propose as P

        with self._x64():
            plane = self._plane(models)
            tps = plane.uniform_tree_count
            if tps is None:
                raise ValueError("propose requires a uniform tree count per source")
            arena, ystats, qs, qs_reason = self._arena_for(plane)
            sig, cols = self._tables_for(sample_space or self.space)
            import jax.numpy as jnp

            n_pool = P.pool_bucket(pool_size or self.pool_size)
            if descent == "auto":
                descent = "qs" if qs is not None and n_pool >= QS_AUTO_MIN else "jax"
            elif descent == "qs" and qs is None:
                raise ValueError(f"no QuickScorer plan: {qs_reason}")
            if rank_impl is None:
                rank_impl = _default_rank_impl()
            k = min(self._pow2(n + self.margin), n_pool)
            S = len(plane.forests)
            inc = jnp.asarray(np.asarray(incumbents, dtype=float))
            w = jnp.asarray(np.asarray(weights, dtype=float))
            static = ("propose", n_pool, plane.depth, S, tps, k, sig,
                      rank_impl, descent, steps)
            first = static not in self.compiled
            self.compiled.add(static)
            obs.count(f"rank_kernel/{rank_impl}")
            with obs.span("propose_step", mode="device_pool", bucket=n_pool,
                          descent=descent, rank=rank_impl, sources=S, k=k,
                          compile=first):
                obs.observe("propose/pool_occupancy", 1.0)
                if steps is None:
                    idx, Xu, agg = P.propose_step(
                        self._next_key(), cols, arena, ystats, inc, w,
                        self._zero(), n_pool=n_pool, depth=plane.depth,
                        n_sources=S, tps=tps, k=k, sig=sig, descent=descent,
                        rank_impl=rank_impl,
                        qs=qs if descent == "qs" else None,
                    )
                else:
                    if self._key is None:
                        import jax
                        self._key = jax.random.PRNGKey(self.seed)
                    self._key, (idx, Xu, agg) = P.propose_scan(
                        self._key, cols, arena, ystats, inc, w, self._zero(),
                        n_pool=n_pool, depth=plane.depth, n_sources=S, tps=tps,
                        k=k, sig=sig, descent=descent, rank_impl=rank_impl,
                        steps=steps, qs=qs if descent == "qs" else None,
                    )
                return np.asarray(idx), np.asarray(Xu), np.asarray(agg)

    def score_topk(
        self,
        models: Sequence,
        X_unit: np.ndarray,
        incumbents: Sequence[float],
        weights: Sequence[float],
        n: int,
        descent: str = "auto",
        rank_impl: Optional[str] = None,
    ) -> np.ndarray:
        """Host-pool mode: score an uploaded unit pool and return the top-n
        candidate indices, bit-identical to the staged numpy path
        (``score_sources`` → ``aggregate_ranks`` → stable argsort)."""
        from ..kernels.forest_eval import propose as P

        X_unit = np.atleast_2d(np.asarray(X_unit, dtype=float))
        with self._x64():
            plane = self._plane(models)
            tps = plane.uniform_tree_count
            if tps is None:
                raise ValueError("score_topk requires a uniform tree count per source")
            arena, ystats, qs, qs_reason = self._arena_for(plane)
            import jax.numpy as jnp

            N, D = X_unit.shape
            bucket = P.pool_bucket(N)
            if descent == "auto":
                descent = "qs" if qs is not None and bucket >= QS_AUTO_MIN else "jax"
            elif descent == "qs" and qs is None:
                raise ValueError(f"no QuickScorer plan: {qs_reason}")
            if rank_impl is None:
                rank_impl = _default_rank_impl()
            Xp = np.zeros((bucket, D))
            Xp[:N] = X_unit
            k = min(self._pow2(n), bucket)
            S = len(plane.forests)
            inc = jnp.asarray(np.asarray(incumbents, dtype=float))
            w = jnp.asarray(np.asarray(weights, dtype=float))
            static = ("score", bucket, plane.depth, S, tps, k, rank_impl,
                      descent)
            first = static not in self.compiled
            self.compiled.add(static)
            obs.count(f"rank_kernel/{rank_impl}")
            with obs.span("propose_step", mode="host_pool", bucket=bucket,
                          descent=descent, rank=rank_impl, sources=S, k=k,
                          compile=first, occupancy=N / bucket):
                obs.observe("propose/pool_occupancy", N / bucket)
                idx, _, _ = P.propose_step(
                    None, None, arena, ystats, inc, w, self._zero(),
                    n_pool=bucket, depth=plane.depth, n_sources=S, tps=tps,
                    k=k, sig=(), descent=descent, rank_impl=rank_impl,
                    X=jnp.asarray(Xp), n_valid=N,
                    qs=qs if descent == "qs" else None,
                )
                return np.asarray(idx)[: min(n, N)]
