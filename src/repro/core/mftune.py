"""MFTune controller (paper §4.1 workflow, §6.3 MFO process).

Per-iteration workflow (Fig. 2):
  (1) similarity of source tasks vs. the current task (meta-feature
      prediction early, Eq. 2 after the transition mechanism fires),
  (2) density-based search-space compression from similar-task observations,
  (3) candidate generation = two-phase warm start + combined-rank BO,
  (4) multi-fidelity evaluation via Hyperband successive halving over
      query-subset proxies (Alg. 2), with median-cost early stopping —
      each rung's survivors are evaluated in one batched
      ``Workload.evaluate_many`` call (the vectorized sparksim grid path),
  (5) results recorded into the knowledge base.

Degradation paths (§6.3): with no same-query-set history, run full-fidelity
BO (with transfer + compression) until the transition mechanism admits the
current task as a source for fidelity partitioning; with no history at all,
start as vanilla BO and self-transfer once enough observations accumulate.

Ablation switches reproduce the paper's variants: w/o MF, data-volume or
early-stop proxies (Fig. 5a), SC strategy replacement (Fig. 6), and the
warm-start phase grid (Table 3).
"""

from __future__ import annotations

import time as _time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .. import obs
from ..tuneapi import Budget, EvalResult, Workload
from .compression import SpaceCompressor
from .fidelity import (
    FidelityPartition,
    collect_query_stats,
    early_stop_subset,
    partition_fidelities,
)
from .generator import CandidateColumns, CandidateGenerator, WarmStartQueue, phase1_config
from .hyperband import HyperbandRunner, Rung, RungTable
from .knowledge import KnowledgeBase, Observation, TaskRecord
from .similarity import SimilarityEngine, TaskWeights
from .space import ConfigSpace, space_backend as _space_backend_ctx

Config = Dict[str, Any]

__all__ = ["MFTuneOptions", "MFTune", "TuningResult"]


@dataclass
class MFTuneOptions:
    R: float = 9.0
    eta: int = 3
    alpha: float = 0.65                  # cumulative density threshold (§7.1: 0.65)
    seed: int = 0
    enable_mfo: bool = True              # False => "MFTune w/o MF"
    enable_sc: bool = True               # False => "w/o SC"
    enable_transfer: bool = True         # False => ignore history entirely
    enable_warmstart_p1: bool = True
    enable_warmstart_p2: bool = True
    fidelity_mode: str = "sql_selection"  # | "data_volume" | "early_stop"
    init_lhs: int = 5                     # LHS initialization size (cold paths)
    min_target_obs_for_partition: int = 8
    sc_refresh_every: int = 1             # iterations between SC refreshes
    early_stop_factor: float = 1.0
    compressor: Optional[Callable[..., ConfigSpace]] = None  # SC strategy override (Fig. 6)
    surrogate_backend: Optional[str] = None  # packed-forest backend; None = module
                                             # default (see set_forest_backend),
                                             # "loop" = legacy per-tree reference
    space_backend: Optional[str] = None      # config-space backend; None = module
                                             # default (see set_space_backend),
                                             # "scalar" = per-element reference
    shapley_backend: str = "batched"         # §5.1 attribution plane; "loop" =
                                             # legacy per-chain reference
                                             # (bit-identical attributions)
    acquisition_backend: Optional[str] = None  # propose-step backend; None =
                                               # module default, "numpy" =
                                               # staged host path, "jax" /
                                               # "pallas" = fused on-device
    acquisition_pool: Optional[str] = None     # fused pool source; "device" =
                                               # on-device draws (SEED NOTE),
                                               # "host" = upload numpy pool
                                               # (bit-identical selections)
    hyperband_backend: Optional[str] = None    # bracket bookkeeping; None =
                                               # module default ("table" rung
                                               # columns), "loop" = scalar
                                               # reference (bit-identical
                                               # survivor sets)


@dataclass
class TrajectoryPoint:
    time: float                      # virtual budget seconds at improvement
    best: float
    config: Config
    fidelity: float
    wall_time: float = 0.0           # time.time() at improvement (0.0 = unset)
    rung: Optional[int] = None       # fidelity-level index into the bracket's
                                     # delta ladder (top level for full-fid BO)


@dataclass
class TuningResult:
    best_config: Optional[Config]
    best_performance: float
    trajectory: List[TrajectoryPoint]
    n_evaluations: int
    n_full_evaluations: int
    mfo_activation_time: Optional[float]
    overheads: Dict[str, float] = field(default_factory=dict)
    surrogate_cache: Dict[str, int] = field(default_factory=dict)  # store hit/miss counters
    plane_cache: Dict[str, int] = field(default_factory=dict)      # fused-plane LRU counters
    rung_tables: List["RungTable"] = field(default_factory=list)   # per-bracket promotion
                                                                   # state (table backend)
    metrics: Dict[str, Any] = field(default_factory=dict)          # full registry snapshot
                                                                   # (obs.Metrics.snapshot())


class MFTune:
    def __init__(
        self,
        workload: Workload,
        kb: Optional[KnowledgeBase] = None,
        options: Optional[MFTuneOptions] = None,
    ):
        self.wl = workload
        self.kb = kb or KnowledgeBase()
        self.opt = options or MFTuneOptions()
        self.space: ConfigSpace = workload.space
        self.rng = np.random.default_rng(self.opt.seed)

        # target task record
        if workload.task_id in self.kb.tasks:
            self.target = self.kb.get(workload.task_id)
        else:
            self.target = TaskRecord(
                task_id=workload.task_id,
                queries=list(workload.queries),
                meta_features=workload.meta_features(),
            )
            self.kb.add_task(self.target, persist=False)

        self.sim = SimilarityEngine(self.space, self.kb, seed=self.opt.seed)
        self.compressor = SpaceCompressor(
            self.space, alpha=self.opt.alpha, seed=self.opt.seed,
            backend=self.opt.shapley_backend,
        )
        self.gen = CandidateGenerator(
            self.space, seed=self.opt.seed, backend=self.opt.surrogate_backend
        )
        self.ws_queue = WarmStartQueue()
        self.hb = HyperbandRunner(
            R=self.opt.R, eta=self.opt.eta, early_stop_factor=self.opt.early_stop_factor,
            seed=self.opt.seed, backend=self.opt.hyperband_backend,
        )
        self.partition: Optional[FidelityPartition] = None
        self._mfo_activation_time: Optional[float] = None
        self._trajectory: List[TrajectoryPoint] = []
        self._n_eval = 0
        self._n_full = 0
        # per-run metrics registry: the single sink that TuningResult's
        # overheads / surrogate_cache / plane_cache fields are views over
        self.metrics = obs.Metrics()
        self._deltas = [r.delta for r in self.hb.brackets[0].rungs]  # e.g. [1/9, 1/3, 1]
        self._delta_rung = {round(d, 6): i for i, d in enumerate(self._deltas)}

    # ------------------------------------------------------------------ utils
    def _charge_overhead(self, key: str, t0: float) -> None:
        self.metrics.counter("overhead/" + key).add(_time.perf_counter() - t0)

    def _best(self) -> Tuple[Optional[Config], float]:
        best = self.target.best()
        if best is None:
            return None, float("inf")
        return best.config, best.performance

    # -------------------------------------------------------------- evaluate
    def _fidelity_params(self, delta: float) -> Tuple[Optional[List[int]], float]:
        """Map a fidelity delta to (query subset, data fraction)."""
        subset: Optional[List[int]] = None
        data_fraction = 1.0
        m = len(self.wl.queries)
        if delta < 1.0:
            mode = self.opt.fidelity_mode
            if mode == "sql_selection":
                assert self.partition is not None
                subset = self.partition.queries_for(delta) or None
            elif mode == "early_stop":
                subset = early_stop_subset(m, delta)
            elif mode == "data_volume":
                subset = None
                data_fraction = delta
            else:
                raise ValueError(mode)
        return subset, data_fraction

    def _record(
        self,
        budget: Budget,
        config: Config,
        delta: float,
        subset: Optional[List[int]],
        res: EvalResult,
    ) -> Tuple[float, bool, float]:
        """Charge the budget and record one evaluation result."""
        budget.charge(res.elapsed, label=f"eval@{delta:.3f}")
        self._n_eval += 1
        # a NaN aggregate is neither failed nor inf: it would poison the rung
        # promotion sort and target.best(), so coerce non-finite to failure
        failed = bool(res.failed) or not np.isfinite(res.aggregate)
        perf = res.aggregate if not failed else float("inf")
        # best-so-far *before* this observation enters the KB: the trajectory
        # gains a point only on strict improvement (ties used to duplicate)
        _, prev_best = self._best()
        ob = Observation(
            config=config,
            performance=perf,
            fidelity=delta,
            per_query_perf=list(res.per_query_latency) if delta >= 1.0 and not failed else None,
            per_query_cost=list(res.per_query_cost) if delta >= 1.0 and not failed else None,
            query_subset=list(subset) if subset is not None else None,
            failed=failed,
            elapsed=res.elapsed,
            time=budget.now,
        )
        self.kb.record(self.target.task_id, ob)
        m = self.metrics
        m.counter("eval/failed" if failed else "eval/ok").add()
        m.counter(
            "budget/full_fidelity_s" if delta >= 1.0 else "budget/low_fidelity_s"
        ).add(res.elapsed)
        m.counter(f"budget/fidelity@{delta:.3f}_s").add(res.elapsed)
        m.histogram("eval/elapsed_s").observe(res.elapsed)
        if delta >= 1.0:
            self._n_full += 1
            if not failed and perf < prev_best:
                self._trajectory.append(
                    TrajectoryPoint(
                        time=budget.now, best=perf, config=config, fidelity=1.0,
                        wall_time=_time.time(),
                        rung=self._delta_rung.get(round(delta, 6)),
                    )
                )
        return perf, failed, res.elapsed

    def _evaluate(
        self, budget: Budget, config: Config, delta: float, cost_cap: Optional[float]
    ) -> Tuple[float, bool, float]:
        """Evaluate config at fidelity delta; record observation; charge budget."""
        config = dict(self.space.default(), **config)
        subset, data_fraction = self._fidelity_params(delta)
        with obs.span("evaluate", delta=delta, n=1, cap=cost_cap) as sp:
            res = self.wl.evaluate(
                config, query_indices=subset, cost_cap=cost_cap, data_fraction=data_fraction
            )
            out = self._record(budget, config, delta, subset, res)
            sp.set(cost=out[2], failed=out[1])
        return out

    def _evaluate_many(
        self, budget: Budget, configs: List[Config], delta: float, cost_cap: Optional[float]
    ) -> List[Tuple[float, bool, float]]:
        """Rung-level batched evaluation through ``Workload.evaluate_many``.

        All configs are evaluated in one workload call; budget charging and
        observation recording then replay sequentially, and configs past the
        point of budget exhaustion are dropped (a result prefix), matching
        the scalar rung loop's between-config should_stop checks.
        """
        configs = [dict(self.space.default(), **c) for c in configs]
        subset, data_fraction = self._fidelity_params(delta)
        with obs.span("evaluate", delta=delta, n=len(configs), cap=cost_cap) as sp:
            results = self.wl.evaluate_many(
                configs, query_indices=subset, cost_cap=cost_cap, data_fraction=data_fraction
            )
            out: List[Tuple[float, bool, float]] = []
            for config, res in zip(configs, results):
                if budget.exhausted:
                    break
                out.append(self._record(budget, config, delta, subset, res))
            sp.set(recorded=len(out),
                   cost=float(sum(r[2] for r in out)),
                   failures=int(sum(1 for r in out if r[1])))
        return out

    # ----------------------------------------------------------- components
    def _weights(self) -> TaskWeights:
        t0 = _time.perf_counter()
        with obs.span("similarity") as sp:
            if not self.opt.enable_transfer:
                w = TaskWeights(weights={}, similarities={}, used_meta=False)
                tgt = self.sim.target_self_weight(self.target)
                if tgt > 0:
                    w.weights["__target__"] = 1.0
            else:
                w = self.sim.compute(self.target)
            sp.set(sources=len(w.weights), used_meta=w.used_meta)
        self._charge_overhead("similarity", t0)
        return w

    def _compress(self, weights: TaskWeights) -> None:
        if not self.opt.enable_sc:
            return
        t0 = _time.perf_counter()
        with obs.span("space_compression") as sp:
            tasks = {t.task_id: t for t in self.kb.source_tasks(self.target.task_id)}
            if self.opt.compressor is not None:
                compressed = self.opt.compressor(
                    space=self.space, weights=weights, tasks=tasks, target=self.target
                )
            else:
                compressed = self.compressor.compress(weights, tasks, target=self.target)
            if len(compressed) > 0:
                self.gen.set_sample_space(compressed)
            sp.set(knobs=len(compressed))
        self._charge_overhead("space_compression", t0)

    def _try_partition(self, weights: TaskWeights) -> None:
        """Derive the fidelity partition once sources (or self) allow it."""
        if self.partition is not None or self.opt.fidelity_mode != "sql_selection":
            return
        t0 = _time.perf_counter()
        with obs.span("fidelity_partition") as sp:
            sources = self.kb.same_query_sources(self.target) if self.opt.enable_transfer else []
            stats = collect_query_stats(sources, weights.weights)
            # degradation (§6.3): the current task becomes its own source once
            # enough of its observations carry query vectors AND its own
            # surrogate has established out-of-sample rank fidelity (positive
            # k-fold tau -> a "__target__" weight). The former gate on the
            # meta/Eq.2 transition deadlocked when history existed but stayed
            # dissimilar: used_meta never flipped, so self-partition never fired.
            if not stats:
                full = self.target.with_query_vectors()
                if (
                    len(full) >= self.opt.min_target_obs_for_partition
                    and weights.weights.get("__target__", 0.0) > 0
                ):
                    stats = collect_query_stats([self.target], {self.target.task_id: 1.0})
            if stats:
                deltas = [d for d in self._deltas if d < 1.0]
                self.partition = partition_fidelities(stats, deltas)
            sp.set(partitioned=self.partition is not None)
        self._charge_overhead("fidelity_partition", t0)

    def _mfo_ready(self) -> bool:
        if not self.opt.enable_mfo:
            return False
        if self.opt.fidelity_mode == "sql_selection":
            return self.partition is not None
        return True  # DV / early-stop proxies need no partition

    # ------------------------------------------------------------------ main
    def run(self, budget: Budget) -> TuningResult:
        from contextlib import ExitStack

        from .acquisition import acquisition_backend, acquisition_pool

        with ExitStack() as stack:
            if self.opt.space_backend is not None:
                stack.enter_context(_space_backend_ctx(self.opt.space_backend))
            if self.opt.acquisition_backend is not None:
                stack.enter_context(acquisition_backend(self.opt.acquisition_backend))
            if self.opt.acquisition_pool is not None:
                stack.enter_context(acquisition_pool(self.opt.acquisition_pool))
            return self._run(budget)

    # service-facing name for the same entry point
    tune = run

    def _run(self, budget: Budget) -> TuningResult:
        from .acquisition import plane_cache_stats

        opt = self.opt
        plane0 = plane_cache_stats()
        # ---------------- Phase 1 warm start (once, full fidelity)
        with obs.span("warm_start") as sp:
            weights = self._weights()
            if opt.enable_warmstart_p1 and opt.enable_transfer:
                tasks = {t.task_id: t for t in self.kb.source_tasks(self.target.task_id)}
                cfg1 = phase1_config(weights, tasks)
                if cfg1 is not None and not budget.exhausted:
                    self._evaluate(budget, cfg1, 1.0, None)
                    sp.set(phase1=True)

        # ---------------- cold-start init if nothing else to go on
        if not weights.weights and not self.target.full_fidelity():
            # anchor on the vendor default first: a feasible reference that
            # floors the result at parity with the default and prices an
            # early-stop cap for the LHS probes — without it, exploratory
            # draws (log-geometry sampling reaches deep into the low-memory
            # OOM region on large inputs) each burn 4x-timeout charges
            with obs.span("cold_start", init_lhs=opt.init_lhs):
                cap = None
                if not budget.exhausted:
                    _, d_failed, d_cost = self._evaluate(
                        budget, dict(self.wl.default_config()), 1.0, None
                    )
                    if not d_failed:
                        cap = opt.early_stop_factor * d_cost
                for cfg in self.space.lhs_sample(self.rng, opt.init_lhs):
                    if budget.exhausted:
                        break
                    self._evaluate(budget, cfg, 1.0, cap)
            weights = self._weights()

        # ---------------- iterative tuning
        it = 0
        while not budget.exhausted:
            it += 1
            with obs.span("iteration", i=it) as sp:
                weights = self._weights()
                if it % max(opt.sc_refresh_every, 1) == 0:
                    self._compress(weights)
                self._try_partition(weights)

                if self._mfo_ready():
                    if self._mfo_activation_time is None:
                        self._mfo_activation_time = budget.now
                    sp.set(mode="mfo")
                    self._run_mfo_bracket(budget, weights)
                else:
                    sp.set(mode="bo")
                    self._run_bo_step(budget, weights)

        best_cfg, best_perf = self._best()
        # absorb the remaining side channels into the registry, then expose
        # the legacy TuningResult fields as views over it
        m = self.metrics
        m.absorb_counters("surrogate_store/", self.gen.cache_stats)
        plane_now = plane_cache_stats()
        m.absorb_counters("plane_cache/", {
            **{k: plane_now[k] - plane0[k] for k in ("hits", "misses", "evictions")},
            "entries": plane_now["entries"],
            "max_entries": plane_now["max_entries"],
        })
        tracer = obs.get_tracer()
        if tracer is not None:
            tracer.emit_metrics(m, scope=self.target.task_id)
        return TuningResult(
            best_config=best_cfg,
            best_performance=best_perf,
            trajectory=self._trajectory,
            n_evaluations=self._n_eval,
            n_full_evaluations=self._n_full,
            mfo_activation_time=self._mfo_activation_time,
            overheads=m.counters_view("overhead/", coerce_int=False),
            surrogate_cache=m.counters_view("surrogate_store/"),
            rung_tables=list(self.hb.tables),
            plane_cache=m.counters_view("plane_cache/"),
            metrics=m.snapshot(),
        )

    # --------------------------------------------------------------- BO step
    def _sources_for_gen(self, weights: TaskWeights):
        tasks = (
            {t.task_id: t for t in self.kb.source_tasks(self.target.task_id)}
            if self.opt.enable_transfer
            else {}
        )
        return self.gen.build_sources(weights, tasks, self.target, self._deltas)

    def _run_bo_step(self, budget: Budget, weights: TaskWeights) -> None:
        t0 = _time.perf_counter()
        with obs.span("bo_recommend", mode="bo_step") as sp:
            sources = self._sources_for_gen(weights)
            incumbent_cfg, _ = self._best()
            # `is not None`: an all-defaults {} incumbent is falsy but real
            incumbents = [incumbent_cfg] if incumbent_cfg is not None else []
            evaluated = [o.config for o in self.target.observations]
            cands = self.gen.recommend(1, sources, incumbents=incumbents, exclude=evaluated)
            sp.set(sources=len(sources), candidates=len(cands))
        self._charge_overhead("bo_recommend", t0)
        if cands:
            self._evaluate(budget, cands[0], 1.0, None)

    # -------------------------------------------------------------- MFO step
    def _run_mfo_bracket(self, budget: Budget, weights: TaskWeights) -> None:
        bracket = self.hb.next_bracket()
        opt = self.opt

        def provide(n: int, rungs: List[Rung]) -> Sequence[Config]:
            t0 = _time.perf_counter()
            with obs.span("bo_recommend", mode="provide", n=n) as sp:
                ws: List[Config] = []
                multi_rung = len(rungs) > 1
                if opt.enable_warmstart_p2 and opt.enable_transfer and multi_rung:
                    tasks = {t.task_id: t for t in self.kb.source_tasks(self.target.task_id)}
                    self.ws_queue.rebuild(weights, tasks)
                    # as many as survive to full fidelity in this inner loop
                    ws = self.ws_queue.take(rungs[-1].n)
                sources = self._sources_for_gen(weights)
                incumbent_cfg, _ = self._best()
                # `is not None`: an all-defaults {} incumbent is falsy but real
                incumbents = [incumbent_cfg] if incumbent_cfg is not None else []
                evaluated = [o.config for o in self.target.observations]
                sp.set(warm_starts=len(ws), sources=len(sources))
                if self.hb.backend == "table":
                    # rung-table provisioning: BO candidates stay one columnar
                    # batch; the table indexes (ws rows + batch rows) by column
                    # and materializes dicts only when an evaluation needs them
                    bo_batch = self.gen.recommend_batch(
                        max(n - len(ws), 0), sources, incumbents=incumbents, exclude=evaluated + ws
                    )
                    self._charge_overhead("bo_recommend", t0)
                    return CandidateColumns(ws, bo_batch, limit=n)
                bo = self.gen.recommend(
                    max(n - len(ws), 0), sources, incumbents=incumbents, exclude=evaluated + ws
                )
                self._charge_overhead("bo_recommend", t0)
                return (ws + bo)[:n]

        def evaluate(cfg: Config, delta: float, cap: Optional[float]):
            return self._evaluate(budget, cfg, delta, cap)

        def evaluate_batch(cfgs: List[Config], delta: float, cap: Optional[float]):
            return self._evaluate_many(budget, cfgs, delta, cap)

        def on_result(cfg, delta, perf, failed, elapsed):
            pass  # recording happens inside _evaluate / _evaluate_many

        with obs.span("mfo_bracket", s=bracket.s, n_rungs=len(bracket.rungs)):
            self.hb.run_bracket(
                bracket,
                provide_candidates=provide,
                evaluate=evaluate,
                on_result=on_result,
                should_stop=lambda: budget.exhausted,
                evaluate_batch=evaluate_batch,
            )
