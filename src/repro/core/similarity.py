"""Similarity identification and weighting (paper §4.2).

Similarity S(i, T) between source task i and target T is the Kendall-tau
coefficient between the source surrogate's predictions and the ground-truth
performance on the target's observations (Eq. 2). Because Eq. 2 is noisy
when |D_T| is small, the initial phase predicts pairwise similarity from
34-d task meta-features with a GBRT regressor trained on historical
pairwise surrogate-agreement labels; a transition mechanism switches to
Eq. 2 once the majority of source tasks have tau p-values < 0.05.

Weighting: sources with non-positive similarity are dropped; the rest are
normalized to weights. The target task participates with a weight derived
from its surrogate's out-of-sample (k-fold) Kendall tau.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
from scipy import stats

from .gbm import GradientBoostedTrees
from .knowledge import KnowledgeBase, TaskRecord
from .space import ConfigSpace
from .surrogate import Surrogate, make_forest

__all__ = [
    "kendall_tau",
    "surrogate_for_task",
    "eq2_similarity",
    "MetaSimilarityModel",
    "SimilarityEngine",
    "TaskWeights",
]


def kendall_tau(a: Sequence[float], b: Sequence[float]) -> Tuple[float, float]:
    """Kendall tau-b and its p-value; (0, 1) for degenerate inputs."""
    a = np.asarray(a, dtype=float)
    b = np.asarray(b, dtype=float)
    if len(a) < 2 or np.all(a == a[0]) or np.all(b == b[0]):
        return 0.0, 1.0
    res = stats.kendalltau(a, b)
    tau = float(res.statistic) if np.isfinite(res.statistic) else 0.0
    p = float(res.pvalue) if np.isfinite(res.pvalue) else 1.0
    return tau, p


def surrogate_for_task(
    space: ConfigSpace,
    task: TaskRecord,
    fidelity: Optional[float] = None,
    seed: int = 0,
    backend: Optional[str] = None,
) -> Optional[Surrogate]:
    """Fit a PRF on a task's observations in the given space encoding."""
    if fidelity is None:
        obs = task.successful()
    else:
        obs = task.at_fidelity(fidelity)
    if len(obs) < 2:
        return None
    X = space.encode_many([o.config for o in obs])
    y = np.array([o.performance for o in obs])
    return make_forest(seed=seed, backend=backend).fit(X, y)


def eq2_similarity(
    space: ConfigSpace,
    source_model: Surrogate,
    target: TaskRecord,
    target_Xy: Optional[Tuple[np.ndarray, np.ndarray]] = None,
) -> Tuple[float, float]:
    """S(i,T) = KendallTau^{D_T}(M_i, Y)  (Eq. 2). Returns (tau, p).

    ``target_Xy`` lets callers that score many sources against the same
    target encode the target observations once (see SimilarityEngine).
    """
    if target_Xy is None:
        obs = target.full_fidelity()
        if len(obs) < 3:
            return 0.0, 1.0
        X = space.encode_many([o.config for o in obs])
        y = np.array([o.performance for o in obs])
    else:
        X, y = target_Xy
        if len(y) < 3:
            return 0.0, 1.0
    pred = source_model.predict_mean(X)
    return kendall_tau(pred, y)


class MetaSimilarityModel:
    """GBRT over concatenated meta-feature pairs (paper's LightGBM role).

    Trained on labels KendallTau^{D_rand}(M_i, M_j): agreement of the two
    source surrogates' predictions on random configurations.
    """

    def __init__(self, seed: int = 0, n_random: int = 64):
        self.seed = seed
        self.n_random = n_random
        self.model: Optional[GradientBoostedTrees] = None

    @staticmethod
    def _pair_features(fi: np.ndarray, fj: np.ndarray) -> np.ndarray:
        # symmetric encoding: |diff| and elementwise product stabilize learning
        return np.concatenate([np.abs(fi - fj), fi * fj])

    def fit(self, space: ConfigSpace, kb: KnowledgeBase, task_ids: Sequence[str]) -> "MetaSimilarityModel":
        rng = np.random.default_rng(self.seed)
        tasks = [kb.get(t) for t in task_ids if kb.get(t).meta_features is not None]
        models: Dict[str, Surrogate] = {}
        for t in tasks:
            m = surrogate_for_task(space, t, seed=self.seed)
            if m is not None:
                models[t.task_id] = m
        tasks = [t for t in tasks if t.task_id in models]
        if len(tasks) < 2:
            return self
        Xrand = space.encode_many(space.sample(rng, self.n_random))
        feats, labels = [], []
        for i in range(len(tasks)):
            pi = models[tasks[i].task_id].predict_mean(Xrand)
            for j in range(len(tasks)):
                if i == j:
                    continue
                pj = models[tasks[j].task_id].predict_mean(Xrand)
                tau, _ = kendall_tau(pi, pj)
                feats.append(
                    self._pair_features(
                        np.asarray(tasks[i].meta_features), np.asarray(tasks[j].meta_features)
                    )
                )
                labels.append(tau)
        self.model = GradientBoostedTrees(seed=self.seed).fit(np.array(feats), np.array(labels))
        return self

    def predict(self, f_target: Sequence[float], f_source: Sequence[float]) -> float:
        if self.model is None:
            return 0.0
        x = self._pair_features(np.asarray(f_target, dtype=float), np.asarray(f_source, dtype=float))
        return float(self.model.predict(x[None, :])[0])


@dataclass
class TaskWeights:
    """Normalized transfer weights; target weight included under key ``__target__``."""

    weights: Dict[str, float]
    similarities: Dict[str, float]
    used_meta: bool  # True while the meta-feature predictor was in charge

    def for_task(self, task_id: str) -> float:
        return self.weights.get(task_id, 0.0)

    @property
    def source_ids(self) -> List[str]:
        return [k for k in self.weights if k != "__target__"]


class SimilarityEngine:
    """Implements §4.2 end-to-end: prediction warm start -> Eq. 2 -> weights."""

    def __init__(
        self,
        space: ConfigSpace,
        kb: KnowledgeBase,
        seed: int = 0,
        p_threshold: float = 0.05,
        cv_folds: int = 4,
    ):
        self.space = space
        self.kb = kb
        self.seed = seed
        self.p_threshold = p_threshold
        self.cv_folds = cv_folds
        self.meta_model: Optional[MetaSimilarityModel] = None
        self._source_models: Dict[str, Surrogate] = {}

    # --------------------------------------------------------------- helpers
    def _ensure_meta_model(self, target: TaskRecord) -> None:
        if self.meta_model is not None:
            return
        ids = [t.task_id for t in self.kb.source_tasks(target.task_id)]
        self.meta_model = MetaSimilarityModel(seed=self.seed).fit(self.space, self.kb, ids)

    def source_model(self, task_id: str) -> Optional[Surrogate]:
        if task_id not in self._source_models:
            m = surrogate_for_task(self.space, self.kb.get(task_id), seed=self.seed)
            if m is None:
                return None
            self._source_models[task_id] = m
        return self._source_models[task_id]

    def target_self_weight(self, target: TaskRecord) -> float:
        """Out-of-sample Kendall tau of the target surrogate via k-fold CV."""
        obs = target.full_fidelity()
        if len(obs) < self.cv_folds + 1:
            return 0.0
        X = self.space.encode_many([o.config for o in obs])
        y = np.array([o.performance for o in obs])
        n = len(y)
        folds = np.arange(n) % self.cv_folds
        preds = np.zeros(n)
        for f in range(self.cv_folds):
            tr, te = folds != f, folds == f
            if tr.sum() < 2 or te.sum() < 1:
                return 0.0
            m = make_forest(seed=self.seed).fit(X[tr], y[tr])
            preds[te] = m.predict_mean(X[te])
        tau, _ = kendall_tau(preds, y)
        return max(tau, 0.0)

    # ------------------------------------------------------------------ main
    def compute(self, target: TaskRecord) -> TaskWeights:
        sources = self.kb.source_tasks(target.task_id)
        sims: Dict[str, float] = {}
        pvals: Dict[str, float] = {}
        # encode the target's observations once; every source model scores
        # the same matrix (the per-source re-encode was a per-knob loop)
        obs = target.full_fidelity()
        target_Xy = (
            (self.space.encode_many([o.config for o in obs]),
             np.array([o.performance for o in obs]))
            if len(obs) >= 3 else None
        )
        for s in sources:
            m = self.source_model(s.task_id)
            if m is None:
                continue
            tau, p = eq2_similarity(self.space, m, target, target_Xy=target_Xy)
            sims[s.task_id] = tau
            pvals[s.task_id] = p

        # transition mechanism: majority of sources significant -> trust Eq. 2
        n_sig = sum(1 for p in pvals.values() if p < self.p_threshold)
        use_eq2 = len(pvals) > 0 and n_sig > len(pvals) / 2

        if not use_eq2:
            # warm-start phase: predict similarity from meta-features
            if target.meta_features is not None:
                self._ensure_meta_model(target)
                for s in sources:
                    if s.task_id in sims or True:  # overwrite with predictions
                        if s.meta_features is not None and self.meta_model is not None:
                            sims[s.task_id] = self.meta_model.predict(
                                target.meta_features, s.meta_features
                            )
            # if no meta features either, fall back to whatever Eq. 2 gave us

        # filter negatives, normalize
        pos = {k: v for k, v in sims.items() if v > 0}
        self_w = self.target_self_weight(target)
        total = sum(pos.values()) + self_w
        weights: Dict[str, float] = {}
        if total > 0:
            for k, v in pos.items():
                weights[k] = v / total
            if self_w > 0:
                weights["__target__"] = self_w / total
        elif target.full_fidelity():
            weights["__target__"] = 1.0
        return TaskWeights(weights=weights, similarities=sims, used_meta=not use_eq2)
