"""Configuration search space: columnar plane + scalar reference.

The space is a flat, named collection of knobs. Four knob kinds are
supported (float / int / categorical / bool), with optional log scaling for
numeric knobs. Every knob can additionally carry a *restriction*: for
numeric knobs a union of closed intervals (the output of the density-based
range compression, paper Eq. 5), and for categorical/bool knobs a subset of
the choices (paper Eq. 6). Sampling, unit-cube encoding and neighbourhood
mutation all respect the active restriction.

Encoding: each knob maps to one dimension in [0, 1]. Numeric knobs are
affinely mapped (in log space when ``log=True``); categorical knobs map to
the bin midpoint of the chosen category. This single encoding is shared by
the surrogates, the Shapley attribution, the KDE compression and LHS so
that all components observe a consistent geometry.

Plane / compile model
---------------------
All whole-pool operations run through a :class:`SpacePlane`, a
struct-of-arrays compile of the space: per-knob transform tables (log-affine
``(t_lo, t_span)`` parameters, restriction CDFs as normalized
cumulative-length arrays, category index tables) built once per
``(space, sampling geometry)`` and cached on the space. ``sample`` /
``lhs_sample`` / ``mutate_many`` / ``encode_many`` / ``decode_many`` /
``project_many`` draw U(0,1) matrices once and push whole knob *columns*
through the tables — a handful of vector ops per knob instead of a
per-config, per-knob Python loop. Results are wrapped in a lazy
:class:`ConfigBatch` (canonical value matrix + cached unit encoding) so the
generator/acquisition path never round-trips through Config dicts; dicts
are materialized only at the evaluation boundary.

Backend contract
----------------
``set_space_backend("columnar" | "scalar")`` switches every batched entry
point. The default ``"columnar"`` path is the plane described above. The
``"scalar"`` path is the per-element reference: it maps one (config, knob)
cell at a time with numpy-scalar arithmetic over the *same* compiled tables
and the knob objects' own ``to_unit`` / ``from_unit`` methods, consuming
the *same* pre-drawn uniform/normal matrices. The two backends are
bit-equivalence-tested against each other (tests/test_space_plane.py); a
fixed seed therefore yields identical pools, mutations and MFTune
trajectories on either backend.

Log-knob sampling geometry: historically ``Intervals.sample`` /
``quantile_map`` were uniform in *raw* units even for ``log=True`` knobs,
while encode/decode are log-affine — sampling and the surrogate encoding
observed different geometries. The plane fixes this by sampling log knobs
uniformly in transformed (log) space, but the fix is gated: it is the
default only on the ``"columnar"`` backend, while the ``"scalar"``
reference keeps the legacy raw-unit geometry. (Geometry, not streams: the
draw protocol itself changed to whole-matrix U(0,1) draws on every
backend, so a pre-refactor seed does not replay bit-identically on
either backend.) ``set_log_sampling(True | False | None)``
overrides the geometry explicitly for either backend (used by the
equivalence tests, which pin both backends to one geometry).
"""

from __future__ import annotations

import contextlib
import math
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .. import obs as _obs

__all__ = [
    "Knob",
    "FloatKnob",
    "IntKnob",
    "CatKnob",
    "BoolKnob",
    "ConfigSpace",
    "ConfigBatch",
    "SpacePlane",
    "Intervals",
    "get_space_backend",
    "set_space_backend",
    "space_backend",
    "set_log_sampling",
    "log_sampling",
]


Interval = Tuple[float, float]


# ---------------------------------------------------------------------------
# Backend switch (columnar plane vs per-element scalar reference)
# ---------------------------------------------------------------------------

_SPACE_BACKENDS = ("columnar", "scalar")
_SPACE_BACKEND = "columnar"
# None = backend default: columnar samples log knobs in log space (the fix),
# scalar keeps the legacy raw-unit geometry. True/False force a geometry.
_LOG_SAMPLING: Optional[bool] = None


def get_space_backend() -> str:
    return _SPACE_BACKEND


def set_space_backend(backend: str) -> None:
    """Set the module-default batched-space backend ("scalar" forces the
    per-element reference everywhere — used by equivalence tests)."""
    if backend not in _SPACE_BACKENDS:
        raise ValueError(f"unknown space backend {backend!r}; use one of {_SPACE_BACKENDS}")
    global _SPACE_BACKEND
    _SPACE_BACKEND = backend


@contextlib.contextmanager
def space_backend(backend: str):
    prev = get_space_backend()
    set_space_backend(backend)
    try:
        yield
    finally:
        set_space_backend(prev)


def set_log_sampling(flag: Optional[bool]) -> None:
    """Override the log-knob sampling geometry (None = backend default)."""
    global _LOG_SAMPLING
    _LOG_SAMPLING = flag


@contextlib.contextmanager
def log_sampling(flag: Optional[bool]):
    global _LOG_SAMPLING
    prev = _LOG_SAMPLING
    _LOG_SAMPLING = flag
    try:
        yield
    finally:
        _LOG_SAMPLING = prev


def _effective_log_sampling(backend: Optional[str] = None) -> bool:
    if _LOG_SAMPLING is not None:
        return _LOG_SAMPLING
    return (backend or _SPACE_BACKEND) == "columnar"


# ---------------------------------------------------------------------------
# Intervals
# ---------------------------------------------------------------------------


class Intervals:
    """A normalized union of closed intervals on the real line."""

    def __init__(self, intervals: Sequence[Interval]):
        self.intervals: List[Interval] = self._normalize(intervals)

    @staticmethod
    def _normalize(intervals: Sequence[Interval]) -> List[Interval]:
        ivs = sorted((float(a), float(b)) for a, b in intervals if b >= a)
        merged: List[Interval] = []
        for a, b in ivs:
            if merged and a <= merged[-1][1]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], b))
            else:
                merged.append((a, b))
        return merged

    def __bool__(self) -> bool:
        return bool(self.intervals)

    def __iter__(self):
        return iter(self.intervals)

    def __repr__(self) -> str:
        return f"Intervals({self.intervals!r})"

    @property
    def total_length(self) -> float:
        return sum(b - a for a, b in self.intervals)

    @property
    def lo(self) -> float:
        return self.intervals[0][0]

    @property
    def hi(self) -> float:
        return self.intervals[-1][1]

    def contains(self, x: float) -> bool:
        return any(a - 1e-12 <= x <= b + 1e-12 for a, b in self.intervals)

    def clip(self, x: float) -> float:
        """Project x onto the nearest point of the union."""
        if self.contains(x):
            return x
        best, bd = x, math.inf
        for a, b in self.intervals:
            for edge in (a, b):
                d = abs(x - edge)
                if d < bd:
                    best, bd = edge, d
        return best

    # Legacy raw-unit sampling helpers. The batched paths go through
    # SpacePlane's CDF tables instead; these remain for direct callers and
    # as the historical reference for the raw-unit geometry.
    def sample(self, rng: np.random.Generator, n: int = 1) -> np.ndarray:
        """Uniform samples over the union (length-weighted across pieces)."""
        lengths = np.array([b - a for a, b in self.intervals], dtype=float)
        if lengths.sum() <= 0:
            # degenerate (point) intervals: pick midpoints uniformly
            pts = np.array([(a + b) / 2 for a, b in self.intervals])
            return rng.choice(pts, size=n)
        probs = lengths / lengths.sum()
        idx = rng.choice(len(self.intervals), size=n, p=probs)
        u = rng.random(n)
        out = np.empty(n)
        for i, (a, b) in enumerate(self.intervals):
            sel = idx == i
            out[sel] = a + u[sel] * (b - a)
        return out

    def quantile_map(self, u: np.ndarray) -> np.ndarray:
        """Map u in [0,1] onto the union, proportionally by length."""
        lengths = np.array([b - a for a, b in self.intervals], dtype=float)
        tot = lengths.sum()
        if tot <= 0:
            pts = np.array([(a + b) / 2 for a, b in self.intervals])
            return pts[np.minimum((u * len(pts)).astype(int), len(pts) - 1)]
        cum = np.concatenate([[0.0], np.cumsum(lengths)]) / tot
        out = np.empty_like(u, dtype=float)
        for i, (a, b) in enumerate(self.intervals):
            sel = (u >= cum[i]) & (u <= cum[i + 1] if i == len(self.intervals) - 1 else u < cum[i + 1])
            if lengths[i] > 0:
                out[sel] = a + (u[sel] - cum[i]) / (cum[i + 1] - cum[i]) * (b - a)
            else:
                out[sel] = a
        return out


def _active_intervals(restriction: Optional[Intervals], lo: float, hi: float) -> Intervals:
    """Restriction clipped to [lo, hi]; the full range when empty/absent.

    Shared by FloatKnob and IntKnob (previously copy-pasted in both).
    """
    if restriction is not None and restriction:
        clipped = [
            (max(a, lo), min(b, hi))
            for a, b in restriction
            if min(b, hi) >= max(a, lo)
        ]
        if clipped:
            return Intervals(clipped)
    return Intervals([(float(lo), float(hi))])


# ---------------------------------------------------------------------------
# Knobs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Knob:
    name: str

    @property
    def kind(self) -> str:
        raise NotImplementedError

    def default_value(self) -> Any:
        raise NotImplementedError


@dataclass(frozen=True)
class FloatKnob(Knob):
    lo: float
    hi: float
    log: bool = False
    default: Optional[float] = None
    restriction: Optional[Intervals] = None  # in raw (untransformed) units

    @property
    def kind(self) -> str:
        return "float"

    def default_value(self) -> float:
        return self.default if self.default is not None else (self.lo + self.hi) / 2

    def _t(self, x: np.ndarray | float) -> np.ndarray | float:
        return np.log(x) if self.log else x

    def _it(self, t: np.ndarray | float) -> np.ndarray | float:
        return np.exp(t) if self.log else t

    def to_unit(self, x: np.ndarray | float) -> np.ndarray | float:
        a, b = self._t(self.lo), self._t(self.hi)
        return (self._t(x) - a) / (b - a)

    def from_unit(self, u: np.ndarray | float) -> np.ndarray | float:
        a, b = self._t(self.lo), self._t(self.hi)
        return self._it(a + np.clip(u, 0.0, 1.0) * (b - a))

    def active_intervals(self) -> Intervals:
        return _active_intervals(self.restriction, self.lo, self.hi)


@dataclass(frozen=True)
class IntKnob(Knob):
    lo: int
    hi: int
    log: bool = False
    default: Optional[int] = None
    restriction: Optional[Intervals] = None

    @property
    def kind(self) -> str:
        return "int"

    def default_value(self) -> int:
        return self.default if self.default is not None else (self.lo + self.hi) // 2

    def _t(self, x):
        return np.log(x) if self.log else x

    def _it(self, t):
        return np.exp(t) if self.log else t

    def to_unit(self, x):
        a, b = self._t(self.lo), self._t(self.hi)
        if b == a:
            return np.zeros_like(np.asarray(x, dtype=float))
        return (self._t(x) - a) / (b - a)

    def from_unit(self, u):
        a, b = self._t(self.lo), self._t(self.hi)
        val = self._it(a + np.clip(u, 0.0, 1.0) * (b - a))
        return np.clip(np.rint(val), self.lo, self.hi).astype(int)

    def active_intervals(self) -> Intervals:
        return _active_intervals(self.restriction, self.lo, self.hi)


@dataclass(frozen=True)
class CatKnob(Knob):
    choices: Tuple[Any, ...]
    default: Optional[Any] = None
    restriction: Optional[Tuple[Any, ...]] = None

    @property
    def kind(self) -> str:
        return "cat"

    def default_value(self) -> Any:
        return self.default if self.default is not None else self.choices[0]

    def active_choices(self) -> Tuple[Any, ...]:
        if self.restriction:
            kept = tuple(c for c in self.choices if c in self.restriction)
            if kept:
                return kept
        return self.choices

    def to_unit(self, x) -> float:
        i = self.choices.index(x)
        return (i + 0.5) / len(self.choices)

    def from_unit(self, u) -> Any:
        i = min(int(np.clip(u, 0.0, 1.0 - 1e-9) * len(self.choices)), len(self.choices) - 1)
        return self.choices[i]


@dataclass(frozen=True)
class BoolKnob(Knob):
    default: bool = False
    restriction: Optional[Tuple[bool, ...]] = None

    @property
    def kind(self) -> str:
        return "bool"

    def default_value(self) -> bool:
        return self.default

    def active_choices(self) -> Tuple[bool, ...]:
        if self.restriction:
            return self.restriction
        return (False, True)

    def to_unit(self, x) -> float:
        return 0.75 if x else 0.25

    def from_unit(self, u) -> bool:
        return bool(u >= 0.5)


Config = Dict[str, Any]

_KIND_FLOAT, _KIND_INT, _KIND_CAT, _KIND_BOOL = 0, 1, 2, 3


# ---------------------------------------------------------------------------
# SpacePlane: struct-of-arrays compile of a ConfigSpace
# ---------------------------------------------------------------------------


@dataclass
class _NumTable:
    """Per-numeric-knob restriction tables (one geometry)."""

    ga: np.ndarray        # piece lower bounds, sampling geometry
    gb: np.ndarray        # piece upper bounds, sampling geometry
    cum: np.ndarray       # (P+1,) normalized cumulative lengths (the CDF)
    raw_a: np.ndarray     # piece lower bounds, raw units (projection)
    raw_b: np.ndarray     # piece upper bounds, raw units
    edges: np.ndarray     # interleaved (a0, b0, a1, b1, ...) raw edges
    mid: np.ndarray       # raw piece midpoints (degenerate-union fallback)
    degenerate: bool      # True when the union has zero total length
    transformed: bool     # True when ga/gb live in log space


@dataclass
class _CatTable:
    """Per-categorical-knob active-choice index table."""

    n: int                # total number of choices (encoding bins)
    act: np.ndarray       # active choice indices into the full choice tuple
    act_set: frozenset    # same, as a set (projection membership)


class SpacePlane:
    """Columnar compile of a :class:`ConfigSpace` (see module docstring).

    One instance per (space, log-sampling geometry); built lazily by
    ``ConfigSpace.plane()`` and cached on the space — knobs are frozen
    dataclasses and the knob list never mutates after construction, so the
    compile stays valid for the space's lifetime.

    Canonical value matrix convention (``values`` of :class:`ConfigBatch`):
    float64, one column per knob — numeric knobs store the raw value (ints
    exactly representable), categorical knobs the index into the *full*
    choice tuple, bool knobs 0.0/1.0.
    """

    def __init__(self, space: "ConfigSpace", log_sampling_: bool):
        self.space = space
        self.log_sampling = bool(log_sampling_)
        knobs = space.knobs
        D = len(knobs)
        self.kind = np.empty(D, dtype=np.int8)
        self.is_log = np.zeros(D, dtype=bool)
        self.lo = np.zeros(D)
        self.hi = np.zeros(D)
        self.t_lo = np.zeros(D)
        self.t_span = np.ones(D)
        self.zero_span = np.zeros(D, dtype=bool)
        self.n_choices = np.zeros(D, dtype=np.int64)
        self.num_tables: List[Optional[_NumTable]] = [None] * D
        self.cat_tables: List[Optional[_CatTable]] = [None] * D
        default_row = np.zeros(D)
        for j, k in enumerate(knobs):
            if isinstance(k, (FloatKnob, IntKnob)):
                self.kind[j] = _KIND_INT if isinstance(k, IntKnob) else _KIND_FLOAT
                self.is_log[j] = bool(k.log)
                self.lo[j], self.hi[j] = float(k.lo), float(k.hi)
                a, b = k._t(float(k.lo)), k._t(float(k.hi))
                self.t_lo[j] = a
                self.t_span[j] = b - a
                self.zero_span[j] = b == a
                iv = k.active_intervals()
                raw_a = np.array([p[0] for p in iv], dtype=float)
                raw_b = np.array([p[1] for p in iv], dtype=float)
                transformed = self.log_sampling and bool(k.log)
                ga = np.log(raw_a) if transformed else raw_a
                gb = np.log(raw_b) if transformed else raw_b
                lengths = gb - ga
                tot = lengths.sum()
                if tot > 0:
                    cum = np.concatenate([[0.0], np.cumsum(lengths) / tot])
                    degenerate = False
                else:
                    cum = np.linspace(0.0, 1.0, len(raw_a) + 1)
                    degenerate = True
                self.num_tables[j] = _NumTable(
                    ga=ga, gb=gb, cum=cum, raw_a=raw_a, raw_b=raw_b,
                    edges=np.stack([raw_a, raw_b], axis=1).reshape(-1),
                    mid=(raw_a + raw_b) / 2, degenerate=degenerate,
                    transformed=transformed,
                )
                default_row[j] = float(k.default_value())
            elif isinstance(k, CatKnob):
                self.kind[j] = _KIND_CAT
                n = len(k.choices)
                self.n_choices[j] = n
                act = np.array([k.choices.index(c) for c in k.active_choices()], dtype=np.int64)
                self.cat_tables[j] = _CatTable(n=n, act=act, act_set=frozenset(int(i) for i in act))
                default_row[j] = float(k.choices.index(k.default_value()))
            elif isinstance(k, BoolKnob):
                self.kind[j] = _KIND_BOOL
                self.n_choices[j] = 2
                act = np.array([1 if c else 0 for c in k.active_choices()], dtype=np.int64)
                self.cat_tables[j] = _CatTable(n=2, act=act, act_set=frozenset(int(i) for i in act))
                default_row[j] = 1.0 if k.default_value() else 0.0
            else:
                raise TypeError(k)
        self.default_row = default_row

    # ----------------------------------------------------------- column ops
    def _to_unit_col(self, j: int, v: np.ndarray) -> np.ndarray:
        """Raw values -> affine unit coordinate (no clipping)."""
        kj = self.kind[j]
        if kj in (_KIND_FLOAT, _KIND_INT):
            if self.zero_span[j]:
                return np.zeros_like(v)
            t = np.log(v) if self.is_log[j] else v
            return (t - self.t_lo[j]) / self.t_span[j]
        if kj == _KIND_CAT:
            return (v + 0.5) / self.n_choices[j]
        return np.where(v != 0, 0.75, 0.25)

    def _from_unit_col(self, j: int, u: np.ndarray) -> np.ndarray:
        """Unit coordinate -> raw canonical value (legacy from_unit)."""
        kj = self.kind[j]
        if kj in (_KIND_FLOAT, _KIND_INT):
            t = self.t_lo[j] + np.clip(u, 0.0, 1.0) * self.t_span[j]
            v = np.exp(t) if self.is_log[j] else t
            if kj == _KIND_INT:
                v = np.clip(np.rint(v), self.lo[j], self.hi[j])
            return v
        if kj == _KIND_CAT:
            n = self.n_choices[j]
            return np.minimum(
                (np.clip(u, 0.0, 1.0 - 1e-9) * n).astype(np.int64), n - 1
            ).astype(float)
        return (u >= 0.5).astype(float)

    def _quantile_col(self, j: int, u: np.ndarray) -> np.ndarray:
        """Unit draw -> raw value, uniform over the active restriction
        (in the plane's sampling geometry for log knobs)."""
        kj = self.kind[j]
        if kj in (_KIND_FLOAT, _KIND_INT):
            tab = self.num_tables[j]
            P = len(tab.ga)
            if tab.degenerate:
                v = tab.mid[np.minimum((u * P).astype(np.int64), P - 1)]
            else:
                i = np.clip(np.searchsorted(tab.cum, u, side="right") - 1, 0, P - 1)
                span = tab.cum[i + 1] - tab.cum[i]
                frac = np.where(span > 0, (u - tab.cum[i]) / np.where(span > 0, span, 1.0), 0.0)
                g = tab.ga[i] + frac * (tab.gb[i] - tab.ga[i])
                v = np.exp(g) if tab.transformed else g
            if kj == _KIND_INT:
                v = np.clip(np.rint(v), self.lo[j], self.hi[j])
            return v
        tab = self.cat_tables[j]
        m = len(tab.act)
        pick = np.minimum((u * m).astype(np.int64), m - 1)
        return tab.act[pick].astype(float)

    def _project_col(self, j: int, v: np.ndarray) -> np.ndarray:
        """Clip a value column into the active restriction (raw units)."""
        kj = self.kind[j]
        if kj in (_KIND_FLOAT, _KIND_INT):
            v = self._iv_clip_col(j, v)
            if kj == _KIND_INT:
                v = np.rint(v)
            return np.clip(v, self.lo[j], self.hi[j])
        tab = self.cat_tables[j]
        ok = np.isin(v.astype(np.int64), tab.act)
        return np.where(ok, v, float(tab.act[0]))

    def _iv_clip_col(self, j: int, v: np.ndarray) -> np.ndarray:
        """Nearest-point projection onto the raw union (no bound clip) —
        the columnar Intervals.clip shared by projection and mutation.
        argmin keeps the first minimum, matching the scalar strict-< scan
        over pieces in order."""
        tab = self.num_tables[j]
        inside = np.zeros(v.shape, dtype=bool)
        for a, b in zip(tab.raw_a, tab.raw_b):
            inside |= (a - 1e-12 <= v) & (v <= b + 1e-12)
        if inside.all():
            return v
        nearest = tab.edges[np.argmin(np.abs(v[:, None] - tab.edges[None, :]), axis=1)]
        return np.where(inside, v, nearest)

    # ----------------------------------------------------------- device pool
    def device_tables(self) -> Tuple[tuple, tuple]:
        """Static per-knob signature + arrays for the on-device sampler.

        Returns ``(sig, cols)``: ``sig`` is a hashable tuple of per-knob
        ``(kind, is_log, transformed, degenerate, zero_span, size)`` tuples
        (a jit static argument for the fused propose step), ``cols`` the
        matching tuple of per-knob numpy array tuples — numeric knobs get
        ``(ga, gb, cum, mid, scal)`` with ``scal = [t_lo, t_span, lo, hi]``
        (the restriction-CDF tables plus the log-affine unit transform),
        categorical/bool knobs ``(act,)`` with the choice count carried in
        the signature. The fused propose step uploads these once and
        replays ``_quantile_col`` + clipped ``_to_unit_col`` per column on
        device.
        """
        sig, cols = [], []
        for j in range(len(self.space.knobs)):
            kj = int(self.kind[j])
            if kj in (_KIND_FLOAT, _KIND_INT):
                tab = self.num_tables[j]
                sig.append((kj, bool(self.is_log[j]), bool(tab.transformed),
                            bool(tab.degenerate), bool(self.zero_span[j]),
                            len(tab.ga)))
                scal = np.array([self.t_lo[j], self.t_span[j],
                                 self.lo[j], self.hi[j]])
                cols.append((tab.ga, tab.gb, tab.cum, tab.mid, scal))
            else:
                tab = self.cat_tables[j]
                sig.append((kj, False, False, False, False,
                            int(self.n_choices[j])))
                cols.append((tab.act,))
        return tuple(sig), tuple(cols)

    # ------------------------------------------------------------ matrix ops
    def encode_values(self, V: np.ndarray) -> np.ndarray:
        U = np.empty_like(V)
        for j in range(V.shape[1]):
            U[:, j] = np.clip(self._to_unit_col(j, V[:, j]), 0.0, 1.0)
        return U

    def decode_units(self, U: np.ndarray) -> np.ndarray:
        """Unit rows -> canonical values, restriction-aware: ``from_unit``
        followed by projection onto the active restriction (the legacy
        ``decode`` silently bypassed restrictions; ``decode``/``decode_many``
        now route here)."""
        V = np.empty_like(U)
        for j in range(U.shape[1]):
            V[:, j] = self._project_col(j, self._from_unit_col(j, U[:, j]))
        return V

    def sample_values(self, U: np.ndarray) -> np.ndarray:
        V = np.empty_like(U)
        for j in range(U.shape[1]):
            V[:, j] = self._quantile_col(j, U[:, j])
        return V

    def mutate_values(
        self, V: np.ndarray, G: np.ndarray, Z: np.ndarray, C: np.ndarray,
        scale: float, p: float,
    ) -> np.ndarray:
        out = V.copy()
        for j in range(V.shape[1]):
            mut = G[:, j] <= p
            if not mut.any():
                continue
            if self.kind[j] in (_KIND_FLOAT, _KIND_INT):
                u = np.clip(self._to_unit_col(j, V[:, j]), 0.0, 1.0)
                u = np.clip(u + scale * Z[:, j], 0.0, 1.0)
                w = self._from_unit_col(j, u)
                w = self._iv_clip_col(j, w)
                if self.kind[j] == _KIND_INT:
                    w = np.clip(np.rint(w), self.lo[j], self.hi[j])
                out[:, j] = np.where(mut, w, V[:, j])
            else:
                out[:, j] = np.where(mut, self._quantile_col(j, C[:, j]), V[:, j])
        return out

    def project_values(self, V: np.ndarray) -> np.ndarray:
        out = np.empty_like(V)
        for j in range(V.shape[1]):
            out[:, j] = self._project_col(j, V[:, j])
        return out

    # --------------------------------------------------------- dict boundary
    def gather(self, cfgs: Sequence[Config]) -> np.ndarray:
        """Config dicts -> canonical value matrix (missing knobs -> default)."""
        knobs = self.space.knobs
        V = np.empty((len(cfgs), len(knobs)))
        for j, k in enumerate(knobs):
            name = k.name
            if self.kind[j] == _KIND_CAT:
                idx = k.choices.index
                dv = float(idx(k.default_value()))
                V[:, j] = [float(idx(c[name])) if name in c else dv for c in cfgs]
            elif self.kind[j] == _KIND_BOOL:
                dv = 1.0 if k.default_value() else 0.0
                V[:, j] = [(1.0 if c[name] else 0.0) if name in c else dv for c in cfgs]
            else:
                dv = float(k.default_value())
                V[:, j] = [float(c.get(name, dv)) for c in cfgs]
        return V

    def materialize_row(self, row: np.ndarray) -> Config:
        """One canonical value row -> Config dict with native value types."""
        out: Config = {}
        for j, k in enumerate(self.space.knobs):
            kj = self.kind[j]
            if kj == _KIND_FLOAT:
                out[k.name] = float(row[j])
            elif kj == _KIND_INT:
                out[k.name] = int(row[j])
            elif kj == _KIND_CAT:
                out[k.name] = k.choices[int(row[j])]
            else:
                out[k.name] = bool(row[j] != 0)
        return out


# ---------------------------------------------------------------------------
# Scalar reference kernels (per-element, numpy-scalar arithmetic)
# ---------------------------------------------------------------------------


def _scalar_quantile(plane: SpacePlane, j: int, u: float) -> float:
    kj = plane.kind[j]
    if kj in (_KIND_FLOAT, _KIND_INT):
        tab = plane.num_tables[j]
        P = len(tab.ga)
        if tab.degenerate:
            v = tab.mid[min(int(u * P), P - 1)]
        else:
            i = min(max(int(np.searchsorted(tab.cum, u, side="right")) - 1, 0), P - 1)
            span = tab.cum[i + 1] - tab.cum[i]
            frac = (u - tab.cum[i]) / span if span > 0 else 0.0
            g = tab.ga[i] + frac * (tab.gb[i] - tab.ga[i])
            v = np.exp(g) if tab.transformed else g
        if kj == _KIND_INT:
            v = np.clip(np.rint(v), plane.lo[j], plane.hi[j])
        return float(v)
    tab = plane.cat_tables[j]
    m = len(tab.act)
    return float(tab.act[min(int(u * m), m - 1)])


def _scalar_project(plane: SpacePlane, j: int, v: float) -> float:
    kj = plane.kind[j]
    if kj in (_KIND_FLOAT, _KIND_INT):
        k = plane.space.knobs[j]
        w = k.active_intervals().clip(float(v))
        if kj == _KIND_INT:
            w = np.rint(w)
        return float(np.clip(w, plane.lo[j], plane.hi[j]))
    tab = plane.cat_tables[j]
    return float(v) if int(v) in tab.act_set else float(tab.act[0])


def _scalar_sample_values(plane: SpacePlane, U: np.ndarray) -> np.ndarray:
    V = np.empty_like(U)
    for i in range(U.shape[0]):
        for j in range(U.shape[1]):
            V[i, j] = _scalar_quantile(plane, j, U[i, j])
    return V


def _scalar_encode_values(plane: SpacePlane, V: np.ndarray) -> np.ndarray:
    knobs = plane.space.knobs
    U = np.empty_like(V)
    for i in range(V.shape[0]):
        for j, k in enumerate(knobs):
            kj = plane.kind[j]
            if kj in (_KIND_FLOAT, _KIND_INT):
                u = k.to_unit(V[i, j])
            elif kj == _KIND_CAT:
                u = (V[i, j] + 0.5) / plane.n_choices[j]
            else:
                u = 0.75 if V[i, j] != 0 else 0.25
            U[i, j] = np.clip(u, 0.0, 1.0)
    return U


def _scalar_decode_units(plane: SpacePlane, U: np.ndarray) -> np.ndarray:
    knobs = plane.space.knobs
    V = np.empty_like(U)
    for i in range(U.shape[0]):
        for j, k in enumerate(knobs):
            kj = plane.kind[j]
            if kj in (_KIND_FLOAT, _KIND_INT):
                v = float(k.from_unit(float(U[i, j])))
            elif kj == _KIND_CAT:
                n = plane.n_choices[j]
                v = float(min(int(np.clip(U[i, j], 0.0, 1.0 - 1e-9) * n), n - 1))
            else:
                v = 1.0 if U[i, j] >= 0.5 else 0.0
            V[i, j] = _scalar_project(plane, j, v)
    return V


def _scalar_mutate_values(
    plane: SpacePlane, V: np.ndarray, G: np.ndarray, Z: np.ndarray, C: np.ndarray,
    scale: float, p: float,
) -> np.ndarray:
    knobs = plane.space.knobs
    out = V.copy()
    for i in range(V.shape[0]):
        for j, k in enumerate(knobs):
            if G[i, j] > p:
                continue
            kj = plane.kind[j]
            if kj in (_KIND_FLOAT, _KIND_INT):
                u = float(np.clip(k.to_unit(V[i, j]), 0.0, 1.0))
                u = float(np.clip(u + scale * Z[i, j], 0.0, 1.0))
                w = float(k.from_unit(u))
                w = k.active_intervals().clip(w)
                if kj == _KIND_INT:
                    w = float(np.clip(np.rint(w), plane.lo[j], plane.hi[j]))
                out[i, j] = w
            else:
                out[i, j] = _scalar_quantile(plane, j, C[i, j])
    return out


def _scalar_project_values(plane: SpacePlane, V: np.ndarray) -> np.ndarray:
    out = np.empty_like(V)
    for i in range(V.shape[0]):
        for j in range(V.shape[1]):
            out[i, j] = _scalar_project(plane, j, V[i, j])
    return out


# ---------------------------------------------------------------------------
# ConfigBatch: lazy columnar view over a pool of configurations
# ---------------------------------------------------------------------------


class ConfigBatch(Sequence):
    """A pool of configurations as a canonical value matrix.

    Behaves as a ``Sequence[Config]`` — indexing/iteration materialize dicts
    one row at a time — while the generator/acquisition path reads
    ``values`` (canonical matrix) and ``unit()`` (cached unit-cube encoding)
    without ever building dicts. ``unit()`` dispatches through the active
    space backend so scalar/columnar runs stay bit-comparable end-to-end.
    """

    __slots__ = ("space", "values", "_unit", "_delta")

    def __init__(self, space: "ConfigSpace", values: np.ndarray):
        self.space = space
        self.values = np.ascontiguousarray(np.atleast_2d(np.asarray(values, dtype=float)))
        if self.values.size == 0:
            self.values = self.values.reshape(0, space.dim)
        if self.values.shape[1] != space.dim:
            raise ValueError(f"value matrix has {self.values.shape[1]} columns, space has {space.dim}")
        self._unit: Optional[np.ndarray] = None
        # mutation provenance: (bases_unit, base_of) when rows derive from
        # incumbent mutations — lets pool scoring reuse per-base word ANDs
        self._delta = None

    @classmethod
    def from_configs(cls, space: "ConfigSpace", cfgs: Sequence[Config]) -> "ConfigBatch":
        if isinstance(cfgs, ConfigBatch):
            if cfgs.space is space:
                return cfgs
            return cls(space, space.plane().gather(list(cfgs)))
        return cls(space, space.plane().gather(cfgs))

    # ------------------------------------------------------------- sequence
    def __len__(self) -> int:
        return self.values.shape[0]

    def __getitem__(self, i):
        if isinstance(i, slice):
            return self.take(range(*i.indices(len(self))))
        return self.space.plane().materialize_row(self.values[i])

    def __iter__(self) -> Iterator[Config]:
        plane = self.space.plane()
        for i in range(len(self)):
            yield plane.materialize_row(self.values[i])

    # -------------------------------------------------------------- columnar
    def unit(self) -> np.ndarray:
        """Unit-cube encoding of the whole pool (cached)."""
        if self._unit is None:
            self._unit = self.space._encode_values(self.values)
        return self._unit

    def take(self, idx) -> "ConfigBatch":
        idx = np.asarray(idx)
        if idx.dtype == bool:
            idx = np.flatnonzero(idx)
        idx = idx.astype(np.int64)
        out = ConfigBatch(self.space, self.values[idx])
        if self._unit is not None:
            out._unit = self._unit[idx]
        if self._delta is not None:
            bases, base_of = self._delta
            out._delta = (bases, base_of[idx])
        return out

    @property
    def delta(self):
        """Mutation provenance ``(bases_unit, base_of)`` or None (see
        :meth:`set_delta`); survives :meth:`take` with remapped rows."""
        return self._delta

    def set_delta(self, bases_unit: np.ndarray, base_of: np.ndarray) -> None:
        """Attach mutation provenance: ``base_of[i]`` is the row of
        ``bases_unit`` candidate i was mutated from (-1 = fresh sample).
        ``bases_unit`` must be in the same unit encoding ``unit()`` yields."""
        base_of = np.asarray(base_of, dtype=np.int64)
        if base_of.shape != (len(self),):
            raise ValueError(f"base_of has shape {base_of.shape}, batch has {len(self)} rows")
        self._delta = (np.asarray(bases_unit, dtype=float), base_of)

    def row_keys(self) -> List[bytes]:
        """Exact-match dedup keys (canonical rows as bytes)."""
        return [self.values[i].tobytes() for i in range(len(self))]

    def materialize(self) -> List[Config]:
        return list(self)

    @staticmethod
    def concat(batches: Sequence["ConfigBatch"]) -> "ConfigBatch":
        if not batches:
            raise ValueError("no batches to concat")
        space = batches[0].space
        return ConfigBatch(space, np.concatenate([b.values for b in batches], axis=0))


# ---------------------------------------------------------------------------
# ConfigSpace
# ---------------------------------------------------------------------------


class ConfigSpace:
    """Ordered collection of knobs with encode/decode/sample/mutate.

    Batched entry points (``sample`` / ``lhs_sample`` / ``mutate_many`` /
    ``encode_many`` / ``decode_many`` / ``project_many``) dispatch through
    the module space backend (columnar plane vs scalar reference) and share
    one unit-draw protocol: uniforms are drawn as whole (n, dim) matrices up
    front, so both backends consume the RNG identically and a fixed seed
    yields bit-identical pools on either backend.
    """

    def __init__(self, knobs: Sequence[Knob]):
        names = [k.name for k in knobs]
        if len(set(names)) != len(names):
            raise ValueError("duplicate knob names")
        self.knobs: List[Knob] = list(knobs)
        self.by_name: Dict[str, Knob] = {k.name: k for k in knobs}
        self._planes: Dict[bool, SpacePlane] = {}

    # ------------------------------------------------------------------ basics
    @property
    def names(self) -> List[str]:
        return [k.name for k in self.knobs]

    @property
    def dim(self) -> int:
        return len(self.knobs)

    def __contains__(self, name: str) -> bool:
        return name in self.by_name

    def __len__(self) -> int:
        return len(self.knobs)

    def default(self) -> Config:
        return {k.name: k.default_value() for k in self.knobs}

    def plane(self, log_sampling_: Optional[bool] = None) -> SpacePlane:
        """The compiled plane for the requested (or effective) geometry."""
        flag = _effective_log_sampling() if log_sampling_ is None else bool(log_sampling_)
        plane = self._planes.get(flag)
        if plane is None:
            plane = SpacePlane(self, flag)
            self._planes[flag] = plane
        return plane

    # ------------------------------------------------------------- en/decoding
    def encode(self, cfg: Config) -> np.ndarray:
        """Config dict -> unit-cube vector (missing knobs -> default)."""
        out = np.empty(self.dim, dtype=float)
        for i, k in enumerate(self.knobs):
            v = cfg.get(k.name, k.default_value())
            out[i] = float(np.clip(k.to_unit(v), 0.0, 1.0))
        return out

    def _encode_values(self, V: np.ndarray) -> np.ndarray:
        plane = self.plane()
        if get_space_backend() == "columnar":
            return plane.encode_values(V)
        return _scalar_encode_values(plane, V)

    def encode_many(self, cfgs: Sequence[Config]) -> np.ndarray:
        if isinstance(cfgs, ConfigBatch) and cfgs.space is self:
            return cfgs.unit()
        if len(cfgs) == 0:
            return np.zeros((0, self.dim))
        if get_space_backend() == "columnar":
            return self.plane().encode_values(self.plane().gather(list(cfgs)))
        return np.stack([self.encode(c) for c in cfgs])

    def decode(self, u: np.ndarray) -> Config:
        """Unit vector -> config, projected onto the active restriction.

        (The legacy decode used raw ``from_unit`` and could return values in
        a region excluded by the restriction; decode now projects.)
        """
        return self.decode_many(np.atleast_2d(np.asarray(u, dtype=float)))[0]

    def decode_many(self, U: np.ndarray) -> ConfigBatch:
        U = np.atleast_2d(np.asarray(U, dtype=float))
        plane = self.plane()
        if get_space_backend() == "columnar":
            V = plane.decode_units(U)
        else:
            V = _scalar_decode_units(plane, U)
        return ConfigBatch(self, V)

    # ---------------------------------------------------------------- sampling
    def sample(self, rng: np.random.Generator, n: int = 1) -> ConfigBatch:
        """n uniform samples over the active (restricted) space.

        Draws one (n, dim) U(0,1) matrix and maps each knob column through
        its restriction CDF table (log knobs in log space on the columnar
        default — see module docstring).
        """
        with _obs.span("space_sample", kind="uniform", n=n, dim=self.dim):
            U = rng.random((n, self.dim))
            return self._map_unit_draws(U)

    def lhs_sample(self, rng: np.random.Generator, n: int) -> ConfigBatch:
        """Latin Hypercube Sampling (McKay et al.), restriction-aware.

        Keeps the legacy per-knob draw order: for each knob (in order) a
        stratified column ``(perm(n) + U(n)) / n``.
        """
        if n <= 0:
            return ConfigBatch(self, np.zeros((0, self.dim)))
        with _obs.span("space_sample", kind="lhs", n=n, dim=self.dim):
            U = np.empty((n, self.dim))
            for j in range(self.dim):
                U[:, j] = (rng.permutation(n) + rng.random(n)) / n
            return self._map_unit_draws(U)

    def _map_unit_draws(self, U: np.ndarray) -> ConfigBatch:
        plane = self.plane()
        if get_space_backend() == "columnar":
            V = plane.sample_values(U)
        else:
            V = _scalar_sample_values(plane, U)
        return ConfigBatch(self, V)

    # ---------------------------------------------------------------- mutation
    def mutate_many(
        self,
        cfgs: Sequence[Config],
        rng: np.random.Generator,
        scale: float = 0.2,
        p: float = 0.3,
    ) -> ConfigBatch:
        """Gaussian-in-unit-space perturbation of a random knob subset,
        vectorized over the whole batch.

        Draw protocol (shared by both backends): a (n, dim) uniform gate
        matrix, a (n, dim) standard-normal step matrix, and a (n, dim)
        uniform resample matrix for categorical/bool knobs.
        """
        with _obs.span("space_sample", kind="mutate", n=len(cfgs), dim=self.dim):
            batch = ConfigBatch.from_configs(self, cfgs)
            n = len(batch)
            G = rng.random((n, self.dim))
            Z = rng.standard_normal((n, self.dim))
            C = rng.random((n, self.dim))
            plane = self.plane()
            if get_space_backend() == "columnar":
                V = plane.mutate_values(batch.values, G, Z, C, scale, p)
            else:
                V = _scalar_mutate_values(plane, batch.values, G, Z, C, scale, p)
            return ConfigBatch(self, V)

    def mutate(self, cfg: Config, rng: np.random.Generator, scale: float = 0.2, p: float = 0.3) -> Config:
        """Single-config convenience wrapper over :meth:`mutate_many`."""
        return self.mutate_many([cfg], rng, scale=scale, p=p)[0]

    # ------------------------------------------------------------- restriction
    def project(self, cfg: Config) -> Config:
        """Clip a config into the active (restricted) space."""
        out: Config = {}
        for k in self.knobs:
            v = cfg.get(k.name, k.default_value())
            if isinstance(k, FloatKnob):
                out[k.name] = float(np.clip(k.active_intervals().clip(float(v)), k.lo, k.hi))
            elif isinstance(k, IntKnob):
                out[k.name] = int(np.clip(np.rint(k.active_intervals().clip(float(v))), k.lo, k.hi))
            elif isinstance(k, CatKnob):
                ch = k.active_choices()
                out[k.name] = v if v in ch else ch[0]
            elif isinstance(k, BoolKnob):
                ch = k.active_choices()
                out[k.name] = bool(v) if bool(v) in ch else ch[0]
        return out

    def project_many(self, cfgs: Sequence[Config]) -> ConfigBatch:
        batch = ConfigBatch.from_configs(self, cfgs)
        plane = self.plane()
        if get_space_backend() == "columnar":
            V = plane.project_values(batch.values)
        else:
            V = _scalar_project_values(plane, batch.values)
        return ConfigBatch(self, V)

    def restrict(
        self,
        keep: Optional[Sequence[str]] = None,
        ranges: Optional[Dict[str, Intervals]] = None,
        cat_subsets: Optional[Dict[str, Sequence[Any]]] = None,
    ) -> "ConfigSpace":
        """Return a new space with knobs dropped and/or ranges restricted.

        Dropped knobs simply disappear from the space; the tuner pins them
        to their defaults (the paper removes them from the search space).
        """
        keep_set = set(keep) if keep is not None else set(self.names)
        new_knobs: List[Knob] = []
        for k in self.knobs:
            if k.name not in keep_set:
                continue
            if isinstance(k, (FloatKnob, IntKnob)) and ranges and k.name in ranges:
                k = replace(k, restriction=ranges[k.name])
            elif isinstance(k, CatKnob) and cat_subsets and k.name in cat_subsets:
                k = replace(k, restriction=tuple(cat_subsets[k.name]))
            elif isinstance(k, BoolKnob) and cat_subsets and k.name in cat_subsets:
                k = replace(k, restriction=tuple(bool(c) for c in cat_subsets[k.name]))
            new_knobs.append(k)
        return ConfigSpace(new_knobs)

    def complete(self, cfg: Config) -> Config:
        """Fill missing knobs with defaults (used after knob-dropping)."""
        out = self.default()
        out.update({k: v for k, v in cfg.items() if k in self.by_name})
        return out

    def complete_batch(self, batch: ConfigBatch) -> ConfigBatch:
        """Lift a batch from a (possibly compressed) sub-space into this
        space: shared knobs copy their canonical columns, dropped knobs take
        this space's defaults. The canonical representation is knob-local,
        so columns transfer without re-encoding."""
        if batch.space is self:
            return batch
        plane = self.plane()
        V = np.broadcast_to(plane.default_row, (len(batch), self.dim)).copy()
        col = {name: j for j, name in enumerate(self.names)}
        for j_src, k in enumerate(batch.space.knobs):
            j_dst = col.get(k.name)
            if j_dst is None:
                continue
            # canonical columns are knob-local: numeric = raw units
            # (universal), cat = index into the knob's own choices tuple —
            # reject a shared name whose representation is incompatible
            # instead of silently materializing the wrong value
            mine = self.knobs[j_dst]
            if mine.kind != k.kind or (
                isinstance(k, CatKnob) and mine.choices != k.choices
            ):
                raise ValueError(
                    f"knob {k.name!r} has incompatible definitions across "
                    f"spaces ({mine.kind} vs {k.kind}); cannot lift batch"
                )
            V[:, j_dst] = batch.values[:, j_src]
        return ConfigBatch(self, V)
