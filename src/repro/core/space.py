"""Configuration search space.

The space is a flat, named collection of knobs. Four knob kinds are
supported (float / int / categorical / bool), with optional log scaling for
numeric knobs. Every knob can additionally carry a *restriction*: for
numeric knobs a union of closed intervals (the output of the density-based
range compression, paper Eq. 5), and for categorical/bool knobs a subset of
the choices (paper Eq. 6). Sampling, unit-cube encoding and neighbourhood
mutation all respect the active restriction.

Encoding: each knob maps to one dimension in [0, 1]. Numeric knobs are
affinely mapped (in log space when ``log=True``); categorical knobs map to
the bin midpoint of the chosen category. This single encoding is shared by
the surrogates, the Shapley attribution, the KDE compression and LHS so
that all components observe a consistent geometry.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Knob",
    "FloatKnob",
    "IntKnob",
    "CatKnob",
    "BoolKnob",
    "ConfigSpace",
    "Intervals",
]


Interval = Tuple[float, float]


class Intervals:
    """A normalized union of closed intervals on the real line."""

    def __init__(self, intervals: Sequence[Interval]):
        self.intervals: List[Interval] = self._normalize(intervals)

    @staticmethod
    def _normalize(intervals: Sequence[Interval]) -> List[Interval]:
        ivs = sorted((float(a), float(b)) for a, b in intervals if b >= a)
        merged: List[Interval] = []
        for a, b in ivs:
            if merged and a <= merged[-1][1]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], b))
            else:
                merged.append((a, b))
        return merged

    def __bool__(self) -> bool:
        return bool(self.intervals)

    def __iter__(self):
        return iter(self.intervals)

    def __repr__(self) -> str:
        return f"Intervals({self.intervals!r})"

    @property
    def total_length(self) -> float:
        return sum(b - a for a, b in self.intervals)

    @property
    def lo(self) -> float:
        return self.intervals[0][0]

    @property
    def hi(self) -> float:
        return self.intervals[-1][1]

    def contains(self, x: float) -> bool:
        return any(a - 1e-12 <= x <= b + 1e-12 for a, b in self.intervals)

    def clip(self, x: float) -> float:
        """Project x onto the nearest point of the union."""
        if self.contains(x):
            return x
        best, bd = x, math.inf
        for a, b in self.intervals:
            for edge in (a, b):
                d = abs(x - edge)
                if d < bd:
                    best, bd = edge, d
        return best

    def sample(self, rng: np.random.Generator, n: int = 1) -> np.ndarray:
        """Uniform samples over the union (length-weighted across pieces)."""
        lengths = np.array([b - a for a, b in self.intervals], dtype=float)
        if lengths.sum() <= 0:
            # degenerate (point) intervals: pick midpoints uniformly
            pts = np.array([(a + b) / 2 for a, b in self.intervals])
            return rng.choice(pts, size=n)
        probs = lengths / lengths.sum()
        idx = rng.choice(len(self.intervals), size=n, p=probs)
        u = rng.random(n)
        out = np.empty(n)
        for i, (a, b) in enumerate(self.intervals):
            sel = idx == i
            out[sel] = a + u[sel] * (b - a)
        return out

    def quantile_map(self, u: np.ndarray) -> np.ndarray:
        """Map u in [0,1] onto the union, proportionally by length.

        Used by LHS so that stratified unit-cube samples remain stratified
        over a restricted (possibly disconnected) range.
        """
        lengths = np.array([b - a for a, b in self.intervals], dtype=float)
        tot = lengths.sum()
        if tot <= 0:
            pts = np.array([(a + b) / 2 for a, b in self.intervals])
            return pts[np.minimum((u * len(pts)).astype(int), len(pts) - 1)]
        cum = np.concatenate([[0.0], np.cumsum(lengths)]) / tot
        out = np.empty_like(u, dtype=float)
        for i, (a, b) in enumerate(self.intervals):
            sel = (u >= cum[i]) & (u <= cum[i + 1] if i == len(self.intervals) - 1 else u < cum[i + 1])
            if lengths[i] > 0:
                out[sel] = a + (u[sel] - cum[i]) / (cum[i + 1] - cum[i]) * (b - a)
            else:
                out[sel] = a
        return out


@dataclass(frozen=True)
class Knob:
    name: str

    @property
    def kind(self) -> str:
        raise NotImplementedError

    def default_value(self) -> Any:
        raise NotImplementedError


@dataclass(frozen=True)
class FloatKnob(Knob):
    lo: float
    hi: float
    log: bool = False
    default: Optional[float] = None
    restriction: Optional[Intervals] = None  # in raw (untransformed) units

    @property
    def kind(self) -> str:
        return "float"

    def default_value(self) -> float:
        return self.default if self.default is not None else (self.lo + self.hi) / 2

    def _t(self, x: np.ndarray | float) -> np.ndarray | float:
        return np.log(x) if self.log else x

    def _it(self, t: np.ndarray | float) -> np.ndarray | float:
        return np.exp(t) if self.log else t

    def to_unit(self, x: np.ndarray | float) -> np.ndarray | float:
        a, b = self._t(self.lo), self._t(self.hi)
        return (self._t(x) - a) / (b - a)

    def from_unit(self, u: np.ndarray | float) -> np.ndarray | float:
        a, b = self._t(self.lo), self._t(self.hi)
        return self._it(a + np.clip(u, 0.0, 1.0) * (b - a))

    def active_intervals(self) -> Intervals:
        if self.restriction is not None and self.restriction:
            clipped = [
                (max(a, self.lo), min(b, self.hi))
                for a, b in self.restriction
                if min(b, self.hi) >= max(a, self.lo)
            ]
            if clipped:
                return Intervals(clipped)
        return Intervals([(self.lo, self.hi)])


@dataclass(frozen=True)
class IntKnob(Knob):
    lo: int
    hi: int
    log: bool = False
    default: Optional[int] = None
    restriction: Optional[Intervals] = None

    @property
    def kind(self) -> str:
        return "int"

    def default_value(self) -> int:
        return self.default if self.default is not None else (self.lo + self.hi) // 2

    def _t(self, x):
        return np.log(x) if self.log else x

    def _it(self, t):
        return np.exp(t) if self.log else t

    def to_unit(self, x):
        a, b = self._t(self.lo), self._t(self.hi)
        if b == a:
            return np.zeros_like(np.asarray(x, dtype=float))
        return (self._t(x) - a) / (b - a)

    def from_unit(self, u):
        a, b = self._t(self.lo), self._t(self.hi)
        val = self._it(a + np.clip(u, 0.0, 1.0) * (b - a))
        return np.clip(np.rint(val), self.lo, self.hi).astype(int)

    def active_intervals(self) -> Intervals:
        if self.restriction is not None and self.restriction:
            clipped = [
                (max(a, self.lo), min(b, self.hi))
                for a, b in self.restriction
                if min(b, self.hi) >= max(a, self.lo)
            ]
            if clipped:
                return Intervals(clipped)
        return Intervals([(float(self.lo), float(self.hi))])


@dataclass(frozen=True)
class CatKnob(Knob):
    choices: Tuple[Any, ...]
    default: Optional[Any] = None
    restriction: Optional[Tuple[Any, ...]] = None

    @property
    def kind(self) -> str:
        return "cat"

    def default_value(self) -> Any:
        return self.default if self.default is not None else self.choices[0]

    def active_choices(self) -> Tuple[Any, ...]:
        if self.restriction:
            kept = tuple(c for c in self.choices if c in self.restriction)
            if kept:
                return kept
        return self.choices

    def to_unit(self, x) -> float:
        i = self.choices.index(x)
        return (i + 0.5) / len(self.choices)

    def from_unit(self, u) -> Any:
        i = min(int(np.clip(u, 0.0, 1.0 - 1e-9) * len(self.choices)), len(self.choices) - 1)
        return self.choices[i]


@dataclass(frozen=True)
class BoolKnob(Knob):
    default: bool = False
    restriction: Optional[Tuple[bool, ...]] = None

    @property
    def kind(self) -> str:
        return "bool"

    def default_value(self) -> bool:
        return self.default

    def active_choices(self) -> Tuple[bool, ...]:
        if self.restriction:
            return self.restriction
        return (False, True)

    def to_unit(self, x) -> float:
        return 0.75 if x else 0.25

    def from_unit(self, u) -> bool:
        return bool(u >= 0.5)


Config = Dict[str, Any]


class ConfigSpace:
    """Ordered collection of knobs with encode/decode/sample/mutate."""

    def __init__(self, knobs: Sequence[Knob]):
        names = [k.name for k in knobs]
        if len(set(names)) != len(names):
            raise ValueError("duplicate knob names")
        self.knobs: List[Knob] = list(knobs)
        self.by_name: Dict[str, Knob] = {k.name: k for k in knobs}

    # ------------------------------------------------------------------ basics
    @property
    def names(self) -> List[str]:
        return [k.name for k in self.knobs]

    @property
    def dim(self) -> int:
        return len(self.knobs)

    def __contains__(self, name: str) -> bool:
        return name in self.by_name

    def __len__(self) -> int:
        return len(self.knobs)

    def default(self) -> Config:
        return {k.name: k.default_value() for k in self.knobs}

    # ------------------------------------------------------------- en/decoding
    def encode(self, cfg: Config) -> np.ndarray:
        """Config dict -> unit-cube vector (missing knobs -> default)."""
        out = np.empty(self.dim, dtype=float)
        for i, k in enumerate(self.knobs):
            v = cfg.get(k.name, k.default_value())
            out[i] = float(np.clip(k.to_unit(v), 0.0, 1.0))
        return out

    def encode_many(self, cfgs: Sequence[Config]) -> np.ndarray:
        return np.stack([self.encode(c) for c in cfgs]) if cfgs else np.zeros((0, self.dim))

    def decode(self, u: np.ndarray) -> Config:
        return {k.name: k.from_unit(float(u[i])) for i, k in enumerate(self.knobs)}

    # ---------------------------------------------------------------- sampling
    def sample(self, rng: np.random.Generator, n: int = 1) -> List[Config]:
        cfgs = []
        for _ in range(n):
            cfg: Config = {}
            for k in self.knobs:
                cfg[k.name] = self._sample_knob(k, rng)
            cfgs.append(cfg)
        return cfgs

    def _sample_knob(self, k: Knob, rng: np.random.Generator) -> Any:
        if isinstance(k, FloatKnob):
            return float(k.active_intervals().sample(rng, 1)[0])
        if isinstance(k, IntKnob):
            v = k.active_intervals().sample(rng, 1)[0]
            return int(np.clip(np.rint(v), k.lo, k.hi))
        if isinstance(k, CatKnob):
            return k.active_choices()[rng.integers(len(k.active_choices()))]
        if isinstance(k, BoolKnob):
            return bool(k.active_choices()[rng.integers(len(k.active_choices()))])
        raise TypeError(k)

    def lhs_sample(self, rng: np.random.Generator, n: int) -> List[Config]:
        """Latin Hypercube Sampling (McKay et al.), restriction-aware."""
        if n <= 0:
            return []
        cfgs: List[Config] = [dict() for _ in range(n)]
        for k in self.knobs:
            # stratified unit samples for this dimension
            u = (rng.permutation(n) + rng.random(n)) / n
            if isinstance(k, (FloatKnob, IntKnob)):
                vals = k.active_intervals().quantile_map(u)
                for j in range(n):
                    v = vals[j]
                    cfgs[j][k.name] = int(np.clip(np.rint(v), k.lo, k.hi)) if isinstance(k, IntKnob) else float(v)
            elif isinstance(k, CatKnob):
                ch = k.active_choices()
                for j in range(n):
                    cfgs[j][k.name] = ch[min(int(u[j] * len(ch)), len(ch) - 1)]
            elif isinstance(k, BoolKnob):
                ch = k.active_choices()
                for j in range(n):
                    cfgs[j][k.name] = bool(ch[min(int(u[j] * len(ch)), len(ch) - 1)])
        return cfgs

    # ---------------------------------------------------------------- mutation
    def mutate(self, cfg: Config, rng: np.random.Generator, scale: float = 0.2, p: float = 0.3) -> Config:
        """Gaussian-in-unit-space perturbation of a subset of knobs."""
        out = dict(cfg)
        for k in self.knobs:
            if rng.random() > p:
                continue
            if isinstance(k, (FloatKnob, IntKnob)):
                u = float(np.clip(k.to_unit(out.get(k.name, k.default_value())), 0, 1))
                u = float(np.clip(u + rng.normal(0.0, scale), 0.0, 1.0))
                v = k.from_unit(u)
                iv = k.active_intervals()
                v = iv.clip(float(v))
                out[k.name] = int(np.clip(np.rint(v), k.lo, k.hi)) if isinstance(k, IntKnob) else float(v)
            else:
                out[k.name] = self._sample_knob(k, rng)
        return out

    # ------------------------------------------------------------- restriction
    def project(self, cfg: Config) -> Config:
        """Clip a config into the active (restricted) space."""
        out: Config = {}
        for k in self.knobs:
            v = cfg.get(k.name, k.default_value())
            if isinstance(k, FloatKnob):
                out[k.name] = float(np.clip(k.active_intervals().clip(float(v)), k.lo, k.hi))
            elif isinstance(k, IntKnob):
                out[k.name] = int(np.clip(np.rint(k.active_intervals().clip(float(v))), k.lo, k.hi))
            elif isinstance(k, CatKnob):
                ch = k.active_choices()
                out[k.name] = v if v in ch else ch[0]
            elif isinstance(k, BoolKnob):
                ch = k.active_choices()
                out[k.name] = bool(v) if bool(v) in ch else ch[0]
        return out

    def restrict(
        self,
        keep: Optional[Sequence[str]] = None,
        ranges: Optional[Dict[str, Intervals]] = None,
        cat_subsets: Optional[Dict[str, Sequence[Any]]] = None,
    ) -> "ConfigSpace":
        """Return a new space with knobs dropped and/or ranges restricted.

        Dropped knobs simply disappear from the space; the tuner pins them
        to their defaults (the paper removes them from the search space).
        """
        keep_set = set(keep) if keep is not None else set(self.names)
        new_knobs: List[Knob] = []
        for k in self.knobs:
            if k.name not in keep_set:
                continue
            if isinstance(k, (FloatKnob, IntKnob)) and ranges and k.name in ranges:
                k = replace(k, restriction=ranges[k.name])
            elif isinstance(k, CatKnob) and cat_subsets and k.name in cat_subsets:
                k = replace(k, restriction=tuple(cat_subsets[k.name]))
            elif isinstance(k, BoolKnob) and cat_subsets and k.name in cat_subsets:
                k = replace(k, restriction=tuple(bool(c) for c in cat_subsets[k.name]))
            new_knobs.append(k)
        return ConfigSpace(new_knobs)

    def complete(self, cfg: Config) -> Config:
        """Fill missing knobs with defaults (used after knob-dropping)."""
        out = self.default()
        out.update({k: v for k, v in cfg.items() if k in self.by_name})
        return out
