"""Knowledge database (paper §4.1 component 2).

Stores, per task: observations (config, aggregate performance, per-query
performance/cost vectors, fidelity, timestamps), the 34-d meta-feature
vector, and the task descriptor (benchmark, scale, hardware, query list).
Persists to a directory of JSON files so tuning sessions can accumulate
history across runs — and so a restarted tuner resumes exactly.
"""

from __future__ import annotations

import json
import os
import tempfile
from dataclasses import dataclass, field, asdict
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

__all__ = ["Observation", "TaskRecord", "KnowledgeBase"]

Config = Dict[str, Any]


@dataclass
class Observation:
    config: Config
    performance: float                      # aggregate objective (latency; lower=better)
    fidelity: float = 1.0                   # delta in (0, 1]
    per_query_perf: Optional[List[float]] = None   # aligned to task.queries (only for evaluated subset at full fid; else subset order)
    per_query_cost: Optional[List[float]] = None
    query_subset: Optional[List[int]] = None        # indices into task.queries that were run
    failed: bool = False
    elapsed: float = 0.0                    # evaluation cost charged to the budget
    time: float = 0.0                       # virtual timestamp at completion

    def to_json(self) -> Dict[str, Any]:
        d = asdict(self)
        return d

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "Observation":
        return Observation(**d)


@dataclass
class TaskRecord:
    task_id: str
    queries: List[str]                      # query names, defines per-query vector order
    meta_features: Optional[List[float]] = None
    descriptor: Dict[str, Any] = field(default_factory=dict)
    observations: List[Observation] = field(default_factory=list)

    # ------------------------------------------------------------------ views
    def full_fidelity(self) -> List[Observation]:
        return [o for o in self.observations if o.fidelity >= 1.0 and not o.failed]

    def at_fidelity(
        self, delta: float, tol: float = 1e-6, include_failed: bool = False
    ) -> List[Observation]:
        return [
            o
            for o in self.observations
            if abs(o.fidelity - delta) <= tol and (include_failed or not o.failed)
        ]

    def successful(self) -> List[Observation]:
        return [o for o in self.observations if not o.failed]

    def best(self) -> Optional[Observation]:
        full = self.full_fidelity()
        return min(full, key=lambda o: o.performance) if full else None

    def with_query_vectors(self) -> List[Observation]:
        """Observations carrying full per-query performance vectors."""
        m = len(self.queries)
        return [
            o
            for o in self.observations
            if not o.failed and o.per_query_perf is not None and len(o.per_query_perf) == m
        ]

    def to_json(self) -> Dict[str, Any]:
        return {
            "task_id": self.task_id,
            "queries": self.queries,
            "meta_features": self.meta_features,
            "descriptor": self.descriptor,
            "observations": [o.to_json() for o in self.observations],
        }

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "TaskRecord":
        return TaskRecord(
            task_id=d["task_id"],
            queries=list(d["queries"]),
            meta_features=d.get("meta_features"),
            descriptor=d.get("descriptor", {}),
            observations=[Observation.from_json(o) for o in d.get("observations", [])],
        )


class KnowledgeBase:
    """In-memory task store with optional directory persistence."""

    def __init__(self, root: Optional[str] = None):
        self.root = root
        self.tasks: Dict[str, TaskRecord] = {}
        if root:
            os.makedirs(root, exist_ok=True)
            for fn in sorted(os.listdir(root)):
                if fn.endswith(".json"):
                    with open(os.path.join(root, fn)) as f:
                        rec = TaskRecord.from_json(json.load(f))
                    self.tasks[rec.task_id] = rec

    # ---------------------------------------------------------------- access
    def add_task(self, rec: TaskRecord, persist: bool = True) -> None:
        self.tasks[rec.task_id] = rec
        if persist:
            self.save_task(rec.task_id)

    def get(self, task_id: str) -> TaskRecord:
        return self.tasks[task_id]

    def source_tasks(self, target_id: str) -> List[TaskRecord]:
        return [t for tid, t in sorted(self.tasks.items()) if tid != target_id]

    def same_query_sources(self, target: TaskRecord) -> List[TaskRecord]:
        """Source tasks whose query set is identical to the target's (§6.1)."""
        tq = list(target.queries)
        return [t for t in self.source_tasks(target.task_id) if list(t.queries) == tq]

    def record(self, task_id: str, obs: Observation, persist: bool = False) -> None:
        self.tasks[task_id].observations.append(obs)
        if persist:
            self.save_task(task_id)

    # ----------------------------------------------------------- persistence
    def save_task(self, task_id: str) -> None:
        if not self.root:
            return
        rec = self.tasks[task_id]
        path = os.path.join(self.root, f"{task_id}.json")
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(rec.to_json(), f, default=_np_default)
            os.replace(tmp, path)  # atomic commit
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    def save_all(self) -> None:
        for tid in self.tasks:
            self.save_task(tid)


def _np_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    if isinstance(o, (np.bool_,)):
        return bool(o)
    raise TypeError(f"not JSON serializable: {type(o)}")
