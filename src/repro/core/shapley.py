"""Shapley-value attribution of knob values (paper §5.1).

The paper uses SHAP to decide, per configuration in the promising set,
whether each knob's *value* helps (negative attribution on latency) or
hurts. Only the sign and rough magnitude matter downstream (Eq. 3).

We compute *interventional* Shapley values of a surrogate model f with a
background dataset B:

    phi_j(x) = E_pi [ f(x_{S u j}) - f(x_S) ],   S = features before j in pi

estimated with antithetic permutation sampling (each sampled permutation is
paired with its reverse, which cuts variance substantially; an odd
``n_permutations`` runs (n-1)//2 pairs plus one unpaired forward draw, so
exactly n permutation chains are evaluated either way). For small
dimensionality an exact enumeration over all permutations is available and
used by the tests to bound the Monte-Carlo error.

Two equivalent evaluation paths:

``backend="batched"`` (default) evaluates whole
(permutations x (d+1) prefix masks x background) blocks at once: through
the bitvector chain kernel (``kernels.forest_eval.chain``) when the
surrogate behind f is supplied via ``model=``, else by materializing the
composite tensor and pushing it through f in a few large chunked calls.
``backend="loop"`` is the legacy per-chain reference. All paths consume
the same pre-drawn permutation matrix and replay the identical
accumulation order, so their attributions are bit-identical; the batch
explainer :func:`shapley_values_batch` extends the same contract across
many explained configs (one fused pass instead of one call per config).

Additivity (sum_j phi_j = f(x) - E_B[f]) holds exactly in expectation and
is enforced by a final residual correction distributed *proportionally* to
|phi_j| (uniform only as a fallback when every attribution is exactly
zero), so the downstream sign logic sees an exactly-additive decomposition
and near-zero-phi knobs are not polluted with spurious residual mass.
"""

from __future__ import annotations

from itertools import permutations
from typing import Callable, Optional

import numpy as np

from .. import obs as _obs

__all__ = [
    "draw_permutations",
    "shapley_values",
    "shapley_values_batch",
    "shapley_values_exact",
]

# rows-per-model-call bound for the batched plane: whole permutation chains
# only, so chunk boundaries never split a (d+1)*nb block and per-row results
# are unchanged by the chunking
_MAX_EVAL_ROWS = 262_144


def draw_permutations(
    d: int, n_permutations: int, rng: np.random.Generator
) -> np.ndarray:
    """Antithetic permutation matrix, shape (n_permutations, d).

    Rows 2i / 2i+1 hold the i-th draw and its reverse (the order the legacy
    per-chain loop consumed them in); an odd count appends one unpaired
    forward draw. Both backends consume this matrix, which is what makes
    them bit-comparable.
    """
    if n_permutations < 1:
        raise ValueError("n_permutations must be >= 1")
    rows = []
    for _ in range(n_permutations // 2):
        perm = rng.permutation(d)
        rows.append(perm)
        rows.append(perm[::-1])
    if n_permutations % 2:
        rows.append(rng.permutation(d))
    return np.stack(rows)


def _prefix_masks(perm: np.ndarray) -> np.ndarray:
    """(d+1, d) boolean prefix-mask chain S_0 = {} ... S_d = all, along perm."""
    d = len(perm)
    masks = np.zeros((d + 1, d), dtype=bool)
    for k in range(1, d + 1):
        masks[k] = masks[k - 1]
        masks[k, perm[k - 1]] = True
    return masks


def _prefix_masks_batch(perms: np.ndarray) -> np.ndarray:
    """(P, d+1, d) prefix-mask chains for a whole permutation matrix.

    rank[p, j] = position of feature j in permutation p; the k-th prefix
    contains exactly the features with rank < k.
    """
    P, d = perms.shape
    rank = np.empty((P, d), dtype=np.int64)
    np.put_along_axis(rank, perms, np.broadcast_to(np.arange(d), (P, d)), axis=1)
    return rank[:, None, :] < np.arange(d + 1)[None, :, None]


def _eval_masked(
    f: Callable[[np.ndarray], np.ndarray],
    x: np.ndarray,
    background: np.ndarray,
    masks: np.ndarray,
) -> np.ndarray:
    """E_b[f(z)] where z takes x on mask==True and background rows elsewhere.

    masks: (m, d) boolean. Returns (m,) averaging over all background rows.
    """
    nb, d = background.shape
    m = len(masks)
    # build (m*nb, d) matrix
    Z = np.broadcast_to(background[None, :, :], (m, nb, d)).copy()
    Xb = np.broadcast_to(x[None, None, :], (m, nb, d))
    M = np.broadcast_to(masks[:, None, :], (m, nb, d))
    Z[M] = Xb[M]
    vals = f(Z.reshape(m * nb, d))
    return vals.reshape(m, nb).mean(axis=1)


def _chain_deltas_loop(
    f: Callable[[np.ndarray], np.ndarray],
    x: np.ndarray,
    background: np.ndarray,
    perms: np.ndarray,
) -> np.ndarray:
    """Per-permutation marginal contributions, one model call per chain.

    The pinned reference: returns (P, d) deltas in *permutation order*
    (row i, column k = contribution of feature perms[i, k]).
    """
    out = np.empty(perms.shape, dtype=float)
    for i, perm in enumerate(perms):
        vals = _eval_masked(f, x, background, _prefix_masks(perm))
        out[i] = vals[1:] - vals[:-1]
    return out


def _chain_deltas_batched(
    f: Callable[[np.ndarray], np.ndarray],
    X: np.ndarray,
    background: np.ndarray,
    perms: np.ndarray,
    max_eval_rows: int,
    model=None,
) -> np.ndarray:
    """Marginal contributions for many (config, permutation) chains at once.

    X: (n, d) configs to explain; perms: (n, P, d) per-config permutation
    matrices. Returns (n, P, d) deltas in permutation order, bit-identical
    to the per-chain loop.

    When ``model`` is a packed-forest surrogate the chains are evaluated by
    the bitvector chain kernel (``kernels.forest_eval.chain``) — no
    composite tensor, ~1 word-AND per row instead of a gather descent.
    Otherwise (or when the kernel doesn't apply: a tree with > 64 leaves,
    d > 64) this builds the (chains x (d+1) prefixes x background)
    composite tensor and evaluates it through ``f`` in calls of at most
    ``max_eval_rows`` rows (never splitting a chain), so one forest pass
    covers many chains while peak memory stays bounded. Per-row model
    outputs and the per-chain background means are independent of how
    chains are grouped into calls, so all three paths agree bit-for-bit.
    """
    n, P, d = perms.shape
    nb = background.shape[0]
    rows_per_chain = (d + 1) * nb
    chains_per_call = max(1, max_eval_rows // rows_per_chain)
    # flatten (config, permutation) -> chain axis
    flat_perms = perms.reshape(n * P, d)
    x_of_chain = np.repeat(np.arange(n), P)
    vals = np.empty((n * P, d + 1), dtype=float)

    plan = None
    if model is not None:
        from ..kernels.forest_eval.chain import build_chain_plan_ex

        plan, _reason = build_chain_plan_ex(model, d)
    _obs.count(
        "shapley/chain_kernel" if plan is not None else "shapley/composite_fallback"
    )
    # route the integer prefix/suffix-AND walk through the pallas chain
    # kernel when the surrogate opted into the pallas backend (ordinals
    # are integers either way, so values stay bit-identical)
    chain_backend = (
        "pallas" if getattr(model, "backend", None) == "pallas" else "numpy"
    )

    for a in range(0, n * P, chains_per_call):
        b = min(a + chains_per_call, n * P)
        if plan is not None:
            vals[a:b] = plan.eval_chains(
                X, background, flat_perms[a:b], x_of_chain[a:b],
                backend=chain_backend,
            )
            continue
        masks = _prefix_masks_batch(flat_perms[a:b])          # (C, d+1, d)
        C = b - a
        M = np.broadcast_to(masks[:, :, None, :], (C, d + 1, nb, d))
        Z = np.broadcast_to(background[None, None, :, :], (C, d + 1, nb, d)).copy()
        Xb = np.broadcast_to(
            X[x_of_chain[a:b], None, None, :], (C, d + 1, nb, d)
        )
        Z[M] = Xb[M]
        out = f(Z.reshape(C * (d + 1) * nb, d))
        # same per-chain reduction as _eval_masked: mean over the background
        # rows of each (chain, prefix) block
        vals[a:b] = np.asarray(out).reshape(C, d + 1, nb).mean(axis=2)
    deltas = vals[:, 1:] - vals[:, :-1]
    return deltas.reshape(n, P, d)


def _reduce_chains(perms: np.ndarray, deltas: np.ndarray) -> np.ndarray:
    """phi from (P, d) permutation-order deltas, replaying the legacy
    accumulation: chains are added feature-wise in draw order, then divided
    by the chain count — the exact op sequence of the old per-chain
    ``phi[p] += vals[1:] - vals[:-1]`` loop."""
    P, d = perms.shape
    contrib = np.empty((P, d), dtype=float)
    rows = np.arange(P)[:, None]
    contrib[rows, perms] = deltas
    phi = np.zeros(d)
    for i in range(P):  # sequential adds preserve the loop's float order
        phi += contrib[i]
    phi /= P
    return phi


def _residual_correct(
    phi: np.ndarray,
    f: Callable[[np.ndarray], np.ndarray],
    x: np.ndarray,
    background: np.ndarray,
    fx: Optional[float] = None,
    f0: Optional[float] = None,
) -> np.ndarray:
    """Exact-additivity correction: distribute the (small) MC residual
    proportionally to |phi| so near-zero attributions stay near zero (a
    knob the model ignores keeps phi exactly 0.0); uniform fallback only
    when every phi is exactly zero."""
    if fx is None:
        fx = float(f(x[None, :])[0])
    if f0 is None:
        f0 = float(np.asarray(f(background)).mean())
    resid = (fx - f0) - phi.sum()
    mag = np.abs(phi)
    total = mag.sum()
    if total > 0:
        phi = phi + resid * (mag / total)
    else:
        phi = phi + resid / len(phi)
    return phi


def shapley_values(
    f: Callable[[np.ndarray], np.ndarray],
    x: np.ndarray,
    background: np.ndarray,
    n_permutations: int = 32,
    rng: Optional[np.random.Generator] = None,
    backend: str = "batched",
    perms: Optional[np.ndarray] = None,
    max_eval_rows: int = _MAX_EVAL_ROWS,
    model=None,
) -> np.ndarray:
    """Antithetic-permutation-sampled interventional Shapley values.

    f: vectorized model, maps (n, d) -> (n,).
    x: (d,) the point to explain. background: (nb, d).
    perms: optional pre-drawn (P, d) permutation matrix (overrides
    n_permutations/rng) — sharing it across backends makes them
    bit-comparable.
    model: optional forest object behind ``f``; lets the batched backend
    evaluate chains through the bitvector kernel (bit-identical, much
    faster) instead of the composite tensor. Ignored by ``backend="loop"``.
    """
    x = np.asarray(x, dtype=float)
    background = np.atleast_2d(np.asarray(background, dtype=float))
    d = len(x)
    if perms is None:
        rng = rng or np.random.default_rng(0)
        perms = draw_permutations(d, n_permutations, rng)
    else:
        perms = np.asarray(perms)
    if backend == "loop":
        deltas = _chain_deltas_loop(f, x, background, perms)
    elif backend == "batched":
        deltas = _chain_deltas_batched(
            f, x[None, :], background, perms[None, :, :], max_eval_rows,
            model=model,
        )[0]
    else:
        raise ValueError(f"unknown shapley backend {backend!r}")
    phi = _reduce_chains(perms, deltas)
    return _residual_correct(phi, f, x, background)


def shapley_values_batch(
    f: Callable[[np.ndarray], np.ndarray],
    X: np.ndarray,
    background: np.ndarray,
    n_permutations: int = 32,
    rng: Optional[np.random.Generator] = None,
    backend: str = "batched",
    perms: Optional[np.ndarray] = None,
    max_eval_rows: int = _MAX_EVAL_ROWS,
    model=None,
) -> np.ndarray:
    """Explain many configs in one masked-evaluation pass. Returns (n, d).

    Permutation matrices are drawn per config *sequentially* from ``rng``
    (config i's draws happen after config i-1's), replaying the draw order
    of one :func:`shapley_values` call per row — so the batch is
    bit-identical to the sequential per-config loop with a shared rng, on
    either backend. ``model`` (the forest behind ``f``) opts the batched
    backend into the bitvector chain kernel.
    """
    X = np.atleast_2d(np.asarray(X, dtype=float))
    background = np.atleast_2d(np.asarray(background, dtype=float))
    n, d = X.shape
    if n == 0:
        return np.zeros((0, d))
    if perms is None:
        rng = rng or np.random.default_rng(0)
        perms = np.stack([draw_permutations(d, n_permutations, rng) for _ in range(n)])
    else:
        perms = np.asarray(perms)
        if perms.ndim == 2:  # one shared matrix for every config
            perms = np.broadcast_to(perms[None, :, :], (n, *perms.shape))
    if backend == "loop":
        deltas = np.stack(
            [_chain_deltas_loop(f, X[i], background, perms[i]) for i in range(n)]
        )
    elif backend == "batched":
        deltas = _chain_deltas_batched(
            f, X, background, perms, max_eval_rows, model=model
        )
    else:
        raise ValueError(f"unknown shapley backend {backend!r}")
    # residual anchors: f(x_i) is evaluated per config in single-row calls —
    # numpy picks a different (pairwise vs sequential) tree-mean reduction
    # for 1-row vs n-row batches, so one f(X) call would drift 1 ULP from
    # the sequential per-config protocol the docstring promises
    fxs = np.array([float(f(X[i : i + 1])[0]) for i in range(n)])
    f0 = float(np.asarray(f(background)).mean())
    out = np.empty((n, d), dtype=float)
    for i in range(n):
        phi = _reduce_chains(perms[i], deltas[i])
        out[i] = _residual_correct(phi, f, X[i], background, fx=float(fxs[i]), f0=f0)
    return out


def shapley_values_exact(
    f: Callable[[np.ndarray], np.ndarray],
    x: np.ndarray,
    background: np.ndarray,
) -> np.ndarray:
    """Exact enumeration (d <= 8 or so) — used to validate the sampler."""
    x = np.asarray(x, dtype=float)
    background = np.atleast_2d(np.asarray(background, dtype=float))
    d = len(x)
    # value function over all 2^d subsets
    n_sub = 1 << d
    masks = np.zeros((n_sub, d), dtype=bool)
    for s in range(n_sub):
        for j in range(d):
            masks[s, j] = bool(s >> j & 1)
    vals = _eval_masked(f, x, background, masks)
    phi = np.zeros(d)
    count = 0
    for p in permutations(range(d)):
        s = 0
        prev = vals[0]
        for j in p:
            s |= 1 << j
            cur = vals[s]
            phi[j] += cur - prev
            prev = cur
        count += 1
    return phi / count
