"""Shapley-value attribution of knob values (paper §5.1).

The paper uses SHAP to decide, per configuration in the promising set,
whether each knob's *value* helps (negative attribution on latency) or
hurts. Only the sign and rough magnitude matter downstream (Eq. 3).

We compute *interventional* Shapley values of a surrogate model f with a
background dataset B:

    phi_j(x) = E_pi [ f(x_{S u j}) - f(x_S) ],   S = features before j in pi

estimated with antithetic permutation sampling (each sampled permutation is
paired with its reverse, which cuts variance substantially). For small
dimensionality an exact enumeration over all permutations is available and
used by the tests to bound the Monte-Carlo error.

Additivity (sum_j phi_j = f(x) - E_B[f]) holds exactly in expectation and
is enforced by a final proportional residual correction, so the downstream
sign logic sees an exactly-additive decomposition.
"""

from __future__ import annotations

from itertools import permutations
from typing import Callable, Optional

import numpy as np

__all__ = ["shapley_values", "shapley_values_exact"]


def _eval_masked(
    f: Callable[[np.ndarray], np.ndarray],
    x: np.ndarray,
    background: np.ndarray,
    masks: np.ndarray,
) -> np.ndarray:
    """E_b[f(z)] where z takes x on mask==True and background rows elsewhere.

    masks: (m, d) boolean. Returns (m,) averaging over all background rows.
    """
    nb, d = background.shape
    m = len(masks)
    # build (m*nb, d) matrix
    Z = np.broadcast_to(background[None, :, :], (m, nb, d)).copy()
    Xb = np.broadcast_to(x[None, None, :], (m, nb, d))
    M = np.broadcast_to(masks[:, None, :], (m, nb, d))
    Z[M] = Xb[M]
    vals = f(Z.reshape(m * nb, d))
    return vals.reshape(m, nb).mean(axis=1)


def shapley_values(
    f: Callable[[np.ndarray], np.ndarray],
    x: np.ndarray,
    background: np.ndarray,
    n_permutations: int = 32,
    rng: Optional[np.random.Generator] = None,
) -> np.ndarray:
    """Antithetic-permutation-sampled interventional Shapley values.

    f: vectorized model, maps (n, d) -> (n,).
    x: (d,) the point to explain. background: (nb, d).
    """
    rng = rng or np.random.default_rng(0)
    x = np.asarray(x, dtype=float)
    background = np.atleast_2d(np.asarray(background, dtype=float))
    d = len(x)
    phi = np.zeros(d)
    half = max(1, n_permutations // 2)
    for _ in range(half):
        perm = rng.permutation(d)
        for p in (perm, perm[::-1]):
            # masks for the prefix chain: S_0=empty, S_k = first k features
            masks = np.zeros((d + 1, d), dtype=bool)
            for k in range(1, d + 1):
                masks[k] = masks[k - 1]
                masks[k, p[k - 1]] = True
            vals = _eval_masked(f, x, background, masks)
            phi[p] += vals[1:] - vals[:-1]
    phi /= 2 * half
    # exact-additivity correction: distribute the (small) MC residual
    fx = float(f(x[None, :])[0])
    f0 = float(f(background).mean())
    resid = (fx - f0) - phi.sum()
    phi += resid / d
    return phi


def shapley_values_exact(
    f: Callable[[np.ndarray], np.ndarray],
    x: np.ndarray,
    background: np.ndarray,
) -> np.ndarray:
    """Exact enumeration (d <= 8 or so) — used to validate the sampler."""
    x = np.asarray(x, dtype=float)
    background = np.atleast_2d(np.asarray(background, dtype=float))
    d = len(x)
    # value function over all 2^d subsets
    n_sub = 1 << d
    masks = np.zeros((n_sub, d), dtype=bool)
    for s in range(n_sub):
        for j in range(d):
            masks[s, j] = bool(s >> j & 1)
    vals = _eval_masked(f, x, background, masks)
    phi = np.zeros(d)
    count = 0
    for p in permutations(range(d)):
        s = 0
        prev = vals[0]
        for j in p:
            s |= 1 << j
            cur = vals[s]
            phi[j] += cur - prev
            prev = cur
        count += 1
    return phi / count
