"""Surrogate models for Bayesian optimization.

The primary surrogate is a Probabilistic Random Forest (paper §3.3 —
"Probabilistic Random Forest [12]", i.e. the SMAC-style forest): an ensemble
of randomized regression trees over the unit-cube encoding; the predictive
mean is the mean of per-tree leaf means and the predictive variance combines
across-tree disagreement with within-leaf empirical variance (law of total
variance, as in Hutter et al. 2011).

A small exact Gaussian Process (Matérn-5/2) is also provided — it is *not*
used by MFTune itself but by the Tuneful baseline's multi-task GP.

Everything is pure numpy; data sets here are O(10^2-10^3) points.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

__all__ = ["RegressionTree", "ProbabilisticRandomForest", "GaussianProcess", "Surrogate"]


class Surrogate:
    """Minimal interface all surrogates implement."""

    def fit(self, X: np.ndarray, y: np.ndarray) -> "Surrogate":
        raise NotImplementedError

    def predict(self, X: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Return (mean, variance), each shape (n,)."""
        raise NotImplementedError

    def predict_mean(self, X: np.ndarray) -> np.ndarray:
        return self.predict(X)[0]


# ---------------------------------------------------------------------------
# Regression trees / random forest
# ---------------------------------------------------------------------------


@dataclass
class _Node:
    feature: int = -1            # -1 => leaf
    threshold: float = 0.0
    left: int = -1
    right: int = -1
    mean: float = 0.0
    var: float = 0.0
    n: int = 0


class RegressionTree:
    """CART regression tree with random feature subsetting at each split."""

    def __init__(
        self,
        max_depth: int = 12,
        min_samples_split: int = 4,
        min_samples_leaf: int = 2,
        max_features: Optional[int] = None,
        rng: Optional[np.random.Generator] = None,
    ):
        self.max_depth = max_depth
        self.min_samples_split = min_samples_split
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.rng = rng or np.random.default_rng()
        self.nodes: List[_Node] = []

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RegressionTree":
        X = np.asarray(X, dtype=float)
        y = np.asarray(y, dtype=float)
        self.nodes = []
        self._build(X, y, np.arange(len(y)), 0)
        self._freeze()
        return self

    def _new_node(self) -> int:
        self.nodes.append(_Node())
        return len(self.nodes) - 1

    def _build(self, X: np.ndarray, y: np.ndarray, idx: np.ndarray, depth: int) -> int:
        nid = self._new_node()
        node = self.nodes[nid]
        ysub = y[idx]
        node.mean = float(ysub.mean())
        node.var = float(ysub.var())
        node.n = len(idx)
        if depth >= self.max_depth or len(idx) < self.min_samples_split or np.ptp(ysub) == 0:
            return nid
        d = X.shape[1]
        k = self.max_features or max(1, int(np.ceil(d / 1.5)))
        feats = self.rng.permutation(d)[: min(k, d)]
        best = None  # (score, feat, thr, mask)
        for f in feats:
            xs = X[idx, f]
            order = np.argsort(xs, kind="stable")
            xs_sorted = xs[order]
            ys_sorted = ysub[order]
            # candidate split positions between distinct values
            csum = np.cumsum(ys_sorted)
            csum2 = np.cumsum(ys_sorted**2)
            n = len(idx)
            pos = np.arange(self.min_samples_leaf, n - self.min_samples_leaf + 1)
            if len(pos) == 0:
                continue
            valid = xs_sorted[pos - 1] < xs_sorted[np.minimum(pos, n - 1)]
            pos = pos[valid[: len(pos)]] if len(valid) >= len(pos) else pos[valid]
            if len(pos) == 0:
                continue
            nl = pos.astype(float)
            nr = n - nl
            sl, sr = csum[pos - 1], csum[-1] - csum[pos - 1]
            s2l, s2r = csum2[pos - 1], csum2[-1] - csum2[pos - 1]
            sse = (s2l - sl**2 / nl) + (s2r - sr**2 / nr)
            j = int(np.argmin(sse))
            if best is None or sse[j] < best[0]:
                thr = 0.5 * (xs_sorted[pos[j] - 1] + xs_sorted[pos[j]])
                best = (float(sse[j]), int(f), float(thr))
        if best is None:
            return nid
        _, f, thr = best
        mask = X[idx, f] <= thr
        li, ri = idx[mask], idx[~mask]
        if len(li) < self.min_samples_leaf or len(ri) < self.min_samples_leaf:
            return nid
        node.feature = f
        node.threshold = thr
        node.left = self._build(X, y, li, depth + 1)
        node.right = self._build(X, y, ri, depth + 1)
        return nid

    def _freeze(self) -> None:
        """Pack nodes into arrays for vectorized descent."""
        n = len(self.nodes)
        self._feat = np.array([nd.feature for nd in self.nodes], dtype=np.int64)
        self._thr = np.array([nd.threshold for nd in self.nodes], dtype=float)
        self._left = np.array([nd.left for nd in self.nodes], dtype=np.int64)
        self._right = np.array([nd.right for nd in self.nodes], dtype=np.int64)
        self._mean = np.array([nd.mean for nd in self.nodes], dtype=float)
        self._var = np.array([nd.var for nd in self.nodes], dtype=float)

    def predict(self, X: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized descent: O(depth * n) per call."""
        X = np.atleast_2d(np.asarray(X, dtype=float))
        if not hasattr(self, "_feat"):
            self._freeze()
        nid = np.zeros(len(X), dtype=np.int64)
        for _ in range(self.max_depth + 1):
            feat = self._feat[nid]
            active = feat >= 0
            if not active.any():
                break
            ai = np.where(active)[0]
            f = feat[ai]
            go_left = X[ai, f] <= self._thr[nid[ai]]
            nid[ai] = np.where(go_left, self._left[nid[ai]], self._right[nid[ai]])
        return self._mean[nid], self._var[nid]


class ProbabilisticRandomForest(Surrogate):
    def __init__(
        self,
        n_trees: int = 10,
        max_depth: int = 12,
        min_samples_split: int = 4,
        min_samples_leaf: int = 1,
        bootstrap: bool = True,
        seed: int = 0,
    ):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.min_samples_split = min_samples_split
        self.min_samples_leaf = min_samples_leaf
        self.bootstrap = bootstrap
        self.seed = seed
        self.trees: List[RegressionTree] = []
        self._y_mean = 0.0
        self._y_std = 1.0
        self.X_: Optional[np.ndarray] = None
        self.y_: Optional[np.ndarray] = None

    def fit(self, X: np.ndarray, y: np.ndarray) -> "ProbabilisticRandomForest":
        X = np.atleast_2d(np.asarray(X, dtype=float))
        y = np.asarray(y, dtype=float)
        self.X_, self.y_ = X, y
        self._y_mean = float(y.mean()) if len(y) else 0.0
        self._y_std = float(y.std()) or 1.0
        yn = (y - self._y_mean) / self._y_std
        rng = np.random.default_rng(self.seed)
        self.trees = []
        n = len(y)
        for t in range(self.n_trees):
            trng = np.random.default_rng(rng.integers(2**63))
            idx = trng.integers(0, n, n) if (self.bootstrap and n > 1) else np.arange(n)
            tree = RegressionTree(
                max_depth=self.max_depth,
                min_samples_split=self.min_samples_split,
                min_samples_leaf=self.min_samples_leaf,
                rng=trng,
            )
            tree.fit(X[idx], yn[idx])
            self.trees.append(tree)
        return self

    def predict(self, X: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        X = np.atleast_2d(np.asarray(X, dtype=float))
        if not self.trees:
            return np.zeros(len(X)), np.ones(len(X))
        ms = np.empty((self.n_trees, len(X)))
        vs = np.empty((self.n_trees, len(X)))
        for i, tree in enumerate(self.trees):
            ms[i], vs[i] = tree.predict(X)
        mean = ms.mean(axis=0)
        # law of total variance across trees
        var = vs.mean(axis=0) + ms.var(axis=0)
        var = np.maximum(var, 1e-10)
        return mean * self._y_std + self._y_mean, var * self._y_std**2


# ---------------------------------------------------------------------------
# Gaussian process (for the Tuneful MTGP baseline)
# ---------------------------------------------------------------------------


class GaussianProcess(Surrogate):
    """Exact GP with Matérn-5/2 kernel, constant mean, jitter + noise MLE-lite.

    Hyperparameters are set by a small grid search over (lengthscale, noise)
    maximizing the log marginal likelihood — adequate at these data sizes.
    """

    def __init__(self, lengthscales=(0.1, 0.2, 0.5, 1.0, 2.0), noises=(1e-6, 1e-4, 1e-2)):
        self.lengthscales = lengthscales
        self.noises = noises
        self.X_: Optional[np.ndarray] = None
        self.alpha_: Optional[np.ndarray] = None
        self.L_: Optional[np.ndarray] = None
        self.ls_: float = 0.5
        self.noise_: float = 1e-4
        self._y_mean = 0.0
        self._y_std = 1.0

    @staticmethod
    def _matern52(A: np.ndarray, B: np.ndarray, ls: float) -> np.ndarray:
        d2 = np.maximum(
            (A**2).sum(1)[:, None] + (B**2).sum(1)[None, :] - 2 * A @ B.T, 0.0
        )
        r = np.sqrt(d2) / ls
        s5r = np.sqrt(5.0) * r
        return (1 + s5r + 5 * d2 / (3 * ls**2)) * np.exp(-s5r)

    def fit(self, X: np.ndarray, y: np.ndarray) -> "GaussianProcess":
        X = np.atleast_2d(np.asarray(X, dtype=float))
        y = np.asarray(y, dtype=float)
        self._y_mean = float(y.mean()) if len(y) else 0.0
        self._y_std = float(y.std()) or 1.0
        yn = (y - self._y_mean) / self._y_std
        best = (np.inf, None)
        n = len(X)
        for ls in self.lengthscales:
            K0 = self._matern52(X, X, ls)
            for noise in self.noises:
                K = K0 + (noise + 1e-8) * np.eye(n)
                try:
                    L = np.linalg.cholesky(K)
                except np.linalg.LinAlgError:
                    continue
                alpha = np.linalg.solve(L.T, np.linalg.solve(L, yn))
                nll = 0.5 * yn @ alpha + np.log(np.diag(L)).sum()
                if nll < best[0]:
                    best = (nll, (ls, noise, L, alpha))
        if best[1] is None:
            raise RuntimeError("GP fit failed")
        self.ls_, self.noise_, self.L_, self.alpha_ = best[1]
        self.X_ = X
        return self

    def predict(self, X: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        X = np.atleast_2d(np.asarray(X, dtype=float))
        Ks = self._matern52(X, self.X_, self.ls_)
        mean = Ks @ self.alpha_
        v = np.linalg.solve(self.L_, Ks.T)
        var = np.maximum(1.0 - (v**2).sum(axis=0), 1e-10)
        return mean * self._y_std + self._y_mean, var * self._y_std**2
