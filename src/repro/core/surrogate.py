"""Surrogate models for Bayesian optimization.

The primary surrogate is a Probabilistic Random Forest (paper §3.3 —
"Probabilistic Random Forest [12]", i.e. the SMAC-style forest): an ensemble
of randomized regression trees over the unit-cube encoding; the predictive
mean is the mean of per-tree leaf means and the predictive variance combines
across-tree disagreement with within-leaf empirical variance (law of total
variance, as in Hutter et al. 2011).

A small exact Gaussian Process (Matérn-5/2) is also provided — it is *not*
used by MFTune itself but by the Tuneful baseline's multi-task GP.

Ensemble inference runs on a *packed* representation: ``pack()`` stacks all
trees of a forest into one struct-of-arrays :class:`PackedForest` (feature /
threshold / child / leaf-stat arrays with per-tree root offsets) so predict
is a single level-synchronous gather descent over (n_trees × n_points)
instead of a per-tree Python loop. :class:`ForestPlane` extends the same
arena across *several* forests (one per source task / fidelity level) so the
combined surrogate of §6.2 is evaluated in one fused pass. The descent also
has jax and pallas backends (``repro.kernels.forest_eval``); all backends
route points to identical leaves, so (mean, var) agree bit-for-bit with the
legacy loop, which is kept as ``predict_loop`` for equivalence tests.

Fitting mirrors inference: on every packed backend trees grow through a
*level-synchronous frontier builder* (one vectorized best-split scan over
all active nodes per depth, against a shared presorted feature order) that
feeds ``pack()`` directly; the ``"loop"`` backend keeps the legacy
node-by-node recursion. Per-node feature subsets come from a
traversal-order-independent seed chain and the split arithmetic replays the
recursion's exact op sequence, so both builders produce bit-identical
trees — backend choice never changes a fitted forest.

The default path is pure numpy; data sets here are O(10^2-10^3) points.
"""

from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .. import obs as _obs

__all__ = [
    "RegressionTree",
    "ProbabilisticRandomForest",
    "PackedForest",
    "ForestPlane",
    "GaussianProcess",
    "Surrogate",
    "make_forest",
    "set_forest_backend",
    "get_forest_backend",
    "forest_backend",
    "packed_descend",
]


class Surrogate:
    """Minimal interface all surrogates implement."""

    def fit(self, X: np.ndarray, y: np.ndarray) -> "Surrogate":
        raise NotImplementedError

    def predict(self, X: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Return (mean, variance), each shape (n,)."""
        raise NotImplementedError

    def predict_mean(self, X: np.ndarray) -> np.ndarray:
        return self.predict(X)[0]


# ---------------------------------------------------------------------------
# Regression trees / random forest
# ---------------------------------------------------------------------------


@dataclass
class _Node:
    feature: int = -1            # -1 => leaf
    threshold: float = 0.0
    left: int = -1
    right: int = -1
    mean: float = 0.0
    var: float = 0.0
    n: int = 0


_MASK64 = (1 << 64) - 1
_GOLDEN = 0x9E3779B97F4A7C15


def _splitmix64(z: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer over uint64 arrays (wrapping mod 2^64)."""
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


def _child_seeds(seeds: np.ndarray, right: int) -> np.ndarray:
    """Traversal-order-independent per-node seed chain (splitmix64-style),
    derived for a whole frontier of parent seeds in one array pass.

    Both tree builders derive each node's feature-subset stream from this
    chain, so the recursive (depth-first) and frontier (level-synchronous)
    builders draw identical subsets regardless of node processing order.
    """
    z = np.asarray(seeds, dtype=np.uint64) + np.uint64((_GOLDEN * (right + 1)) & _MASK64)
    return _splitmix64(z) & np.uint64((1 << 63) - 1)


def _child_seed(seed: int, right: int) -> int:
    """Scalar view of the chain for the recursive reference builder."""
    return int(_child_seeds(np.asarray([seed], dtype=np.uint64), right)[0])


def _feature_subsets(seeds: np.ndarray, d: int, k: int) -> np.ndarray:
    """Per-node random k-of-d feature subsets for a whole frontier at once.

    A partial Fisher-Yates driven by a splitmix64 counter stream per node:
    k vectorized swap steps replace one ``Generator`` construction plus a
    ``permutation`` call *per node* — the dominant Python cost of a frontier
    level. Deterministic in the node seed and shared by both builders
    (modulo bias at d <= 64 vs 2^64 states is negligible).
    """
    seeds = np.asarray(seeds, dtype=np.uint64)
    W = len(seeds)
    perm = np.broadcast_to(np.arange(d), (W, d)).copy()
    rows = np.arange(W)
    state = seeds
    for i in range(min(k, d)):
        state = state + np.uint64(_GOLDEN)
        draw = _splitmix64(state)
        j = i + (draw % np.uint64(d - i)).astype(np.int64)
        pi = perm[rows, i].copy()
        perm[rows, i] = perm[rows, j]
        perm[rows, j] = pi
    return perm[:, :k]


class RegressionTree:
    """CART regression tree with random feature subsetting at each split.

    Two equivalent builders: ``"frontier"`` (default) grows the tree one
    *level* at a time — a vectorized best-split scan over all active nodes
    per depth against a shared presorted feature order — while
    ``"recursive"`` is the legacy node-by-node Python recursion kept as the
    equivalence reference. Both consume the per-node seed chain and compute
    split SSEs with the identical op sequence (padded per-node row cumsums),
    so they produce bit-identical trees.
    """

    def __init__(
        self,
        max_depth: int = 12,
        min_samples_split: int = 4,
        min_samples_leaf: int = 2,
        max_features: Optional[int] = None,
        rng: Optional[np.random.Generator] = None,
        builder: str = "frontier",
        root_seed: Optional[int] = None,
    ):
        self.max_depth = max_depth
        self.min_samples_split = min_samples_split
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.rng = rng or np.random.default_rng()
        if builder not in ("frontier", "recursive"):
            raise ValueError(f"unknown tree builder {builder!r}")
        self.builder = builder
        # explicit root of the per-node seed chain (forest fits derive all
        # tree roots in one array pass); None = draw from self.rng
        self.root_seed = root_seed
        self.nodes: List[_Node] = []

    def _n_features(self, d: int) -> int:
        k = self.max_features or max(1, int(np.ceil(d / 1.5)))
        return min(k, d)

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RegressionTree":
        X = np.asarray(X, dtype=float)
        y = np.asarray(y, dtype=float)
        self.nodes = []
        root_seed = self.root_seed if self.root_seed is not None else int(self.rng.integers(2**63))
        if self.builder == "recursive":
            self._build(X, y, np.arange(len(y)), 0, root_seed)
        else:
            self._build_frontier(X, y, root_seed)
        self._freeze()
        return self

    def _new_node(self, ysub: np.ndarray) -> int:
        node = _Node()
        # raw ufunc reduces replay numpy's _mean/_var op sequence (pairwise
        # umr_sum, then the same subtract/square/divide) without the method
        # dispatch overhead — bit-identical to ysub.mean()/ysub.var(), which
        # dominates per-node cost in both builders
        n = len(ysub)
        m = np.add.reduce(ysub) / n
        dev = ysub - m
        node.mean = float(m)
        node.var = float(np.add.reduce(dev * dev) / n)
        node.n = n
        self.nodes.append(node)
        return len(self.nodes) - 1

    def _build(self, X: np.ndarray, y: np.ndarray, idx: np.ndarray, depth: int, seed: int) -> int:
        nid = self._new_node(y[idx])
        node = self.nodes[nid]
        ysub = y[idx]
        if depth >= self.max_depth or len(idx) < self.min_samples_split or np.ptp(ysub) == 0:
            return nid
        d = X.shape[1]
        feats = _feature_subsets(np.asarray([seed], np.uint64), d, self._n_features(d))[0]
        best = None  # (score, feat, thr)
        for f in feats:
            xs = X[idx, f]
            order = np.argsort(xs, kind="stable")
            xs_sorted = xs[order]
            ys_sorted = ysub[order]
            # candidate split positions between distinct values
            csum = np.cumsum(ys_sorted)
            csum2 = np.cumsum(ys_sorted**2)
            n = len(idx)
            pos = np.arange(self.min_samples_leaf, n - self.min_samples_leaf + 1)
            pos = pos[(pos >= 1) & (pos <= n - 1)]  # both sides non-empty
            if len(pos) == 0:
                continue
            valid = xs_sorted[pos - 1] < xs_sorted[pos]  # split between distinct values
            pos = pos[valid]
            if len(pos) == 0:
                continue
            nl = pos.astype(float)
            nr = n - nl
            sl, sr = csum[pos - 1], csum[-1] - csum[pos - 1]
            s2l, s2r = csum2[pos - 1], csum2[-1] - csum2[pos - 1]
            sse = (s2l - sl**2 / nl) + (s2r - sr**2 / nr)
            j = int(np.argmin(sse))
            if best is None or sse[j] < best[0]:
                thr = 0.5 * (xs_sorted[pos[j] - 1] + xs_sorted[pos[j]])
                best = (float(sse[j]), int(f), float(thr))
        if best is None:
            return nid
        _, f, thr = best
        mask = X[idx, f] <= thr
        li, ri = idx[mask], idx[~mask]
        if len(li) < self.min_samples_leaf or len(ri) < self.min_samples_leaf:
            return nid
        node.feature = f
        node.threshold = thr
        node.left = self._build(X, y, li, depth + 1, _child_seed(seed, 0))
        node.right = self._build(X, y, ri, depth + 1, _child_seed(seed, 1))
        return nid

    def _build_frontier(self, X: np.ndarray, y: np.ndarray, root_seed: int) -> None:
        """Level-synchronous builder: one vectorized split scan per depth.

        Per level, the samples of every splittable node are grouped (via one
        stable argsort against the shared presorted feature order) into
        padded (node, position) matrices, and the SSE of every candidate
        split of every node is computed in a few whole-frontier array ops.
        Per-node Python work shrinks to the feature-subset draw and the
        child bookkeeping. Arithmetic is arranged to be bit-identical to the
        recursion: padded rows reproduce each node's own cumsum sequence,
        and argmins keep the recursion's first-strict-min tie-breaking.
        """
        n, d = X.shape
        k = self._n_features(d)
        msl = self.min_samples_leaf
        mss = self.min_samples_split
        sorted_mat = np.argsort(X, axis=0, kind="stable") if n else np.zeros((0, d), np.int64)
        root_idx = np.arange(n)
        self._new_node(y[root_idx])
        # frontier entries: (nid, idx, seed, splittable) — the splittable
        # flag (count and ptp gates, same booleans as the recursion's) is
        # computed when the node is created, from the y-gather it needs
        # anyway, so the level filter does no per-node array work
        root_ok = bool(
            n >= mss and n > 0 and np.maximum.reduce(y) != np.minimum.reduce(y)
        )
        frontier: List[Tuple[int, np.ndarray, int, bool]] = [(0, root_idx, root_seed, root_ok)]
        level = 0
        cols = np.arange(d)
        # one errstate for the whole build (padded lanes divide by zero
        # before they are masked invalid) instead of one context per level
        with np.errstate(divide="ignore", invalid="ignore"):
            self._frontier_levels(X, y, frontier, sorted_mat, cols, k, msl, mss, level)

    def _frontier_levels(self, X, y, frontier, sorted_mat, cols, k, msl, mss, level) -> None:
        n, d = X.shape
        while frontier and level < self.max_depth:
            active = [t for t in frontier if t[3]]
            if not active:
                break
            W = len(active)
            counts = np.array([len(t[1]) for t in active], dtype=np.int64)
            M = int(counts.max())
            n_act = int(counts.sum())
            starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
            slot_rep = np.repeat(np.arange(W), counts)
            cat = np.concatenate([t[1] for t in active])  # node-order sample ids
            # group every feature column by node in ONE stable argsort of the
            # (n, d) slot matrix: inactive samples carry sentinel W and sink
            # to the bottom; ties (same node) keep the presorted x-order
            slot_of = np.full(n, W, dtype=np.int64)
            slot_of[cat] = slot_rep
            gorder = np.argsort(slot_of[sorted_mat], axis=0, kind="stable")[:n_act]
            gidx = sorted_mat[gorder, cols[None, :]]  # (n_act, d)
            rowpos = np.arange(n_act) - starts[slot_rep]
            best_sse = np.full((W, d), np.inf)
            best_thr = np.zeros((W, d))
            # padded (node, position, feature) blocks: each (w, :, f) lane is
            # that node's feature-sorted value/target sequence, so the lane
            # cumsums replay the recursion's per-node cumsum bit-for-bit;
            # scatter by flat row index (node * M + position)
            dst = slot_rep * M + rowpos
            xs3 = np.zeros((W * M, d))
            ys3 = np.zeros((W * M, d))
            xs3[dst] = X[gidx, cols[None, :]]
            ys3[dst] = y[gidx]
            xs3 = xs3.reshape(W, M, d)
            ys3 = ys3.reshape(W, M, d)
            if M > 1:
                rows = np.arange(W)[:, None]
                pos = np.arange(1, M)
                nl = pos.astype(float)[None, :, None]
                cs = np.cumsum(ys3, axis=1)
                cs2 = np.cumsum(ys3**2, axis=1)
                sl = cs[:, :-1, :]
                s2l = cs2[:, :-1, :]
                tot = cs[rows[:, 0], counts - 1, :][:, None, :]
                tot2 = cs2[rows[:, 0], counts - 1, :][:, None, :]
                nr = counts[:, None, None] - nl
                sse = (s2l - sl**2 / nl) + ((tot2 - s2l) - (tot - sl) ** 2 / nr)
                valid = (
                    (pos[None, :, None] >= max(msl, 1))
                    & (pos[None, :, None] <= (counts[:, None] - max(msl, 1))[:, :, None])
                    & (xs3[:, :-1, :] < xs3[:, 1:, :])
                )
                sse = np.where(valid, sse, np.inf)
                j = np.argmin(sse, axis=1)  # (W, d): first minimum per lane
                # pos = arange(1, M), so lane argmin j maps to split position
                # j + 1; direct fancy gathers replace take_along_axis
                best_sse = sse[rows, j, cols[None, :]]
                bp = j + 1
                best_thr = 0.5 * (xs3[rows, bp - 1, cols[None, :]] + xs3[rows, bp, cols[None, :]])
            # whole-frontier feature pick + child masks: the per-node seed
            # chain and feature subsets come from one splitmix64 array
            # derivation (no per-node Generator constructions; the recursion
            # consumes the identical chain, so builders still agree
            # bit-for-bit); argmin over the perm gather keeps the
            # recursion's first-strict-min tie-breaking across features
            rows_w = np.arange(W)
            seeds_w = np.array([t[2] for t in active], dtype=np.uint64)
            lseeds = _child_seeds(seeds_w, 0)
            rseeds = _child_seeds(seeds_w, 1)
            P = _feature_subsets(seeds_w, d, k)
            FS = best_sse[rows_w[:, None], P]
            R = np.argmin(FS, axis=1)
            F = P[rows_w, R]
            split_ok = np.isfinite(FS[rows_w, R])
            THR = best_thr[rows_w, F]
            mask_flat = X[cat, np.repeat(F, counts)] <= np.repeat(THR, counts)
            next_frontier: List[Tuple[int, np.ndarray, int, bool]] = []
            for s in np.flatnonzero(split_ok):
                nid, idx, seed, _ = active[s]
                a = starts[s]
                m = mask_flat[a : a + counts[s]]
                li, ri = idx[m], idx[~m]
                if len(li) < msl or len(ri) < msl:
                    continue
                node = self.nodes[nid]
                node.feature = int(F[s])
                node.threshold = float(THR[s])
                yl, yr = y[li], y[ri]
                node.left = self._new_node(yl)
                node.right = self._new_node(yr)
                next_frontier.append((
                    node.left, li, int(lseeds[s]),
                    len(li) >= mss and np.maximum.reduce(yl) != np.minimum.reduce(yl),
                ))
                next_frontier.append((
                    node.right, ri, int(rseeds[s]),
                    len(ri) >= mss and np.maximum.reduce(yr) != np.minimum.reduce(yr),
                ))
            frontier = next_frontier
            level += 1

    def _freeze(self) -> None:
        """Pack nodes into arrays for vectorized descent."""
        n = len(self.nodes)
        self._feat = np.array([nd.feature for nd in self.nodes], dtype=np.int64)
        self._thr = np.array([nd.threshold for nd in self.nodes], dtype=float)
        self._left = np.array([nd.left for nd in self.nodes], dtype=np.int64)
        self._right = np.array([nd.right for nd in self.nodes], dtype=np.int64)
        self._mean = np.array([nd.mean for nd in self.nodes], dtype=float)
        self._var = np.array([nd.var for nd in self.nodes], dtype=float)
        # actual depth (children are appended after their parent, so one
        # forward pass assigns levels top-down)
        level = np.zeros(n, dtype=np.int64)
        depth = 0
        for i in range(n):
            if self._feat[i] >= 0:
                child_level = level[i] + 1
                level[self._left[i]] = child_level
                level[self._right[i]] = child_level
                depth = max(depth, int(child_level))
        self._depth = depth

    def predict(self, X: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized descent: O(depth * n) per call."""
        X = np.atleast_2d(np.asarray(X, dtype=float))
        if not hasattr(self, "_feat"):
            self._freeze()
        nid = np.zeros(len(X), dtype=np.int64)
        for _ in range(self.max_depth + 1):
            feat = self._feat[nid]
            active = feat >= 0
            if not active.any():
                break
            ai = np.where(active)[0]
            f = feat[ai]
            go_left = X[ai, f] <= self._thr[nid[ai]]
            nid[ai] = np.where(go_left, self._left[nid[ai]], self._right[nid[ai]])
        return self._mean[nid], self._var[nid]


# ---------------------------------------------------------------------------
# Packed forest plane (struct-of-arrays ensemble inference)
# ---------------------------------------------------------------------------


def packed_descend(
    feat: np.ndarray,
    thr: np.ndarray,
    child: np.ndarray,
    roots: np.ndarray,
    X: np.ndarray,
    depth: int,
) -> np.ndarray:
    """Level-synchronous descent over a packed node arena (numpy backend).

    Node encoding: leaves carry ``thr = +inf`` and self-loop children, so
    every lane takes the "left" branch into itself once it lands on a leaf
    and the loop needs no active-lane bookkeeping. ``child`` interleaves the
    two children of node ``i`` at ``[2i, 2i+1]`` so the post-comparison
    branch is a single gather. Returns leaf node ids, shape (T, N).
    """
    X = np.ascontiguousarray(X, dtype=float)
    N, D = X.shape
    T = len(roots)
    xflat = X.reshape(-1)
    col = np.broadcast_to((np.arange(N, dtype=np.intp) * D)[None, :], (T, N))
    nid = np.repeat(roots[:, None], N, axis=1)
    buf_i = np.empty((T, N), dtype=np.intp)
    buf_x = np.empty((T, N))
    buf_t = np.empty((T, N))
    for _ in range(depth):
        np.take(feat, nid, out=buf_i)
        buf_i += col
        np.take(xflat, buf_i, out=buf_x)
        np.take(thr, nid, out=buf_t)
        go_right = buf_x > buf_t
        nid += nid
        nid += go_right
        np.take(child, nid, out=nid)
    return nid


@dataclass
class PackedForest:
    """All trees of one forest stacked into a struct-of-arrays node arena.

    ``feat``/``thr``/``mean``/``var`` are per-node (leaves: feat clamped to
    0, thr = +inf); ``child`` holds the interleaved (left, right) pointers
    rebased to arena indices, with leaves pointing at themselves; ``roots``
    holds each tree's root index. ``y_mean``/``y_std`` carry the fit-time
    target normalization so predictions are self-contained.
    """

    feat: np.ndarray          # (n_nodes,) intp
    thr: np.ndarray           # (n_nodes,) float64
    child: np.ndarray         # (2 * n_nodes,) intp
    mean: np.ndarray          # (n_nodes,) float64
    var: np.ndarray           # (n_nodes,) float64
    roots: np.ndarray         # (n_trees,) intp
    depth: int                # max tree depth in the arena
    y_mean: float = 0.0
    y_std: float = 1.0

    @property
    def n_trees(self) -> int:
        return len(self.roots)

    @property
    def n_nodes(self) -> int:
        return len(self.feat)

    @staticmethod
    def from_trees(
        trees: Sequence[RegressionTree], y_mean: float = 0.0, y_std: float = 1.0
    ) -> "PackedForest":
        feat, thr, child, mean, var, roots = [], [], [], [], [], []
        off = 0
        depth = 0
        for tree in trees:
            if not hasattr(tree, "_feat"):
                tree._freeze()
            n = len(tree._feat)
            leaf = tree._feat < 0
            feat.append(np.where(leaf, 0, tree._feat))
            thr.append(np.where(leaf, np.inf, tree._thr))
            self_idx = np.arange(n)
            left = np.where(leaf, self_idx, tree._left) + off
            right = np.where(leaf, self_idx, tree._right) + off
            child.append(np.stack([left, right], axis=1).reshape(-1))
            mean.append(tree._mean)
            var.append(tree._var)
            roots.append(off)
            depth = max(depth, tree._depth)
            off += n
        return PackedForest(
            feat=np.concatenate(feat).astype(np.intp),
            thr=np.concatenate(thr),
            child=np.concatenate(child).astype(np.intp),
            mean=np.concatenate(mean),
            var=np.concatenate(var),
            roots=np.asarray(roots, dtype=np.intp),
            depth=depth,
            y_mean=y_mean,
            y_std=y_std,
        )

    # ------------------------------------------------------------- inference
    def predict_trees(
        self, X: np.ndarray, backend: str = "numpy", chunk_n: Optional[int] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Per-tree leaf stats, each shape (n_trees, n_points). ``chunk_n``
        bounds rows per descent dispatch (see ``forest_eval``) for oversized
        pools such as the batched Shapley composite tensor."""
        X = np.atleast_2d(np.asarray(X, dtype=float))
        if backend == "numpy" and chunk_n is None:
            nid = packed_descend(self.feat, self.thr, self.child, self.roots, X, self.depth)
            return np.take(self.mean, nid), np.take(self.var, nid)
        from ..kernels.forest_eval.ops import forest_eval

        return forest_eval(
            self.feat, self.thr, self.child, self.mean, self.var, self.roots,
            X, self.depth, backend=backend, chunk_n=chunk_n,
        )

    def combine(self, m_t: np.ndarray, v_t: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Ensemble (mean, var) from per-tree stats — the exact op sequence
        of the legacy per-tree loop, so results are bit-identical."""
        mean = m_t.mean(axis=0)
        var = v_t.mean(axis=0) + m_t.var(axis=0)
        var = np.maximum(var, 1e-10)
        return mean * self.y_std + self.y_mean, var * self.y_std**2

    def predict(
        self, X: np.ndarray, backend: str = "numpy", chunk_n: Optional[int] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        return self.combine(*self.predict_trees(X, backend=backend, chunk_n=chunk_n))


class ForestPlane:
    """Several packed forests fused into one arena for multi-source predict.

    The combined surrogate (one PRF per source task plus one per fidelity
    level, §6.2) evaluates every source on the same candidate pool; fusing
    the arenas means one gather descent over all sources' trees instead of a
    Python loop over forests. Per-source combination still runs on each
    forest's own tree slice, so the output matches per-forest ``predict``
    bit-for-bit.
    """

    def __init__(self, forests: Sequence[PackedForest]):
        if not forests:
            raise ValueError("ForestPlane needs at least one forest")
        self.forests = list(forests)
        offs = np.cumsum([0] + [f.n_nodes for f in forests])
        self.feat = np.concatenate([f.feat for f in forests])
        self.thr = np.concatenate([f.thr for f in forests])
        self.child = np.concatenate([f.child + off for f, off in zip(forests, offs)])
        self.mean = np.concatenate([f.mean for f in forests])
        self.var = np.concatenate([f.var for f in forests])
        self.roots = np.concatenate([f.roots + off for f, off in zip(forests, offs)])
        self.depth = max(f.depth for f in forests)
        tree_counts = np.cumsum([0] + [f.n_trees for f in forests])
        self.tree_slices = [
            (int(a), int(b)) for a, b in zip(tree_counts[:-1], tree_counts[1:])
        ]
        self.y_means = np.array([f.y_mean for f in forests])
        self.y_stds = np.array([f.y_std for f in forests])

    @staticmethod
    def from_forests(forests: Sequence[PackedForest]) -> "ForestPlane":
        return ForestPlane(forests)

    @property
    def uniform_tree_count(self) -> Optional[int]:
        """Trees per source when all sources agree, else None — the shape
        contract for the fused device paths (forest_plane_eval and the
        propose step), which slice the leaf-stat matrix per source."""
        counts = {f.n_trees for f in self.forests}
        return next(iter(counts)) if len(counts) == 1 else None

    def predict(
        self, X: np.ndarray, backend: str = "numpy", delta=None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Fused multi-source predict: (means, vars), each (S, N).

        ``delta`` opts the host path into bitvector pool scoring with
        per-base reuse: a ``(bases, base_of)`` pair (see
        ``chain.PoolPlan.leaf_stats``) from a mutation-heavy candidate
        pool. Leaf routing via the QuickScorer words is bit-identical to
        the gather descent, so the output is unchanged — only the
        per-candidate cost drops to the mutated coordinates plus
        O(log d) segment lookups. Ignored on accelerated backends (the
        fused device descent already carries those).
        """
        X = np.atleast_2d(np.asarray(X, dtype=float))
        if backend == "numpy":
            m_t = None
            if delta is not None and X.shape[0]:
                from ..kernels.forest_eval.chain import build_pool_plan_ex

                plan, _reason = build_pool_plan_ex(self, X.shape[1])
                if plan is not None:
                    _obs.count("forest_plane/chain_delta")
                    m_t, v_t = plan.leaf_stats(X, *delta)
            if m_t is None:
                _obs.count("forest_plane/numpy")
                nid = packed_descend(
                    self.feat, self.thr, self.child, self.roots, X, self.depth
                )
                m_t, v_t = np.take(self.mean, nid), np.take(self.var, nid)
        else:
            tree_counts = {f.n_trees for f in self.forests}
            if backend in ("jax", "auto") and len(tree_counts) == 1:
                # uniform tree counts: descent + combine fuse on device
                from ..kernels.forest_eval.ops import forest_plane_eval

                try:
                    out = forest_plane_eval(
                        self.feat, self.thr, self.child, self.mean, self.var,
                        self.roots, X, self.depth, self.y_means, self.y_stds,
                        trees_per_source=next(iter(tree_counts)),
                    )
                    _obs.count("forest_plane/fused_device")
                    return out
                except RuntimeError:
                    pass  # no jax: fall through to the numpy-combine path
            from ..kernels.forest_eval.ops import forest_eval

            _obs.count("forest_plane/host_combine")
            m_t, v_t = forest_eval(
                self.feat, self.thr, self.child, self.mean, self.var, self.roots,
                X, self.depth, backend=backend,
            )
        means = np.empty((len(self.forests), X.shape[0]))
        vars_ = np.empty_like(means)
        for s, ((a, b), f) in enumerate(zip(self.tree_slices, self.forests)):
            means[s], vars_[s] = f.combine(m_t[a:b], v_t[a:b])
        return means, vars_


class ProbabilisticRandomForest(Surrogate):
    def __init__(
        self,
        n_trees: int = 10,
        max_depth: int = 12,
        min_samples_split: int = 4,
        min_samples_leaf: int = 1,
        bootstrap: bool = True,
        seed: int = 0,
        backend: Optional[str] = None,
    ):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.min_samples_split = min_samples_split
        self.min_samples_leaf = min_samples_leaf
        self.bootstrap = bootstrap
        self.seed = seed
        # "loop" = legacy per-tree reference; "numpy"/"jax"/"pallas"/"auto"
        # select the packed-descent backend (None = module default)
        self.backend = backend or get_forest_backend()
        self.trees: List[RegressionTree] = []
        self._packed: Optional[PackedForest] = None
        self._y_mean = 0.0
        self._y_std = 1.0
        self.X_: Optional[np.ndarray] = None
        self.y_: Optional[np.ndarray] = None

    def fit(self, X: np.ndarray, y: np.ndarray) -> "ProbabilisticRandomForest":
        X = np.atleast_2d(np.asarray(X, dtype=float))
        y = np.asarray(y, dtype=float)
        _obs.count("surrogate/fits")
        _obs.observe("surrogate/fit_n_obs", float(len(y)))
        self.X_, self.y_ = X, y
        self._y_mean = float(y.mean()) if len(y) else 0.0
        self._y_std = float(y.std()) or 1.0
        yn = (y - self._y_mean) / self._y_std
        rng = np.random.default_rng(self.seed)
        self.trees = []
        self._packed = None
        n = len(y)
        # "loop" pins the legacy recursive builder along with the per-tree
        # predict loop; every packed backend fits via the level-synchronous
        # frontier builder (bit-identical trees either way).
        builder = "recursive" if self.backend == "loop" else "frontier"
        # one splitmix64 array derivation replaces the per-tree default_rng
        # constructions: a single PCG64 array draw seeds a counter stream
        # per tree, which yields every tree's bootstrap rows and the root of
        # its per-node seed chain without touching a Generator again
        tree_seeds = rng.integers(2**63, size=self.n_trees, dtype=np.uint64)
        root_seeds = _splitmix64(tree_seeds ^ np.uint64(0xD1B54A32D192ED03)) & np.uint64(
            (1 << 63) - 1
        )
        if self.bootstrap and n > 1:
            ctr = tree_seeds[:, None] + np.uint64(_GOLDEN) * np.arange(
                1, n + 1, dtype=np.uint64
            )
            boot = (_splitmix64(ctr) % np.uint64(n)).astype(np.intp)
        else:
            boot = np.broadcast_to(np.arange(n), (self.n_trees, n))
        for t in range(self.n_trees):
            tree = RegressionTree(
                max_depth=self.max_depth,
                min_samples_split=self.min_samples_split,
                min_samples_leaf=self.min_samples_leaf,
                root_seed=int(root_seeds[t]),
                builder=builder,
            )
            tree.fit(X[boot[t]], yn[boot[t]])
            self.trees.append(tree)
        return self

    def pack(self) -> PackedForest:
        """Stack all trees into one struct-of-arrays arena (cached per fit)."""
        if not self.trees:
            raise ValueError("pack() before fit()")
        if self._packed is None:
            self._packed = PackedForest.from_trees(self.trees, self._y_mean, self._y_std)
        return self._packed

    def predict(self, X: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        X = np.atleast_2d(np.asarray(X, dtype=float))
        if not self.trees:
            return np.zeros(len(X)), np.ones(len(X))
        if self.backend == "loop":
            return self.predict_loop(X)
        return self.pack().predict(X, backend=self.backend)

    def predict_loop(self, X: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Legacy per-tree loop — kept as the reference the packed plane is
        equivalence-tested against."""
        X = np.atleast_2d(np.asarray(X, dtype=float))
        if not self.trees:
            return np.zeros(len(X)), np.ones(len(X))
        ms = np.empty((self.n_trees, len(X)))
        vs = np.empty((self.n_trees, len(X)))
        for i, tree in enumerate(self.trees):
            ms[i], vs[i] = tree.predict(X)
        mean = ms.mean(axis=0)
        # law of total variance across trees
        var = vs.mean(axis=0) + ms.var(axis=0)
        var = np.maximum(var, 1e-10)
        return mean * self._y_std + self._y_mean, var * self._y_std**2


# ---------------------------------------------------------------------------
# Forest factory — the one PRF construction point the whole repo shares
# ---------------------------------------------------------------------------

_DEFAULT_BACKEND = "numpy"


def get_forest_backend() -> str:
    return _DEFAULT_BACKEND


def set_forest_backend(backend: str) -> None:
    """Set the module-default packed-descent backend ("loop" forces the
    legacy per-tree reference everywhere — used by equivalence tests)."""
    global _DEFAULT_BACKEND
    _DEFAULT_BACKEND = backend


@contextlib.contextmanager
def forest_backend(backend: str):
    prev = get_forest_backend()
    set_forest_backend(backend)
    try:
        yield
    finally:
        set_forest_backend(prev)


def make_forest(seed: int = 0, backend: Optional[str] = None, **kwargs) -> ProbabilisticRandomForest:
    """Packed factory: every surrogate stack in the repo builds PRFs here."""
    return ProbabilisticRandomForest(seed=seed, backend=backend, **kwargs)


# ---------------------------------------------------------------------------
# Gaussian process (for the Tuneful MTGP baseline)
# ---------------------------------------------------------------------------


class GaussianProcess(Surrogate):
    """Exact GP with Matérn-5/2 kernel, constant mean, jitter + noise MLE-lite.

    Hyperparameters are set by a small grid search over (lengthscale, noise)
    maximizing the log marginal likelihood — adequate at these data sizes.
    """

    def __init__(self, lengthscales=(0.1, 0.2, 0.5, 1.0, 2.0), noises=(1e-6, 1e-4, 1e-2)):
        self.lengthscales = lengthscales
        self.noises = noises
        self.X_: Optional[np.ndarray] = None
        self.alpha_: Optional[np.ndarray] = None
        self.L_: Optional[np.ndarray] = None
        self.ls_: float = 0.5
        self.noise_: float = 1e-4
        self._y_mean = 0.0
        self._y_std = 1.0

    @staticmethod
    def _matern52(A: np.ndarray, B: np.ndarray, ls: float) -> np.ndarray:
        d2 = np.maximum(
            (A**2).sum(1)[:, None] + (B**2).sum(1)[None, :] - 2 * A @ B.T, 0.0
        )
        r = np.sqrt(d2) / ls
        s5r = np.sqrt(5.0) * r
        return (1 + s5r + 5 * d2 / (3 * ls**2)) * np.exp(-s5r)

    def fit(self, X: np.ndarray, y: np.ndarray) -> "GaussianProcess":
        X = np.atleast_2d(np.asarray(X, dtype=float))
        y = np.asarray(y, dtype=float)
        self._y_mean = float(y.mean()) if len(y) else 0.0
        self._y_std = float(y.std()) or 1.0
        yn = (y - self._y_mean) / self._y_std
        best = (np.inf, None)
        n = len(X)
        for ls in self.lengthscales:
            K0 = self._matern52(X, X, ls)
            for noise in self.noises:
                K = K0 + (noise + 1e-8) * np.eye(n)
                try:
                    L = np.linalg.cholesky(K)
                except np.linalg.LinAlgError:
                    continue
                alpha = np.linalg.solve(L.T, np.linalg.solve(L, yn))
                nll = 0.5 * yn @ alpha + np.log(np.diag(L)).sum()
                if nll < best[0]:
                    best = (nll, (ls, noise, L, alpha))
        if best[1] is None:
            raise RuntimeError("GP fit failed")
        self.ls_, self.noise_, self.L_, self.alpha_ = best[1]
        self.X_ = X
        return self

    def predict(self, X: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        X = np.atleast_2d(np.asarray(X, dtype=float))
        Ks = self._matern52(X, self.X_, self.ls_)
        mean = Ks @ self.alpha_
        v = np.linalg.solve(self.L_, Ks.T)
        var = np.maximum(1.0 - (v**2).sum(axis=0), 1e-10)
        return mean * self._y_std + self._y_mean, var * self._y_std**2
