"""Weighted kernel density estimation and minimal α-mass regions (paper §5.2).

Continuous knobs: Gaussian-kernel weighted KDE (Eq. 4) with Silverman's
rule-of-thumb bandwidth; the promising range is the *smallest* union of
grid cells capturing at least α of the probability mass (Eq. 5), returned
as a union of closed intervals.

Categorical knobs: the discrete analogue (Eq. 6) — normalized weighted
frequencies; the promising subset is the smallest set of categories whose
cumulative mass reaches α.
"""

from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

import numpy as np

from .space import Intervals

__all__ = [
    "silverman_bandwidth",
    "WeightedKDE",
    "alpha_mass_region",
    "alpha_mass_categories",
]


def silverman_bandwidth(samples: np.ndarray, weights: np.ndarray) -> float:
    """Silverman's rule of thumb with weighted moments.

    h = 0.9 * min(sigma, IQR/1.34) * n_eff^{-1/5}, with Kish effective
    sample size for weighted data.
    """
    samples = np.asarray(samples, dtype=float)
    weights = np.asarray(weights, dtype=float)
    w = weights / weights.sum()
    mu = float((w * samples).sum())
    sigma = float(np.sqrt(max((w * (samples - mu) ** 2).sum(), 1e-18)))
    # weighted IQR via weighted quantiles
    order = np.argsort(samples)
    cw = np.cumsum(w[order])
    q25 = samples[order][np.searchsorted(cw, 0.25)]
    q75 = samples[order][np.searchsorted(cw, min(0.75, cw[-1] - 1e-12))]
    iqr = float(q75 - q25)
    spread = min(sigma, iqr / 1.34) if iqr > 0 else sigma
    n_eff = float(weights.sum() ** 2 / np.maximum((weights**2).sum(), 1e-18))
    h = 0.9 * spread * n_eff ** (-0.2)
    if not np.isfinite(h) or h <= 0:
        h = max(1e-3 * (samples.max() - samples.min()), 1e-9)
    return float(h)


class WeightedKDE:
    """Gaussian weighted KDE, Eq. 4."""

    def __init__(self, samples: Sequence[float], weights: Sequence[float], bandwidth: float | None = None):
        self.samples = np.asarray(samples, dtype=float)
        self.weights = np.asarray(weights, dtype=float)
        if len(self.samples) == 0:
            raise ValueError("empty KDE")
        if self.weights.sum() <= 0:
            self.weights = np.ones_like(self.samples)
        self.h = bandwidth if bandwidth is not None else silverman_bandwidth(self.samples, self.weights)

    def __call__(self, x: np.ndarray) -> np.ndarray:
        x = np.atleast_1d(np.asarray(x, dtype=float))
        z = (x[:, None] - self.samples[None, :]) / self.h
        k = np.exp(-0.5 * z**2) / np.sqrt(2 * np.pi)
        dens = (self.weights[None, :] * k).sum(axis=1) / (self.h * self.weights.sum())
        return dens


def alpha_mass_region(
    kde: WeightedKDE,
    lo: float,
    hi: float,
    alpha: float,
    grid_size: int = 512,
) -> Intervals:
    """Smallest union of grid cells with cumulative density mass >= alpha.

    Implements the solution procedure of Eq. 5: evaluate g-hat on a grid,
    sort cells by density descending, accumulate mass until alpha is
    reached, return the covered cells merged into intervals.
    """
    if hi <= lo:
        return Intervals([(lo, hi)])
    edges = np.linspace(lo, hi, grid_size + 1)
    mids = 0.5 * (edges[:-1] + edges[1:])
    dens = kde(mids)
    cell_mass = dens * (edges[1] - edges[0])
    total = cell_mass.sum()
    if total <= 0:
        return Intervals([(lo, hi)])
    mass = cell_mass / total
    order = np.argsort(-dens, kind="stable")
    cum = np.cumsum(mass[order])
    k = int(np.searchsorted(cum, alpha)) + 1
    chosen = np.zeros(grid_size, dtype=bool)
    chosen[order[:k]] = True
    # merge chosen cells into intervals
    ivs: List[Tuple[float, float]] = []
    i = 0
    while i < grid_size:
        if chosen[i]:
            j = i
            while j + 1 < grid_size and chosen[j + 1]:
                j += 1
            ivs.append((float(edges[i]), float(edges[j + 1])))
            i = j + 1
        else:
            i += 1
    return Intervals(ivs)


def alpha_mass_categories(
    values: Sequence[Any], weights: Sequence[float], alpha: float
) -> List[Any]:
    """Discrete analogue, Eq. 6: smallest category set with mass >= alpha."""
    mass: Dict[Any, float] = {}
    for v, w in zip(values, weights):
        mass[v] = mass.get(v, 0.0) + float(w)
    total = sum(mass.values())
    if total <= 0:
        return list(mass.keys())
    items = sorted(mass.items(), key=lambda kv: -kv[1])
    out, cum = [], 0.0
    for v, m in items:
        out.append(v)
        cum += m / total
        if cum >= alpha:
            break
    return out
