"""Density-based search-space compression (paper §5).

Step 1 (§5.1): per source task, promising configs G_i = better-than-median;
SHAP attributions over the source surrogate decide which knob *values*
helped (negative attribution on latency); each kept value carries weight
v(x) = w_i * (f_med - f(x)) / f_med   (Eq. 3).

Step 2 (§5.2): a knob whose promising set is weighted-majority-empty is
dropped (sum_i w_i * 1[P_j^i = empty] > 0.5); otherwise the union of
promising value sets feeds a weighted KDE whose minimal alpha-mass region
becomes the knob's restricted range (Eq. 4-5); categoricals use the
discrete analogue (Eq. 6).

The compressed space adapts every iteration as similarities sharpen.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .. import obs as _obs
from .kde import WeightedKDE, alpha_mass_categories, alpha_mass_region
from .knowledge import TaskRecord
from .shapley import shapley_values_batch
from .similarity import TaskWeights, surrogate_for_task
from .space import BoolKnob, CatKnob, ConfigSpace, FloatKnob, IntKnob, Intervals

__all__ = ["PromisingRegion", "extract_promising_regions", "compress_space", "SpaceCompressor"]


@dataclass
class PromisingRegion:
    """Per-task promising value sets: knob name -> list of (value, weight)."""

    task_id: str
    weight: float
    values: Dict[str, List[Tuple[Any, float]]] = field(default_factory=dict)
    n_good: int = 0
    importance: Dict[str, float] = field(default_factory=dict)  # sum |phi_j|

    def is_empty(self, knob: str, share_floor: float = 0.5) -> bool:
        """The paper's P_j = {} drop criterion, smoothed: a knob counts as
        empty for this task if no promising values were attributed to it OR
        its aggregate |SHAP| share is below ``share_floor``x the uniform
        share (exact-zero attributions are rare with a sampled explainer,
        so literal emptiness almost never fires; see DESIGN.md §9)."""
        if not self.values.get(knob):
            return True
        total = sum(self.importance.values())
        if total <= 0:
            return False
        share = self.importance.get(knob, 0.0) / total
        return share < share_floor / max(len(self.importance), 1)


def extract_promising_regions(
    space: ConfigSpace,
    task: TaskRecord,
    task_weight: float,
    seed: int = 0,
    n_permutations: int = 16,
    max_configs: int = 32,
    backend: str = "batched",
) -> Optional[PromisingRegion]:
    """§5.1 for one source task (or the target acting as its own source).

    All promising configs are explained in one batched masked-evaluation
    pass (``shapley_values_batch``); ``backend="loop"`` pins the legacy
    per-chain path, bit-identical under the shared permutation draws.
    """
    obs = task.full_fidelity()
    if len(obs) < 4:
        return None
    perf = np.array([o.performance for o in obs])
    f_med = float(np.median(perf))
    if f_med <= 0:
        return None
    good = [o for o in obs if o.performance < f_med]
    if not good:
        return None
    # cap SHAP cost: explain the best configs first
    good = sorted(good, key=lambda o: o.performance)[:max_configs]

    model = surrogate_for_task(space, task, seed=seed)
    if model is None:
        return None
    X_all = space.encode_many([o.config for o in obs])
    # independent child streams for the background subsample and the Shapley
    # permutation draws — seeding both with the raw `seed` made them the
    # *same* stream, coupling the background choice to the permutations
    bg_seed, perm_seed = np.random.SeedSequence(seed).spawn(2)
    bg_rng = np.random.default_rng(bg_seed)
    background = X_all if len(X_all) <= 16 else X_all[bg_rng.choice(len(X_all), 16, replace=False)]
    f = lambda Z: model.predict_mean(Z)

    region = PromisingRegion(task_id=task.task_id, weight=task_weight, n_good=len(good))
    rng = np.random.default_rng(perm_seed)
    X_good = space.encode_many([o.config for o in good])  # one columnar pass
    with _obs.span("shapley_attribution", task=task.task_id,
                   n_configs=len(good), perms=n_permutations, backend=backend):
        phis = shapley_values_batch(
            f, X_good, background, n_permutations=n_permutations, rng=rng,
            backend=backend, model=model,
        )
    # Eq. 3 keeps values with negative SHAP. We additionally require the
    # attribution to clear a noise floor (5% of the config's largest
    # |phi|): irrelevant knobs fluctuate around +-eps and would otherwise
    # never be dropped by the majority-empty rule (DESIGN.md §9). Note the
    # proportional residual correction in `shapley_values` keeps a knob the
    # surrogate ignores at phi == 0.0 exactly (the old uniform resid/d
    # spread pushed such knobs past this floor and let them dodge the
    # majority-empty drop rule).
    abs_phis = np.abs(phis)
    thrs = np.where(abs_phis.max(axis=1) > 0, 0.05 * abs_phis.max(axis=1), 0.0)
    names = [k.name for k in space.knobs]
    region.importance = dict(zip(names, abs_phis.sum(axis=0).astype(float)))
    for phi, thr, o in zip(phis, thrs, good):
        v = task_weight * (f_med - o.performance) / f_med  # Eq. 3 weight
        for j in np.flatnonzero(phi < -thr):  # value significantly reduced latency
            knob = space.knobs[j]
            region.values.setdefault(knob.name, []).append(
                (o.config.get(knob.name, knob.default_value()), float(v))
            )
    # ensure every knob key exists (possibly empty) so the drop rule sees it
    for knob in space.knobs:
        region.values.setdefault(knob.name, [])
    return region


def compress_space(
    space: ConfigSpace,
    regions: Sequence[PromisingRegion],
    alpha: float = 0.65,
    drop_threshold: float = 0.5,
    min_points_for_kde: int = 3,
    range_cache: Optional["OrderedDict"] = None,
) -> ConfigSpace:
    """§5.2: knob drop rule + KDE range compression -> new ConfigSpace.

    ``range_cache`` (an OrderedDict managed by :class:`SpaceCompressor`)
    memoizes the per-knob KDE fit + alpha-mass region keyed by the exact
    (knob, alpha, promising pairs) fingerprint: source-task regions are
    frozen and task weights are stable between weight refreshes, so
    successive compression calls mostly re-derive identical unions.
    """
    if not regions:
        return space
    total_w = sum(r.weight for r in regions)
    if total_w <= 0:
        return space

    keep: List[str] = []
    ranges: Dict[str, Intervals] = {}
    cat_subsets: Dict[str, Sequence[Any]] = {}

    for knob in space.knobs:
        empty_mass = sum(r.weight for r in regions if r.is_empty(knob.name)) / total_w
        if empty_mass > drop_threshold:
            continue  # knob not worth tuning (paper's drop rule)
        keep.append(knob.name)

        # P_j = union over tasks (Eq. union in §5.2)
        pairs: List[Tuple[Any, float]] = []
        for r in regions:
            pairs.extend(r.values.get(knob.name, []))
        if not pairs:
            continue
        vals = [p[0] for p in pairs]
        wts = [max(p[1], 1e-9) for p in pairs]

        key = None
        if range_cache is not None:
            key = (knob.name, float(alpha), tuple(vals), tuple(wts))
            hit = range_cache.get(key)
            if hit is not None:
                _obs.count("kde_cache/hits")
                range_cache.move_to_end(key)
                kind, payload = hit
                if kind == "range":
                    ranges[knob.name] = payload
                elif kind == "cats":
                    cat_subsets[knob.name] = payload
                continue  # "skip" payloads re-derive nothing
            _obs.count("kde_cache/misses")

        if isinstance(knob, (FloatKnob, IntKnob)):
            xs = np.asarray(vals, dtype=float)
            if len(xs) < min_points_for_kde or np.ptp(xs) == 0:
                entry = ("skip", None)  # too little signal; keep the full range
            else:
                kde = WeightedKDE(xs, np.asarray(wts))
                region = alpha_mass_region(kde, float(knob.lo), float(knob.hi), alpha)
                ranges[knob.name] = region
                entry = ("range", region)
        elif isinstance(knob, (CatKnob, BoolKnob)):
            kept = alpha_mass_categories(vals, wts, alpha)
            cat_subsets[knob.name] = kept
            entry = ("cats", kept)
        else:
            entry = ("skip", None)
        if range_cache is not None and key is not None:
            range_cache[key] = entry
            while len(range_cache) > _RANGE_CACHE_MAX:
                range_cache.popitem(last=False)

    return space.restrict(keep=keep, ranges=ranges, cat_subsets=cat_subsets)


_RANGE_CACHE_MAX = 512


class SpaceCompressor:
    """Stateful wrapper used by the controller: caches per-task regions.

    Regions for *source* tasks depend only on (task observations, weight);
    observations of historical tasks are frozen, so regions are cached and
    only re-scaled when weights change. The target task's own region is
    recomputed as its observation set grows. KDE fits / alpha-mass regions
    are additionally memoized across ``compress`` calls (see
    ``compress_space``'s ``range_cache``).
    """

    def __init__(
        self,
        space: ConfigSpace,
        alpha: float = 0.65,
        seed: int = 0,
        backend: str = "batched",
    ):
        self.space = space
        self.alpha = alpha
        self.seed = seed
        self.backend = backend
        self._cache: Dict[str, PromisingRegion] = {}
        self._range_cache: "OrderedDict" = OrderedDict()

    def _region(self, task: TaskRecord, weight: float, refresh: bool = False) -> Optional[PromisingRegion]:
        _obs.count(
            "region_cache/misses"
            if refresh or task.task_id not in self._cache
            else "region_cache/hits"
        )
        if refresh or task.task_id not in self._cache:
            # drop any stale entry *before* recomputing: if the recompute
            # returns None (e.g. the target briefly falls below 4 full-
            # fidelity observations) the old region must not survive to be
            # silently served by the next non-refresh call
            self._cache.pop(task.task_id, None)
            r = extract_promising_regions(
                self.space, task, 1.0, seed=self.seed, backend=self.backend
            )
            if r is None:
                return None
            self._cache[task.task_id] = r
        base = self._cache[task.task_id]
        # re-scale cached unit-weight region by the current task weight
        scaled = PromisingRegion(task_id=base.task_id, weight=weight, n_good=base.n_good,
                                 importance=dict(base.importance))
        for k, pairs in base.values.items():
            scaled.values[k] = [(v, w * weight) for v, w in pairs]
        return scaled

    def compress(
        self,
        weights: TaskWeights,
        tasks: Dict[str, TaskRecord],
        target: Optional[TaskRecord] = None,
    ) -> ConfigSpace:
        regions: List[PromisingRegion] = []
        for tid, w in weights.weights.items():
            if w <= 0:
                continue
            if tid == "__target__":
                if target is not None:
                    r = self._region(target, w, refresh=True)
                    if r:
                        regions.append(r)
            elif tid in tasks:
                r = self._region(tasks[tid], w)
                if r:
                    regions.append(r)
        if not regions:
            return self.space
        return compress_space(
            self.space, regions, alpha=self.alpha, range_cache=self._range_cache
        )
