"""HLO-text cost walker: FLOPs, memory traffic, and collective bytes.

Why not ``compiled.cost_analysis()``? XLA:CPU's HloCostAnalysis counts a
``while`` body ONCE, not multiplied by its trip count (verified empirically
— a 10-iteration scan reports exactly 1/10 of the FLOPs). Layer-scanned
models would be undercounted by ~n_layers. This walker parses the
optimized HLO text, attributes per-op costs, reads while-loop trip counts
from XLA's ``backend_config={"known_trip_count":{"n":...}}`` annotation
(with a condition-constant fallback), and multiplies nested-loop costs
through.

Parsing notes (calibrated against XLA:CPU 0.8 text dumps):
  * operand types are NOT printed at use sites; a per-computation symbol
    table (op name -> output type string) resolves operand byte sizes;
  * opcodes follow the (possibly tuple) result type annotation;
  * collectives carry ``replica_groups=[G,S]<=[...]`` (S = group size).

Costs:
  flops            2*M*N*K for dot ops; elementwise/reduce counted inside
                   fusion bodies; convolution approximated.
  bytes            memory traffic at fusion boundaries: output + operand
                   bytes of top-level ops (parameters/constants/GTE/tuple/
                   bitcast excluded).
  collective_bytes ring-model wire bytes per device: all-reduce
                   2(n-1)/n * size; all-gather/reduce-scatter/all-to-all
                   (n-1)/n * size; collective-permute full size.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCosts"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "tanh", "rsqrt", "sqrt", "logistic", "log", "negate",
    "compare", "select", "and", "or", "xor", "abs", "sign", "floor", "ceil",
    "round-nearest-afz", "clamp", "convert", "cosine", "sine", "expm1", "log1p",
}


@dataclass
class HloCosts:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: Dict[str, float] = field(default_factory=dict)
    n_while: int = 0
    trip_counts: Dict[str, int] = field(default_factory=dict)
    notes: List[str] = field(default_factory=list)

    def merge_scaled(self, other: "HloCosts", k: float) -> None:
        self.flops += k * other.flops
        self.bytes += k * other.bytes
        self.collective_bytes += k * other.collective_bytes
        for name, b in other.collectives.items():
            self.collectives[name] = self.collectives.get(name, 0.0) + k * b
        self.notes.extend(other.notes)


def _shapes_in(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt = m.group(1)
        if dt not in DTYPE_BYTES:
            continue
        dims = [int(x) for x in m.group(2).split(",") if x] if m.group(2) else []
        out.append((dt, dims))
    return out


def _nbytes_of(shapes: List[Tuple[str, List[int]]]) -> float:
    total = 0.0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES.get(dt, 4)
    return total


@dataclass
class _Op:
    name: str
    opcode: str
    out_types: str           # raw type text
    operands: List[str]      # operand op names
    line: str


_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")


def _parse_module(hlo: str) -> Tuple[Dict[str, List[_Op]], Optional[str]]:
    comps: Dict[str, List[_Op]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for raw in hlo.splitlines():
        ls = raw.strip()
        if cur is None:
            m = _HEADER_RE.match(ls)
            if m and ls.endswith("{") and "->" in ls:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
            continue
        if ls.startswith("}"):
            cur = None
            continue
        if " = " not in ls:
            continue
        lhs, rhs = ls.split(" = ", 1)
        name = lhs.strip().lstrip("%").rstrip()
        if name.startswith("ROOT "):
            name = name[5:].lstrip("%")
        if lhs.strip().startswith("ROOT"):
            name = lhs.strip()[4:].strip().lstrip("%")
        # skip the (possibly tuple) result type to find the opcode
        i = 0
        if rhs.startswith("("):
            depth = 0
            for j, ch in enumerate(rhs):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        i = j + 1
                        break
        m2 = re.match(r"\s*(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?\s*)?([a-z][\w\-]*)\s*\(", rhs[i:])
        if not m2:
            continue
        opcode = m2.group(1)
        type_text = rhs[: i + m2.start(1)]
        # operands: %names inside the first paren group after the opcode
        paren_start = i + m2.end(1)
        args_text = rhs[paren_start:]
        # cut at the matching close paren
        depth = 0
        end = len(args_text)
        for j, ch in enumerate(args_text):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = j
                    break
        operand_names = re.findall(r"%([\w\.\-]+)", args_text[:end])
        comps[cur].append(_Op(name, opcode, type_text, operand_names, ls))
    return comps, entry


_CALL_ATTRS = ("to_apply", "condition", "body", "calls")


def _attr_comp(line: str, attr: str) -> Optional[str]:
    m = re.search(rf"{attr}=%?([\w\.\-]+)", line)
    return m.group(1) if m else None


def _trip_count_from_config(line: str) -> Optional[int]:
    m = re.search(r'known_trip_count[^0-9]*"n"\s*:\s*"?(\d+)"?', line)
    return int(m.group(1)) if m else None


def _trip_count_from_cond(ops: List[_Op]) -> Optional[int]:
    consts = {}
    for op in ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", op.line)
            if m and re.search(r"[su]\d+\[\]", op.out_types + op.line.split("=")[1][:24]):
                consts[op.name] = int(m.group(1))
    for op in ops:
        if op.opcode == "compare" and "direction=LT" in op.line:
            for nm in op.operands:
                if nm in consts:
                    return consts[nm]
    return max(consts.values()) if consts else None


COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
               "after-all", "iota", "partition-id", "replica-id"}
# ops that touch only a slice-sized region of their big operand: counting the
# full operand would overcount loop bodies that dynamic-slice stacked scan
# buffers by ~trip-count x stack-size (HloCostAnalysis models these the same
# way: bytes ~ the transferred region, not the addressed buffer)
_SLICING = {"dynamic-slice", "slice", "gather"}
_UPDATING = {"dynamic-update-slice", "scatter", "scatter-add"}


def _dot_flops(op: _Op, defs: Dict[str, str]) -> float:
    outs = _shapes_in(op.out_types)
    if not outs:
        return 0.0
    out_elems = math.prod(outs[0][1]) if outs[0][1] else 1
    lhs_type = defs.get(op.operands[0], "") if op.operands else ""
    lhs_shapes = _shapes_in(lhs_type)
    if not lhs_shapes:
        return 2.0 * out_elems
    lhs_dims = lhs_shapes[0][1]
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    k = 1
    if m and m.group(1):
        for ci in m.group(1).split(","):
            ci = int(ci)
            if ci < len(lhs_dims):
                k *= lhs_dims[ci]
    return 2.0 * out_elems * k


def _region_bytes(c: _Op, body_defs: Dict[str, str]) -> float:
    """Traffic region of a slicing/updating op: slice output, or the update
    operand for dynamic-update-slice/scatter."""
    if c.opcode in _UPDATING and len(c.operands) > 1:
        upd = body_defs.get(c.operands[1], "")
        if upd:
            return _nbytes_of(_shapes_in(upd))
    return _nbytes_of(_shapes_in(c.out_types)) if c.opcode not in _UPDATING else 0.0


def _fusion_bytes(op: _Op, outer_defs: Dict[str, str], body: List[_Op]) -> float:
    """Effective traffic of a fusion: parameters consumed ONLY by slicing/
    updating ops contribute their region sizes (not the full — possibly
    scan-stacked — buffer); a dynamic-update-slice ROOT writes only its
    update region (the stack is updated in place)."""
    param_names: Dict[int, str] = {}
    body_defs = {b.name: b.out_types for b in body}
    for b in body:
        if b.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", b.line)
            if m:
                param_names[int(m.group(1))] = b.name
    consumers: Dict[str, List[_Op]] = {}
    for b in body:
        for nm in b.operands:
            consumers.setdefault(nm, []).append(b)
    # ---- operands
    total = 0.0
    for i, nm in enumerate(op.operands):
        t = outer_defs.get(nm)
        if not t:
            continue
        full = _nbytes_of(_shapes_in(t))
        pname = param_names.get(i)
        cons = consumers.get(pname, []) if pname else []
        if cons and all(c.opcode in _SLICING | _UPDATING for c in cons):
            region = sum(_region_bytes(c, body_defs) for c in cons)
            total += min(region, full)
        else:
            total += full
    # ---- output
    root = body[-1] if body else None
    out_full = _nbytes_of(_shapes_in(op.out_types))
    if root is not None and root.opcode in _UPDATING:
        total += min(_region_bytes(root, body_defs) or out_full, out_full)
    else:
        total += out_full
    return total


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return max(int(m.group(2)), 1)
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip() != ""]), 1)
    return 2


def analyze_hlo(hlo: str, trip_hint: Optional[int] = None) -> HloCosts:
    comps, entry = _parse_module(hlo)
    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else ""

    defs_cache: Dict[str, Dict[str, str]] = {}

    def defs_of(comp: str) -> Dict[str, str]:
        if comp not in defs_cache:
            defs_cache[comp] = {op.name: op.out_types for op in comps.get(comp, [])}
        return defs_cache[comp]

    fusion_flops_cache: Dict[str, float] = {}

    def fusion_flops(comp: str) -> float:
        if comp in fusion_flops_cache:
            return fusion_flops_cache[comp]
        fl = 0.0
        d = defs_of(comp)
        for op in comps.get(comp, ()):
            if op.opcode == "dot":
                fl += _dot_flops(op, d)
            elif op.opcode in _ELEMENTWISE:
                outs = _shapes_in(op.out_types)
                if outs:
                    fl += math.prod(outs[0][1]) if outs[0][1] else 1
            elif op.opcode == "reduce":
                in_t = defs_of(comp).get(op.operands[0], "") if op.operands else ""
                sh = _shapes_in(in_t)
                if sh:
                    fl += math.prod(sh[0][1]) if sh[0][1] else 1
            elif op.opcode == "fusion":
                c = _attr_comp(op.line, "calls")
                if c:
                    fl += fusion_flops(c)
        fusion_flops_cache[comp] = fl
        return fl

    def walk(comp: str, stack: Tuple[str, ...] = ()) -> HloCosts:
        cost = HloCosts()
        if comp in stack or comp not in comps:
            return cost
        d = defs_of(comp)
        for op in comps[comp]:
            if op.opcode == "while":
                body = _attr_comp(op.line, "body")
                cond = _attr_comp(op.line, "condition")
                trips = _trip_count_from_config(op.line)
                if trips is None and cond:
                    trips = _trip_count_from_cond(comps.get(cond, []))
                if trips is None:
                    trips = trip_hint or 1
                    cost.notes.append(f"while {op.name}: unknown trips, used {trips}")
                inner = walk(body, stack + (comp,)) if body else HloCosts()
                cost.merge_scaled(inner, trips)
                cost.n_while += 1 + inner.n_while
                cost.trip_counts[body or "?"] = trips
                continue
            if op.opcode in ("call", "conditional", "async-start"):
                for attr in _CALL_ATTRS:
                    c = _attr_comp(op.line, attr)
                    if c:
                        cost.merge_scaled(walk(c, stack + (comp,)), 1.0)
                continue

            outs = _shapes_in(op.out_types)
            if op.opcode in _SLICING:
                cost.bytes += 2.0 * _nbytes_of(outs)            # read region + write
            elif op.opcode in _UPDATING:
                # read-modify-write of the update region (operand 1)
                upd = d.get(op.operands[1], "") if len(op.operands) > 1 else ""
                cost.bytes += 3.0 * _nbytes_of(_shapes_in(upd)) if upd else _nbytes_of(outs)
            elif op.opcode == "fusion":
                c = _attr_comp(op.line, "calls")
                cost.bytes += _fusion_bytes(op, d, comps.get(c, []) if c else [])
            elif op.opcode not in _SKIP_BYTES:
                cost.bytes += _nbytes_of(outs)
                for nm in op.operands:
                    t = d.get(nm)
                    if t:
                        cost.bytes += _nbytes_of(_shapes_in(t))

            if op.opcode == "dot":
                cost.flops += _dot_flops(op, d)
            elif op.opcode == "fusion":
                c = _attr_comp(op.line, "calls")
                if c:
                    cost.flops += fusion_flops(c)
            elif op.opcode in _ELEMENTWISE:
                if outs:
                    cost.flops += math.prod(outs[0][1]) if outs[0][1] else 1
            elif op.opcode == "reduce":
                in_t = d.get(op.operands[0], "") if op.operands else ""
                sh = _shapes_in(in_t)
                if sh:
                    cost.flops += math.prod(sh[0][1]) if sh[0][1] else 1
            elif op.opcode == "convolution":
                if outs and len(op.operands) >= 2:
                    k_t = d.get(op.operands[1], "")
                    ksh = _shapes_in(k_t)
                    if ksh:
                        cost.flops += 2.0 * math.prod(outs[0][1] or [1]) * math.prod(ksh[0][1] or [1])

            for kind in COLLECTIVES:
                if op.opcode == kind or op.opcode.startswith(kind + "-start"):
                    size = _nbytes_of(outs)
                    n = _group_size(op.line)
                    if kind == "all-reduce":
                        wire = 2.0 * (n - 1) / n * size
                    elif kind == "collective-permute":
                        wire = size
                    else:
                        wire = (n - 1) / n * size
                    cost.collective_bytes += wire
                    cost.collectives[kind] = cost.collectives.get(kind, 0.0) + wire
                    break
        return cost

    return walk(entry)
