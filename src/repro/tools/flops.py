"""Analytic model FLOPs: the 6*N*D accounting for §Roofline's
MODEL_FLOPS / HLO_FLOPs usefulness ratio."""

from __future__ import annotations

from typing import Optional

from ..configs.base import ArchConfig, ShapeConfig

__all__ = ["model_flops"]


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """6 * N_active * tokens for training; 2 * N_active * tokens for
    forward-only (prefill); decode processes global_batch tokens."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence, but attention reads the whole cache —
    # param-FLOPs only here; cache reads are a *memory* term.
    tokens = shape.global_batch
    return 2.0 * n * tokens
