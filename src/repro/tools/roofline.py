"""Three-term TPU v5e roofline from compiled dry-run artifacts.

    compute    = HLO_FLOPs   / (chips * peak_FLOP/s)
    memory     = HLO_bytes   / (chips * HBM_bw)
    collective = coll_bytes  / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from the while-loop-corrected HLO walker
(tools/hlo_analysis — see its docstring for why cost_analysis() alone is
insufficient on this backend); collective bytes are summed over all-gather
/ all-reduce / reduce-scatter / all-to-all / collective-permute ops.

The walker sees the *per-device* SPMD program, so its totals are already
per-chip: terms divide by per-chip peaks only.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from .hlo_analysis import HloCosts

__all__ = ["V5E", "RooflineReport", "roofline_terms"]


@dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops: float     # bf16 FLOP/s
    hbm_bw: float         # bytes/s
    link_bw: float        # ICI bytes/s per link


V5E = ChipSpec("tpu-v5e", 197e12, 819e9, 50e9)


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device HLO totals
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collectives: Dict[str, float] = field(default_factory=dict)
    model_flops: float = 0.0           # analytic 6*N*D (global)
    raw_cost_analysis_flops: float = 0.0
    raw_cost_analysis_bytes: float = 0.0

    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic (perfect-overlap) step time = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (chips * HLO_FLOPs): compiled-compute usefulness."""
        tot = self.chips * self.hlo_flops
        return self.model_flops / tot if tot > 0 else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the modeled step
        time: (MODEL_FLOPS / step_time) / (chips * peak)."""
        if self.step_time_s <= 0:
            return 0.0
        achieved = self.model_flops / self.step_time_s
        return achieved / (self.chips * V5E.peak_flops)

    def to_json(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes, "collective_bytes": self.collective_bytes,
            "collectives": self.collectives, "model_flops": self.model_flops,
            "raw_cost_analysis_flops": self.raw_cost_analysis_flops,
            "raw_cost_analysis_bytes": self.raw_cost_analysis_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s, "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline_terms(
    arch: str, shape: str, mesh: str, chips: int,
    costs: HloCosts, model_fl: float,
    raw_flops: float = 0.0, raw_bytes: float = 0.0,
    chip: ChipSpec = V5E,
) -> RooflineReport:
    r = RooflineReport(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        hlo_flops=costs.flops, hlo_bytes=costs.bytes,
        collective_bytes=costs.collective_bytes,
        collectives=dict(costs.collectives),
        model_flops=model_fl,
        raw_cost_analysis_flops=raw_flops, raw_cost_analysis_bytes=raw_bytes,
    )
    r.compute_s = costs.flops / chip.peak_flops
    r.memory_s = costs.bytes / chip.hbm_bw
    r.collective_s = costs.collective_bytes / chip.link_bw
    return r
