from .hlo_analysis import analyze_hlo, HloCosts
from .roofline import roofline_terms, RooflineReport, V5E
from .flops import model_flops

__all__ = ["analyze_hlo", "HloCosts", "roofline_terms", "RooflineReport", "V5E", "model_flops"]
