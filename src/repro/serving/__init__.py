from .engine import ServingEngine, prefill_with_cache

__all__ = ["ServingEngine", "prefill_with_cache"]
