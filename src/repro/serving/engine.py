"""Batched serving: prefill + decode over a shared KV cache.

``ServingEngine`` drives a static-batch continuous loop: requests join a
slot, prefill fills their cache region token-by-token cheaply for smoke
scales (a production deployment lowers prefill as one sequence-level
program — exactly what the prefill_32k dry-run cells compile), and decode
steps advance every active slot together. Greedy or temperature sampling.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import Runtime, decode_step, forward, init_cache

__all__ = ["ServingEngine", "prefill_with_cache"]


def prefill_with_cache(params, cfg: ArchConfig, rt: Runtime, cache, tokens: jax.Array):
    """Sequential prefill through the decode path (fills the cache exactly
    as decode will read it). tokens: (B, S_prompt). Returns (logits_last,
    cache)."""
    B, S = tokens.shape

    def step(carry, t):
        cache, _ = carry
        logits, cache = decode_step(params, cfg, rt, cache, t[:, None])
        return (cache, logits), None

    (cache, logits), _ = jax.lax.scan(step, (cache, jnp.zeros((B, 1, cfg.vocab), rt.cdtype)),
                                      tokens.T)
    return logits, cache


@dataclass
class Request:
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    generated: List[int] = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, params, cfg: ArchConfig, rt: Runtime, batch_size: int = 4,
                 max_len: int = 256, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.rt = rt
        self.batch = batch_size
        self.max_len = max_len
        self.rng = np.random.default_rng(seed)
        self._decode = jax.jit(lambda p, c, t: decode_step(p, cfg, rt, c, t))

    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve a list of requests in static batches."""
        for start in range(0, len(requests), self.batch):
            group = requests[start:start + self.batch]
            self._serve_group(group)
        return requests

    def _serve_group(self, group: List[Request]) -> None:
        B = self.batch
        cache = init_cache(self.cfg, self.rt, B, self.max_len,
                           enc_len=self.max_len if self.cfg.family == "encdec" else 0)
        maxp = max(len(r.prompt) for r in group)
        toks = np.zeros((B, maxp), np.int32)
        for i, r in enumerate(group):
            toks[i, -len(r.prompt):] = r.prompt  # left-pad
        logits = None
        for t in range(maxp):
            logits, cache = self._decode(self.params, cache, jnp.asarray(toks[:, t:t + 1]))
        steps = max(r.max_new_tokens for r in group)
        cur = self._sample(logits, group)
        for _ in range(steps):
            for i, r in enumerate(group):
                if not r.done:
                    r.generated.append(int(cur[i]))
                    if len(r.generated) >= r.max_new_tokens:
                        r.done = True
            if all(r.done for r in group):
                break
            logits, cache = self._decode(self.params, cache, jnp.asarray(cur[:, None]))
            cur = self._sample(logits, group)

    def _sample(self, logits, group) -> np.ndarray:
        lg = np.asarray(logits[:, -1, :], np.float32)
        out = np.zeros(len(lg), np.int32)
        for i, r in enumerate(group[: len(lg)]):
            if r.temperature <= 0:
                out[i] = int(lg[i].argmax())
            else:
                p = np.exp((lg[i] - lg[i].max()) / r.temperature)
                p /= p.sum()
                out[i] = int(self.rng.choice(len(p), p=p))
        return out
