"""jaxwl: the framework's own distributed configuration as an MFTune
workload (the beyond-paper objective, DESIGN.md §5).

Queries = (arch x shape) cells. Latency of a query under a configuration =
the three-term v5e roofline step time of the cell's compiled HLO with that
runtime configuration. Evaluations lower+compile real programs (minutes on
one CPU core), so results are cached by (cell, canonical-config) — the C1
"prohibitively expensive evaluation" regime the paper targets, in genuine
form.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .. import obs
from ..core.space import BoolKnob, CatKnob, ConfigSpace, FloatKnob, IntKnob
from ..tuneapi import EvalResult, Workload

__all__ = ["CellWorkload", "runtime_space"]

Config = Dict[str, Any]


def runtime_space() -> ConfigSpace:
    """Tunable runtime knobs that change the compiled program."""
    return ConfigSpace([
        CatKnob("remat", ("none", "dots", "full"), default="full"),
        BoolKnob("seq_shard", default=True),
        BoolKnob("fsdp", default=True),
        CatKnob("attn_chunk", (512, 1024, 2048, 4096), default=1024),
        CatKnob("scan_unroll", (1, 2), default=1),
        FloatKnob("capacity_factor", 1.0, 2.0, default=1.25),
        CatKnob("opt_state_dtype", ("float32", "bfloat16"), default="float32"),
        BoolKnob("act_shard", default=True),
    ])


class CellWorkload(Workload):
    def __init__(
        self,
        cells: Sequence[Tuple[str, str]],
        multi_pod: bool = False,
        cache_path: str = ".cache/jaxwl_evals.json",
    ):
        self.cells = list(cells)
        self.multi_pod = multi_pod
        self._space = runtime_space()
        self.task_id = "jaxwl-" + "-".join(f"{a}.{s}" for a, s in self.cells)
        self.cache_path = cache_path
        self._cache: Dict[str, float] = {}
        if cache_path and os.path.exists(cache_path):
            with open(cache_path) as f:
                self._cache = json.load(f)

    @property
    def queries(self) -> List[str]:
        return [f"{a}:{s}" for a, s in self.cells]

    @property
    def space(self) -> ConfigSpace:
        return self._space

    # ------------------------------------------------------------------ eval
    @staticmethod
    def _canon(cfg: Config) -> str:
        return json.dumps({k: cfg[k] for k in sorted(cfg)}, default=str)

    def _key(self, cell: Tuple[str, str], cfg: Config) -> str:
        return f"{cell[0]}|{cell[1]}|{'mp' if self.multi_pod else 'sp'}|{self._canon(cfg)}"

    def _overrides(self, cfg: Config, shape_kind: str) -> Dict[str, Any]:
        ov = dict(cfg)
        # decode/prefill cells never remat and ignore seq_shard-for-carries
        if shape_kind != "train":
            ov["remat"] = "none"
            ov["seq_shard"] = False
        return ov

    def _eval_cell(self, cell: Tuple[str, str], cfg: Config) -> Optional[float]:
        key = self._key(cell, cfg)
        if key in self._cache:
            return self._cache[key]
        from ..configs import SHAPES
        from ..launch.dryrun import run_cell

        shape = SHAPES[cell[1]]
        try:
            r = run_cell(cell[0], cell[1], self.multi_pod, self._overrides(cfg, shape.kind))
        except Exception:
            self._cache[key] = -1.0
            self._persist()
            return None
        if r.get("status") != "ok":
            self._cache[key] = -1.0
            self._persist()
            return None
        t = float(r["roofline"]["step_time_s"])
        self._cache[key] = t
        self._persist()
        return t

    def _persist(self) -> None:
        if not self.cache_path:
            return
        os.makedirs(os.path.dirname(self.cache_path) or ".", exist_ok=True)
        with open(self.cache_path + ".tmp", "w") as f:
            json.dump(self._cache, f)
        os.replace(self.cache_path + ".tmp", self.cache_path)

    def evaluate(
        self,
        config: Config,
        query_indices: Optional[Sequence[int]] = None,
        cost_cap: Optional[float] = None,
        data_fraction: float = 1.0,
    ) -> EvalResult:
        cfg = dict(self._space.default(), **config)
        idx = list(query_indices) if query_indices is not None else range(len(self.cells))
        with obs.span("workload_eval", task=self.task_id, n=1, queries=len(idx)) as sp:
            lats: List[float] = []
            total = 0.0
            for qi in idx:
                t = self._eval_cell(self.cells[qi], cfg)
                if t is None or t < 0:
                    obs.count("workload/compile_error")
                    sp.set(failed=True, reason="compile_error")
                    return EvalResult(per_query_latency=lats + [float("inf")],
                                      per_query_cost=lats + [0.0], failed=True,
                                      failure_reason="compile_error")
                if cost_cap is not None and total + t > cost_cap:
                    obs.count("workload/early_stop")
                    sp.set(failed=True, reason="early_stop")
                    return EvalResult(per_query_latency=lats + [t],
                                      per_query_cost=lats + [max(cost_cap - total, 0.0)],
                                      failed=True, failure_reason="early_stop")
                lats.append(t)
                total += t
            obs.count("workload/ok")
            sp.set(failed=False, reason="ok")
            return EvalResult(per_query_latency=lats, per_query_cost=list(lats))

    def evaluate_many(
        self,
        configs: Sequence[Config],
        query_indices: Optional[Sequence[int]] = None,
        cost_cap=None,
        data_fraction: float = 1.0,
    ) -> List[EvalResult]:
        """Batched evaluation for compiled cells.

        A rung batch reduces to one compile per unique (config, cap) pair:
        duplicates share the first pair's EvalResult outright, and distinct
        configs go through the scalar path, whose (cell, canonical-config)
        cache memoizes the compile itself.
        """
        caps = self._per_config_caps(cost_cap, len(configs))
        memo: Dict[Tuple[str, Optional[float]], EvalResult] = {}
        out: List[EvalResult] = []
        for cfg, cap in zip(configs, caps):
            key = (self._canon(dict(self._space.default(), **cfg)), cap)
            if key not in memo:
                memo[key] = self.evaluate(
                    cfg, query_indices=query_indices, cost_cap=cap,
                    data_fraction=data_fraction,
                )
            else:
                obs.count("workload/batch_dedup")
            out.append(memo[key])
        return out

    def meta_features(self) -> Optional[List[float]]:
        return None
