from .workload import CellWorkload, runtime_space

__all__ = ["CellWorkload", "runtime_space"]
