"""Tracing core: nested spans over a lock-free event buffer.

Design constraints (see docs/OBSERVABILITY.md):

* **Disabled path is one branch.** Every module-level helper
  (:func:`span`, :func:`instant`, :func:`count`, :func:`gauge`,
  :func:`observe`) checks a single module global; when no tracer is
  installed they return a shared no-op singleton / fall through without
  allocating. The tuner loop is instrumented unconditionally and pays
  ~a dict-miss-free branch per call when tracing is off.
* **Lock-free buffer.** Events are appended to a plain list by the
  emitting thread — ``list.append`` is atomic under the GIL, so a
  single-process multi-threaded run needs no lock. Span *stacks* are
  per-thread (keyed by ``threading.get_ident()``) so nesting resolves
  correctly if workload evaluation ever fans out to threads.
* **No RNG, no semantics.** Instrumentation never touches random state
  or alters control flow: trajectories are bit-identical tracer-on vs
  tracer-off at a fixed seed (pinned in ``tests/test_obs.py``).

Event vocabulary (validated against ``trace_schema.json``):

``span``      closed span: name, ts, dur (seconds from tracer epoch),
              id, parent (-1 = top level), tid, args
``instant``   point event: name, ts, tid, args
``counter`` / ``gauge`` / ``histogram``
              metric snapshots emitted by :meth:`Tracer.emit_metrics`
``meta``      one per trace: epoch timestamps + tracer name
"""

from __future__ import annotations

import itertools
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

from .metrics import Metrics

__all__ = [
    "Span", "Tracer", "get_tracer", "set_tracer", "tracing",
    "span", "instant", "count", "gauge", "observe",
]


class Span:
    """A span in flight. Use as a context manager; ``set(**attrs)``
    attaches result attributes discovered mid-span (cost, cache hit...)."""

    __slots__ = ("_tr", "name", "args", "id", "parent", "tid", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self._tr = tracer
        self.name = name
        self.args = args
        self.id = next(tracer._ids)
        self.parent = -1
        self.tid = 0
        self._t0 = 0.0

    def set(self, **attrs: Any) -> "Span":
        self.args.update(attrs)
        return self

    def __enter__(self) -> "Span":
        tr = self._tr
        tid = threading.get_ident()
        self.tid = tid
        stack = tr._stacks.get(tid)
        if stack is None:
            stack = tr._stacks[tid] = []
        if stack:
            self.parent = stack[-1]
        stack.append(self.id)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        t1 = time.perf_counter()
        tr = self._tr
        stack = tr._stacks.get(self.tid)
        if stack and stack[-1] == self.id:
            stack.pop()
        elif stack and self.id in stack:  # mis-nested exit: unwind to self
            del stack[stack.index(self.id):]
        tr._emit({
            "type": "span",
            "name": self.name,
            "ts": self._t0 - tr.epoch,
            "dur": t1 - self._t0,
            "id": self.id,
            "parent": self.parent,
            "tid": self.tid,
            "args": self.args,
        })


class _NoopSpan:
    """Shared do-nothing span returned when tracing is disabled."""

    __slots__ = ()
    id = -1
    parent = -1

    def set(self, **attrs: Any) -> "_NoopSpan":
        return self

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        return None


NOOP_SPAN = _NoopSpan()


class Tracer:
    """Collects events for one run. Cheap enough to leave on in tests;
    bounded by ``max_events`` (drops and counts overflow, never grows
    unboundedly in a service loop)."""

    def __init__(self, name: str = "run", metrics: Optional[Metrics] = None,
                 max_events: int = 1_000_000):
        self.name = name
        self.epoch = time.perf_counter()
        self.wall_epoch = time.time()
        self.events: List[Dict[str, Any]] = []
        self.metrics = metrics if metrics is not None else Metrics()
        self.max_events = max_events
        self.dropped = 0
        self._ids = itertools.count(1)
        self._stacks: Dict[int, List[int]] = {}

    # ----------------------------------------------------------------- emit
    def now(self) -> float:
        return time.perf_counter() - self.epoch

    def _emit(self, ev: Dict[str, Any]) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def span(self, name: str, **args: Any) -> Span:
        return Span(self, name, args)

    def instant(self, name: str, **args: Any) -> None:
        self._emit({
            "type": "instant",
            "name": name,
            "ts": self.now(),
            "tid": threading.get_ident(),
            "args": args,
        })

    def current_span_id(self) -> int:
        stack = self._stacks.get(threading.get_ident())
        return stack[-1] if stack else -1

    def emit_metrics(self, metrics: Optional[Metrics] = None,
                     scope: str = "global") -> None:
        """Append one event per metric in ``metrics`` (default: the
        tracer's own registry). ``scope`` distinguishes per-run registries
        from the module-global one in a multi-session export."""
        m = metrics if metrics is not None else self.metrics
        ts = self.now()
        snap = m.snapshot()
        for k, v in snap["counters"].items():
            self._emit({"type": "counter", "name": k, "ts": ts,
                        "scope": scope, "value": v})
        for k, v in snap["gauges"].items():
            self._emit({"type": "gauge", "name": k, "ts": ts,
                        "scope": scope, "value": v})
        for k, h in snap["histograms"].items():
            self._emit({"type": "histogram", "name": k, "ts": ts,
                        "scope": scope, **h})


# --------------------------------------------------------------------------
# Module-level tracer: the one-branch disabled path.
# --------------------------------------------------------------------------

_TRACER: Optional[Tracer] = None


def get_tracer() -> Optional[Tracer]:
    return _TRACER


def set_tracer(tracer: Optional[Tracer]) -> Optional[Tracer]:
    """Install ``tracer`` as the process-global tracer; returns the
    previous one so callers can restore it."""
    global _TRACER
    prev = _TRACER
    _TRACER = tracer
    return prev


@contextmanager
def tracing(tracer: Optional[Tracer] = None, name: str = "run"):
    """``with tracing() as tr: ...`` — install a tracer for the block."""
    tr = tracer if tracer is not None else Tracer(name)
    prev = set_tracer(tr)
    try:
        yield tr
    finally:
        set_tracer(prev)


def span(name: str, **args: Any):
    """Open a span on the global tracer, or a shared no-op when disabled."""
    tr = _TRACER
    if tr is None:
        return NOOP_SPAN
    return Span(tr, name, args)


def instant(name: str, **args: Any) -> None:
    tr = _TRACER
    if tr is None:
        return
    tr.instant(name, **args)


def count(name: str, n: float = 1.0) -> None:
    tr = _TRACER
    if tr is None:
        return
    tr.metrics.counter(name).add(n)


def gauge(name: str, v: float) -> None:
    tr = _TRACER
    if tr is None:
        return
    tr.metrics.gauge(name).set(v)


def observe(name: str, v: float) -> None:
    tr = _TRACER
    if tr is None:
        return
    tr.metrics.histogram(name).observe(v)
