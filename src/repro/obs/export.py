"""Trace exporters: JSONL event log + Chrome/Perfetto ``trace_event`` JSON.

The JSONL format is the source of truth (one event dict per line, the
exact schema in ``trace_schema.json``). The Perfetto export is a lossless
re-encoding of the same events into the Chrome trace_event format so a
run opens directly in https://ui.perfetto.dev — :func:`read_events`
round-trips either file back to the canonical event list.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional

import numpy as np

from .trace import Tracer

__all__ = [
    "trace_events", "export_jsonl", "export_perfetto", "read_events",
    "load_schema", "validate_events", "SCHEMA_PATH",
]

SCHEMA_PATH = os.path.join(os.path.dirname(__file__), "trace_schema.json")


def _json_default(o: Any) -> Any:
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    if isinstance(o, (set, frozenset, tuple)):
        return list(o)
    return str(o)


def _normalize(ev: Dict[str, Any]) -> Dict[str, Any]:
    """Round-trip through json to coerce numpy scalars etc. to plain types."""
    return json.loads(json.dumps(ev, default=_json_default))


def trace_events(tracer: Tracer) -> List[Dict[str, Any]]:
    """The canonical event list for a tracer: one ``meta`` header, the
    buffered span/instant events, then the tracer's own (global-scope)
    metric snapshots."""
    meta = {
        "type": "meta",
        "name": tracer.name,
        "epoch": tracer.epoch,
        "wall_epoch": tracer.wall_epoch,
        "dropped": tracer.dropped,
    }
    n0 = len(tracer.events)
    tracer.emit_metrics(tracer.metrics, scope="global")
    metric_evs = tracer.events[n0:]
    del tracer.events[n0:]  # keep the buffer re-exportable
    return [_normalize(e) for e in [meta] + tracer.events + metric_evs]


def export_jsonl(tracer: Tracer, path: str) -> List[Dict[str, Any]]:
    events = trace_events(tracer)
    with open(path, "w") as f:
        for ev in events:
            f.write(json.dumps(ev) + "\n")
    return events


# --------------------------------------------------------------------------
# Chrome/Perfetto trace_event encoding
# --------------------------------------------------------------------------

def _tid_map(events: List[Dict[str, Any]]) -> Dict[int, int]:
    """Remap raw thread idents to small stable ints for display."""
    out: Dict[int, int] = {}
    for ev in events:
        tid = ev.get("tid")
        if tid is not None and tid not in out:
            out[tid] = len(out)
    return out


def export_perfetto(tracer: Tracer, path: str) -> Dict[str, Any]:
    """Write ``{"traceEvents": [...]}`` JSON openable in ui.perfetto.dev.

    Encoding (lossless — ``read_events`` inverts it):
      span      -> "X" complete event, ts/dur in microseconds; the event
                   id/parent ride along inside ``args``.
      instant   -> "i" instant event (scope "t").
      counter/gauge -> "C" counter samples.
      histogram -> "i" instant carrying the full snapshot in args.
      meta      -> a process_name "M" metadata record + one instant
                   ("trace_meta") holding the epoch timestamps.
    """
    events = trace_events(tracer)
    tids = _tid_map(events)
    pid = 1
    out: List[Dict[str, Any]] = [
        {"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
         "args": {"name": f"repro.obs:{tracer.name}"}},
    ]
    for raw, small in tids.items():
        out.append({"ph": "M", "pid": pid, "tid": small, "name": "thread_name",
                    "args": {"name": f"thread-{small}"}})
    for ev in events:
        t = ev["type"]
        if t == "meta":
            out.append({"ph": "i", "pid": pid, "tid": 0, "ts": 0.0, "s": "p",
                        "name": "trace_meta",
                        "args": {k: ev[k] for k in ev if k != "type"}})
        elif t == "span":
            out.append({
                "ph": "X", "pid": pid, "tid": tids.get(ev["tid"], 0),
                "name": ev["name"],
                "ts": ev["ts"] * 1e6, "dur": ev["dur"] * 1e6,
                "args": dict(ev["args"], id=ev["id"], parent=ev["parent"]),
            })
        elif t == "instant":
            out.append({
                "ph": "i", "pid": pid, "tid": tids.get(ev["tid"], 0),
                "s": "t", "name": ev["name"], "ts": ev["ts"] * 1e6,
                "args": ev["args"],
            })
        elif t in ("counter", "gauge"):
            out.append({
                "ph": "C", "pid": pid, "tid": 0, "name": ev["name"],
                "ts": ev["ts"] * 1e6,
                "args": {"value": ev["value"], "scope": ev["scope"],
                         "kind": t},
            })
        elif t == "histogram":
            out.append({
                "ph": "i", "pid": pid, "tid": 0, "s": "p",
                "name": f"histogram:{ev['name']}", "ts": ev["ts"] * 1e6,
                "args": {k: ev[k] for k in ev if k != "type"},
            })
    doc = {"traceEvents": out, "displayTimeUnit": "ms"}
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def read_events(path: str) -> List[Dict[str, Any]]:
    """Load a trace file back into canonical events. Auto-detects format:
    a JSON object with ``traceEvents`` is decoded from the Perfetto
    encoding; anything else is treated as JSONL."""
    with open(path) as f:
        text = f.read()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        doc = None  # multiple lines -> JSONL
    if isinstance(doc, dict) and "traceEvents" in doc:
        return _decode_perfetto(doc)
    if isinstance(doc, dict):
        return [doc]  # single-event JSONL
    return [json.loads(line) for line in text.splitlines() if line.strip()]


def _decode_perfetto(doc: Dict[str, Any]) -> List[Dict[str, Any]]:
    events: List[Dict[str, Any]] = []
    for ev in doc.get("traceEvents", []):
        ph = ev.get("ph")
        name = ev.get("name", "")
        if ph == "M":
            continue
        if ph == "i" and name == "trace_meta":
            events.append(dict({"type": "meta"}, **ev["args"]))
        elif ph == "X":
            args = dict(ev["args"])
            eid = args.pop("id")
            parent = args.pop("parent")
            events.append({
                "type": "span", "name": name,
                "ts": ev["ts"] / 1e6, "dur": ev["dur"] / 1e6,
                "id": eid, "parent": parent, "tid": ev["tid"], "args": args,
            })
        elif ph == "C":
            args = ev["args"]
            events.append({
                "type": args.get("kind", "counter"), "name": name,
                "ts": ev["ts"] / 1e6, "scope": args.get("scope", "global"),
                "value": args["value"],
            })
        elif ph == "i" and name.startswith("histogram:"):
            rest = dict(ev["args"])
            events.append(dict({"type": "histogram"}, **rest))
        elif ph == "i":
            events.append({
                "type": "instant", "name": name, "ts": ev["ts"] / 1e6,
                "tid": ev["tid"], "args": ev.get("args", {}),
            })
    return events


# --------------------------------------------------------------------------
# Schema validation (minimal subset validator — no external deps)
# --------------------------------------------------------------------------

def load_schema(path: str = SCHEMA_PATH) -> Dict[str, Any]:
    with open(path) as f:
        return json.load(f)


_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "number": (int, float),
    "integer": int,
    "boolean": bool,
}


def _check(value: Any, spec: Dict[str, Any], where: str,
           errors: List[str]) -> None:
    t = spec.get("type")
    if t is not None:
        py = _TYPES[t]
        ok = isinstance(value, py)
        if t in ("number", "integer") and isinstance(value, bool):
            ok = False
        if not ok:
            errors.append(f"{where}: expected {t}, got {type(value).__name__}")
            return
    if "enum" in spec and value not in spec["enum"]:
        errors.append(f"{where}: {value!r} not in {spec['enum']}")
    if "minimum" in spec and isinstance(value, (int, float)) \
            and not isinstance(value, bool) and value < spec["minimum"]:
        errors.append(f"{where}: {value} < minimum {spec['minimum']}")
    if t == "object":
        for req in spec.get("required", []):
            if req not in value:
                errors.append(f"{where}: missing required key {req!r}")
        props = spec.get("properties", {})
        for k, v in value.items():
            if k in props:
                _check(v, props[k], f"{where}.{k}", errors)
            elif spec.get("additionalProperties") is False:
                errors.append(f"{where}: unexpected key {k!r}")
    elif t == "array" and "items" in spec:
        for i, item in enumerate(value):
            _check(item, spec["items"], f"{where}[{i}]", errors)


def validate_events(events: List[Dict[str, Any]],
                    schema: Optional[Dict[str, Any]] = None) -> List[str]:
    """Validate events against the checked-in schema; returns a list of
    human-readable violations (empty = valid)."""
    if schema is None:
        schema = load_schema()
    kinds = schema["eventTypes"]
    errors: List[str] = []
    for i, raw in enumerate(events):
        ev = _normalize(raw)
        t = ev.get("type")
        if t not in kinds:
            errors.append(f"event[{i}]: unknown type {t!r}")
            continue
        _check(ev, kinds[t], f"event[{i}]({t})", errors)
    return errors
