"""Text summaries over canonical trace events (``scripts/inspect_run.py``).

Works from the event list alone (JSONL or Perfetto file via
``export.read_events``) — no live tracer needed, so perf regressions can
be diagnosed from committed artifacts.
"""

from __future__ import annotations

from collections import defaultdict
from typing import Any, Dict, List

__all__ = ["summarize"]


def _fmt_s(v: float) -> str:
    if v >= 1.0:
        return f"{v:9.3f}s"
    return f"{v * 1e3:8.2f}ms"


def _stage_breakdown(spans: List[Dict[str, Any]], lines: List[str]) -> None:
    agg: Dict[str, List[float]] = defaultdict(list)
    # top-level spans only: children are counted inside their parents
    for sp in spans:
        if sp["parent"] == -1:
            agg[sp["name"]].append(sp["dur"])
    if not agg:
        return
    total = sum(sum(v) for v in agg.values())
    lines.append("stage time breakdown (top-level spans):")
    lines.append(f"  {'stage':<24}{'count':>7}{'total':>12}{'mean':>12}{'share':>8}")
    for name, durs in sorted(agg.items(), key=lambda kv: -sum(kv[1])):
        t = sum(durs)
        share = 100.0 * t / total if total else 0.0
        lines.append(f"  {name:<24}{len(durs):>7}{_fmt_s(t):>12}"
                     f"{_fmt_s(t / len(durs)):>12}{share:>7.1f}%")
    lines.append("")


def _cache_rates(counters: Dict[str, float], lines: List[str]) -> None:
    groups: Dict[str, Dict[str, float]] = defaultdict(dict)
    for name, v in counters.items():
        if name.endswith(("/hits", "/misses")):
            prefix, _, leaf = name.rpartition("/")
            groups[prefix][leaf] = v
    rows = []
    for prefix, g in sorted(groups.items()):
        hits = g.get("hits", 0.0)
        misses = g.get("misses", 0.0)
        total = hits + misses
        if total:
            rows.append((prefix, hits, misses, 100.0 * hits / total))
    if not rows:
        return
    lines.append("cache hit rates:")
    lines.append(f"  {'cache':<28}{'hits':>10}{'misses':>10}{'rate':>8}")
    for prefix, hits, misses, rate in rows:
        lines.append(f"  {prefix:<28}{int(hits):>10}{int(misses):>10}{rate:>7.1f}%")
    lines.append("")


def _rung_funnel(spans: List[Dict[str, Any]], lines: List[str]) -> None:
    rungs = [sp for sp in spans if sp["name"] == "rung_eval"]
    if not rungs:
        return
    lines.append("rung survival funnel:")
    lines.append(f"  {'bracket':>8}{'rung':>6}{'delta':>8}{'n':>6}{'ok':>6}"
                 f"{'promoted':>10}{'cost':>12}")
    for sp in rungs:
        a = sp["args"]
        cost = a.get("cost", 0.0)
        lines.append(
            f"  {a.get('s', '?'):>8}{a.get('rung', '?'):>6}"
            f"{a.get('delta', 0.0):>8.3f}{a.get('n', 0):>6}"
            f"{a.get('ok', 0):>6}{a.get('survivors', 0):>10}"
            f"{cost:>11.1f}s")
    lines.append("")


def _budget_attribution(counters: Dict[str, float], lines: List[str]) -> None:
    full = counters.get("budget/full_fidelity_s", 0.0)
    low = counters.get("budget/low_fidelity_s", 0.0)
    per = {name[len("budget/fidelity@"):-2]: v
           for name, v in counters.items()
           if name.startswith("budget/fidelity@") and name.endswith("_s")}
    if not (full or low or per):
        return
    total = full + low
    lines.append("budget attribution (virtual seconds charged):")
    if total:
        lines.append(f"  full fidelity : {full:>12.1f}s ({100.0 * full / total:5.1f}%)")
        lines.append(f"  low fidelity  : {low:>12.1f}s ({100.0 * low / total:5.1f}%)")
    for d, v in sorted(per.items(), key=lambda kv: float(kv[0])):
        lines.append(f"    delta={d:<8}: {v:>12.1f}s")
    lines.append("")


def _eval_outcomes(counters: Dict[str, float], lines: List[str]) -> None:
    rows = [(name, v) for name, v in sorted(counters.items())
            if name.startswith(("workload/", "eval/"))
            and not name.endswith("_s")]
    if not rows:
        return
    lines.append("evaluation outcomes:")
    for name, v in rows:
        lines.append(f"  {name:<32}{int(v) if float(v).is_integer() else v:>10}")
    lines.append("")


def _histograms(hists: List[Dict[str, Any]], lines: List[str]) -> None:
    shown = [h for h in hists if h.get("n", 0) > 0]
    if not shown:
        return
    lines.append("histograms:")
    for h in shown:
        mean = h["total"] / h["n"]
        lines.append(f"  {h['name']:<28} n={h['n']:<7} mean={mean:<12.4g}"
                     f" min={h['min']:<12.4g} max={h['max']:.4g}")
    lines.append("")


def summarize(events: List[Dict[str, Any]]) -> str:
    """Render a text report: stage breakdown, cache hit rates, rung
    funnel, budget attribution, evaluation outcomes, histogram digests."""
    spans = [e for e in events if e["type"] == "span"]
    metas = [e for e in events if e["type"] == "meta"]
    # last snapshot wins per (scope, name); global scope preferred for the
    # roll-ups, per-run scopes listed separately below.
    counters: Dict[str, float] = {}
    scoped: Dict[str, Dict[str, float]] = defaultdict(dict)
    hists: List[Dict[str, Any]] = []
    for e in events:
        if e["type"] in ("counter", "gauge"):
            if e.get("scope", "global") == "global":
                counters[e["name"]] = e["value"]
            else:
                scoped[e["scope"]][e["name"]] = e["value"]
        elif e["type"] == "histogram":
            hists.append(e)
    # fold per-run scopes into the roll-up where a name is absent globally
    merged: Dict[str, float] = defaultdict(float)
    for scope_vals in scoped.values():
        for name, v in scope_vals.items():
            merged[name] += v
    for name, v in merged.items():
        counters.setdefault(name, v)

    lines: List[str] = []
    if metas:
        m = metas[0]
        lines.append(f"trace: {m.get('name', '?')}  "
                     f"(events={len(events)}, spans={len(spans)}, "
                     f"dropped={m.get('dropped', 0)})")
        lines.append("")
    _stage_breakdown(spans, lines)
    _cache_rates(counters, lines)
    _rung_funnel(spans, lines)
    _budget_attribution(counters, lines)
    _eval_outcomes(counters, lines)
    _histograms(hists, lines)
    if scoped:
        lines.append(f"scopes: {', '.join(sorted(scoped))}")
    return "\n".join(lines).rstrip() + "\n"
