"""repro.obs — unified tracing & metrics plane for the tuner loop.

Hot-path API (one-branch no-ops when no tracer is installed):

    from repro import obs

    with obs.span("surrogate_fit", rung=r) as sp:
        ...
        sp.set(n_trees=len(trees))
    obs.count("surrogate_store/hits")
    obs.observe("eval/elapsed_s", dt)

Enable tracing for a block and export:

    with obs.tracing(name="tpch-run") as tr:
        result = MFTune(wl, kb, opts).run(budget)
    obs.export_perfetto(tr, "run.perfetto.json")   # ui.perfetto.dev
    obs.export_jsonl(tr, "run.trace.jsonl")

See docs/OBSERVABILITY.md for the span/metric vocabulary.
"""

from .metrics import Counter, Gauge, Histogram, Metrics
from .trace import (
    Span, Tracer, get_tracer, set_tracer, tracing,
    span, instant, count, gauge, observe,
)
from .export import (
    trace_events, export_jsonl, export_perfetto, read_events,
    load_schema, validate_events, SCHEMA_PATH,
)
from .report import summarize

__all__ = [
    "Counter", "Gauge", "Histogram", "Metrics",
    "Span", "Tracer", "get_tracer", "set_tracer", "tracing",
    "span", "instant", "count", "gauge", "observe",
    "trace_events", "export_jsonl", "export_perfetto", "read_events",
    "load_schema", "validate_events", "SCHEMA_PATH",
    "summarize",
]
