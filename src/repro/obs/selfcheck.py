"""End-to-end trace self-check: ``python -m repro.obs.selfcheck``.

Runs one small tracing-enabled ``MFTune.tune()`` against the warm-history
TPC-H recipe (the same one the tier-1 identity tests pin), exports the
trace in both formats, and asserts the acceptance properties of the
observability plane:

  * every event validates against ``trace_schema.json``;
  * the span stream covers every tuner stage: pool generation, surrogate
    fit/eval, propose, rung evaluation (MFO must activate), compression,
    and workload evaluation;
  * the Perfetto export is plain JSON (``json.load`` round-trips) and
    decodes back to schema-valid canonical events;
  * the run summary renders.

Exit code 0 = all checks passed. Used by scripts/check.sh as the
trace-schema validation gate.
"""

from __future__ import annotations

import json
import os
import sys
import tempfile

REQUIRED_SPANS = {
    "pool_gen",
    "surrogate_fit",
    "surrogate_eval",
    "bo_recommend",
    "rung_eval",
    "space_compression",
    "workload_eval",
    "evaluate",
    "iteration",
}


def traced_run():
    """One warm-history MFTune run under a fresh tracer."""
    from .. import obs
    from ..core import MFTune, MFTuneOptions
    from ..core.knowledge import KnowledgeBase
    from ..sparksim import SparkWorkload, TaskSpec, generate_history
    from ..tuneapi import Budget

    kb = KnowledgeBase()
    kb.add_task(
        generate_history(
            TaskSpec("tpch", 100, "A").workload(), n_obs=12, n_init=5, seed=3
        ),
        persist=False,
    )
    wl = SparkWorkload("tpch", 100, "A")
    tracer = obs.Tracer("selfcheck")
    with obs.tracing(tracer):
        res = MFTune(wl, kb, MFTuneOptions(seed=0)).tune(Budget(8 * 3600.0))
    return res, tracer


def main(argv=None) -> int:
    from .. import obs

    res, tracer = traced_run()
    events = obs.trace_events(tracer)
    failures = []

    violations = obs.validate_events(events)
    if violations:
        failures.append(f"schema: {len(violations)} violations, e.g. {violations[:3]}")

    seen = {e["name"] for e in events if e["type"] == "span"}
    missing = REQUIRED_SPANS - seen
    if missing:
        failures.append(f"span coverage: missing {sorted(missing)}")

    if not any(e["type"] == "counter" for e in events):
        failures.append("no counter events exported")
    if res.overheads != res.metrics["counters"] and not res.overheads:
        failures.append("TuningResult.overheads view is empty")

    with tempfile.TemporaryDirectory() as td:
        pf = os.path.join(td, "trace.json")
        jl = os.path.join(td, "trace.jsonl")
        obs.export_perfetto(tracer, pf)
        obs.export_jsonl(tracer, jl)
        with open(pf) as f:
            doc = json.load(f)  # must be plain JSON for ui.perfetto.dev
        if "traceEvents" not in doc:
            failures.append("perfetto export lacks traceEvents")
        for path in (pf, jl):
            back = obs.read_events(path)
            v = obs.validate_events(back)
            if v:
                failures.append(f"{os.path.basename(path)} round-trip: {v[:3]}")
        if len(obs.read_events(pf)) != len(obs.read_events(jl)):
            failures.append("perfetto and jsonl round-trips disagree on event count")

    print(obs.summarize(events))
    print()
    n_spans = sum(e["type"] == "span" for e in events)
    print(f"selfcheck: {len(events)} events, {n_spans} spans, "
          f"{len(seen)} distinct span names, {len(violations)} schema violations")
    if failures:
        for f in failures:
            print("FAIL:", f)
        return 1
    print("selfcheck: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
