"""Typed metrics registry for the tuner loop.

Three metric kinds, all allocation-free on the hot path:

``Counter``
    A monotonically-growing float sum (``add``). Stage overheads, budget
    attribution and cache hit/miss tallies are counters.
``Gauge``
    A last-value-wins float (``set``). Pool-bucket occupancy, cache sizes.
``Histogram``
    Fixed *log-spaced* bin edges chosen at creation, so recording a value
    is one ``np.searchsorted`` + one integer increment — no rebinning, no
    per-observation allocation. Bin ``i`` (``1 <= i <= len(edges) - 1``)
    covers the half-open range ``[edges[i-1], edges[i])``; index ``0`` is
    the underflow bin (``v < edges[0]``) and index ``len(edges)`` the
    overflow bin (``v >= edges[-1]``).

The registry is the single sink for what used to be ad-hoc side channels
(``TuningResult.overheads`` / ``surrogate_cache`` / ``plane_cache``):
controllers record into a :class:`Metrics` instance and the legacy result
fields are materialized as *views* over it (:meth:`Metrics.counters_view`),
preserving their exact key/value shapes.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "Metrics"]

# default histogram geometry: 12 decades (1e-6 .. 1e6), 4 bins per decade
HIST_LO = 1e-6
HIST_HI = 1e6
HIST_BINS = 48


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def add(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed log-spaced bins; one ``searchsorted`` per observation."""

    __slots__ = ("name", "edges", "counts", "n", "total", "vmin", "vmax")

    def __init__(self, name: str, lo: float = HIST_LO, hi: float = HIST_HI,
                 bins: int = HIST_BINS):
        if not (lo > 0 and hi > lo and bins >= 1):
            raise ValueError(f"bad histogram geometry lo={lo} hi={hi} bins={bins}")
        self.name = name
        self.edges = np.logspace(np.log10(lo), np.log10(hi), bins + 1)
        self.counts = np.zeros(bins + 2, dtype=np.int64)  # +under/overflow
        self.n = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")

    def observe(self, v: float) -> None:
        self.counts[int(np.searchsorted(self.edges, v, side="right"))] += 1
        self.n += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def snapshot(self) -> Dict[str, Any]:
        return {
            "edges": [float(e) for e in self.edges],
            "counts": [int(c) for c in self.counts],
            "n": int(self.n),
            "total": float(self.total),
            "min": float(self.vmin) if self.n else 0.0,
            "max": float(self.vmax) if self.n else 0.0,
        }


class Metrics:
    """Name-keyed registry of counters / gauges / histograms."""

    __slots__ = ("_counters", "_gauges", "_hists")

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}

    # ------------------------------------------------------------- accessors
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str, lo: float = HIST_LO, hi: float = HIST_HI,
                  bins: int = HIST_BINS) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram(name, lo, hi, bins)
        return h

    # ----------------------------------------------------------------- views
    def set_counter(self, name: str, value: float) -> None:
        self.counter(name).value = float(value)

    def absorb_counters(self, prefix: str, values: Dict[str, float]) -> None:
        """Install externally-tracked tallies (e.g. a cache's hit/miss
        counters) under ``prefix`` so exports see one vocabulary."""
        for k, v in values.items():
            self.set_counter(prefix + k, v)

    def counters_view(self, prefix: str, coerce_int: bool = True) -> Dict[str, Any]:
        """Legacy-dict view of the counters under ``prefix``: keys lose the
        prefix; with ``coerce_int`` integral values come back as ints (the
        historical shapes of ``TuningResult.surrogate_cache`` /
        ``plane_cache``; ``overheads`` keeps floats)."""
        out: Dict[str, Any] = {}
        for name, c in self._counters.items():
            if name.startswith(prefix):
                v = c.value
                if coerce_int and float(v).is_integer():
                    v = int(v)
                out[name[len(prefix):]] = v
        return out

    def names(self) -> List[str]:
        return list(self._counters) + list(self._gauges) + list(self._hists)

    def snapshot(self) -> Dict[str, Any]:
        return {
            "counters": {k: c.value for k, c in self._counters.items()},
            "gauges": {k: g.value for k, g in self._gauges.items()},
            "histograms": {k: h.snapshot() for k, h in self._hists.items()},
        }
