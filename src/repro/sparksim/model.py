"""Analytic Spark SQL cost model.

Latency of a query = scan + compute + shuffle + scheduling terms, with
memory-pressure (spill), GC, and OOM-failure mechanics, under a hardware
scenario (nodes x cores x RAM, Table 2 of the paper). The model is built
so the paper's *phenomena* hold structurally:

- heterogeneous per-query sensitivities (scan- vs shuffle- vs compute- vs
  memory-bound) => representative query subsets exist (SQL Selection works);
- profiles drift along the query index => prefix subsets are biased
  (Early Stop decorrelates);
- bottlenecks bind only at scale (spill/OOM/network saturation vanish on
  small data; small data underutilizes the cluster) => reducing data volume
  reshuffles config rankings (Data Volume decorrelates, Fig. 1b);
- the resource-sizing optimum moves with hardware and scale, but smoothly
  => historical tasks transfer (Figs. 3-4);
- oversized executor heaps pay superlinear GC; undersized ones spill then
  OOM => the spark.executor.memory discussion in §1.

All stochasticity is multiplicative lognormal noise seeded per
(task, config, query): repeated evaluation of a config is deterministic.
The lognormal draw is derived from a blake2b hash of the cell identity via
Box-Muller (no per-cell ``np.random.Generator`` construction), so the same
formula evaluates one cell or a whole (configs x queries) grid.

Two evaluation paths share the model:

- ``evaluate``        — the reference scalar path: queries walked in order,
  one ``query_latency`` call per query.
- ``evaluate_batch``  — the vectorized engine: per-query profile arrays are
  precomputed at construction, per-config scalars are extracted once per
  config, and the full (configs x queries) latency grid is produced with
  NumPy broadcasting. Early-stop / OOM masking is applied per config after
  the grid, reproducing ``evaluate``'s sequential semantics (latencies,
  costs, failure flags and early-stop charging) bit-for-bit.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

__all__ = ["HardwareScenario", "QueryProfile", "SparkCostModel", "SCENARIOS"]

Config = Dict[str, Any]


@dataclass(frozen=True)
class HardwareScenario:
    name: str
    nodes: int
    cores: int   # per node
    ram_gb: int  # per node


# Table 2 of the paper
SCENARIOS: Dict[str, HardwareScenario] = {
    "A": HardwareScenario("A", 3, 64, 256),
    "B": HardwareScenario("B", 3, 32, 128),
    "C": HardwareScenario("C", 3, 32, 256),
    "D": HardwareScenario("D", 3, 64, 128),
    "E": HardwareScenario("E", 2, 64, 256),
    "F": HardwareScenario("F", 2, 32, 128),
    "G": HardwareScenario("G", 2, 32, 256),
    "H": HardwareScenario("H", 2, 64, 128),
}


@dataclass
class QueryProfile:
    name: str
    scan_frac: float          # fraction of the dataset this query scans
    shuffle_frac: float       # shuffle bytes as a fraction of scanned bytes
    cpu_per_gb: float         # CPU-seconds per scanned GB (per slot)
    mem_per_gb: float         # working-set GB per shuffled GB per task unit
    skew: float               # >= 1; max-partition inflation
    small_table_mb: float     # size of broadcastable dim table (0 = none)
    broadcast_benefit: float  # shuffle reduction when broadcast fires (0..0.9)
    parallelism_ceiling: int  # max useful concurrent tasks
    oom_resilience: float     # spill ratio beyond which the query OOMs
    gc_sensitivity: float     # how much long-heap GC hurts this query


def _stable_u32(*parts: str) -> int:
    h = hashlib.blake2b("|".join(parts).encode(), digest_size=4)
    return int.from_bytes(h.digest(), "little")


def make_query_profiles(benchmark: str, n_queries: int, seed: int = 1234) -> List[QueryProfile]:
    """Benchmark-level profiles: identical across tasks of the benchmark."""
    rng = np.random.default_rng(_stable_u32(benchmark, str(seed)))
    profiles = []
    for i in range(n_queries):
        t = i / max(n_queries - 1, 1)  # index drift: later queries more shuffle/memory bound
        # each query touches a slice of the dataset; the whole workload scans
        # ~6x the dataset regardless of how many queries it is split into
        scan_frac = float(np.clip(rng.lognormal(np.log(6.0 / n_queries), 0.7), 0.01, 1.0))
        shuffle_frac = float(np.clip(rng.beta(1.6, 4.0) * (0.5 + 1.1 * t) * 1.6, 0.01, 1.8))
        cpu_per_gb = float(np.clip(rng.lognormal(np.log(2.2), 0.5) * (1.3 - 0.5 * t), 0.3, 10.0))
        mem_per_gb = float(np.clip(rng.lognormal(np.log(1.0), 0.45) * (0.6 + 0.9 * t), 0.15, 4.0))
        skew = float(1.0 + rng.beta(1.2, 4.0) * 5.0 * (0.4 + 0.6 * t))
        has_bjoin = rng.random() < 0.55
        small_table_mb = float(rng.uniform(4, 320)) if has_bjoin else 0.0
        broadcast_benefit = float(rng.uniform(0.25, 0.75)) if has_bjoin else 0.0
        parallelism_ceiling = int(rng.integers(48, 384))
        oom_resilience = float(rng.uniform(2.0, 5.0))
        gc_sensitivity = float(rng.uniform(0.3, 1.6))
        profiles.append(
            QueryProfile(
                name=f"q{i + 1}",
                scan_frac=scan_frac,
                shuffle_frac=shuffle_frac,
                cpu_per_gb=cpu_per_gb,
                mem_per_gb=mem_per_gb,
                skew=skew,
                small_table_mb=small_table_mb,
                broadcast_benefit=broadcast_benefit,
                parallelism_ceiling=parallelism_ceiling,
                oom_resilience=oom_resilience,
                gc_sensitivity=gc_sensitivity,
            )
        )
    return profiles


# machine constants (per-slot / per-node bandwidths, seconds). Calibrated so
# that a tuned TPC-H/600GB run takes ~1.5h and a poor one tens of hours —
# matching the paper's ~29 full evaluations per 48h budget (§1, Fig. 1a).
IO_BW_PER_SLOT = 0.045       # GB/s effective scan bandwidth per task slot
NET_BW_PER_NODE = 0.30       # GB/s shuffle network bandwidth per node
PROC_BW_PER_SLOT = 0.10      # GB/s shuffle processing bandwidth per slot
TASK_OVERHEAD = 0.04         # s scheduling overhead per task
TIMEOUT_FACTOR = 4.0         # failed queries charge 4x their nominal latency

CODEC = {  # (compression ratio, cpu overhead factor)
    "lz4": (0.55, 1.00),
    "snappy": (0.60, 0.97),
    "zstd": (0.38, 1.12),
}


class SparkCostModel:
    def __init__(
        self,
        benchmark: str,
        data_gb: float,
        hardware: HardwareScenario,
        seed: int = 1234,
        noise: float = 0.03,
    ):
        self.benchmark = benchmark
        self.data_gb = float(data_gb)
        self.hw = hardware
        self.seed = seed
        self.noise = noise
        n_queries = {"tpch": 22, "tpcds": 99}[benchmark]
        self.profiles = make_query_profiles(benchmark, n_queries, seed=seed)
        # per-query profile arrays, precomputed once for the batched engine
        self._q = {
            name: np.array([getattr(p, name) for p in self.profiles])
            for name in (
                "scan_frac", "shuffle_frac", "cpu_per_gb", "mem_per_gb", "skew",
                "small_table_mb", "broadcast_benefit", "oom_resilience",
                "gc_sensitivity",
            )
        }
        self._q["parallelism_ceiling"] = np.array(
            [p.parallelism_ceiling for p in self.profiles], dtype=np.int64
        )

    # ------------------------------------------------------------ resources
    def _executors(self, cfg: Config) -> Tuple[int, int, float]:
        """Return (executors, slots, task_mem_gb). Spark sizing semantics:
        the cluster caps how many executors actually launch."""
        hw = self.hw
        cores = int(cfg["spark.executor.cores"])
        mem = float(cfg["spark.executor.memory"])
        overhead_gb = float(cfg["spark.executor.memoryOverhead"]) / 1024.0
        per_node_by_cores = hw.cores // max(cores, 1)
        per_node_by_mem = int((hw.ram_gb * 0.92) // max(mem + overhead_gb, 0.5))
        launched = min(
            int(cfg["spark.executor.instances"]),
            max(per_node_by_cores, 0) * hw.nodes,
            max(per_node_by_mem, 0) * hw.nodes,
        )
        launched = max(launched, 1)
        slots = launched * cores
        # unified memory: (heap - 300MB) * fraction, split across concurrent tasks
        frac = float(cfg["spark.memory.fraction"])
        storage = float(cfg["spark.memory.storageFraction"])
        usable = max(mem - 0.3, 0.2) * frac * (1.0 - 0.5 * storage)
        offheap_gb = (
            float(cfg["spark.memory.offHeap.size"]) / 1024.0
            if cfg.get("spark.memory.offHeap.enabled")
            else 0.0
        )
        task_mem = (usable + 0.7 * offheap_gb) / max(cores, 1)
        return launched, slots, task_mem

    # ---------------------------------------------------------- query model
    def query_latency(
        self, cfg: Config, q: QueryProfile, data_fraction: float = 1.0
    ) -> Tuple[float, bool, Dict[str, float]]:
        """Return (latency_s, failed, latency breakdown)."""
        hw = self.hw
        E, slots, task_mem = self._executors(cfg)
        data_gb = self.data_gb * float(np.clip(data_fraction, 1e-3, 1.0))
        scan_gb = q.scan_frac * data_gb

        eff_slots = max(min(slots, q.parallelism_ceiling * hw.nodes), 1)

        # ---- scan: wave quantization from maxPartitionBytes
        mpb_gb = float(cfg["spark.sql.files.maxPartitionBytes"]) / 1024.0
        map_tasks = max(int(np.ceil(scan_gb / max(mpb_gb, 1e-3))), 1)
        waves = np.ceil(map_tasks / eff_slots)
        util = map_tasks / (waves * eff_slots)  # <=1; poor when few big tasks
        codec_ratio, codec_cpu = CODEC[cfg["spark.io.compression.codec"]]
        scan_time = (
            scan_gb / (IO_BW_PER_SLOT * eff_slots * max(util, 1e-3)) * codec_cpu
            + map_tasks * TASK_OVERHEAD / max(slots, 1)
        )

        # ---- compute
        ser_factor = 0.86 if cfg["spark.serializer"] == "kryo" else 1.0
        if cfg["spark.serializer"] == "kryo" and float(cfg["spark.kryoserializer.buffer.max"]) < 16:
            ser_factor *= 1.06  # undersized kryo buffer causes re-serialization
        codegen = 0.93 if cfg.get("spark.sql.codegen.wholeStage", True) else 1.0
        gc_factor = 1.0 + 0.05 * q.gc_sensitivity * (float(cfg["spark.executor.memory"]) / 12.0) ** 1.4
        compute_time = q.cpu_per_gb * scan_gb / eff_slots * ser_factor * codegen * gc_factor

        # ---- shuffle
        shuffle_gb = q.shuffle_frac * scan_gb
        bcast_thresh = float(cfg["spark.sql.autoBroadcastJoinThreshold"])
        if q.small_table_mb > 0 and bcast_thresh >= q.small_table_mb:
            shuffle_gb *= 1.0 - q.broadcast_benefit
        p = float(cfg["spark.sql.shuffle.partitions"])
        aqe = bool(cfg["spark.sql.adaptive.enabled"])
        if aqe and cfg["spark.sql.adaptive.coalescePartitions.enabled"]:
            # AQE coalesce pulls the effective partition count toward a
            # data-derived target (128MB per partition)
            p_target = max(shuffle_gb / 0.125, eff_slots)
            p = np.clip(p, p_target * 0.75, None) if p > p_target else 0.5 * (p + p_target)
        skew = q.skew
        if aqe and cfg["spark.sql.adaptive.skewJoin.enabled"]:
            skew = 1.0 + (skew - 1.0) * 0.35
        comp_on = bool(cfg["spark.shuffle.compress"])
        wire_gb = shuffle_gb * (codec_ratio if comp_on else 1.0)
        comp_cpu = codec_cpu if comp_on else 1.0
        net_time = 2.0 * wire_gb / (NET_BW_PER_NODE * hw.nodes)
        per_part_gb = shuffle_gb * skew / max(p, 1.0)
        reduce_waves = np.ceil(p / eff_slots)
        fetch_eff = 1.0 + 0.04 * np.log2(48.0 / np.clip(float(cfg["spark.reducer.maxSizeInFlight"]), 8, 256))
        buf_eff = 1.0 + 0.03 * np.log2(64.0 / np.clip(float(cfg["spark.shuffle.file.buffer"]), 16, 1024))
        proc_time = (
            reduce_waves * per_part_gb / PROC_BW_PER_SLOT * comp_cpu * max(fetch_eff, 0.9) * max(buf_eff, 0.9)
        )
        sched_time = p * TASK_OVERHEAD / max(slots, 1)

        # ---- memory pressure: spill & OOM
        working_gb = per_part_gb * q.mem_per_gb
        spill_ratio = working_gb / max(task_mem, 1e-3)
        failed = bool(spill_ratio > q.oom_resilience)
        spill_mult = 1.0
        if spill_ratio > 1.0:
            spill_comp = 0.85 if cfg.get("spark.shuffle.spill.compress", True) else 1.0
            spill_mult = 1.0 + 0.9 * spill_comp * (spill_ratio - 1.0)
        shuffle_time = (net_time + proc_time) * spill_mult + sched_time

        # ---- straggler/scheduling extras
        tail = 1.0 + 0.06 * (skew - 1.0)
        if cfg["spark.speculation"]:
            tail = 1.0 + (tail - 1.0) * 0.55  # speculation clips the tail
        loc_wait = float(cfg["spark.locality.wait"])
        tail += 0.004 * loc_wait * (waves + reduce_waves)

        latency = (scan_time + compute_time + shuffle_time) * tail

        # ---- long-tail knobs: tiny deterministic per-(knob,value) wiggle
        latency *= self._minor_knob_factor(cfg)

        breakdown = {
            "scan": float(scan_time),
            "compute": float(compute_time),
            "shuffle": float(shuffle_time),
            "spill_ratio": float(spill_ratio),
            "slots": float(slots),
            "executors": float(E),
        }
        if failed:
            return TIMEOUT_FACTOR * latency, True, breakdown
        return float(latency), False, breakdown

    def _minor_knob_factor(self, cfg: Config) -> float:
        """Sub-percent deterministic effects for the long-tail knobs."""
        f = 1.0
        for name in (
            "spark.rpc.askTimeout",
            "spark.network.timeout",
            "spark.storage.memoryMapThreshold",
            "spark.task.maxFailures",
            "spark.cleaner.periodicGC.interval",
            "spark.sql.codegen.maxFields",
            "spark.sql.statistics.histogram.numBins",
        ):
            u = _stable_u32(name, repr(cfg.get(name))) / 2**32
            f *= 1.0 + (u - 0.5) * 0.004
        return f

    # ------------------------------------------------------------- noise
    def _cell_seeds(self, cfg_key: str, query_indices: Sequence[int]) -> np.ndarray:
        """64-bit hash per (config, query) cell, one prefix hash per config."""
        prefix = hashlib.blake2b(digest_size=8)
        prefix.update(
            "|".join([self.benchmark, str(self.data_gb), self.hw.name, cfg_key, ""]).encode()
        )
        seeds = np.empty(len(query_indices), dtype=np.uint64)
        for i, qi in enumerate(query_indices):
            h = prefix.copy()
            h.update(str(qi).encode())
            seeds[i] = int.from_bytes(h.digest(), "little")
        return seeds

    def _lognormal_from_seeds(self, seeds: np.ndarray) -> np.ndarray:
        """Multiplicative lognormal noise from 64-bit seeds via Box-Muller."""
        hi = (seeds >> np.uint64(32)).astype(np.float64)
        lo = (seeds & np.uint64(0xFFFFFFFF)).astype(np.float64)
        u1 = (hi + 0.5) / 2**32
        u2 = (lo + 0.5) / 2**32
        z = np.sqrt(-2.0 * np.log(u1)) * np.cos(2.0 * np.pi * u2)
        return np.exp(self.noise * z)

    def _noise(self, cfg_key: str, qi: int) -> float:
        return float(self._lognormal_from_seeds(self._cell_seeds(cfg_key, [qi]))[0])

    def evaluate(
        self,
        cfg: Config,
        query_indices: Optional[List[int]] = None,
        data_fraction: float = 1.0,
        cost_cap: Optional[float] = None,
    ) -> Tuple[List[float], List[float], bool, str]:
        """Run queries in order. Returns (latencies, costs, failed, reason)."""
        idx = list(query_indices) if query_indices is not None else list(range(len(self.profiles)))
        cfg_key = self._cfg_key(cfg)
        lats: List[float] = []
        costs: List[float] = []
        total = 0.0
        for qi in idx:
            lat, failed, _ = self.query_latency(cfg, self.profiles[qi], data_fraction)
            lat *= self._noise(cfg_key, qi)
            if cost_cap is not None and total + lat > cost_cap:
                # §6.3 median early stop: abort, charge only up to the cap
                costs.append(max(cost_cap - total, 0.0))
                lats.append(lat)
                return lats, costs, True, "early_stop"
            lats.append(lat)
            costs.append(lat)
            total += lat
            if failed:
                return lats, costs, True, "oom"
        return lats, costs, False, ""

    @staticmethod
    def _cfg_key(cfg: Config) -> str:
        return repr(sorted((k, repr(v)) for k, v in cfg.items()))

    # ----------------------------------------------------- batched evaluation
    def _config_scalars(self, cfg: Config) -> Dict[str, float]:
        """Per-config constants of the latency model (everything that does
        not depend on the query), with the same expressions as
        ``query_latency`` so the batched grid matches it bit-for-bit."""
        E, slots, task_mem = self._executors(cfg)
        codec_ratio, codec_cpu = CODEC[cfg["spark.io.compression.codec"]]
        ser_factor = 0.86 if cfg["spark.serializer"] == "kryo" else 1.0
        if cfg["spark.serializer"] == "kryo" and float(cfg["spark.kryoserializer.buffer.max"]) < 16:
            ser_factor *= 1.06
        codegen = 0.93 if cfg.get("spark.sql.codegen.wholeStage", True) else 1.0
        aqe = bool(cfg["spark.sql.adaptive.enabled"])
        comp_on = bool(cfg["spark.shuffle.compress"])
        fetch_eff = 1.0 + 0.04 * np.log2(48.0 / np.clip(float(cfg["spark.reducer.maxSizeInFlight"]), 8, 256))
        buf_eff = 1.0 + 0.03 * np.log2(64.0 / np.clip(float(cfg["spark.shuffle.file.buffer"]), 16, 1024))
        return {
            "slots_i": slots,
            "task_mem_floor": max(task_mem, 1e-3),
            "mpb_gb_floor": max(float(cfg["spark.sql.files.maxPartitionBytes"]) / 1024.0, 1e-3),
            "codec_cpu": codec_cpu,
            "ser_factor": ser_factor,
            "codegen": codegen,
            "gc_pow": (float(cfg["spark.executor.memory"]) / 12.0) ** 1.4,
            "bcast_thresh": float(cfg["spark.sql.autoBroadcastJoinThreshold"]),
            "p0": float(cfg["spark.sql.shuffle.partitions"]),
            "aqe_coalesce": float(aqe and cfg["spark.sql.adaptive.coalescePartitions.enabled"]),
            "aqe_skew": float(aqe and cfg["spark.sql.adaptive.skewJoin.enabled"]),
            "wire_factor": codec_ratio if comp_on else 1.0,
            "comp_cpu": codec_cpu if comp_on else 1.0,
            "fetch_eff": max(fetch_eff, 0.9),
            "buf_eff": max(buf_eff, 0.9),
            "spill_gain": 0.9 * (0.85 if cfg.get("spark.shuffle.spill.compress", True) else 1.0),
            "speculation": float(bool(cfg["spark.speculation"])),
            "loc_wait": float(cfg["spark.locality.wait"]),
            "minor": self._minor_knob_factor(cfg),
        }

    def _latency_grid(
        self, cfgs: Sequence[Config], idx: List[int], data_fraction: float
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Noisy latency grid for (configs x queries).

        Returns ``(lat, failed)`` of shape (C, Q): ``lat`` already includes
        the OOM timeout factor and the deterministic per-cell noise, exactly
        as the scalar ``evaluate`` path computes per cell.
        """
        hw = self.hw
        C, Q = len(cfgs), len(idx)
        sc = {k: np.empty(C) for k in (
            "task_mem_floor", "mpb_gb_floor", "codec_cpu", "ser_factor",
            "codegen", "gc_pow", "bcast_thresh", "p0", "aqe_coalesce", "aqe_skew",
            "wire_factor", "comp_cpu", "fetch_eff", "buf_eff", "spill_gain",
            "speculation", "loc_wait", "minor",
        )}
        slots_i = np.empty(C, dtype=np.int64)
        seeds = np.empty((C, Q), dtype=np.uint64)
        for ci, cfg in enumerate(cfgs):
            s = self._config_scalars(cfg)
            for k in sc:
                sc[k][ci] = s[k]
            slots_i[ci] = s["slots_i"]
            seeds[ci] = self._cell_seeds(self._cfg_key(cfg), idx)

        def col(name):  # (C, 1) view of a per-config scalar
            return sc[name][:, None]

        q = {k: v[idx] for k, v in self._q.items()}
        data_gb = self.data_gb * float(np.clip(data_fraction, 1e-3, 1.0))
        scan_gb = q["scan_frac"] * data_gb                               # (Q,)
        slots = slots_i[:, None]                                         # (C, 1)
        eff_slots = np.maximum(
            np.minimum(slots, q["parallelism_ceiling"][None, :] * hw.nodes), 1
        )                                                                # (C, Q)

        # ---- scan (operation order mirrors query_latency exactly)
        map_tasks = np.maximum(np.ceil(scan_gb[None, :] / col("mpb_gb_floor")), 1.0)
        waves = np.ceil(map_tasks / eff_slots)
        util = map_tasks / (waves * eff_slots)
        scan_time = (
            scan_gb[None, :] / (IO_BW_PER_SLOT * eff_slots * np.maximum(util, 1e-3)) * col("codec_cpu")
            + map_tasks * TASK_OVERHEAD / np.maximum(slots, 1)
        )

        # ---- compute
        gc_factor = 1.0 + (0.05 * q["gc_sensitivity"])[None, :] * col("gc_pow")
        compute_time = (
            (q["cpu_per_gb"] * scan_gb)[None, :] / eff_slots
            * col("ser_factor") * col("codegen") * gc_factor
        )

        # ---- shuffle
        shuffle_gb = np.broadcast_to((q["shuffle_frac"] * scan_gb)[None, :], (C, Q))
        bcast = (q["small_table_mb"][None, :] > 0) & (col("bcast_thresh") >= q["small_table_mb"][None, :])
        shuffle_gb = np.where(bcast, shuffle_gb * (1.0 - q["broadcast_benefit"])[None, :], shuffle_gb)
        p = np.broadcast_to(col("p0"), (C, Q))
        p_target = np.maximum(shuffle_gb / 0.125, eff_slots)
        p_coalesced = np.where(p > p_target, p, 0.5 * (p + p_target))
        p = np.where(col("aqe_coalesce") > 0, p_coalesced, p)
        skew = np.broadcast_to(q["skew"][None, :], (C, Q))
        skew = np.where(col("aqe_skew") > 0, 1.0 + (skew - 1.0) * 0.35, skew)
        wire_gb = shuffle_gb * col("wire_factor")
        net_time = 2.0 * wire_gb / (NET_BW_PER_NODE * hw.nodes)
        per_part_gb = shuffle_gb * skew / np.maximum(p, 1.0)
        reduce_waves = np.ceil(p / eff_slots)
        proc_time = (
            reduce_waves * per_part_gb / PROC_BW_PER_SLOT * col("comp_cpu")
            * col("fetch_eff") * col("buf_eff")
        )
        sched_time = p * TASK_OVERHEAD / np.maximum(slots, 1)

        # ---- memory pressure: spill & OOM
        working_gb = per_part_gb * q["mem_per_gb"][None, :]
        spill_ratio = working_gb / col("task_mem_floor")
        failed = spill_ratio > q["oom_resilience"][None, :]
        spill_mult = np.where(
            spill_ratio > 1.0, 1.0 + col("spill_gain") * (spill_ratio - 1.0), 1.0
        )
        shuffle_time = (net_time + proc_time) * spill_mult + sched_time

        # ---- straggler/scheduling extras
        tail = 1.0 + 0.06 * (skew - 1.0)
        tail = np.where(col("speculation") > 0, 1.0 + (tail - 1.0) * 0.55, tail)
        tail = tail + 0.004 * col("loc_wait") * (waves + reduce_waves)

        latency = (scan_time + compute_time + shuffle_time) * tail
        latency = latency * col("minor")
        latency = np.where(failed, TIMEOUT_FACTOR * latency, latency)
        latency = latency * self._lognormal_from_seeds(seeds)
        return latency, failed

    def evaluate_batch(
        self,
        cfgs: Sequence[Config],
        query_indices: Optional[List[int]] = None,
        data_fraction: float = 1.0,
        cost_cap: Union[None, float, Sequence[Optional[float]]] = None,
    ) -> List[Tuple[List[float], List[float], bool, str]]:
        """Vectorized ``evaluate`` over many configs at once.

        Computes the full (configs x queries) latency grid with one
        broadcasted NumPy pass, then applies per-config sequential masking
        (cost-cap early stop, OOM abort) so each returned tuple matches
        ``evaluate(cfg, ...)`` bit-for-bit. ``cost_cap`` may be a scalar
        (same cap for every config) or a per-config sequence.
        """
        idx = list(query_indices) if query_indices is not None else list(range(len(self.profiles)))
        caps: List[Optional[float]]
        if cost_cap is None or np.isscalar(cost_cap):
            caps = [cost_cap] * len(cfgs)  # type: ignore[list-item]
        else:
            caps = list(cost_cap)
            if len(caps) != len(cfgs):
                raise ValueError(f"{len(caps)} cost caps for {len(cfgs)} configs")
        lat, failed = self._latency_grid(cfgs, idx, data_fraction)
        out: List[Tuple[List[float], List[float], bool, str]] = []
        n = len(idx)
        for ci in range(len(cfgs)):
            row = lat[ci]
            # cumulative cost *before* each query, via the same sequential
            # additions the scalar loop performs (np.cumsum accumulates
            # left-to-right, so the partial sums are bitwise identical)
            before = np.concatenate(([0.0], np.cumsum(row)[:-1]))
            cap = caps[ci]
            j_es = n
            if cap is not None:
                hits = np.nonzero(before + row > cap)[0]
                if hits.size:
                    j_es = int(hits[0])
            ooms = np.nonzero(failed[ci])[0]
            j_oom = int(ooms[0]) if ooms.size else n
            if j_es <= j_oom and j_es < n:
                lats = [float(x) for x in row[: j_es + 1]]
                costs = [float(x) for x in row[:j_es]] + [max(float(cap) - float(before[j_es]), 0.0)]
                out.append((lats, costs, True, "early_stop"))
            elif j_oom < n:
                lats = [float(x) for x in row[: j_oom + 1]]
                out.append((lats, list(lats), True, "oom"))
            else:
                lats = [float(x) for x in row]
                out.append((lats, list(lats), False, ""))
        return out
