"""The 32-task grid and historical-data generation (paper §7.1).

Tasks = {tpch, tpcds} x {100, 600} GB x hardware scenarios A..H. Histories
are produced by running vanilla Bayesian optimization (PRF surrogate + EI,
LHS init — exactly the paper's historical-data protocol) for 50
observations per task, storing full per-query latency/cost vectors so that
fidelity partitioning has the data it needs. Generation is cached on disk
through the KnowledgeBase JSON format.
"""

from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.acquisition import ei_scores
from ..core.knowledge import KnowledgeBase, Observation, TaskRecord
from ..core.surrogate import make_forest
from .knobs import spark_space
from .workload import SparkWorkload, make_task_id

__all__ = ["TaskSpec", "all_task_specs", "generate_history", "build_knowledge_base"]


@dataclass(frozen=True)
class TaskSpec:
    benchmark: str
    data_gb: int
    hardware: str

    @property
    def task_id(self) -> str:
        return make_task_id(self.benchmark, self.data_gb, self.hardware)

    def workload(self, seed: int = 1234) -> SparkWorkload:
        return SparkWorkload(self.benchmark, self.data_gb, self.hardware, seed=seed)


def all_task_specs() -> List[TaskSpec]:
    specs = []
    for bench in ("tpch", "tpcds"):
        for gb in (100, 600):
            for hw in "ABCDEFGH":
                specs.append(TaskSpec(bench, gb, hw))
    return specs


def generate_history(
    wl: SparkWorkload, n_obs: int = 50, n_init: int = 8, seed: int = 0
) -> TaskRecord:
    """Vanilla BO (PRF + EI) history with per-query vectors."""
    rng = np.random.default_rng(seed)
    space = wl.space
    rec = TaskRecord(
        task_id=wl.task_id,
        queries=list(wl.queries),
        meta_features=wl.meta_features(),
        descriptor={"benchmark": wl.benchmark, "data_gb": wl.data_gb, "hardware": wl.hardware},
    )
    clock = 0.0

    def run(cfg) -> None:
        nonlocal clock
        res = wl.evaluate(cfg)
        clock += res.elapsed
        rec.observations.append(
            Observation(
                config=dict(cfg),
                performance=res.aggregate if not res.failed else float("inf"),
                fidelity=1.0,
                per_query_perf=list(res.per_query_latency) if not res.failed else None,
                per_query_cost=list(res.per_query_cost) if not res.failed else None,
                failed=res.failed,
                elapsed=res.elapsed,
                time=clock,
            )
        )

    for cfg in space.lhs_sample(rng, n_init):
        run(cfg)
    while len(rec.observations) < n_obs:
        ok = [o for o in rec.observations if not o.failed]
        if len(ok) >= 2:
            X = space.encode_many([o.config for o in ok])
            y = np.array([o.performance for o in ok])
            model = make_forest(seed=seed).fit(X, y)
            # columnar pool: sampled, encoded and scored without dicts;
            # only the EI winner materializes for evaluation
            pool = space.sample(rng, 192)
            scores = ei_scores(model, pool.unit(), float(y.min()))
            cfg = pool[int(np.argmax(scores))]
        else:
            cfg = space.sample(rng, 1)[0]
        run(cfg)
    return rec


def build_knowledge_base(
    root: Optional[str] = None,
    specs: Optional[Sequence[TaskSpec]] = None,
    n_obs: int = 50,
    seed: int = 0,
    verbose: bool = False,
) -> KnowledgeBase:
    """Load-or-generate histories for the task grid; cached under ``root``."""
    kb = KnowledgeBase(root)
    specs = list(specs) if specs is not None else all_task_specs()
    for i, spec in enumerate(specs):
        if spec.task_id in kb.tasks and len(kb.get(spec.task_id).observations) >= n_obs:
            continue
        if verbose:
            print(f"[sparksim] generating history {spec.task_id} ({i + 1}/{len(specs)})", flush=True)
        rec = generate_history(spec.workload(), n_obs=n_obs, seed=seed + i)
        kb.add_task(rec, persist=root is not None)
    return kb
