from .knobs import spark_space, INFLUENTIAL_KNOBS
from .model import HardwareScenario, QueryProfile, SparkCostModel, SCENARIOS
from .workload import SparkWorkload, make_task_id
from .tasks import TaskSpec, all_task_specs, build_knowledge_base, generate_history

__all__ = [
    "spark_space", "INFLUENTIAL_KNOBS",
    "HardwareScenario", "QueryProfile", "SparkCostModel", "SCENARIOS",
    "SparkWorkload", "make_task_id",
    "TaskSpec", "all_task_specs", "build_knowledge_base", "generate_history",
]
