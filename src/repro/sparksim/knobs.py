"""The 60-knob Spark SQL configuration space (paper §7.1: Tuneful's space
extended to 60 performance-relevant parameters).

Roughly a third of the knobs drive the cost model strongly (the realistic
regime — most Spark knobs barely matter for a given workload, which is
exactly why the paper's knob-selection mechanism exists). The remainder
have small or negligible effects so that compression must *discover*
importance rather than being handed it.
"""

from __future__ import annotations

from ..core.space import BoolKnob, CatKnob, ConfigSpace, FloatKnob, IntKnob

__all__ = ["spark_space", "INFLUENTIAL_KNOBS"]


# knobs the cost model gives first-order effects to
INFLUENTIAL_KNOBS = [
    "spark.executor.instances",
    "spark.executor.cores",
    "spark.executor.memory",
    "spark.executor.memoryOverhead",
    "spark.memory.fraction",
    "spark.memory.storageFraction",
    "spark.sql.shuffle.partitions",
    "spark.sql.files.maxPartitionBytes",
    "spark.sql.autoBroadcastJoinThreshold",
    "spark.io.compression.codec",
    "spark.serializer",
    "spark.shuffle.compress",
    "spark.sql.adaptive.enabled",
    "spark.sql.adaptive.coalescePartitions.enabled",
    "spark.sql.adaptive.skewJoin.enabled",
    "spark.reducer.maxSizeInFlight",
    "spark.shuffle.file.buffer",
    "spark.speculation",
    "spark.locality.wait",
    "spark.default.parallelism",
]


def spark_space() -> ConfigSpace:
    knobs = [
        # ---- resource sizing (first order). Defaults model a plausible
        # ops-team baseline (the paper's "default Spark configuration"),
        # suboptimal by the 2-4x the paper reports, not pathological.
        IntKnob("spark.executor.instances", 2, 48, default=12),
        IntKnob("spark.executor.cores", 1, 16, default=4),
        IntKnob("spark.executor.memory", 2, 64, log=True, default=12),           # GB
        IntKnob("spark.executor.memoryOverhead", 384, 8192, log=True, default=384),  # MB
        FloatKnob("spark.memory.fraction", 0.3, 0.9, default=0.6),
        FloatKnob("spark.memory.storageFraction", 0.1, 0.9, default=0.5),
        # ---- parallelism / partitioning (first order)
        IntKnob("spark.sql.shuffle.partitions", 20, 4000, log=True, default=200),
        IntKnob("spark.default.parallelism", 20, 2000, log=True, default=200),
        IntKnob("spark.sql.files.maxPartitionBytes", 16, 1024, log=True, default=128),  # MB
        IntKnob("spark.sql.autoBroadcastJoinThreshold", 0, 512, default=10),     # MB, 0=off
        # ---- shuffle & IO (first order)
        CatKnob("spark.io.compression.codec", ("lz4", "snappy", "zstd"), default="lz4"),
        CatKnob("spark.serializer", ("java", "kryo"), default="java"),
        BoolKnob("spark.shuffle.compress", default=True),
        IntKnob("spark.reducer.maxSizeInFlight", 8, 256, log=True, default=48),  # MB
        IntKnob("spark.shuffle.file.buffer", 16, 1024, log=True, default=32),    # KB
        # ---- adaptive execution (first order)
        BoolKnob("spark.sql.adaptive.enabled", default=True),
        BoolKnob("spark.sql.adaptive.coalescePartitions.enabled", default=True),
        BoolKnob("spark.sql.adaptive.skewJoin.enabled", default=False),
        # ---- scheduling (moderate)
        BoolKnob("spark.speculation", default=False),
        FloatKnob("spark.locality.wait", 0.0, 10.0, default=3.0),                # s
        # ---- moderate / second order
        BoolKnob("spark.shuffle.spill.compress", default=True),
        IntKnob("spark.kryoserializer.buffer.max", 8, 256, log=True, default=64),  # MB
        IntKnob("spark.sql.inMemoryColumnarStorage.batchSize", 1000, 100000, log=True, default=10000),
        BoolKnob("spark.sql.inMemoryColumnarStorage.compressed", default=True),
        IntKnob("spark.shuffle.io.numConnectionsPerPeer", 1, 8, default=1),
        IntKnob("spark.shuffle.sort.bypassMergeThreshold", 50, 1000, default=200),
        BoolKnob("spark.memory.offHeap.enabled", default=False),
        IntKnob("spark.memory.offHeap.size", 0, 16384, default=0),               # MB
        IntKnob("spark.broadcast.blockSize", 1, 32, default=4),                  # MB
        IntKnob("spark.sql.broadcastTimeout", 120, 1200, default=300),           # s
        FloatKnob("spark.speculation.multiplier", 1.1, 5.0, default=1.5),
        FloatKnob("spark.speculation.quantile", 0.5, 0.95, default=0.75),
        # ---- long tail (negligible effect in the model; must be pruned)
        IntKnob("spark.rpc.askTimeout", 30, 600, default=120),
        IntKnob("spark.network.timeout", 60, 800, default=120),
        IntKnob("spark.storage.memoryMapThreshold", 1, 16, default=2),           # MB
        IntKnob("spark.locality.wait.node", 0, 10, default=3),
        IntKnob("spark.locality.wait.rack", 0, 10, default=3),
        IntKnob("spark.scheduler.revive.interval", 1, 10, default=1),
        IntKnob("spark.task.maxFailures", 1, 8, default=4),
        IntKnob("spark.stage.maxConsecutiveAttempts", 2, 8, default=4),
        BoolKnob("spark.shuffle.service.enabled", default=False),
        IntKnob("spark.shuffle.registration.timeout", 500, 10000, default=5000),
        IntKnob("spark.cleaner.periodicGC.interval", 10, 60, default=30),
        BoolKnob("spark.rdd.compress", default=False),
        IntKnob("spark.io.compression.lz4.blockSize", 8, 128, default=32),       # KB
        IntKnob("spark.io.compression.zstd.level", 1, 9, default=1),
        IntKnob("spark.sql.codegen.maxFields", 50, 500, default=100),
        BoolKnob("spark.sql.codegen.wholeStage", default=True),
        IntKnob("spark.sql.sources.parallelPartitionDiscovery.threshold", 8, 128, default=32),
        IntKnob("spark.sql.statistics.histogram.numBins", 64, 1024, default=254),
        BoolKnob("spark.sql.join.preferSortMergeJoin", default=True),
        IntKnob("spark.sql.limit.scaleUpFactor", 2, 16, default=4),
        IntKnob("spark.sql.shuffle.sortBeforeRepartition", 0, 1, default=1),
        FloatKnob("spark.scheduler.listenerbus.eventqueue.capacity", 1000, 100000, log=True, default=10000),
        IntKnob("spark.broadcast.compress", 0, 1, default=1),
        IntKnob("spark.checkpoint.compress", 0, 1, default=0),
        IntKnob("spark.files.maxPartitionBytes", 16, 512, default=128),
        IntKnob("spark.files.openCostInBytes", 1, 64, default=4),                # MB
        FloatKnob("spark.sql.cbo.joinReorder.card.weight", 0.0, 1.0, default=0.7),
        BoolKnob("spark.sql.cbo.enabled", default=False),
    ]
    assert len(knobs) == 60, f"expected 60 knobs, got {len(knobs)}"
    return ConfigSpace(knobs)
