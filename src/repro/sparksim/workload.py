"""SparkWorkload: the Workload-protocol adapter over the cost model,
including SparkEventLog-style 34-d meta-feature extraction (paper §4.2).

``evaluate`` runs one config through the scalar reference path;
``evaluate_many`` routes a whole batch of configs through the vectorized
``SparkCostModel.evaluate_batch`` grid engine (bit-for-bit equivalent to a
loop over ``evaluate``, but one NumPy pass over all configs x queries) —
this is the path Hyperband rungs use.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Union

import numpy as np

from .. import obs
from ..core.space import ConfigSpace
from ..tuneapi import EvalResult, Workload
from .knobs import spark_space
from .model import SCENARIOS, HardwareScenario, SparkCostModel

__all__ = ["SparkWorkload", "make_task_id"]

Config = Dict[str, Any]


def make_task_id(benchmark: str, data_gb: int, hardware: str) -> str:
    return f"{benchmark}-{data_gb}gb-{hardware}"


class SparkWorkload(Workload):
    def __init__(
        self,
        benchmark: str = "tpch",
        data_gb: int = 600,
        hardware: str = "A",
        seed: int = 1234,
        space: Optional[ConfigSpace] = None,
    ):
        self.benchmark = benchmark
        self.data_gb = data_gb
        self.hardware = hardware
        self.model = SparkCostModel(benchmark, data_gb, SCENARIOS[hardware], seed=seed)
        self._space = space or spark_space()
        self.task_id = make_task_id(benchmark, data_gb, hardware)

    @property
    def queries(self) -> List[str]:
        return [p.name for p in self.model.profiles]

    @property
    def space(self) -> ConfigSpace:
        return self._space

    def evaluate(
        self,
        config: Config,
        query_indices: Optional[Sequence[int]] = None,
        cost_cap: Optional[float] = None,
        data_fraction: float = 1.0,
    ) -> EvalResult:
        cfg = dict(self._space.default(), **config)
        with obs.span("workload_eval", task=self.task_id, n=1,
                      queries=len(query_indices) if query_indices is not None
                      else len(self.model.profiles)) as sp:
            lats, costs, failed, reason = self.model.evaluate(
                cfg,
                query_indices=list(query_indices) if query_indices is not None else None,
                data_fraction=data_fraction,
                cost_cap=cost_cap,
            )
            obs.count(f"workload/{reason or 'ok'}")
            sp.set(failed=failed, reason=reason or "ok")
        return EvalResult(
            per_query_latency=lats, per_query_cost=costs, failed=failed, failure_reason=reason
        )

    def evaluate_many(
        self,
        configs: Sequence[Config],
        query_indices: Optional[Sequence[int]] = None,
        cost_cap: Union[None, float, Sequence[Optional[float]]] = None,
        data_fraction: float = 1.0,
    ) -> List[EvalResult]:
        """Batched evaluation via the vectorized cost-model grid."""
        caps = self._per_config_caps(cost_cap, len(configs))
        cfgs = [dict(self._space.default(), **c) for c in configs]
        with obs.span("workload_eval", task=self.task_id, n=len(cfgs),
                      queries=len(query_indices) if query_indices is not None
                      else len(self.model.profiles)) as sp:
            rows = self.model.evaluate_batch(
                cfgs,
                query_indices=list(query_indices) if query_indices is not None else None,
                data_fraction=data_fraction,
                cost_cap=caps,
            )
            n_failed = 0
            for _, _, failed, reason in rows:
                obs.count(f"workload/{reason or 'ok'}")
                n_failed += bool(failed)
            sp.set(failures=n_failed)
        return [
            EvalResult(per_query_latency=lats, per_query_cost=costs,
                       failed=failed, failure_reason=reason)
            for lats, costs, failed, reason in rows
        ]

    # ----------------------------------------------------------- meta features
    def meta_features(self) -> List[float]:
        """34-d vector from the default-config 'event log' (paper §4.2).

        Per-query latencies and stage breakdowns under the default config
        are summarized into workload-level statistics.
        """
        cfg = self._space.default()
        lats, scans, computes, shuffles, spills, skews, shuffle_fracs = [], [], [], [], [], [], []
        for p in self.model.profiles:
            lat, _failed, bd = self.model.query_latency(cfg, p)
            lats.append(lat)
            scans.append(bd["scan"])
            computes.append(bd["compute"])
            shuffles.append(bd["shuffle"])
            spills.append(bd["spill_ratio"])
            skews.append(p.skew)
            shuffle_fracs.append(p.shuffle_frac)
        lats = np.asarray(lats)
        log_l = np.log(np.maximum(lats, 1e-6))
        total = lats.sum()
        parts = np.asarray([scans, computes, shuffles])  # (3, m)
        part_frac = parts.sum(axis=1) / max(parts.sum(), 1e-9)

        def stats(x: np.ndarray) -> List[float]:
            return [
                float(np.mean(x)), float(np.std(x)),
                float(np.percentile(x, 25)), float(np.percentile(x, 50)),
                float(np.percentile(x, 75)), float(np.max(x)), float(np.min(x)),
            ]

        feats: List[float] = []
        feats += stats(log_l)                               # 7: latency distribution
        feats += stats(np.log(np.maximum(np.asarray(shuffles), 1e-6)))  # 7: shuffle time dist
        feats += list(part_frac)                            # 3: scan/compute/shuffle split
        feats += [float(np.log(total)), float(len(lats))]   # 2
        feats += stats(np.asarray(spills))                  # 7: memory pressure dist
        feats += [float(np.mean(skews)), float(np.max(skews))]          # 2
        feats += [float(np.mean(shuffle_fracs)), float(np.max(shuffle_fracs))]  # 2
        feats += [
            float(self.model.hw.nodes),
            float(self.model.hw.cores),
            float(np.log(self.model.hw.ram_gb)),
            float(np.log(self.model.data_gb)),
        ]                                                   # 4
        assert len(feats) == 34, len(feats)
        return feats
