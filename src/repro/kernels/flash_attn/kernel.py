"""FlashAttention-2 Pallas TPU kernels (forward + backward).

TPU mapping: the grid walks (batch*kv_heads, q_blocks); each program
instance streams KV blocks through VMEM with a fori_loop, carrying the
running (m, l, acc) in f32 VMEM scratch. Block shapes put the
last-two-dims at MXU-friendly multiples (q_block x head_dim, head_dim
multiple of 128 where the arch allows); the (G*Dq) flattening keeps the
grouped-query heads contiguous in lanes. Backward runs two kernels, dq
(grid over q blocks) and dkv (grid over kv blocks), each recomputing p
from the saved lse — the HBM<->VMEM traffic profile of FA-2.

Masking is positional (block-offset arithmetic in-kernel), so causal and
sliding-window variants share one kernel.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["flash_fwd_pallas", "flash_dq_pallas", "flash_dkv_pallas"]

NEG_INF = -1e30


def _mask(qpos, kpos, causal: bool, window: Optional[int]):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), jnp.bool_)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        m &= qpos[:, None] - kpos[None, :] < window
    return m


# ------------------------------------------------------------------ forward


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, causal, window,
                q_offset, kv_block, n_kv, scale):
    qi = pl.program_id(1)
    qb, G, D = q_ref.shape[1], q_ref.shape[2], q_ref.shape[3]
    Dv = v_ref.shape[-1]
    q = q_ref[0].astype(jnp.float32) * scale            # (qb, G, D)

    def body(ki, carry):
        m, l, acc = carry
        k = k_ref[0, pl.ds(ki * kv_block, kv_block)].astype(jnp.float32)   # (kb, D)
        v = v_ref[0, pl.ds(ki * kv_block, kv_block)].astype(jnp.float32)   # (kb, Dv)
        s = jax.lax.dot_general(q.reshape(qb * G, D), k,
                                (((1,), (1,)), ((), ()))).reshape(qb, G, kv_block)
        qpos = q_offset + qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, 1), 0)[:, 0]
        kpos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32, (kv_block, 1), 0)[:, 0]
        msk = _mask(qpos, kpos, causal, window)
        s = jnp.where(msk[:, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jax.lax.dot_general(p.reshape(qb * G, kv_block), v,
                                 (((1,), (0,)), ((), ()))).reshape(qb, G, Dv)
        acc = acc * corr[..., None] + pv
        return m_new, l_new, acc

    m0 = jnp.full((qb, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((qb, G), jnp.float32)
    a0 = jnp.zeros((qb, G, Dv), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kv, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(o_ref.dtype)
    lse_ref[0] = (m + jnp.log(jnp.maximum(l, 1e-30))).astype(lse_ref.dtype)


def flash_fwd_pallas(q, k, v, *, causal=True, window=None, q_offset=0,
                     q_block=128, kv_block=128, interpret=True):
    """q: (BH, Sq, G, D); k/v: (BH, Sk, D*). BH = batch*kv_heads (pre-fused).
    Returns (o, lse)."""
    BH, Sq, G, D = q.shape
    Sk, Dv = k.shape[1], v.shape[-1]
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    assert Sq % q_block == 0 and Sk % kv_block == 0
    grid = (BH, Sq // q_block)
    scale = 1.0 / np.sqrt(D)
    kernel = functools.partial(
        _fwd_kernel, causal=causal, window=window, q_offset=q_offset,
        kv_block=kv_block, n_kv=Sk // kv_block, scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, G, D), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, Sk, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, Sk, Dv), lambda b, i: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q_block, G, Dv), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, q_block, G), lambda b, i: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sq, G, Dv), q.dtype),
            jax.ShapeDtypeStruct((BH, Sq, G), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# ----------------------------------------------------------------- backward


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *,
               causal, window, q_offset, kv_block, n_kv, scale):
    qi = pl.program_id(1)
    qb, G, D = q_ref.shape[1], q_ref.shape[2], q_ref.shape[3]
    q = q_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)                  # (qb, G, Dv)
    lse = lse_ref[0]                                    # (qb, G)
    delta = delta_ref[0]                                # (qb, G)

    def body(ki, dq):
        k = k_ref[0, pl.ds(ki * kv_block, kv_block)].astype(jnp.float32)
        v = v_ref[0, pl.ds(ki * kv_block, kv_block)].astype(jnp.float32)
        s = jax.lax.dot_general((q * scale).reshape(qb * G, D), k,
                                (((1,), (1,)), ((), ()))).reshape(qb, G, kv_block)
        qpos = q_offset + qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, 1), 0)[:, 0]
        kpos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32, (kv_block, 1), 0)[:, 0]
        s = jnp.where(_mask(qpos, kpos, causal, window)[:, None, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])
        dp = jax.lax.dot_general(do.reshape(qb * G, -1), v,
                                 (((1,), (1,)), ((), ()))).reshape(qb, G, kv_block)
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jax.lax.dot_general(ds.reshape(qb * G, kv_block), k,
                                      (((1,), (0,)), ((), ()))).reshape(qb, G, D)
        return dq

    dq = jax.lax.fori_loop(0, n_kv, body, jnp.zeros((qb, G, D), jnp.float32))
    dq_ref[0] = dq.astype(dq_ref.dtype)


def flash_dq_pallas(q, k, v, do, lse, delta, *, causal=True, window=None,
                    q_offset=0, q_block=128, kv_block=128, interpret=True):
    BH, Sq, G, D = q.shape
    Sk, Dv = k.shape[1], v.shape[-1]
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    grid = (BH, Sq // q_block)
    kernel = functools.partial(
        _dq_kernel, causal=causal, window=window, q_offset=q_offset,
        kv_block=kv_block, n_kv=Sk // kv_block, scale=1.0 / np.sqrt(D),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, G, D), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, Sk, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, Sk, Dv), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, q_block, G, Dv), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, q_block, G), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, q_block, G), lambda b, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, G, D), lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, G, D), q.dtype),
        interpret=interpret,
    )(q, k, v, do, lse, delta)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref, *,
                causal, window, q_offset, q_block, n_q, scale):
    ki = pl.program_id(1)
    kb, D = k_ref.shape[1], k_ref.shape[2]
    G = q_ref.shape[2]
    Dv = v_ref.shape[-1]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)

    def body(qi, carry):
        dk, dv = carry
        q = q_ref[0, pl.ds(qi * q_block, q_block)].astype(jnp.float32)       # (qb,G,D)
        do = do_ref[0, pl.ds(qi * q_block, q_block)].astype(jnp.float32)
        lse = lse_ref[0, pl.ds(qi * q_block, q_block)]
        delta = delta_ref[0, pl.ds(qi * q_block, q_block)]
        s = jax.lax.dot_general((q * scale).reshape(q_block * G, D), k,
                                (((1,), (1,)), ((), ()))).reshape(q_block, G, kb)
        qpos = q_offset + qi * q_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, 1), 0)[:, 0]
        kpos = ki * kb + jax.lax.broadcasted_iota(jnp.int32, (kb, 1), 0)[:, 0]
        s = jnp.where(_mask(qpos, kpos, causal, window)[:, None, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                                      # (qb,G,kb)
        dv = dv + jax.lax.dot_general(p.reshape(q_block * G, kb),
                                      do.reshape(q_block * G, Dv),
                                      (((0,), (0,)), ((), ())))
        dp = jax.lax.dot_general(do.reshape(q_block * G, Dv), v,
                                 (((1,), (1,)), ((), ()))).reshape(q_block, G, kb)
        ds = p * (dp - delta[..., None]) * scale
        dk = dk + jax.lax.dot_general(ds.reshape(q_block * G, kb),
                                      q.reshape(q_block * G, D),
                                      (((0,), (0,)), ((), ())))
        return dk, dv

    dk0 = jnp.zeros((kb, D), jnp.float32)
    dv0 = jnp.zeros((kb, Dv), jnp.float32)
    dk, dv = jax.lax.fori_loop(0, n_q, body, (dk0, dv0))
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)


def flash_dkv_pallas(q, k, v, do, lse, delta, *, causal=True, window=None,
                     q_offset=0, q_block=128, kv_block=128, interpret=True):
    BH, Sq, G, D = q.shape
    Sk, Dv = k.shape[1], v.shape[-1]
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    grid = (BH, Sk // kv_block)
    kernel = functools.partial(
        _dkv_kernel, causal=causal, window=window, q_offset=q_offset,
        q_block=q_block, n_q=Sq // q_block, scale=1.0 / np.sqrt(D),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Sq, G, D), lambda b, i: (b, 0, 0, 0)),
            pl.BlockSpec((1, kv_block, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, kv_block, Dv), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Sq, G, Dv), lambda b, i: (b, 0, 0, 0)),
            pl.BlockSpec((1, Sq, G), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, Sq, G), lambda b, i: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, kv_block, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, kv_block, Dv), lambda b, i: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sk, D), k.dtype),
            jax.ShapeDtypeStruct((BH, Sk, Dv), v.dtype),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
