"""Jit'd public wrapper: custom-VJP flash attention backed by the Pallas
kernels. Layout adapter: model code uses (B, S, Hkv, G, D); the kernels run
on (B*Hkv, S, G, D) so the grid's leading axis fuses batch and KV heads.

``interpret=True`` (the CPU-validation mode) is the default off-TPU; on a
TPU runtime pass interpret=False for the compiled path.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_dkv_pallas, flash_dq_pallas, flash_fwd_pallas

__all__ = ["flash_attention"]


def _to_kernel_layout(q, k, v):
    B, Sq, Hkv, G, D = q.shape
    Sk, Dv = k.shape[1], v.shape[-1]
    qk = q.transpose(0, 2, 1, 3, 4).reshape(B * Hkv, Sq, G, D)
    kk = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    vk = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, Dv)
    return qk, kk, vk


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, window, q_offset, q_block, kv_block, interpret):
    o, _ = flash_fwd_pallas(q, k, v, causal=causal, window=window, q_offset=q_offset,
                            q_block=q_block, kv_block=kv_block, interpret=interpret)
    return o


def _flash_fwd(q, k, v, causal, window, q_offset, q_block, kv_block, interpret):
    o, lse = flash_fwd_pallas(q, k, v, causal=causal, window=window, q_offset=q_offset,
                              q_block=q_block, kv_block=kv_block, interpret=interpret)
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, window, q_offset, q_block, kv_block, interpret, res, do):
    q, k, v, o, lse = res
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    kw = dict(causal=causal, window=window, q_offset=q_offset,
              q_block=q_block, kv_block=kv_block, interpret=interpret)
    dq = flash_dq_pallas(q, k, v, do, lse, delta, **kw)
    dk, dv = flash_dkv_pallas(q, k, v, do, lse, delta, **kw)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "q_block", "kv_block", "interpret"))
def flash_attention(
    q: jax.Array,            # (B, Sq, Hkv, G, D)
    k: jax.Array,            # (B, Sk, Hkv, D)
    v: jax.Array,            # (B, Sk, Hkv, Dv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, Sq, Hkv, G, D = q.shape
    Dv = v.shape[-1]
    qk, kk, vk = _to_kernel_layout(q, k, v)
    o = _flash(qk, kk, vk, causal, window, q_offset,
               min(q_block, Sq), min(kv_block, k.shape[1]), interpret)
    return o.reshape(B, Hkv, Sq, G, Dv).transpose(0, 2, 1, 3, 4)
