"""Pure-jnp oracle for flash attention (causal / sliding-window / GQA)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["attention_ref"]


def attention_ref(
    q: jax.Array,            # (B, Sq, Hkv, G, D)
    k: jax.Array,            # (B, Sk, Hkv, D)
    v: jax.Array,            # (B, Sk, Hkv, Dv)
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jax.Array:
    B, Sq, Hkv, G, D = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqhgd,bkhd->bqhgk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / np.sqrt(D)
    qp = q_offset + jnp.arange(Sq)
    kp = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qp[:, None] >= kp[None, :]
    if window is not None:
        mask &= qp[:, None] - kp[None, :] < window
    s = jnp.where(mask[:, None, None, :][None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32)).astype(q.dtype)
