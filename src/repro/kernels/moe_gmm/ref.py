"""Oracle for the grouped (per-expert) matmul."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["gmm_ref"]


def gmm_ref(x, w, group_sizes=None):
    """x: (E, C, D); w: (E, D, F); group_sizes: (E,) valid rows per expert
    (padded rows are zeroed). Returns (E, C, F)."""
    out = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32), w.astype(jnp.float32))
    if group_sizes is not None:
        C = x.shape[1]
        valid = jnp.arange(C)[None, :] < group_sizes[:, None]
        out = jnp.where(valid[..., None], out, 0.0)
    return out.astype(x.dtype)
