"""Grouped expert matmul Pallas kernel (MegaBlocks-on-TPU analogue).

Grid: (E, C/bc, F/bf). Each instance computes one (bc x bf) output tile of
one expert by streaming the shared D dimension in VMEM-sized slabs through
a fori_loop with an f32 accumulator. ``group_sizes`` masks the padded
capacity rows so dropped-token slots contribute nothing (and on real
hardware the (e, ci) tiles past the group boundary early-out — here the
mask keeps interpret-mode semantics identical).

Block defaults (128, 128, 512-slab) are MXU-aligned; the per-instance VMEM
footprint is bc*slab + slab*bf + bc*bf floats.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gmm_pallas"]


def _gmm_kernel(x_ref, w_ref, gs_ref, o_ref, *, d_slab, n_slabs, c_block):
    ci = pl.program_id(1)
    e_gs = gs_ref[0]

    def body(di, acc):
        xs = x_ref[0, :, pl.ds(di * d_slab, d_slab)].astype(jnp.float32)   # (bc, slab)
        ws = w_ref[0, pl.ds(di * d_slab, d_slab), :].astype(jnp.float32)   # (slab, bf)
        return acc + jax.lax.dot_general(xs, ws, (((1,), (0,)), ((), ())))

    acc = jax.lax.fori_loop(
        0, n_slabs, body, jnp.zeros((x_ref.shape[1], o_ref.shape[2]), jnp.float32)
    )
    rows = ci * c_block + jax.lax.broadcasted_iota(jnp.int32, (c_block, 1), 0)[:, 0]
    acc = jnp.where((rows < e_gs)[:, None], acc, 0.0)
    o_ref[0] = acc.astype(o_ref.dtype)


def gmm_pallas(x, w, group_sizes=None, *, c_block=128, f_block=128, d_slab=512,
               interpret=True):
    """x: (E, C, D); w: (E, D, F) -> (E, C, F)."""
    E, C, D = x.shape
    F = w.shape[-1]
    if group_sizes is None:
        group_sizes = jnp.full((E,), C, jnp.int32)
    c_block = min(c_block, C)
    while C % c_block:
        c_block //= 2
    f_block = min(f_block, F)
    while F % f_block:
        f_block //= 2
    d_slab = min(d_slab, D)
    while D % d_slab:
        d_slab //= 2
    kernel = functools.partial(_gmm_kernel, d_slab=d_slab, n_slabs=D // d_slab,
                               c_block=c_block)
    return pl.pallas_call(
        kernel,
        grid=(E, C // c_block, F // f_block),
        in_specs=[
            pl.BlockSpec((1, c_block, D), lambda e, i, j: (e, i, 0)),
            pl.BlockSpec((1, D, f_block), lambda e, i, j: (e, 0, j)),
            pl.BlockSpec((1,), lambda e, i, j: (e,)),
        ],
        out_specs=pl.BlockSpec((1, c_block, f_block), lambda e, i, j: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        interpret=interpret,
    )(x, w, group_sizes)
