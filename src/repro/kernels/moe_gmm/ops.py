"""Jit'd wrapper for the grouped expert matmul."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import gmm_pallas

__all__ = ["grouped_matmul"]


@functools.partial(jax.jit, static_argnames=("interpret",))
def grouped_matmul(x, w, group_sizes=None, interpret: bool = True):
    """x: (E, C, D) dispatched tokens; w: (E, D, F) expert weights."""
    return gmm_pallas(x, w, group_sizes, interpret=interpret)
