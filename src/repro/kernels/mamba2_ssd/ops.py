"""Jit'd wrapper: Pallas SSD forward + recompute-based exact backward.

The backward differentiates the sequential oracle (itself a scan) under
recompute — exact gradients, O(S) memory, no transposed kernel needed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_fwd_pallas
from .ref import ssd_ref

__all__ = ["ssd_scan"]


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _ssd(x, B, C, a, chunk, interpret):
    y, _ = ssd_fwd_pallas(x, B, C, a, chunk=chunk, interpret=interpret)
    return y


def _ssd_fwd(x, B, C, a, chunk, interpret):
    y, _ = ssd_fwd_pallas(x, B, C, a, chunk=chunk, interpret=interpret)
    return y, (x, B, C, a)


def _ssd_bwd(chunk, interpret, res, dy):
    x, B, C, a = res
    # oracle expects (Bt, S, H, P) layout; our kernel layout folds H into Bt
    def f(x_, B_, C_, a_):
        y, _ = ssd_ref(x_[:, :, None, :], B_[:, :, None, :], C_[:, :, None, :], a_[:, :, None])
        return y[:, :, 0, :]

    _, vjp = jax.vjp(f, x, B, C, a)
    return vjp(dy)


_ssd.defvjp(_ssd_fwd, _ssd_bwd)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, B, C, a, *, chunk=64, interpret=True):
    """x: (BH, S, P); B/C: (BH, S, N); a: (BH, S) log decay. Returns y."""
    return _ssd(x, B, C, a, chunk, interpret)
