"""Mamba2 SSD chunk-scan Pallas kernel (forward).

Grid: (batch*heads,). Each program instance owns one (b, h) stream and
walks the chunks with a fori_loop, carrying the (P, N) state in VMEM
scratch-equivalent registers. Within a chunk everything is dense matmul
(MXU): the intra-chunk quadratic form with the separable decay mask, the
state read (C . h) and the state update (decay-weighted B^T x). Chunk
size is the VMEM knob: (c x c) + 2(c x N) + (c x P) tiles.

Backward uses the pure-jnp sequential oracle under jax.checkpoint (the
SSD backward is itself a scan; recompute-based AD through the oracle is
exact and O(S) — see ops.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ssd_fwd_pallas"]


def _ssd_kernel(x_ref, b_ref, c_ref, a_ref, y_ref, hout_ref, *, chunk, n_chunks):
    P = x_ref.shape[-1]
    N = b_ref.shape[-1]

    def chunk_body(ci, h):
        sl = pl.ds(ci * chunk, chunk)
        xk = x_ref[0, sl].astype(jnp.float32)        # (c, P)
        bk = b_ref[0, sl].astype(jnp.float32)        # (c, N)
        ck = c_ref[0, sl].astype(jnp.float32)        # (c, N)
        ak = a_ref[0, sl].astype(jnp.float32)        # (c,)
        cs = jnp.cumsum(ak)                          # (c,)
        total = cs[-1]
        # intra-chunk: y_q += sum_{s<=q} exp(cs_q - cs_s) (C_q.B_s) x_s
        rel = cs[:, None] - cs[None, :]
        tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
               >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
        L = jnp.where(tri, jnp.exp(rel), 0.0)
        scores = jax.lax.dot_general(ck, bk, (((1,), (1,)), ((), ()))) * L   # (c, c)
        y = jax.lax.dot_general(scores, xk, (((1,), (0,)), ((), ())))        # (c, P)
        # state read
        y = y + jax.lax.dot_general(ck * jnp.exp(cs)[:, None], h,
                                    (((1,), (1,)), ((), ())))                 # (c, P) via (N,P)->wait
        # state update: h' = exp(total) h + sum_s exp(total - cs_s) x_s^T B_s
        w = jnp.exp(total - cs)[:, None]
        h = jnp.exp(total) * h + jax.lax.dot_general(xk * 1.0, bk * w,
                                                     (((0,), (0,)), ((), ())))  # (P, N)
        y_ref[0, sl] = y.astype(y_ref.dtype)
        return h

    h0 = jnp.zeros((P, N), jnp.float32)
    h = jax.lax.fori_loop(0, n_chunks, chunk_body, h0)
    hout_ref[0] = h


def ssd_fwd_pallas(x, B, C, a, *, chunk=64, interpret=True):
    """x: (BH, S, P); B/C: (BH, S, N); a: (BH, S). Returns (y, h_final)."""
    BH, S, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=S // chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH,),
        in_specs=[
            pl.BlockSpec((1, S, P), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, S, N), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, S, N), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, S), lambda b: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, P), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, P, N), lambda b: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), x.dtype),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, B, C, a)
