"""Oracle for the Mamba2 SSD chunk: sequential recurrence, O(S) exact.

h_t = exp(a_t) h_{t-1} + B_t x_t^T ;  y_t = C_t . h_t
(scalar-identity A per head; a_t = log-decay <= 0).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssd_ref"]


def ssd_ref(x, B, C, a, h0=None):
    """x: (Bt, S, H, P); B/C: (Bt, S, H, N); a: (Bt, S, H) log decay.
    Returns (y (Bt,S,H,P), h_final (Bt,H,P,N))."""
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    h = jnp.zeros((Bt, H, P, N), jnp.float32) if h0 is None else h0

    def step(h, inp):
        xt, Bt_, Ct, at = inp
        h = h * jnp.exp(at.astype(jnp.float32))[..., None, None] + jnp.einsum(
            "bhn,bhp->bhpn", Bt_.astype(jnp.float32), xt.astype(jnp.float32)
        )
        y = jnp.einsum("bhpn,bhn->bhp", h, Ct.astype(jnp.float32))
        return h, y

    xs = (x.transpose(1, 0, 2, 3), B.transpose(1, 0, 2, 3),
          C.transpose(1, 0, 2, 3), a.transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h
