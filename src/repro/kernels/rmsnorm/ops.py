"""Jit'd custom-VJP wrapper for the fused RMSNorm kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import rmsnorm_bwd_pallas, rmsnorm_fwd_pallas

__all__ = ["rmsnorm"]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _rmsnorm(x, w, eps, interpret):
    o, _ = rmsnorm_fwd_pallas(x, w, eps=eps, interpret=interpret)
    return o


def _fwd(x, w, eps, interpret):
    o, rstd = rmsnorm_fwd_pallas(x, w, eps=eps, interpret=interpret)
    return o, (x, w, rstd)


def _bwd(eps, interpret, res, do):
    x, w, rstd = res
    dx, dw = rmsnorm_bwd_pallas(x, w, rstd, do, interpret=interpret)
    return dx, dw


_rmsnorm.defvjp(_fwd, _bwd)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5, interpret: bool = True) -> jax.Array:
    """x: (..., D). Fused RMSNorm with Pallas fwd+bwd."""
    shape = x.shape
    out = _rmsnorm(x.reshape(-1, shape[-1]), w, eps, interpret)
    return out.reshape(shape)
