"""Fused RMSNorm Pallas kernel (forward + backward).

Row-blocked: each program instance normalizes a (rows_block, D) tile kept
entirely in VMEM — one HBM read and one write per element, the fusion XLA
sometimes misses when the scale multiply lands in a different fusion.
Backward fuses the three reductions (dw, and the two per-row dot terms of
dx) into the same tile pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm_fwd_pallas", "rmsnorm_bwd_pallas"]


def _fwd_kernel(x_ref, w_ref, o_ref, rstd_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    rstd = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    o_ref[...] = (x * rstd * w[None, :]).astype(o_ref.dtype)
    rstd_ref[...] = rstd[:, 0]


def rmsnorm_fwd_pallas(x, w, eps=1e-5, rows_block=128, interpret=True):
    """x: (N, D) -> (out (N, D), rstd (N,))."""
    N, D = x.shape
    rows_block = min(rows_block, N)
    while N % rows_block:
        rows_block //= 2
    return pl.pallas_call(
        functools.partial(_fwd_kernel, eps=eps),
        grid=(N // rows_block,),
        in_specs=[
            pl.BlockSpec((rows_block, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((rows_block, D), lambda i: (i, 0)),
            pl.BlockSpec((rows_block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, D), x.dtype),
            jax.ShapeDtypeStruct((N,), jnp.float32),
        ],
        interpret=interpret,
    )(x, w)


def _bwd_kernel(x_ref, w_ref, rstd_ref, do_ref, dx_ref, dwp_ref):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    rstd = rstd_ref[...][:, None]
    do = do_ref[...].astype(jnp.float32)
    xhat = x * rstd
    dw_partial = (do * xhat).sum(axis=0)
    dxhat = do * w[None, :]
    # dx = rstd * (dxhat - xhat * mean(dxhat * xhat))
    mean_term = (dxhat * xhat).mean(axis=-1, keepdims=True)
    dx = rstd * (dxhat - xhat * mean_term)
    dx_ref[...] = dx.astype(dx_ref.dtype)
    dwp_ref[...] = dw_partial[None, :]


def rmsnorm_bwd_pallas(x, w, rstd, do, rows_block=128, interpret=True):
    N, D = x.shape
    rows_block = min(rows_block, N)
    while N % rows_block:
        rows_block //= 2
    dx, dw_parts = pl.pallas_call(
        _bwd_kernel,
        grid=(N // rows_block,),
        in_specs=[
            pl.BlockSpec((rows_block, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
            pl.BlockSpec((rows_block,), lambda i: (i,)),
            pl.BlockSpec((rows_block, D), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rows_block, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, D), x.dtype),
            jax.ShapeDtypeStruct((N // rows_block, D), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, rstd, do)
    return dx, dw_parts.sum(axis=0).astype(w.dtype)
