"""Oracle for fused RMSNorm."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rmsnorm_ref"]


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return ((x32 * rms) * w.astype(jnp.float32)).astype(x.dtype)
