"""Oracle for single-token decode attention over a KV cache."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["decode_ref"]


def decode_ref(q, k, v, lengths):
    """q: (B, Hkv, G, D); k/v: (B, S, Hkv, D*); lengths: (B,) valid prefix.
    Returns (B, Hkv, G, Dv)."""
    B, S = k.shape[:2]
    s = jnp.einsum("bhgd,bkhd->bhgk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / np.sqrt(q.shape[-1])
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32)).astype(q.dtype)
