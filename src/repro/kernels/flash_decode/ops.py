"""Jit'd wrapper for split-KV decode attention."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_decode_pallas

__all__ = ["decode_attention"]


@functools.partial(jax.jit, static_argnames=("kv_splits", "kv_block", "interpret"))
def decode_attention(q, k, v, lengths, *, kv_splits=4, kv_block=128, interpret=True):
    """q: (B, Hkv, G, D); k/v: (B, S, Hkv, D*); lengths: (B,).
    Returns (B, Hkv, G, Dv)."""
    B, Hkv, G, D = q.shape
    S, Dv = k.shape[1], v.shape[-1]
    qk = q.reshape(B * Hkv, G, D)
    kk = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vk = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, Dv)
    lens = jnp.repeat(lengths, Hkv)
    o = flash_decode_pallas(qk, kk, vk, lens, kv_splits=kv_splits,
                            kv_block=kv_block, interpret=interpret)
    return o.reshape(B, Hkv, G, Dv)
