"""Split-KV flash-decode Pallas kernel (FlashDecoding-style).

Decode is memory-bound: one query token attends over a long cache. The
grid splits the KV sequence into chunks processed by separate program
instances — (batch*kv_heads, kv_splits) — each emitting a partial
(o, m, l) triple; a cheap jnp combine merges the partials with the
standard logsumexp algebra. On TPU this turns one long HBM stream into
``kv_splits`` parallel streams, the roofline-optimal shape for B=1 long-
context serving.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["flash_decode_pallas"]

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, *,
                   split_size, kv_block, scale):
    si = pl.program_id(1)
    G, D = q_ref.shape[1], q_ref.shape[2]
    Dv = v_ref.shape[-1]
    q = q_ref[0].astype(jnp.float32) * scale           # (G, D)
    length = len_ref[0]

    n_blocks = split_size // kv_block

    def body(bi, carry):
        m, l, acc = carry
        base = si * split_size + bi * kv_block
        k = k_ref[0, pl.ds(bi * kv_block, kv_block)].astype(jnp.float32)  # (kb, D)
        v = v_ref[0, pl.ds(bi * kv_block, kv_block)].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))           # (G, kb)
        kpos = base + jax.lax.broadcasted_iota(jnp.int32, (kv_block, 1), 0)[:, 0]
        s = jnp.where((kpos < length)[None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc = acc * corr[:, None] + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        return m_new, l_new, acc

    m0 = jnp.full((G,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((G,), jnp.float32)
    a0 = jnp.zeros((G, Dv), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, a0))
    o_ref[0, 0] = acc.astype(o_ref.dtype)
    m_ref[0, 0] = m
    l_ref[0, 0] = l


def flash_decode_pallas(q, k, v, lengths, *, kv_splits=4, kv_block=128, interpret=True):
    """q: (BH, G, D); k/v: (BH, S, D*); lengths: (BH,). Returns (BH, G, Dv)."""
    BH, G, D = q.shape
    S, Dv = k.shape[1], v.shape[-1]
    while S % (kv_splits * kv_block) and kv_splits > 1:
        kv_splits -= 1
    kv_block = min(kv_block, S // kv_splits)
    while (S // kv_splits) % kv_block:
        kv_block //= 2
    split_size = S // kv_splits
    kernel = functools.partial(
        _decode_kernel, split_size=split_size, kv_block=kv_block, scale=1.0 / np.sqrt(D)
    )
    o, m, l = pl.pallas_call(
        kernel,
        grid=(BH, kv_splits),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, split_size, D), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, split_size, Dv), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1,), lambda b, s: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, Dv), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, 1, G), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, 1, G), lambda b, s: (b, s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, kv_splits, G, Dv), jnp.float32),
            jax.ShapeDtypeStruct((BH, kv_splits, G), jnp.float32),
            jax.ShapeDtypeStruct((BH, kv_splits, G), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lengths)
    # combine partials (logsumexp algebra): per-split o is the UNNORMALIZED
    # sum_k p_k v_k at local max m_s; rescale by exp(m_s - m_all) and divide
    # by the combined denominator sum_s exp(m_s - m_all) l_s.
    m_all = m.max(axis=1)                                          # (BH, G)
    corr = jnp.exp(m - m_all[:, None, :])                          # (BH, splits, G)
    denom = (corr * l).sum(axis=1)
    o_comb = (o * corr[..., None]).sum(axis=1) / jnp.maximum(denom, 1e-30)[..., None]
    return o_comb.astype(q.dtype)
