"""Oracle for the RWKV6 WKV recurrence (sequential, exact).

S_t = diag(exp(w_t)) S_{t-1} + k_t^T v_t
y_t = r_t S_{t-1} + (r_t . (u * k_t)) v_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["wkv_ref"]


def wkv_ref(r, k, v, w, u, S0=None):
    """r/k/v: (B, S, H, K); w: (B, S, H, K) log decay; u: (H, K).
    Returns (y (B,S,H,K), S_final (B,H,K,K))."""
    B, S, H, K = r.shape
    state = jnp.zeros((B, H, K, K), jnp.float32) if S0 is None else S0

    def step(s, inp):
        rt, kt, vt, wt = (z.astype(jnp.float32) for z in inp)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s)
        y = y + (rt * u[None] * kt).sum(-1, keepdims=True) * vt
        s = jnp.exp(wt)[..., None] * s + jnp.einsum("bhk,bhv->bhkv", kt, vt)
        return s, y

    xs = tuple(z.transpose(1, 0, 2, 3) for z in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), state
