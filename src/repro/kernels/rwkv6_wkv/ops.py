"""Jit'd wrapper: Pallas WKV forward + recompute backward via the oracle."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import wkv_fwd_pallas
from .ref import wkv_ref

__all__ = ["wkv_scan"]


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _wkv(r, k, v, w, u, chunk, interpret):
    y, _ = wkv_fwd_pallas(r, k, v, w, u, chunk=chunk, interpret=interpret)
    return y


def _wkv_fwd(r, k, v, w, u, chunk, interpret):
    y, _ = wkv_fwd_pallas(r, k, v, w, u, chunk=chunk, interpret=interpret)
    return y, (r, k, v, w, u)


def _wkv_bwd(chunk, interpret, res, dy):
    r, k, v, w, u = res

    # per-(b,h) u rows: oracle wants (H,K); kernel layout fuses BH — treat
    # each row independently by vmapping the single-head oracle
    def g(r_, k_, v_, w_, u_):
        def one(rr, kk, vv, ww, uu):
            y, _ = wkv_ref(rr[None, :, None, :], kk[None, :, None, :],
                           vv[None, :, None, :], ww[None, :, None, :], uu[None, :])
            return y[0, :, 0, :]
        return jax.vmap(one)(r_, k_, v_, w_, u_)

    _, vjp = jax.vjp(g, r, k, v, w, u)
    return vjp(dy)


_wkv.defvjp(_wkv_fwd, _wkv_bwd)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_scan(r, k, v, w, u, *, chunk=32, interpret=True):
    """r/k/v/w: (BH, S, K); u: (BH, K). Returns y (BH, S, K)."""
    return _wkv(r, k, v, w, u, chunk, interpret)
