"""RWKV6 chunked WKV Pallas kernel (forward).

Grid: (batch*heads,). Chunked parallel form with the *separable* decay
factorization (exp(cs_q - w_q - cs_s) = exp(cs_q - w_q - m) * exp(m - cs_s))
so the intra-chunk attention is two MXU matmuls — never a (c, c, K)
tensor. The per-channel (K, K) state streams through the chunks in a
fori_loop. The midpoint shift m keeps both factors within f32 range for
chunk <= 64 given the model's decay floor (see models/rwkv6.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["wkv_fwd_pallas"]


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, sout_ref, *, chunk, n_chunks):
    K = r_ref.shape[-1]
    u = u_ref[0].astype(jnp.float32)                 # (K,)

    def body(ci, s):
        sl = pl.ds(ci * chunk, chunk)
        rk = r_ref[0, sl].astype(jnp.float32)        # (c, K)
        kk = k_ref[0, sl].astype(jnp.float32)
        vk = v_ref[0, sl].astype(jnp.float32)
        wk = w_ref[0, sl].astype(jnp.float32)
        cs = jnp.cumsum(wk, axis=0)                  # (c, K)
        total = cs[-1]                               # (K,)
        # state contribution
        y = jax.lax.dot_general(rk * jnp.exp(cs - wk), s, (((1,), (0,)), ((), ())))
        # intra-chunk, separable factorization (strictly lower triangular)
        m = 0.5 * (total - wk[0])
        r_f = rk * jnp.exp(cs - wk - m[None, :])
        k_f = kk * jnp.exp(m[None, :] - cs)
        att = jax.lax.dot_general(r_f, k_f, (((1,), (1,)), ((), ())))   # (c, c)
        tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
               > jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
        att = jnp.where(tri, att, 0.0)
        y = y + jax.lax.dot_general(att, vk, (((1,), (0,)), ((), ())))
        # bonus (current token)
        y = y + (rk * u[None, :] * kk).sum(-1, keepdims=True) * vk
        # state update
        wts = jnp.exp(total[None, :] - cs)
        s = jnp.exp(total)[:, None] * s + jax.lax.dot_general(
            kk * wts, vk, (((0,), (0,)), ((), ())))
        y_ref[0, sl] = y.astype(y_ref.dtype)
        return s

    s0 = jnp.zeros((K, K), jnp.float32)
    s = jax.lax.fori_loop(0, n_chunks, body, s0)
    sout_ref[0] = s


def wkv_fwd_pallas(r, k, v, w, u, *, chunk=32, interpret=True):
    """r/k/v/w: (BH, S, K); u: (BH, K) per-head bonus. Returns (y, S_final)."""
    BH, S, K = r.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    kernel = functools.partial(_wkv_kernel, chunk=chunk, n_chunks=S // chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH,),
        in_specs=[
            pl.BlockSpec((1, S, K), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, S, K), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, S, K), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, S, K), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, K), lambda b: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, K), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, K, K), lambda b: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, K), r.dtype),
            jax.ShapeDtypeStruct((BH, K, K), jnp.float32),
        ],
        interpret=interpret,
    )(r, k, v, w, u)
