"""Pallas gather-descent kernel over a packed forest arena.

Candidate-blocked: each program instance descends *all* trees for a
(block_n)-wide slab of the candidate pool, keeping the whole node arena
(feature / threshold / interleaved-children / leaf stats) resident in VMEM —
the arena is O(10^3-10^4) nodes, far under the VMEM budget, while the
candidate axis is the one that scales with pool size. The descent itself is
``depth`` rounds of four gathers (feature, x-value, threshold, child); leaf
self-loops make the loop body branch-free.

Gathers use dynamic advanced indexing, which Mosaic does not lower on all
TPU generations — like the other kernels in this package the wrapper
defaults to ``interpret=True`` and the jnp reference carries CPU execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["forest_eval_pallas"]


def _forest_kernel(feat_ref, thr_ref, child_ref, mean_ref, var_ref, roots_ref,
                   x_ref, m_ref, v_ref, *, depth):
    feat = feat_ref[...]
    thr = thr_ref[...]
    child = child_ref[...]
    roots = roots_ref[...]
    X = x_ref[...]
    T = roots.shape[0]
    Nb, D = X.shape
    xflat = X.reshape(-1)
    col = jax.lax.broadcasted_iota(roots.dtype, (1, Nb), 1) * D
    nid = jnp.broadcast_to(roots[:, None], (T, Nb))

    def body(_, nid):
        f = feat[nid]
        xv = xflat[col + f]
        go_right = (xv > thr[nid]).astype(nid.dtype)
        return child[2 * nid + go_right]

    nid = jax.lax.fori_loop(0, depth, body, nid)
    m_ref[...] = mean_ref[...][nid]
    v_ref[...] = var_ref[...][nid]


def forest_eval_pallas(feat, thr, child, mean, var, roots, X, depth,
                       block_n: int = 128, interpret: bool = True):
    """Per-tree leaf stats via the Pallas descent: (mean, var), each (T, N)."""
    T = roots.shape[0]
    N, D = X.shape
    n_nodes = feat.shape[0]
    block_n = min(block_n, N)
    while N % block_n:
        block_n //= 2
    return pl.pallas_call(
        functools.partial(_forest_kernel, depth=depth),
        grid=(N // block_n,),
        in_specs=[
            pl.BlockSpec((n_nodes,), lambda i: (0,)),
            pl.BlockSpec((n_nodes,), lambda i: (0,)),
            pl.BlockSpec((2 * n_nodes,), lambda i: (0,)),
            pl.BlockSpec((n_nodes,), lambda i: (0,)),
            pl.BlockSpec((n_nodes,), lambda i: (0,)),
            pl.BlockSpec((T,), lambda i: (0,)),
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((T, block_n), lambda i: (0, i)),
            pl.BlockSpec((T, block_n), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, N), mean.dtype),
            jax.ShapeDtypeStruct((T, N), var.dtype),
        ],
        interpret=interpret,
    )(feat, thr, child, mean, var, roots, X)
