"""Pallas gather-descent kernel over a packed forest arena.

Candidate-blocked: each program instance descends *all* trees for a
(block_n)-wide slab of the candidate pool, keeping the whole node arena
(feature / threshold / interleaved-children / leaf stats) resident in VMEM —
the arena is O(10^3-10^4) nodes, far under the VMEM budget, while the
candidate axis is the one that scales with pool size. The descent itself is
``depth`` rounds of four gathers (feature, x-value, threshold, child); leaf
self-loops make the loop body branch-free.

Gathers use dynamic advanced indexing, which Mosaic does not lower on all
TPU generations — like the other kernels in this package the wrapper
defaults to ``interpret=True`` and the jnp reference carries CPU execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["forest_eval_pallas", "chain_ordinals_pallas"]


def _forest_kernel(feat_ref, thr_ref, child_ref, mean_ref, var_ref, roots_ref,
                   x_ref, m_ref, v_ref, *, depth):
    feat = feat_ref[...]
    thr = thr_ref[...]
    child = child_ref[...]
    roots = roots_ref[...]
    X = x_ref[...]
    T = roots.shape[0]
    Nb, D = X.shape
    xflat = X.reshape(-1)
    col = jax.lax.broadcasted_iota(roots.dtype, (1, Nb), 1) * D
    nid = jnp.broadcast_to(roots[:, None], (T, Nb))

    def body(_, nid):
        f = feat[nid]
        xv = xflat[col + f]
        go_right = (xv > thr[nid]).astype(nid.dtype)
        return child[2 * nid + go_right]

    nid = jax.lax.fori_loop(0, depth, body, nid)
    m_ref[...] = mean_ref[...][nid]
    v_ref[...] = var_ref[...][nid]


def forest_eval_pallas(feat, thr, child, mean, var, roots, X, depth,
                       block_n: int = 128, interpret: bool = True):
    """Per-tree leaf stats via the Pallas descent: (mean, var), each (T, N)."""
    T = roots.shape[0]
    N, D = X.shape
    n_nodes = feat.shape[0]
    block_n = min(block_n, N)
    while N % block_n:
        block_n //= 2
    return pl.pallas_call(
        functools.partial(_forest_kernel, depth=depth),
        grid=(N // block_n,),
        in_specs=[
            pl.BlockSpec((n_nodes,), lambda i: (0,)),
            pl.BlockSpec((n_nodes,), lambda i: (0,)),
            pl.BlockSpec((2 * n_nodes,), lambda i: (0,)),
            pl.BlockSpec((n_nodes,), lambda i: (0,)),
            pl.BlockSpec((n_nodes,), lambda i: (0,)),
            pl.BlockSpec((T,), lambda i: (0,)),
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((T, block_n), lambda i: (0, i)),
            pl.BlockSpec((T, block_n), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, N), mean.dtype),
            jax.ShapeDtypeStruct((T, N), var.dtype),
        ],
        interpret=interpret,
    )(feat, thr, child, mean, var, roots, X)


def _chain_kernel(wx_ref, wb_ref, perm_ref, idx_ref, *, d, n_words):
    """Prefix/suffix-AND walk for one Shapley chain (QuickScorer exit).

    Statically unrolled over the d permutation levels: build the running
    prefix-AND of the chain's x-term words, then walk levels d..0 keeping
    the suffix-AND of background-term words; the exit leaf of
    (level, background row) is the lowest set bit of prefix & suffix —
    word 0 scanned first for two-word trees. Pure uint64 bit ops; the
    float leaf gather stays on the host so values match the numpy walk
    bit-for-bit.
    """
    wx = wx_ref[...][0]          # (d, T, W)
    wb = wb_ref[...]             # (nb, d, T, W)
    perm = perm_ref[...][0]      # (d,)
    ones = ~jnp.uint64(0)

    pref = [jnp.full(wx.shape[1:], ones, dtype=jnp.uint64)]
    for k in range(d):
        pref.append(pref[k] & jnp.take(wx, perm[k], axis=0))

    suf = jnp.full(wb.shape[:1] + wb.shape[2:], ones, dtype=jnp.uint64)
    for k in range(d, -1, -1):
        acc = pref[k][None] & suf                       # (nb, T, W)
        lsb = acc & (jnp.uint64(0) - acc)
        pc = jax.lax.population_count(lsb - jnp.uint64(1)).astype(jnp.int32)
        o = pc[..., 0]
        for w in range(1, n_words):
            o = jnp.where(acc[..., w - 1] != 0, o, 64 * w + pc[..., w])
        idx_ref[0, k] = o
        if k > 0:
            suf = suf & jnp.take(wb, perm[k - 1], axis=1)


def chain_ordinals_pallas(word_x, word_b, perms, interpret: bool = True):
    """(C, d+1, nb, T) exit-leaf ordinals via the Pallas chain walk.

    Accepts the ``ChainPlan.row_words`` layouts — (n, d, T) one-word or
    (n, d, T, W) two-word — and returns exactly what the numpy
    ``_leaf_ordinals`` walk would. One program instance per chain; the
    background word block is shared by every instance.
    """
    import numpy as np

    if word_x.ndim == 3:
        word_x = word_x[..., None]
        word_b = word_b[..., None]
    C, d, T, W = word_x.shape
    nb = word_b.shape[0]
    with jax.experimental.enable_x64(True):
        idx = pl.pallas_call(
            functools.partial(_chain_kernel, d=d, n_words=W),
            grid=(C,),
            in_specs=[
                pl.BlockSpec((1, d, T, W), lambda c: (c, 0, 0, 0)),
                pl.BlockSpec((nb, d, T, W), lambda c: (0, 0, 0, 0)),
                pl.BlockSpec((1, d), lambda c: (c, 0)),
            ],
            out_specs=pl.BlockSpec((1, d + 1, nb, T), lambda c: (c, 0, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((C, d + 1, nb, T), jnp.int32),
            interpret=interpret,
        )(jnp.asarray(word_x), jnp.asarray(word_b),
          jnp.asarray(perms, dtype=jnp.int32))
        return np.asarray(idx).astype(np.intp)
