"""Bitvector chain evaluator for the batched Shapley plane.

The §5.1 attribution path evaluates, per (config, permutation) chain, the
(d+1) prefix-composite rows ``z_S`` (x on the prefix set S, background
elsewhere) averaged over every background row. A gather descent costs
O(trees * depth) random accesses per composite row; this module replaces it
with a QuickScorer-style bitvector evaluation (Lucchese et al., SIGIR'15)
that exploits the chain structure:

* Each tree's leaves get ordinals in left-to-right order (<= 64 per tree,
  one uint64 word). Every internal node carries a mask clearing its left
  subtree's leaf bits; a row's exit leaf is the lowest set bit of the AND
  of the masks of all *false* nodes (``v > thr``, i.e. the row goes right).
* Which nodes are false depends only on per-feature threshold *ranks*, so
  per feature we sort the split thresholds and prefix-AND their masks:
  ``table[j][r]`` = AND of masks of the r smallest thresholds — the false
  set of any value v with rank r = #(thr < v). Rank compares replay the
  descent's exact float comparisons, so the exit leaf is identical.
* A composite row's value vector mixes x and background coordinates by the
  prefix mask, so its AND factorizes along the permutation: AND of x-term
  words over the prefix, AND of background-term words over the suffix.
  Prefix/suffix cumulative ANDs turn the whole chain into ~1 word-AND per
  (level, background row) instead of a fresh descent.

Leaf means are the exact arena floats and the ensemble reduction replays
``PackedForest.combine``'s mean ops on the same (trees, rows) layout, so
chain values are bit-identical to evaluating the materialized composite
tensor through ``PackedForest.predict`` (see tests/test_shapley_batched.py).

``build_chain_plan`` returns None when the encoding does not apply (a tree
with more than 64 leaves, or more than 64 features); callers fall back to
the generic composite-tensor path. Values must be NaN-free (threshold
ranks come from ``np.searchsorted``).
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

__all__ = ["ChainPlan", "build_chain_plan", "chain_decline_reason"]

_ONES = np.uint64(0xFFFFFFFFFFFFFFFF)
_PLAN_ATTR = "_chain_plan_cache"

# why the most recent build_chain_plan call returned None ("" after a success);
# surfaces the fallback cause so callers/tests can assert it instead of
# guessing from a bare None
_DECLINE_REASON = ""


def chain_decline_reason() -> str:
    """Reason the last ``build_chain_plan`` call declined, "" on success."""
    return _DECLINE_REASON


class ChainPlan:
    """Per-forest precompute: feature threshold tables + leaf ordinals."""

    def __init__(self, forest, d: int,
                 thrs: List[np.ndarray], tables: List[np.ndarray],
                 leaf_mean: np.ndarray, leaf_offs: np.ndarray):
        self.forest = forest          # PackedForest (for the y denorm)
        self.d = d
        self.thrs = thrs              # per feature: sorted split thresholds
        self.tables = tables          # per feature: (n_thr + 1, T) prefix-ANDs
        self.leaf_mean = leaf_mean    # flat leaf means, ordinal-indexed
        self.leaf_offs = leaf_offs    # (T,) offsets into the flat leaf array

    @property
    def n_trees(self) -> int:
        return len(self.leaf_offs)

    def row_words(self, V: np.ndarray) -> np.ndarray:
        """Per-row false-node words, shape (n, d, T).

        ``word[i, j]`` is the AND of the masks of every node on feature j
        that row i's value makes false — rank r = #(thr < v) via
        ``searchsorted(..., 'left')``, the exact ``v > thr`` comparison of
        the packed descent.
        """
        V = np.asarray(V, dtype=float)
        out = np.empty((len(V), self.d, self.n_trees), dtype=np.uint64)
        for j in range(self.d):
            out[:, j, :] = self.tables[j][
                np.searchsorted(self.thrs[j], V[:, j], side="left")
            ]
        return out

    def eval_chains(
        self,
        X: np.ndarray,
        background: np.ndarray,
        perms: np.ndarray,
        x_of_chain: np.ndarray,
    ) -> np.ndarray:
        """Chain values for (chain, level): E_b[f(z_{S_k})], shape (C, d+1).

        perms: (C, d) permutation per chain; x_of_chain: (C,) row of X each
        chain explains. Matches the composite-tensor path bit-for-bit: the
        exact mean ops of ``PackedForest.combine`` over the full (T, rows)
        block, then the same contiguous-axis mean over background rows.
        """
        d, nb, T = self.d, len(background), self.n_trees
        C = len(perms)
        word_x = self.row_words(X)[x_of_chain]        # (C, d, T)
        word_b = self.row_words(background)           # (nb, d, T)

        # prefix-AND of x-term words along each chain
        pref = np.empty((C, d + 1, T), dtype=np.uint64)
        pref[:, 0] = _ONES
        for k in range(d):
            pref[:, k + 1] = pref[:, k] & np.take_along_axis(
                word_x, perms[:, k][:, None, None], axis=1
            )[:, 0]

        # walk levels d..0 keeping the running suffix-AND of background-term
        # words; the exit leaf of row (chain, level, bg) is the lowest set
        # bit of pref & suffix (QuickScorer), extracted via the float64
        # exponent of the isolated bit (exact for powers of two)
        idx = np.empty((C, d + 1, nb, T), dtype=np.intp)
        suf = np.broadcast_to(_ONES, (C, nb, T)).copy()
        for k in range(d, -1, -1):
            acc = pref[:, k][:, None, :] & suf
            low = acc & (np.uint64(0) - acc)
            idx[:, k] = (
                (low.astype(np.float64).view(np.uint64) >> np.uint64(52))
                - np.uint64(1023)
            ).astype(np.intp)
            if k > 0:
                suf &= word_b[:, perms[:, k - 1], :].transpose(1, 0, 2)

        flat = np.ascontiguousarray((idx + self.leaf_offs).reshape(-1, T).T)
        m_t = self.leaf_mean.take(flat)               # (T, rows) C-contiguous
        # ``PackedForest.combine``'s mean output never reads the variance
        # stats: replaying its exact mean ops here (sequential tree-axis
        # reduction on the C-contiguous (T, rows) block, then denorm) keeps
        # bit-identity while skipping the leaf-variance gather entirely
        mean_rows = m_t.mean(axis=0) * self.forest.y_std + self.forest.y_mean
        return mean_rows.reshape(C, d + 1, nb).mean(axis=2)


def _pack_of(model):
    """PackedForest from a PRF/PackedForest-like model, else None."""
    pack = getattr(model, "pack", None)
    if callable(pack):
        try:
            return pack()
        except Exception:
            return None
    return model if hasattr(model, "roots") and hasattr(model, "combine") else None


def build_chain_plan(model, d: int) -> Optional[ChainPlan]:
    """Build (and cache on the packed arena) a ChainPlan, or None.

    None when the model is not a packable forest, a tree exceeds 64 leaves
    (one uint64 word per tree), or d > 64 (prefix sets as mask bits).
    """
    global _DECLINE_REASON
    pf = _pack_of(model)
    if pf is None:
        _DECLINE_REASON = "not a packable forest"
        return None
    if d > 64:
        _DECLINE_REASON = f"d={d} > 64 prefix-mask bits"
        return None
    cached = getattr(pf, _PLAN_ATTR, None)
    if cached is not None and cached[0] == d:
        _DECLINE_REASON = ""
        return cached[1]

    feat, thr, child = pf.feat, pf.thr, pf.child
    nodes_by_feat: List[List[Tuple[float, int, np.uint64]]] = [[] for _ in range(d)]
    leaf_mean: List[float] = []
    leaf_offs = np.empty(pf.n_trees, dtype=np.intp)

    for t in range(pf.n_trees):
        leaf_offs[t] = len(leaf_mean)
        # iterative DFS: leaves get ordinals left-to-right; internal nodes
        # record (thr, tree, mask clearing the left subtree's leaf span)
        base = len(leaf_mean)
        stack = [(int(pf.roots[t]), False)]
        spans = {}  # node -> (lo, hi) leaf-ordinal range within this tree
        while stack:
            n, expanded = stack.pop()
            if child[2 * n] == n:  # leaf: self-loop encoding
                spans[n] = (len(leaf_mean) - base, len(leaf_mean) - base + 1)
                leaf_mean.append(float(pf.mean[n]))
                continue
            if not expanded:
                stack.append((n, True))
                stack.append((int(child[2 * n + 1]), False))
                stack.append((int(child[2 * n]), False))
                continue
            lo, mid = spans[int(child[2 * n])]
            _, hi = spans[int(child[2 * n + 1])]
            spans[n] = (lo, hi)
            if int(feat[n]) >= d:
                _DECLINE_REASON = (
                    f"tree {t} splits on feature {int(feat[n])} outside the "
                    f"{d}-dim space"
                )
                return None
            if hi > 64:
                _DECLINE_REASON = (
                    f"tree {t} has {hi} leaves > 64-bit leaf word"
                )
                return None
            span = np.uint64(((1 << (mid - lo)) - 1) << lo)
            nodes_by_feat[int(feat[n])].append(
                (float(thr[n]), t, np.uint64(~span & _ONES))
            )

    thrs, tables = [], []
    for j in range(d):
        nds = sorted(nodes_by_feat[j], key=lambda z: z[0])
        tab = np.full((len(nds) + 1, pf.n_trees), _ONES, dtype=np.uint64)
        for r, (_, t, m) in enumerate(nds):
            tab[r + 1] = tab[r]
            tab[r + 1, t] &= m
        thrs.append(np.array([z[0] for z in nds]))
        tables.append(tab)

    plan = ChainPlan(pf, d, thrs, tables, np.asarray(leaf_mean), leaf_offs)
    _DECLINE_REASON = ""
    try:
        setattr(pf, _PLAN_ATTR, (d, plan))
    except Exception:
        pass  # frozen/slotted arena: just skip the cache
    return plan
