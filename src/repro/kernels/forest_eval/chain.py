"""Bitvector chain evaluator for the batched Shapley plane.

The §5.1 attribution path evaluates, per (config, permutation) chain, the
(d+1) prefix-composite rows ``z_S`` (x on the prefix set S, background
elsewhere) averaged over every background row. A gather descent costs
O(trees * depth) random accesses per composite row; this module replaces it
with a QuickScorer-style bitvector evaluation (Lucchese et al., SIGIR'15)
that exploits the chain structure:

* Each tree's leaves get ordinals in left-to-right order, packed into
  ``W`` uint64 leaf words per tree (W = 1 up to 64 leaves, W = 2 up to
  128 — leaf L lives in word L // 64, bit L % 64). Every internal node
  carries masks clearing its left subtree's leaf bits; a row's exit leaf
  is the lowest set bit across the ANDed word vector of all *false* nodes
  (``v > thr``, i.e. the row goes right) — word 0 scanned first.
* Which nodes are false depends only on per-feature threshold *ranks*, so
  per feature we sort the split thresholds and prefix-AND their masks:
  ``table[j][r]`` = AND of masks of the r smallest thresholds — the false
  set of any value v with rank r = #(thr < v). Rank compares replay the
  descent's exact float comparisons, so the exit leaf is identical.
* A composite row's value vector mixes x and background coordinates by the
  prefix mask, so its AND factorizes along the permutation: AND of x-term
  words over the prefix, AND of background-term words over the suffix.
  Prefix/suffix cumulative ANDs turn the whole chain into ~1 word-AND per
  (level, background row) instead of a fresh descent.

Leaf means are the exact arena floats and the ensemble reduction replays
``PackedForest.combine``'s mean ops on the same (trees, rows) layout, so
chain values are bit-identical to evaluating the materialized composite
tensor through ``PackedForest.predict`` (see tests/test_shapley_batched.py).

The leaf-ordinal walk and prefix-AND table construction are shared with
the fused propose step's merged QuickScorer plan
(``propose.build_qs_plan``) via :func:`pack_leaf_spans` /
:func:`build_false_tables`.

``build_chain_plan_ex`` returns ``(plan, reason)`` — ``(None, why)`` when
the encoding does not apply (a tree with more than 128 leaves, or more
than 64 features); callers fall back to the generic composite-tensor
path. Values must be NaN-free (threshold ranks come from
``np.searchsorted``).
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

__all__ = [
    "ChainPlan",
    "PoolPlan",
    "pack_leaf_spans",
    "build_false_tables",
    "build_chain_plan",
    "build_chain_plan_ex",
    "build_pool_plan_ex",
    "chain_decline_reason",
]

_ONES = np.uint64(0xFFFFFFFFFFFFFFFF)
_PLAN_ATTR = "_chain_plan_cache"
_POOL_PLAN_ATTR = "_pool_plan_cache"

# the widest supported leaf word vector: 2 x uint64 = 128 leaves per tree
MAX_LEAF_WORDS = 2

# why the most recent build_chain_plan call returned None ("" after a success).
# Back-compat only: the reason now travels on the (plan, reason) return of
# ``build_chain_plan_ex`` so interleaved builds can't clobber each other;
# this slot just mirrors the latest call for the legacy accessor.
_DECLINE_REASON = ""


def chain_decline_reason() -> str:
    """Reason the last ``build_chain_plan`` call declined, "" on success.

    Back-compat shim over the module-global last-call slot — prefer the
    ``reason`` returned by :func:`build_chain_plan_ex`, which is immune to
    interleaved builds.
    """
    return _DECLINE_REASON


# ---------------------------------------------------------------------------
# shared packer: leaf-ordinal walk + per-feature false-set tables
# ---------------------------------------------------------------------------


def pack_leaf_spans(feat, thr, child, mean, var, roots, d):
    """Walk every tree of a packed arena, assigning leaf ordinals
    left-to-right and collecting per-feature split spans.

    Returns ``(payload, reason)`` where payload is ``None`` with a decline
    reason, or ``(nodes_by_feat, leaf_mean, leaf_var, leaf_offs, n_words)``:

    * nodes_by_feat[j] — list of ``(thr, tree, lo, mid)`` spans: the node
      splits feature j at thr, and its false mask clears leaf ordinals
      [lo, mid) of that tree.
    * leaf_mean / leaf_var — flat float64 leaf stats, ordinal-indexed via
      leaf_offs (T,).
    * n_words — uint64 leaf words per tree (1 or 2) for the widest tree.
    """
    T = len(roots)
    nodes_by_feat: List[List[Tuple[float, int, int, int]]] = [[] for _ in range(d)]
    leaf_mean: List[float] = []
    leaf_var: List[float] = []
    leaf_offs = np.empty(T, dtype=np.int64)
    n_leaves_max = 0
    for t in range(T):
        base = len(leaf_mean)
        leaf_offs[t] = base
        stack = [(int(roots[t]), False)]
        spans = {}  # node -> (lo, hi) leaf-ordinal range within this tree
        while stack:
            n, expanded = stack.pop()
            if child[2 * n] == n:  # leaf: self-loop encoding
                spans[n] = (len(leaf_mean) - base, len(leaf_mean) - base + 1)
                leaf_mean.append(float(mean[n]))
                leaf_var.append(float(var[n]))
                continue
            if not expanded:
                stack.append((n, True))
                stack.append((int(child[2 * n + 1]), False))
                stack.append((int(child[2 * n]), False))
                continue
            lo, mid = spans[int(child[2 * n])]
            _, hi = spans[int(child[2 * n + 1])]
            spans[n] = (lo, hi)
            if int(feat[n]) >= d:
                return None, (
                    f"tree {t} splits on feature {int(feat[n])} outside the "
                    f"{d}-dim space"
                )
            if hi > 64 * MAX_LEAF_WORDS:
                return None, (
                    f"tree {t} has {hi} leaves > "
                    f"{64 * MAX_LEAF_WORDS}-bit leaf words"
                )
            n_leaves_max = max(n_leaves_max, hi)
            nodes_by_feat[int(feat[n])].append((float(thr[n]), t, lo, mid))
    n_words = 1 if n_leaves_max <= 64 else 2
    return (
        nodes_by_feat,
        np.asarray(leaf_mean),
        np.asarray(leaf_var),
        leaf_offs,
        n_words,
    ), ""


def _span_mask(lo: int, mid: int, w: int) -> np.uint64:
    """uint64 word ``w`` of the mask clearing leaf ordinals [lo, mid)."""
    a = min(max(lo - 64 * w, 0), 64)
    b = min(max(mid - 64 * w, 0), 64)
    if b <= a:
        return _ONES
    return np.uint64(~(((1 << (b - a)) - 1) << a) & int(_ONES))


def build_false_tables(nodes_by_feat, T: int, n_words: int):
    """Per-feature sorted thresholds + prefix-ANDed false-set tables.

    Returns ``(thrs, tables)``: tables[j] has shape (n_thr + 1, T) for one
    leaf word, (n_thr + 1, T, n_words) otherwise — row r is the AND of the
    masks of the r smallest thresholds on that feature.
    """
    thrs, tables = [], []
    for nds in (sorted(f, key=lambda z: z[0]) for f in nodes_by_feat):
        shape = (len(nds) + 1, T) if n_words == 1 else (len(nds) + 1, T, n_words)
        tab = np.full(shape, _ONES, dtype=np.uint64)
        for r, (_, t, lo, mid) in enumerate(nds):
            tab[r + 1] = tab[r]
            if n_words == 1:
                tab[r + 1, t] &= _span_mask(lo, mid, 0)
            else:
                for w in range(n_words):
                    tab[r + 1, t, w] &= _span_mask(lo, mid, w)
        thrs.append(np.array([z[0] for z in nds]))
        tables.append(tab)
    return thrs, tables


def _lowbit_ordinal(acc: np.ndarray) -> np.ndarray:
    """Ordinal of the lowest set bit of each uint64 (via the float64
    exponent of the isolated bit — exact for powers of two); an all-zero
    word yields a negative garbage value the caller must mask."""
    low = acc & (np.uint64(0) - acc)
    return (
        (low.astype(np.float64).view(np.uint64) >> np.uint64(52))
        - np.uint64(1023)
    ).astype(np.intp)


class ChainPlan:
    """Per-forest precompute: feature threshold tables + leaf ordinals."""

    def __init__(self, forest, d: int,
                 thrs: List[np.ndarray], tables: List[np.ndarray],
                 leaf_mean: np.ndarray, leaf_offs: np.ndarray,
                 n_words: int = 1):
        self.forest = forest          # PackedForest (for the y denorm)
        self.d = d
        self.thrs = thrs              # per feature: sorted split thresholds
        self.tables = tables          # per feature: (n_thr + 1, T[, W]) prefix-ANDs
        self.leaf_mean = leaf_mean    # flat leaf means, ordinal-indexed
        self.leaf_offs = leaf_offs    # (T,) offsets into the flat leaf array
        self.n_words = n_words        # uint64 leaf words per tree (1 or 2)
        self.decline_reason = ""      # always "" on a built plan

    @property
    def n_trees(self) -> int:
        return len(self.leaf_offs)

    def row_words(self, V: np.ndarray) -> np.ndarray:
        """Per-row false-node words, shape (n, d, T) or (n, d, T, W).

        ``word[i, j]`` is the AND of the masks of every node on feature j
        that row i's value makes false — rank r = #(thr < v) via
        ``searchsorted(..., 'left')``, the exact ``v > thr`` comparison of
        the packed descent.
        """
        V = np.asarray(V, dtype=float)
        shape = (len(V), self.d, self.n_trees)
        if self.n_words > 1:
            shape += (self.n_words,)
        out = np.empty(shape, dtype=np.uint64)
        for j in range(self.d):
            out[:, j] = self.tables[j][
                np.searchsorted(self.thrs[j], V[:, j], side="left")
            ]
        return out

    def _leaf_ordinals(self, word_x, word_b, perms):
        """(C, d+1, nb, T) exit-leaf ordinals for every (chain, level, bg).

        Prefix-AND of x-term words along each chain, then a level walk
        d..0 keeping the running suffix-AND of background-term words; the
        exit leaf of row (chain, level, bg) is the lowest set bit of
        pref & suffix (QuickScorer) — word 0 first for two-word trees.
        """
        C, d, T = word_x.shape[:3]
        nb = word_b.shape[0]
        two = self.n_words > 1
        tail = (T, self.n_words) if two else (T,)
        pidx = perms[:, :, None, None] if two else perms[:, :, None]

        pref = np.empty((C, d + 1) + tail, dtype=np.uint64)
        pref[:, 0] = _ONES
        for k in range(d):
            pref[:, k + 1] = pref[:, k] & np.take_along_axis(
                word_x, pidx[:, k][:, None], axis=1
            )[:, 0]

        idx = np.empty((C, d + 1, nb, T), dtype=np.intp)
        suf = np.broadcast_to(_ONES, (C, nb) + tail).copy()
        for k in range(d, -1, -1):
            acc = pref[:, k][:, None] & suf
            if two:
                o0 = _lowbit_ordinal(acc[..., 0])
                o1 = _lowbit_ordinal(acc[..., 1])
                idx[:, k] = np.where(acc[..., 0] != 0, o0, 64 + o1)
            else:
                idx[:, k] = _lowbit_ordinal(acc)
            if k > 0:
                wb = word_b[:, perms[:, k - 1]]  # (nb, C, ...) fancy-indexed
                suf &= np.moveaxis(wb, 0, 1)
        return idx

    def eval_chains(
        self,
        X: np.ndarray,
        background: np.ndarray,
        perms: np.ndarray,
        x_of_chain: np.ndarray,
        backend: str = "numpy",
    ) -> np.ndarray:
        """Chain values for (chain, level): E_b[f(z_{S_k})], shape (C, d+1).

        perms: (C, d) permutation per chain; x_of_chain: (C,) row of X each
        chain explains. Matches the composite-tensor path bit-for-bit: the
        exact mean ops of ``PackedForest.combine`` over the full (T, rows)
        block, then the same contiguous-axis mean over background rows.

        ``backend="pallas"`` runs the integer prefix/suffix-AND walk in
        the pallas chain-ordinal kernel (``kernel.chain_ordinals_pallas``);
        the leaf ordinals are integers either way, so the float tail is
        shared and the values stay bit-identical.
        """
        d, nb, T = self.d, len(background), self.n_trees
        C = len(perms)
        word_x = self.row_words(X)[x_of_chain]        # (C, d, T[, W])
        word_b = self.row_words(background)           # (nb, d, T[, W])

        if backend == "pallas":
            from .kernel import chain_ordinals_pallas
            idx = chain_ordinals_pallas(word_x, word_b,
                                        np.asarray(perms, dtype=np.int32))
        else:
            idx = self._leaf_ordinals(word_x, word_b, perms)

        flat = np.ascontiguousarray((idx + self.leaf_offs).reshape(-1, T).T)
        m_t = self.leaf_mean.take(flat)               # (T, rows) C-contiguous
        # ``PackedForest.combine``'s mean output never reads the variance
        # stats: replaying its exact mean ops here (sequential tree-axis
        # reduction on the C-contiguous (T, rows) block, then denorm) keeps
        # bit-identity while skipping the leaf-variance gather entirely
        mean_rows = m_t.mean(axis=0) * self.forest.y_std + self.forest.y_mean
        return mean_rows.reshape(C, d + 1, nb).mean(axis=2)


def _pack_of(model):
    """PackedForest from a PRF/PackedForest-like model, else None."""
    pack = getattr(model, "pack", None)
    if callable(pack):
        try:
            return pack()
        except Exception:
            return None
    return model if hasattr(model, "roots") and hasattr(model, "combine") else None


def build_chain_plan_ex(model, d: int) -> Tuple[Optional[ChainPlan], str]:
    """Build (and cache on the packed arena) a ChainPlan.

    Returns ``(plan, "")`` on success and ``(None, reason)`` when the
    model is not a packable forest, a tree exceeds 64 * MAX_LEAF_WORDS
    leaves, or d > 64 (prefix sets as mask bits).
    """
    pf = _pack_of(model)
    if pf is None:
        return None, "not a packable forest"
    if d > 64:
        return None, f"d={d} > 64 prefix-mask bits"
    cached = getattr(pf, _PLAN_ATTR, None)
    if cached is not None and cached[0] == d:
        return cached[1], ""

    packed, reason = pack_leaf_spans(pf.feat, pf.thr, pf.child, pf.mean,
                                     pf.var, pf.roots, d)
    if packed is None:
        return None, reason
    nodes_by_feat, leaf_mean, _leaf_var, leaf_offs, n_words = packed
    thrs, tables = build_false_tables(nodes_by_feat, pf.n_trees, n_words)

    plan = ChainPlan(pf, d, thrs, tables, leaf_mean,
                     leaf_offs.astype(np.intp), n_words)
    try:
        setattr(pf, _PLAN_ATTR, (d, plan))
    except Exception:
        pass  # frozen/slotted arena: just skip the cache
    return plan, ""


def build_chain_plan(model, d: int) -> Optional[ChainPlan]:
    """Back-compat wrapper over :func:`build_chain_plan_ex`; the decline
    reason lands in the legacy ``chain_decline_reason()`` slot."""
    global _DECLINE_REASON
    plan, _DECLINE_REASON = build_chain_plan_ex(model, d)
    return plan


# ---------------------------------------------------------------------------
# delta pool scoring: per-base shared-coordinate AND reuse
# ---------------------------------------------------------------------------


class PoolPlan:
    """False-word tables over a (possibly fused multi-forest) arena for
    whole-pool leaf routing with per-base delta reuse.

    Mutation pools share most coordinates with their base incumbent: a
    candidate's QuickScorer word is the AND of its d per-feature false
    words, and every unmutated coordinate contributes the *base's* word.
    Per base this plan precomputes a doubling (sparse) range-AND table
    over the feature axis — AND is idempotent, so any feature segment is
    two overlapping power-of-two lookups — and per candidate re-ANDs only
    the mutated coordinates plus one segment per gap between them. Leaf
    routing is bit-identical to the gather descent (the rank compare
    replays ``v > thr`` exactly), so ``predict`` through this plan returns
    the descent's exact leaf stats.
    """

    def __init__(self, d: int,
                 thrs: List[np.ndarray], tables: List[np.ndarray],
                 leaf_mean: np.ndarray, leaf_var: np.ndarray,
                 leaf_offs: np.ndarray, n_words: int = 1):
        self.d = d
        self.thrs = thrs
        self.tables = tables
        self.leaf_mean = leaf_mean
        self.leaf_var = leaf_var
        self.leaf_offs = leaf_offs
        self.n_words = n_words
        self.decline_reason = ""

    @property
    def n_trees(self) -> int:
        return len(self.leaf_offs)

    # same per-row false-word gather as the chain plan
    row_words = ChainPlan.row_words

    def _ordinals(self, acc: np.ndarray) -> np.ndarray:
        """Exit-leaf ordinals from ANDed word vectors (word 0 first)."""
        if self.n_words == 1:
            return _lowbit_ordinal(acc)
        o0 = _lowbit_ordinal(acc[..., 0])
        o1 = _lowbit_ordinal(acc[..., 1])
        return np.where(acc[..., 0] != 0, o0, 64 + o1)

    def leaf_stats(self, X: np.ndarray, bases: np.ndarray,
                   base_of: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(m_t, v_t), each (T, N) — the descent's exact per-tree leaf stats.

        ``bases`` is the (B, d) matrix of base rows; ``base_of[i]`` names
        candidate i's base (-1 = no base: a fresh random sample, evaluated
        by the vectorized full-row AND instead). Which coordinates mutated
        is recovered by exact value comparison against the base row — a
        mutation that lands back on the base value is simply shared.
        """
        X = np.asarray(X, dtype=float)
        N = len(X)
        T = self.n_trees
        base_of = np.asarray(base_of)
        m_t = np.empty((T, N))
        v_t = np.empty((T, N))

        free = np.flatnonzero(base_of < 0)
        if free.size:
            acc = np.bitwise_and.reduce(self.row_words(X[free]), axis=1)
            flat = self._ordinals(acc) + self.leaf_offs  # (nf, T)
            m_t[:, free] = self.leaf_mean.take(flat).T
            v_t[:, free] = self.leaf_var.take(flat).T

        mut = np.flatnonzero(base_of >= 0)
        if mut.size:
            bases = np.asarray(bases, dtype=float)
            bw = self.row_words(bases)                   # (B, d, T[, W])
            # doubling range-AND table: lvl[l][:, j] = AND of the words of
            # features [j, j + 2^l); idempotence lets two overlapping
            # power-of-two segments cover any [a, b)
            lvls = [bw]
            span = 1
            while span < self.d:
                prev = lvls[-1]
                nxt = prev.copy()
                nxt[:, : self.d - span] &= prev[:, span:]
                lvls.append(nxt)
                span *= 2

            def seg(b: int, a: int, e: int) -> np.ndarray:
                l = (e - a).bit_length() - 1
                return lvls[l][b, a] & lvls[l][b, e - (1 << l)]

            for i in mut:
                b = int(base_of[i])
                changed = np.flatnonzero(X[i] != bases[b])
                acc = np.broadcast_to(
                    _ONES, self.tables[0].shape[1:]
                ).copy()
                prev_j = 0
                for j in changed:
                    j = int(j)
                    if j > prev_j:
                        acc &= seg(b, prev_j, j)
                    acc &= self.tables[j][
                        int(np.searchsorted(self.thrs[j], X[i, j], side="left"))
                    ]
                    prev_j = j + 1
                if prev_j < self.d:
                    acc &= seg(b, prev_j, self.d)
                flat = self._ordinals(acc) + self.leaf_offs
                m_t[:, i] = self.leaf_mean[flat]
                v_t[:, i] = self.leaf_var[flat]
        return m_t, v_t


def build_pool_plan_ex(arena, d: int) -> Tuple[Optional["PoolPlan"], str]:
    """Build (and cache on the arena object) a PoolPlan.

    ``arena`` is anything carrying the packed node arrays (a PackedForest
    or a fused ForestPlane). Returns ``(plan, "")`` or ``(None, reason)``
    under the same decline conditions as :func:`build_chain_plan_ex`.
    """
    for attr in ("feat", "thr", "child", "mean", "var", "roots"):
        if not hasattr(arena, attr):
            return None, "not a packed arena"
    if d > 64:
        return None, f"d={d} > 64 prefix-mask bits"
    cached = getattr(arena, _POOL_PLAN_ATTR, None)
    if cached is not None and cached[0] == d:
        return cached[1], cached[2]

    packed, reason = pack_leaf_spans(arena.feat, arena.thr, arena.child,
                                     arena.mean, arena.var, arena.roots, d)
    if packed is None:
        plan = None
    else:
        nodes_by_feat, leaf_mean, leaf_var, leaf_offs, n_words = packed
        thrs, tables = build_false_tables(nodes_by_feat, len(arena.roots),
                                          n_words)
        plan = PoolPlan(d, thrs, tables, leaf_mean, leaf_var,
                        leaf_offs.astype(np.intp), n_words)
        reason = ""
    try:
        setattr(arena, _POOL_PLAN_ATTR, (d, plan, reason))
    except Exception:
        pass  # slotted arena: just skip the cache
    return plan, reason
