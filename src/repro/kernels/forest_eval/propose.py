"""Fused on-device BO propose step (jax, x64).

One jitted program runs an entire propose iteration with zero host round
trips until the chosen candidate indices come back: pool draw (uniform +
LHS halves in unit space, replaying ``SpacePlane._quantile_col`` /
``_to_unit_col`` from the uploaded transform tables), packed-forest descent
(merged QuickScorer bitvector tables across all sources' trees by default;
the ``forest_eval`` gather or pallas kernel otherwise), per-source ensemble
combine, EI, weighted rank aggregation, and stable top-k.

Bit-equivalence contract (vs the numpy acquisition reference):

* Descent does no float arithmetic — leaf routing is bit-exact (PR 2).
* The combine unrolls ``PackedForest.combine``'s numpy op sequence per
  source at trace time: numpy's axis-0 mean/var reduce rows *sequentially*,
  so the jax side accumulates tree rows in the same order.
* EI instantiates the same portable Cephes expression tree as the numpy
  reference (``acquisition.make_portable_kernels``).
* Rank aggregation dispatches on a static ``rank_impl`` (see ``rank.py``):
  the default CPU path ranks each row with the host radix kernel through a
  ``pure_callback`` (~5x the sort path at 131072), while ``"sort"`` keeps
  the monotone-uint64 ``lax.sort`` + scatter-add reference. Every impl
  produces the exact stable-argsort ranks and accumulates w_s * rank_s in
  source order — numpy's exact per-element add sequence — so the aggregate
  is bit-identical across impls.
* Every product that can feed an add is routed through an XOR-seal
  (:func:`seal`) — a bitcast round trip XORed with a *runtime* uint64 zero
  argument. XLA cannot constant-fold it (the zero is a parameter) and LLVM
  cannot contract a multiply with an integer XOR in between into an FMA,
  which is the one source of 1-ulp divergence on XLA:CPU. Overhead ~2%.

Pool shapes are padded to power-of-two buckets (256 … 131072) so a tuning
run compiles a handful of programs, not one per pool size. Padding rows
are appended *after* the real rows and forced to EI = -1 (< any real EI,
which is >= 0), so under a stable descending sort every real row keeps its
exact unpadded rank; aggregate ranks of padding are masked to +inf before
the final stable top-k argsort.

``propose_scan`` wraps the same step body in ``lax.scan``, splitting the
PRNG key per step — the multi-step inner loop the ISSUE asks for.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

try:
    import jax
    import jax.numpy as jnp
    from jax import lax
except ImportError as _e:  # pragma: no cover - jax ships with the image
    jax = None
    _jax_err = _e

from ...core import acquisition as _acq
from . import rank as _rank
from .ref import _descend

__all__ = [
    "POOL_BUCKET_MIN",
    "POOL_BUCKET_MAX",
    "pool_bucket",
    "seal",
    "build_qs_plan",
    "build_qs_plan_ex",
    "propose_step",
    "propose_scan",
    "ei_host",
    "aggregate_ranks_host",
]

# Bucketed-shape protocol: pool sizes pad up to the next power of two in
# [256, 131072]; larger pools keep padding to powers of two (the jit cache
# then grows logarithmically, guarded by the bench).
POOL_BUCKET_MIN = 256
POOL_BUCKET_MAX = 131072


def pool_bucket(n: int) -> int:
    """Power-of-two pool bucket for ``n`` candidates (>= POOL_BUCKET_MIN)."""
    return max(POOL_BUCKET_MIN, 1 << (max(int(n), 1) - 1).bit_length())


def _require_jax():
    if jax is None:  # pragma: no cover
        raise RuntimeError(f"jax is required for the fused propose step: {_jax_err}")


def _x64():
    _require_jax()
    return jax.experimental.enable_x64(True)


# ---------------------------------------------------------------------------
# FMA barrier + portable-kernel plumbing
# ---------------------------------------------------------------------------


def seal(x, zi):
    """FMA barrier: bitcast -> XOR with runtime-zero ``zi`` -> bitcast back.

    Value-preserving, but opaque to both XLA's algebraic simplifier (zi is
    a parameter, not a constant) and LLVM's fmul+fadd contraction (integer
    ops break the float dataflow). Apply to any product that may feed an
    add/sub when bit-identity with numpy matters.
    """
    bits = lax.bitcast_convert_type(x, jnp.uint64)
    return lax.bitcast_convert_type(bits ^ zi, jnp.float64)


def _seal_mul(zi):
    def mul(a, b):
        return seal(jnp.multiply(a, b), zi)

    return mul


def _seal_div(zi):
    # sealing the denominator keeps XLA from rewriting division by a
    # constant into multiplication by its rounded reciprocal
    def div(a, b):
        return jnp.divide(a, seal(jnp.asarray(b, dtype=jnp.float64), zi))

    return div


def _pow2_bits(k):
    """Exact 2**k for integral float k in normal range (exponent bitcast)."""
    ki = (k.astype(jnp.int64) + 1023) << 52
    return lax.bitcast_convert_type(ki, jnp.float64)


def _kernels(zi):
    return _acq.make_portable_kernels(jnp, _seal_mul(zi), _pow2_bits,
                                      div=_seal_div(zi))


# ---------------------------------------------------------------------------
# numpy-replay building blocks (traced)
# ---------------------------------------------------------------------------


def _combine_source(m_t, v_t, y_mean, y_std, y_std2, mul, div):
    """Replay ``PackedForest.combine`` on one source's (tps, N) leaf stats.

    numpy's axis-0 reductions add rows sequentially in index order; the
    trace-time unroll reproduces that order with sealed squares/denorms
    (and sealed /T divisions — T is a trace-time constant).
    """
    T = m_t.shape[0]
    ms = m_t[0]
    for t in range(1, T):
        ms = ms + m_t[t]
    mean = div(ms, T)
    vs = v_t[0]
    for t in range(1, T):
        vs = vs + v_t[t]
    vmean = div(vs, T)
    dev = m_t[0] - mean
    acc = mul(dev, dev)
    for t in range(1, T):
        dev = m_t[t] - mean
        acc = acc + mul(dev, dev)
    var = jnp.maximum(vmean + div(acc, T), 1e-10)
    return mul(mean, y_std) + y_mean, mul(var, y_std2)


def _sort_perm_desc(scores):
    """The permutation ``jnp.argsort(-scores, axis=1, stable=True)`` would
    return, via a stable sort of monotone uint64 keys with an int32 payload
    (~15% faster than the f64-keyed argsort on XLA:CPU, and it skips the
    i64 payload x64 mode would impose). +/-0 compare equal under the f64
    order but map to distinct bit patterns, so they are canonicalized to
    one key first — ties then fall back to index order exactly like the
    stable numpy argsort. The remap is all-integer (see
    ``rank.monotone_keys_traced``): XLA:CPU compute threads run with
    FTZ/DAZ set, so a float ``jnp.negative`` / ``== 0.0`` here would
    silently flush subnormal scores into the zero tie group."""
    mapped = _rank.monotone_keys_traced(scores)
    iota = jnp.broadcast_to(
        jnp.arange(scores.shape[1], dtype=jnp.int32)[None, :], scores.shape
    )
    _, perm = lax.sort((mapped, iota), dimension=1, is_stable=True, num_keys=1)
    return perm


def _sort_perm_asc1d(v):
    """``jnp.argsort(v, stable=True)`` for a 1-D float vector via the same
    monotone uint64 key + int32 payload trick (+/-0 canonicalized in the
    integer domain, FTZ-immune — see ``_sort_perm_desc``)."""
    msb = jnp.uint64(1) << jnp.uint64(63)
    bits = lax.bitcast_convert_type(v, jnp.uint64)
    bits = jnp.where((bits & ~msb) == 0, jnp.uint64(0), bits)
    sign = (bits >> jnp.uint64(63)).astype(bool)
    mapped = jnp.where(sign, ~bits, bits | msb)
    iota = jnp.arange(v.shape[0], dtype=jnp.int32)
    _, perm = lax.sort((mapped, iota), dimension=0, is_stable=True, num_keys=1)
    return perm


def _aggregate_ranks_traced(scores, weights, n_sources, mul, rank_impl="sort"):
    """Replay ``acquisition.aggregate_ranks`` on an (S, N) score matrix.

    With ``rank_impl="sort"`` (the pure-XLA reference): ranks_s is the
    inverse permutation of the stable descending argsort; instead of
    materializing it (a second argsort), each source's weighted ranks
    scatter directly into the aggregate at its sorted positions. The
    scatters run in source order with a data dependency between them, so
    every element accumulates w_s * rank_s in numpy's exact add sequence
    (s = 0 initializes via set, preserving the sign of a +/-0 first term).

    Other impls ("callback", "pallas" — see ``rank.rank_rows_traced``)
    materialize the rank matrix directly and accumulate elementwise in
    source order: the ranks are the exact same integers, the sealed
    products are the same floats, and the per-element add sequence is
    numpy's, so every impl returns the bit-identical aggregate. On
    XLA:CPU the callback radix is ~5x the sort+scatter path at 131072.
    """
    if rank_impl != "sort":
        ranks = _rank.rank_rows_traced(scores, rank_impl)
        agg = mul(weights[0], ranks[0])
        for s in range(1, n_sources):
            agg = agg + mul(weights[s], ranks[s])
        return agg
    perm = _sort_perm_desc(scores)
    n = scores.shape[1]
    iota_f = jnp.arange(n, dtype=jnp.float64)
    agg = jnp.zeros(n, dtype=jnp.float64)
    agg = agg.at[perm[0]].set(mul(weights[0], iota_f), unique_indices=True)
    for s in range(1, n_sources):
        agg = agg.at[perm[s]].add(mul(weights[s], iota_f), unique_indices=True)
    return agg


# ---------------------------------------------------------------------------
# device-side pool draw from SpacePlane transform tables
# ---------------------------------------------------------------------------

_K_FLOAT, _K_INT, _K_CAT, _K_BOOL, _K_CONST = 0, 1, 2, 3, 4


def _unit_col(sig_j, tab, u):
    """One knob column: unit draw -> restriction-CDF value -> unit encode.

    Replays ``SpacePlane._quantile_col`` followed by the clipped
    ``_to_unit_col`` (the exact host pool construction, so device pools
    have the host pools' distribution — the draws themselves come from the
    jax PRNG, see the CHANGES SEED NOTE).
    """
    kind, is_log, transformed, degenerate, zero_span, size = sig_j
    if kind == _K_CONST:
        return jnp.broadcast_to(tab[0][0], u.shape)
    if kind in (_K_FLOAT, _K_INT):
        ga, gb, cum, mid, scal = tab
        P = size
        if degenerate:
            v = mid[jnp.minimum((u * P).astype(jnp.int64), P - 1)]
        else:
            i = jnp.clip(jnp.searchsorted(cum, u, side="right") - 1, 0, P - 1)
            span = cum[i + 1] - cum[i]
            frac = jnp.where(span > 0, (u - cum[i]) / jnp.where(span > 0, span, 1.0), 0.0)
            g = ga[i] + frac * (gb[i] - ga[i])
            v = jnp.exp(g) if transformed else g
        if kind == _K_INT:
            v = jnp.clip(jnp.round(v), scal[2], scal[3])
        if zero_span:
            return jnp.zeros_like(v)
        t = jnp.log(v) if is_log else v
        return jnp.clip((t - scal[0]) / scal[1], 0.0, 1.0)
    act = tab[0]
    m = act.shape[0]
    pick = jnp.minimum((u * m).astype(jnp.int64), m - 1)
    a = act[pick].astype(jnp.float64)
    if kind == _K_CAT:
        return (a + 0.5) / size
    return jnp.where(a != 0, 0.75, 0.25)


def _draw_unit_pool(key, sig, cols, n):
    """(n, D) unit-space pool: uniform half + per-knob-stratified LHS half.

    LHS strata are shuffled by a random LCG bijection ``p(i) = (a*i + b)
    mod m`` per knob (a odd => a bijection on Z_m for the power-of-two
    strata count the bucket protocol guarantees) — a rank-1-lattice-style
    stratification ~45x cheaper than ``jax.random.permutation`` on XLA:CPU
    while keeping exactly one sample per stratum per knob. Non-bucketed
    strata counts fall back to true per-knob permutations.
    """
    D = len(sig)
    n_lhs = n // 2
    n_uni = n - n_lhs
    k_uni, k_ab, k_frac = jax.random.split(key, 3)
    u_uni = jax.random.uniform(k_uni, (n_uni, D), dtype=jnp.float64)
    frac = jax.random.uniform(k_frac, (n_lhs, D), dtype=jnp.float64)
    if n_lhs > 0 and (n_lhs & (n_lhs - 1)) == 0:
        ab = jax.random.bits(k_ab, (2, D), dtype=jnp.uint32)
        i = jnp.arange(n_lhs, dtype=jnp.uint32)[:, None]
        p = (i * (ab[0] | jnp.uint32(1)) + ab[1]) & jnp.uint32(n_lhs - 1)
        strata = p.astype(jnp.float64)
    else:
        keys = jax.random.split(k_ab, max(D, 1))
        strata = jnp.stack(
            [jax.random.permutation(keys[j], n_lhs) for j in range(D)], axis=1
        ).astype(jnp.float64)
    lhs = (strata + frac) / n_lhs
    out = []
    for j, s in enumerate(sig):
        u = jnp.concatenate([u_uni[:, j], lhs[:, j]])
        out.append(_unit_col(s, cols[j], u))
    return jnp.stack(out, axis=1)


# ---------------------------------------------------------------------------
# merged QuickScorer descent (bitvector tables across every source's trees)
# ---------------------------------------------------------------------------

_ONES = np.uint64(0xFFFFFFFFFFFFFFFF)


def build_qs_plan_ex(feat, thr, child, mean, var, roots, d):
    """Host-side QuickScorer tables for a fused multi-source arena.

    Same encoding as ``chain.build_chain_plan`` — and literally the same
    packer (``chain.pack_leaf_spans`` / ``chain.build_false_tables``):
    leaf ordinals left-to-right in one or two uint64 leaf words per tree,
    per-node masks clearing the left subtree's leaf span, per-feature
    sorted thresholds prefix-ANDed into false-set tables — but merged
    across ALL sources' trees into one table set: the tree axis spans
    every source, so a single searchsorted + AND chain per feature routes
    the whole pool through the whole arena. Rank ``r = #(thr < v)``
    replays the descent's exact ``v > thr`` float comparisons, so leaf
    routing — and therefore every downstream float — is bit-identical to
    the gather descent.

    Returns ``((thrs, tables, leaf_mean, leaf_var, leaf_offs), "")`` — the
    word count is implicit in the table shapes ((n_thr+1, T) one-word,
    (n_thr+1, T, 2) two-word) — or ``(None, reason)`` when a tree exceeds
    128 leaves or splits outside the d-dim space; callers fall back to the
    gather/pallas descent.
    """
    from .chain import build_false_tables, pack_leaf_spans

    packed, reason = pack_leaf_spans(feat, thr, child, mean, var, roots, d)
    if packed is None:
        return None, reason
    nodes_by_feat, leaf_mean, leaf_var, leaf_offs, n_words = packed
    thrs, tables = build_false_tables(nodes_by_feat, len(roots), n_words)
    return (tuple(thrs), tuple(tables), leaf_mean, leaf_var, leaf_offs), ""


def build_qs_plan(feat, thr, child, mean, var, roots, d):
    """Back-compat wrapper over :func:`build_qs_plan_ex` (drops the
    decline reason)."""
    return build_qs_plan_ex(feat, thr, child, mean, var, roots, d)[0]


def _qs_leaf_stats(qs, X):
    """Traced QuickScorer eval: (T, N) leaf means/vars for a unit pool.

    One searchsorted per feature ranks the whole column, the prefix tables
    turn ranks into per-tree false-node words, and the AND chain isolates
    each tree's exit leaf as the lowest set bit (ordinal via popcount of
    ``lsb - 1``). Replaces O(T * depth) random gathers with D cache-resident
    table lookups + D word-ANDs per row. Two-word trees (65..128 leaves,
    tables with a trailing word axis) scan word 0 first: an empty word 0
    underflows ``lsb - 1`` to all-ones (popcount 64), so the select picks
    64 + the word-1 ordinal.
    """
    thrs, tabs, lm, lv, offs = qs
    w = None
    for j in range(len(thrs)):
        if thrs[j].shape[0] == 0:
            continue
        r = jnp.searchsorted(thrs[j], X[:, j], side="left")
        wj = tabs[j][r]
        w = wj if w is None else w & wj
    if w is None:  # degenerate forest of root-leaves
        idx = jnp.broadcast_to(offs[None, :], (X.shape[0], offs.shape[0]))
    elif w.ndim == 3:  # two leaf words per tree
        w0, w1 = w[..., 0], w[..., 1]
        lsb0 = w0 & (jnp.uint64(0) - w0)
        lsb1 = w1 & (jnp.uint64(0) - w1)
        leaf = jnp.where(
            w0 != 0,
            lax.population_count(lsb0 - jnp.uint64(1)),
            jnp.uint64(64) + lax.population_count(lsb1 - jnp.uint64(1)),
        ).astype(jnp.int64)
        idx = offs[None, :] + leaf
    else:
        lsb = w & (jnp.uint64(0) - w)
        leaf = lax.population_count(lsb - jnp.uint64(1)).astype(jnp.int64)
        idx = offs[None, :] + leaf
    return lm[idx].T, lv[idx].T


# ---------------------------------------------------------------------------
# the fused step
# ---------------------------------------------------------------------------


def _leaf_stats(arena, X, depth, descent):
    feat, thr, child, mean, var, roots = arena
    if descent == "pallas":
        from .kernel import forest_eval_pallas

        interpret = jax.default_backend() == "cpu"
        return forest_eval_pallas(feat, thr, child, mean, var, roots, X,
                                  depth, interpret=interpret)
    nid = _descend(feat, thr, child, roots, X, depth)
    return mean[nid], var[nid]


def _step_body(key, cols, X, arena, qs, ystats, incumbents, weights, n_valid,
               zi, *, n_pool, depth, n_sources, tps, k, sig, descent,
               rank_impl="sort"):
    if X is None:
        X = _draw_unit_pool(key, sig, cols, n_pool)
    mul = _seal_mul(zi)
    div = _seal_div(zi)
    kern = _kernels(zi)
    if descent == "qs":
        m_leaf, v_leaf = _qs_leaf_stats(qs, X)
    else:
        m_leaf, v_leaf = _leaf_stats(arena, X, depth, descent)
    y_means, y_stds, y_stds2 = ystats
    means, vars_ = [], []
    for s in range(n_sources):
        a = s * tps
        mn, vr = _combine_source(m_leaf[a:a + tps], v_leaf[a:a + tps],
                                 y_means[s], y_stds[s], y_stds2[s], mul, div)
        means.append(mn)
        vars_.append(vr)
    means = jnp.stack(means)
    vars_ = jnp.stack(vars_)
    scores = kern["ei"](means, vars_, incumbents[:, None])
    valid = jnp.arange(X.shape[0]) < n_valid
    # padding: EI = -1 < 0 <= any real EI, appended after real rows =>
    # real rows keep their exact unpadded ranks under the stable sort
    scores = jnp.where(valid[None, :], scores, -1.0)
    agg = _aggregate_ranks_traced(scores, weights, n_sources, mul, rank_impl)
    agg = jnp.where(valid, agg, jnp.inf)
    idx = _sort_perm_asc1d(agg)[:k]
    return idx, jnp.take(X, idx, axis=0), jnp.take(agg, idx)


@functools.partial(
    jax.jit if jax is not None else lambda f, **kw: f,
    static_argnames=("n_pool", "depth", "n_sources", "tps", "k", "sig",
                     "rank_impl", "descent"),
)
def _propose_jit(key, cols, X, arena, qs, ystats, incumbents, weights,
                 n_valid, zi, *, n_pool, depth, n_sources, tps, k, sig,
                 rank_impl, descent):
    return _step_body(key, cols, X, arena, qs, ystats, incumbents, weights,
                      n_valid, zi, n_pool=n_pool, depth=depth,
                      n_sources=n_sources, tps=tps, k=k, sig=sig,
                      descent=descent, rank_impl=rank_impl)


@functools.partial(
    jax.jit if jax is not None else lambda f, **kw: f,
    static_argnames=("n_pool", "depth", "n_sources", "tps", "k", "sig",
                     "rank_impl", "descent", "steps"),
)
def _propose_scan_jit(key, cols, arena, qs, ystats, incumbents, weights, zi,
                      *, n_pool, depth, n_sources, tps, k, sig, rank_impl,
                      descent, steps):
    n_valid = jnp.asarray(n_pool, dtype=jnp.int64)

    def body(carry, _):
        carry, sub = jax.random.split(carry)
        out = _step_body(sub, cols, None, arena, qs, ystats, incumbents,
                         weights, n_valid, zi, n_pool=n_pool, depth=depth,
                         n_sources=n_sources, tps=tps, k=k, sig=sig,
                         descent=descent, rank_impl=rank_impl)
        return carry, out

    key, outs = lax.scan(body, key, None, length=steps)
    return key, outs


def propose_step(key, cols, arena, ystats, incumbents, weights, zi,
                 *, n_pool, depth, n_sources, tps, k, sig, descent="jax",
                 rank_impl=None, X=None, n_valid=None, qs=None):
    """One fused propose step. ``X=None`` draws the pool on device from
    ``key``; an uploaded ``X`` (host pool mode) pins the candidates so the
    selection is bit-identical to the staged numpy path. ``descent="qs"``
    routes leaves through the merged QuickScorer tables in ``qs`` (from
    :func:`build_qs_plan`, uploaded). ``rank_impl`` picks the rank-matrix
    kernel (``rank.RANK_IMPLS``; None = backend default). Returns
    (idx, X[idx], agg[idx]), each length ``k``."""
    if n_valid is None:
        n_valid = n_pool
    if rank_impl is None:
        rank_impl = _rank.default_rank_impl()
    return _propose_jit(key, cols, X, arena, qs, ystats, incumbents, weights,
                        jnp.asarray(n_valid, dtype=jnp.int64), zi,
                        n_pool=n_pool, depth=depth, n_sources=n_sources,
                        tps=tps, k=k, sig=sig, rank_impl=rank_impl,
                        descent=descent)


def propose_scan(key, cols, arena, ystats, incumbents, weights, zi, *,
                 n_pool, depth, n_sources, tps, k, sig, descent="jax",
                 rank_impl=None, steps=1, qs=None):
    """``steps`` fused propose iterations under one ``lax.scan``, splitting
    the PRNG key per step. Returns (next_key, (idx, X_sel, agg_sel)) with a
    leading ``steps`` axis on each output."""
    if rank_impl is None:
        rank_impl = _rank.default_rank_impl()
    return _propose_scan_jit(key, cols, arena, qs, ystats, incumbents,
                             weights, zi, n_pool=n_pool, depth=depth,
                             n_sources=n_sources, tps=tps, k=k, sig=sig,
                             rank_impl=rank_impl, descent=descent, steps=steps)


# ---------------------------------------------------------------------------
# host-callable, bucket-padded wrappers (bit-equivalence surface for tests)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit if jax is not None else lambda f: f)
def _ei_pad_jit(mean, var, best, zi):
    return _kernels(zi)["ei"](mean, var, best)


@functools.partial(
    jax.jit if jax is not None else lambda f, **kw: f,
    static_argnames=("n_sources", "rank_impl"),
)
def _ranks_pad_jit(scores, weights, zi, *, n_sources, rank_impl="sort"):
    return _aggregate_ranks_traced(scores, weights, n_sources, _seal_mul(zi),
                                   rank_impl)


def ei_host(mean, var, best) -> np.ndarray:
    """Jax EI, padded to the pool bucket; bit-identical (x64) to
    ``acquisition.expected_improvement``."""
    mean = np.asarray(mean, dtype=float)
    var = np.asarray(var, dtype=float)
    best = np.asarray(best, dtype=float)
    shape = np.broadcast_shapes(mean.shape, var.shape, best.shape)
    mf = np.broadcast_to(mean, shape).reshape(-1)
    vf = np.broadcast_to(var, shape).reshape(-1)
    bf = np.broadcast_to(best, shape).reshape(-1)
    n = max(mf.size, 1)
    bucket = pool_bucket(n)
    mp = np.zeros(bucket)
    vp = np.ones(bucket)
    bp = np.zeros(bucket)
    mp[:mf.size], vp[:vf.size], bp[:bf.size] = mf, vf, bf
    with _x64():
        zi = jnp.zeros((), dtype=jnp.uint64)
        out = _ei_pad_jit(jnp.asarray(mp), jnp.asarray(vp), jnp.asarray(bp), zi)
        return np.asarray(out)[:mf.size].reshape(shape)


def aggregate_ranks_host(scores, weights, rank_impl=None) -> np.ndarray:
    """Jax rank aggregation, padded to the pool bucket with -inf scores
    (strictly below any finite score, appended last => real columns keep
    their exact unpadded ranks); bit-identical to
    ``acquisition.aggregate_ranks`` for finite scores under every
    ``rank_impl`` (None = backend default)."""
    scores = np.atleast_2d(np.asarray(scores, dtype=float))
    if scores.size == 0:
        raise ValueError("no scores to aggregate")
    s, n = scores.shape
    bucket = pool_bucket(n)
    sp = np.full((s, bucket), -np.inf)
    sp[:, :n] = scores
    w = np.asarray(weights, dtype=float)
    if rank_impl is None:
        rank_impl = _rank.default_rank_impl()
    with _x64():
        zi = jnp.zeros((), dtype=jnp.uint64)
        agg = _ranks_pad_jit(jnp.asarray(sp), jnp.asarray(w), zi, n_sources=s,
                             rank_impl=rank_impl)
        return np.asarray(agg)[:n]
