"""Backend dispatch for packed-forest evaluation.

``forest_eval`` evaluates a packed node arena (see
``repro.core.surrogate.PackedForest``) over a candidate matrix and returns
per-tree leaf stats, shape (n_trees, n_points) each. Backends:

  numpy   — the core level-synchronous descent (always available)
  jax     — jitted jnp reference (``ref.forest_eval_ref``)
  pallas  — candidate-blocked gather kernel (``kernel.forest_eval_pallas``)
  auto    — jax when importable, else numpy

The jax/pallas paths run under a scoped ``enable_x64`` so threshold
comparisons happen in float64 — leaf routing, and therefore (mean, var),
is bit-identical to the numpy plane. Arena sizes change on every refit, so
node/root arrays are padded to power-of-two buckets (padding nodes are
self-loop leaves) and the descent depth to a multiple of 4, keeping the
jit cache small across Hyperband rungs.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Tuple

import numpy as np

try:
    import jax

    _HAS_JAX = True
except Exception:  # pragma: no cover - jax is baked into this image
    _HAS_JAX = False

__all__ = ["forest_eval", "forest_plane_eval", "available_backends"]

# Padded device-resident arenas, keyed by the identity of the arena's feat
# array (arenas are immutable once packed, so identity is a sound key; the
# stored reference also guards against id() reuse after gc). Bounded LRU —
# forests refit every rung, so stale arenas age out.
_DEVICE_CACHE: "OrderedDict[int, tuple]" = OrderedDict()
_DEVICE_CACHE_MAX = 32


def available_backends() -> Tuple[str, ...]:
    return ("numpy", "jax", "pallas") if _HAS_JAX else ("numpy",)


def _pad_pow2(n: int) -> int:
    return 1 << max(3, int(n - 1).bit_length())


def _pad_arena(feat, thr, child, mean, var, roots, depth):
    """Bucket the arena so recompiles only happen on size-class changes."""
    n = len(feat)
    n_pad = _pad_pow2(n)
    if n_pad != n:
        extra = n_pad - n
        self_idx = np.arange(n, n_pad, dtype=feat.dtype)
        feat = np.concatenate([feat, np.zeros(extra, feat.dtype)])
        thr = np.concatenate([thr, np.full(extra, np.inf)])
        child = np.concatenate([child, np.stack([self_idx, self_idx], axis=1).reshape(-1)])
        mean = np.concatenate([mean, np.zeros(extra)])
        var = np.concatenate([var, np.zeros(extra)])
    t = len(roots)
    t_pad = _pad_pow2(t)
    if t_pad != t:
        roots = np.concatenate([roots, np.full(t_pad - t, roots[0], roots.dtype)])
    depth_pad = -(-max(depth, 1) // 4) * 4
    return feat, thr, child, mean, var, roots, depth_pad


def _pad_pool(X):
    """Bucket the candidate axis too — recommend() dedups its pool, so N
    drifts call-to-call and would otherwise recompile the jitted descent."""
    n = X.shape[0]
    n_pad = _pad_pow2(n)
    if n_pad != n:
        X = np.concatenate([X, np.zeros((n_pad - n, X.shape[1]))])
    return X, n


def _device_arena(feat, thr, child, mean, var, roots, depth):
    """Pad and upload an arena once; reuse device buffers across predicts."""
    import jax.numpy as jnp

    key = id(feat)
    entry = _DEVICE_CACHE.get(key)
    if entry is not None and entry[0] is feat:
        _DEVICE_CACHE.move_to_end(key)
        return entry[1], entry[2]
    padded = _pad_arena(feat, thr, child, mean, var, roots, depth)
    dev = (
        jnp.asarray(padded[0], jnp.int64),
        jnp.asarray(padded[1], jnp.float64),
        jnp.asarray(padded[2], jnp.int64),
        jnp.asarray(padded[3], jnp.float64),
        jnp.asarray(padded[4], jnp.float64),
        jnp.asarray(padded[5], jnp.int64),
    )
    _DEVICE_CACHE[key] = (feat, dev, padded[6])
    while len(_DEVICE_CACHE) > _DEVICE_CACHE_MAX:
        _DEVICE_CACHE.popitem(last=False)
    return dev, padded[6]


def forest_eval(feat, thr, child, mean, var, roots, X, depth,
                backend: str = "auto", interpret: bool = True,
                block_n: int = 128,
                chunk_n: int = None) -> Tuple[np.ndarray, np.ndarray]:
    """Per-tree (mean, var) over the packed arena, each (n_trees, n_points).

    ``chunk_n`` bounds the candidate rows handled per backend dispatch:
    oversized pools (the batched Shapley plane builds hundreds of thousands
    of composite rows) are split into row blocks and the results
    concatenated. Per-point descent is independent, so chunking never
    changes a result; on the jax path it also pins the pool-padding bucket
    to one size class instead of jitting a fresh giant bucket per call.
    """
    if backend == "auto":
        backend = "jax" if _HAS_JAX else "numpy"
    X = np.atleast_2d(np.asarray(X, dtype=float))
    if chunk_n is not None and X.shape[0] > chunk_n:
        parts = [
            forest_eval(feat, thr, child, mean, var, roots, X[a:a + chunk_n],
                        depth, backend=backend, interpret=interpret, block_n=block_n)
            for a in range(0, X.shape[0], chunk_n)
        ]
        return (np.concatenate([p[0] for p in parts], axis=1),
                np.concatenate([p[1] for p in parts], axis=1))
    if backend == "numpy":
        from ...core.surrogate import packed_descend

        nid = packed_descend(feat, thr, child, roots, X, depth)
        return np.take(mean, nid), np.take(var, nid)
    if not _HAS_JAX:
        raise RuntimeError(f"backend {backend!r} requires jax; use 'numpy'")
    if backend not in ("jax", "pallas"):
        raise ValueError(f"unknown forest_eval backend {backend!r}")
    T = len(roots)
    X, n = _pad_pool(X)
    with jax.experimental.enable_x64(True):
        import jax.numpy as jnp

        dev, depth = _device_arena(feat, thr, child, mean, var, roots, depth)
        Xd = jnp.asarray(X, jnp.float64)
        if backend == "jax":
            from .ref import forest_eval_ref

            m_t, v_t = forest_eval_ref(*dev, Xd, depth)
        else:
            from .kernel import forest_eval_pallas

            m_t, v_t = forest_eval_pallas(*dev, Xd, depth, block_n=block_n, interpret=interpret)
        return np.asarray(m_t)[:T, :n], np.asarray(v_t)[:T, :n]


def forest_plane_eval(feat, thr, child, mean, var, roots, X, depth,
                      y_means, y_stds, trees_per_source: int) -> Tuple[np.ndarray, np.ndarray]:
    """Fully fused multi-source evaluation on the jax backend.

    Descent *and* the per-source ensemble combine (law of total variance +
    denormalization) run on device; only (S, N) results are transferred.
    Requires a uniform tree count per source; raises RuntimeError without
    jax so callers can fall back to the per-tree path.
    """
    if not _HAS_JAX:
        raise RuntimeError("forest_plane_eval requires jax; use the numpy plane")
    n_sources = len(roots) // trees_per_source
    X = np.atleast_2d(np.asarray(X, dtype=float))
    X, n = _pad_pool(X)
    with jax.experimental.enable_x64(True):
        import jax.numpy as jnp

        from .ref import forest_plane_eval_ref

        dev, depth = _device_arena(feat, thr, child, mean, var, roots, depth)
        means, vars_ = forest_plane_eval_ref(
            *dev,
            jnp.asarray(X, jnp.float64),
            jnp.asarray(y_means, jnp.float64),
            jnp.asarray(y_stds, jnp.float64),
            depth,
            n_sources,
            trees_per_source,
        )
        return np.asarray(means)[:, :n], np.asarray(vars_)[:, :n]
