"""jax oracle for the packed-forest gather descent.

Same node encoding as ``repro.core.surrogate.packed_descend``: leaves have
``thr = +inf`` and self-loop children, so the descent needs no active-lane
masking — every lane converges to its leaf and then spins in place. Runs in
whatever precision the inputs carry; the ops dispatcher feeds it float64
(x64-scoped) so leaf routing is bit-identical to the numpy plane.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["forest_eval_ref", "forest_plane_eval_ref"]


def _descend(feat, thr, child, roots, X, depth):
    T = roots.shape[0]
    N, D = X.shape
    xflat = X.reshape(-1)
    col = jnp.arange(N, dtype=roots.dtype) * D
    nid = jnp.broadcast_to(roots[:, None], (T, N))

    def body(_, nid):
        f = feat[nid]
        xv = xflat[col[None, :] + f]
        go_right = (xv > thr[nid]).astype(nid.dtype)
        return child[2 * nid + go_right]

    return jax.lax.fori_loop(0, depth, body, nid)


@functools.partial(jax.jit, static_argnames=("depth",))
def forest_eval_ref(feat, thr, child, mean, var, roots, X, depth):
    """Per-tree leaf stats for a packed arena: returns (mean, var), each (T, N)."""
    nid = _descend(feat, thr, child, roots, X, depth)
    return mean[nid], var[nid]


@functools.partial(jax.jit, static_argnames=("depth", "n_sources", "trees_per_source"))
def forest_plane_eval_ref(feat, thr, child, mean, var, roots, X, y_mean, y_std,
                          depth, n_sources, trees_per_source):
    """Descent + per-source ensemble combine fused on device.

    For a plane whose forests all hold ``trees_per_source`` trees: returns
    denormalized (means, vars), each (n_sources, N) — only the combined
    stats cross back to the host, not the per-tree matrices.
    """
    nid = _descend(feat, thr, child, roots, X, depth)
    T = n_sources * trees_per_source
    m_t = mean[nid[:T]].reshape(n_sources, trees_per_source, -1)
    v_t = var[nid[:T]].reshape(n_sources, trees_per_source, -1)
    mean_s = m_t.mean(axis=1)
    var_s = jnp.maximum(v_t.mean(axis=1) + m_t.var(axis=1), 1e-10)
    return (
        mean_s * y_std[:, None] + y_mean[:, None],
        var_s * y_std[:, None] ** 2,
    )
