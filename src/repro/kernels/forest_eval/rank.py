"""Radix rank kernels for weighted rank aggregation (numpy + jax + pallas).

``aggregate_ranks`` needs, per score row, the float rank each candidate
would get under ``np.argsort(-scores, kind="stable")`` — rank 0 = highest
score, ties broken by index. The comparison sorts spent ~0.6 s at MFTune's
12 x 131072 propose scale (lax.sort u64+i32 on XLA:CPU) and ~0.18 s
(numpy f64 stable argsort); both are the measured rank-aggregation floor
of the fused propose step (see ROADMAP / PR 7).

This module replaces them with an LSD radix over a *monotone uint64
remap* of the negated scores, in the package's usual triple pattern:

* numpy (:func:`rank_rows_radix`) — four 16-bit digit passes. numpy's
  stable argsort on a ``uint16`` column IS an O(n) counting/radix sort in
  C, so composing ``perm = perm[argsort(digit[perm])]`` low-to-high digit
  replays a textbook LSD radix at memory speed: ~6.5x over the lax.sort
  path and ~2.4x over the f64 argsort at 12 x 131072 on this host. The
  permutation equals ``np.argsort(keys, kind="stable")`` *exactly* (each
  pass is stable, u64 order = descending float order by construction), so
  ranks are bit-identical to the reference — including all-tied rows, ±0
  and subnormal scores (pinned in tests/test_rank_kernel.py).
* jax (:func:`rank_rows_traced`) — three trace-time implementations:
  ``"callback"`` hands the key halves to the numpy radix through a raw
  ``emit_python_callback`` primitive (on the CPU backend the "device"
  *is* the host, so the callback is a plain function call on the operand
  buffers — the honest fast path inside the fused propose program);
  ``"sort"`` keeps the monotone-key ``lax.sort`` as the portable
  pure-XLA reference; ``"pallas"`` uses the histogram kernel below.
* pallas (:func:`radix_rank_pallas`) — 8-bit histogram radix passes, one
  program per score row: digit histogram → exclusive prefix (digit base)
  → stable within-digit offsets from a blocked lower-triangular equality
  count plus a running per-digit occupancy. Like the other kernels in
  this package it defaults to ``interpret=True`` (dynamic scatters do not
  lower on all TPU generations) and exists as the accelerator-shaped
  formulation; the jnp/numpy paths carry CPU execution.

Scores must be NaN-free (numpy sorts any NaN last; the monotone remap
would order -NaN first). EI scores — the only caller — are >= 0 or the
padding sentinels (-1 / -inf), all NaN-free.
"""

from __future__ import annotations

import functools

import numpy as np

try:
    import jax
    import jax.numpy as jnp
    from jax import lax
except ImportError:  # pragma: no cover - jax ships with the image
    jax = None

from ... import obs as _obs

__all__ = [
    "RADIX_MIN_N",
    "RANK_IMPLS",
    "monotone_keys",
    "radix_argsort",
    "rank_rows_radix",
    "rank_rows_reference",
    "rank_rows",
    "default_rank_impl",
    "monotone_keys_traced",
    "rank_rows_traced",
    "radix_rank_pallas",
]

# numpy dispatch crossover: below this row length the single f64 stable
# argsort beats four digit passes (measured ~1024 on this host; radix is
# 1.7x at 4096 and ~2.4x from 16384 up)
RADIX_MIN_N = 1024

# trace-time implementations of the rank matrix inside a jitted program
RANK_IMPLS = ("callback", "sort", "pallas")

_U16 = np.uint64(0xFFFF)
_MSB = np.uint64(1) << np.uint64(63)


# ---------------------------------------------------------------------------
# numpy: monotone key remap + 16-bit digit-pass radix
# ---------------------------------------------------------------------------


def monotone_keys(scores: np.ndarray) -> np.ndarray:
    """uint64 keys whose ascending order is the descending float order.

    Everything happens in the integer domain: IEEE negation is a sign-bit
    XOR, ±0 detection is a bit-pattern test, and the classic monotone
    remap (negatives complement, positives set the MSB) is pure bit
    arithmetic. No float op ever touches the values — deliberately, since
    XLA:CPU runs its compute threads with FTZ/DAZ set, and a float
    ``-scores`` / ``== 0.0`` there silently flushes subnormal scores into
    the zero tie group (observed: all ±subnormals collapsing onto ±0 when
    the same remap ran inside a ``pure_callback``). The integer path is
    bit-exact under any FPU mode. ±0 compare equal as floats but differ
    bitwise, so they canonicalize to one key — ties then fall back to
    index order exactly like the stable numpy argsort.
    """
    x = np.ascontiguousarray(np.asarray(scores, dtype=np.float64))
    bits = x.view(np.uint64) ^ _MSB  # negate: flip the sign bit
    bits = np.where((bits & ~_MSB) == 0, np.uint64(0), bits)  # ±0 -> +0
    sign = (bits >> np.uint64(63)).astype(bool)
    return np.where(sign, ~bits, bits | _MSB)


def _radix_perm_row(keys: np.ndarray) -> np.ndarray:
    """Stable ascending argsort of a u64 key row via 4 LSD 16-bit passes.

    numpy's stable argsort on uint16 is an O(n) counting sort; composing
    the per-digit permutations low-to-high digit is the classic LSD radix
    and yields the exact stable u64 argsort.
    """
    perm = np.argsort((keys & _U16).astype(np.uint16), kind="stable")
    for shift in (np.uint64(16), np.uint64(32), np.uint64(48)):
        digit = ((keys >> shift) & _U16).astype(np.uint16)
        perm = perm[np.argsort(digit[perm], kind="stable")]
    return perm


def radix_argsort(scores: np.ndarray) -> np.ndarray:
    """Row-wise ``np.argsort(-scores, axis=1, kind="stable")``, via radix."""
    K = monotone_keys(np.atleast_2d(scores))
    out = np.empty(K.shape, dtype=np.intp)
    for s in range(K.shape[0]):
        out[s] = _radix_perm_row(K[s])
    return out


def rank_rows_radix(scores: np.ndarray) -> np.ndarray:
    """Float ranks per row (rank 0 = best) via the radix permutation."""
    K = monotone_keys(np.atleast_2d(scores))
    out = np.empty(K.shape, dtype=np.float64)
    r = np.arange(K.shape[1], dtype=np.float64)
    for s in range(K.shape[0]):
        out[s, _radix_perm_row(K[s])] = r
    return out


def rank_rows_reference(scores: np.ndarray) -> np.ndarray:
    """The pinned reference: stable f64 argsort + put_along_axis."""
    scores = np.atleast_2d(np.asarray(scores, dtype=float))
    s, n = scores.shape
    order = np.argsort(-scores, axis=1, kind="stable")
    ranks = np.empty((s, n), dtype=float)
    np.put_along_axis(
        ranks, order, np.broadcast_to(np.arange(n, dtype=float), (s, n)), axis=1
    )
    return ranks


def rank_rows(scores: np.ndarray) -> np.ndarray:
    """Rank matrix with the numpy dispatch: radix above RADIX_MIN_N,
    reference argsort below (both produce bit-identical ranks)."""
    scores = np.atleast_2d(np.asarray(scores, dtype=float))
    if scores.shape[1] >= RADIX_MIN_N:
        _obs.count("rank_kernel/radix")
        return rank_rows_radix(scores)
    _obs.count("rank_kernel/argsort")
    return rank_rows_reference(scores)


# ---------------------------------------------------------------------------
# jax: traced rank matrix (callback / sort / pallas)
# ---------------------------------------------------------------------------


def default_rank_impl() -> str:
    """Trace-time default: the host radix callback on CPU (where device
    memory is host memory), the pure-XLA sort elsewhere."""
    if jax is None:
        return "sort"
    return "callback" if jax.default_backend() == "cpu" else "sort"


def monotone_keys_traced(scores):
    """Traced :func:`monotone_keys` — same all-integer remap, so the key
    order survives XLA:CPU's FTZ/DAZ compute threads bit-exactly."""
    msb = jnp.uint64(1) << jnp.uint64(63)
    bits = lax.bitcast_convert_type(scores, jnp.uint64) ^ msb
    bits = jnp.where((bits & ~msb) == 0, jnp.uint64(0), bits)
    sign = (bits >> jnp.uint64(63)).astype(bool)
    return jnp.where(sign, ~bits, bits | msb)


def _rank_callback(lo_hi) -> np.ndarray:
    """int32 ranks from a raw (S, N, 2) uint32 key-half buffer.

    Invoked by the XLA runtime directly on views of the custom-call
    operand buffers — NOT through :func:`jax.pure_callback`. The stock
    callback primitives ``device_put`` their args back onto the device
    before calling the Python function; on XLA:CPU any copy over the
    small-transfer threshold is enqueued on the same single-thread
    executor that is blocked running the enclosing program, so the
    callback deadlocks waiting for its own arguments (and whether the
    zero-copy path saves you depends on the operand's arena alignment —
    it reproduced flakily from 65536-candidate pools up). Lowering
    through ``mlir.emit_python_callback`` hands this function plain numpy
    views with no device round-trip, which removes the mechanism.

    The boundary also sticks to 32-bit dtypes on purpose: the repo
    enables x64 in *scopes* while the global config stays x32, and the
    runtime thread canonicalizes return dtypes under the *global* mode —
    uint32 in / int32 out are canonical under both. The u64 keys are
    remapped in-graph (integer ops, FTZ-immune) and reassembled here;
    ranks convert to float64 exactly in-graph.
    """
    a = np.asarray(lo_hi)
    K = a[..., 0].astype(np.uint64) | (a[..., 1].astype(np.uint64) << np.uint64(32))
    out = np.empty(K.shape, dtype=np.int32)
    r = np.arange(K.shape[1], dtype=np.int32)
    for s in range(K.shape[0]):
        out[s, _radix_perm_row(K[s])] = r
    return out


if jax is not None:
    from jax._src import core as _jcore
    from jax._src.interpreters import mlir as _jmlir

    _rank_rows_p = _jcore.Primitive("repro_rank_rows")
    _rank_rows_p.def_abstract_eval(
        lambda aval: _jcore.ShapedArray(aval.shape[:-1], np.dtype(np.int32))
    )
    _rank_rows_p.def_impl(lambda lo_hi: _rank_callback(np.asarray(lo_hi)))

    def _rank_rows_lowering(ctx, lo_hi):
        res, _, _ = _jmlir.emit_python_callback(
            ctx,
            lambda a: (_rank_callback(np.asarray(a)),),
            None,
            [lo_hi],
            ctx.avals_in,
            ctx.avals_out,
            has_side_effect=False,
        )
        return res

    _jmlir.register_lowering(_rank_rows_p, _rank_rows_lowering)


def rank_rows_traced(scores, impl: str):
    """(S, N) float ranks inside a jitted program.

    ``impl`` is trace-time static: "callback" (host radix via the raw
    callback primitive — the CPU fast path, ~5x the sort path at
    12 x 131072), "sort" (monotone-key ``lax.sort`` + per-row scatter,
    pure XLA), or "pallas" (the histogram radix kernel, interpreted on
    CPU). All three return the exact reference ranks.
    """
    if impl == "callback":
        keys = monotone_keys_traced(scores)
        lo_hi = lax.bitcast_convert_type(keys, jnp.uint32)  # (..., 2) LE halves
        return _rank_rows_p.bind(lo_hi).astype(jnp.float64)
    if impl == "pallas":
        keys = monotone_keys_traced(scores)
        return radix_rank_pallas(
            keys, interpret=jax.default_backend() == "cpu"
        )
    if impl != "sort":
        raise ValueError(f"unknown rank impl {impl!r}; expected one of {RANK_IMPLS}")
    keys = monotone_keys_traced(scores)
    iota = jnp.broadcast_to(
        jnp.arange(scores.shape[1], dtype=jnp.int32)[None, :], scores.shape
    )
    _, perm = lax.sort((keys, iota), dimension=1, is_stable=True, num_keys=1)
    iota_f = jnp.broadcast_to(
        jnp.arange(scores.shape[1], dtype=jnp.float64)[None, :], scores.shape
    )
    rows = jnp.arange(scores.shape[0], dtype=jnp.int32)[:, None]
    return jnp.zeros(scores.shape, dtype=jnp.float64).at[rows, perm].set(
        iota_f, unique_indices=True
    )


# ---------------------------------------------------------------------------
# pallas: histogram radix rank, one program per score row
# ---------------------------------------------------------------------------


def _radix_rank_kernel(keys_ref, rank_ref, *, n, occ_block):
    """8 x 8-bit LSD histogram passes over one row of u64 keys.

    Per pass: gather the digits in current permutation order, histogram
    them (256 bins), exclusive-prefix the histogram into per-digit base
    offsets, then walk the row in ``occ_block`` slabs computing each
    element's stable within-digit offset as (strictly-lower-triangular
    equality count inside the slab) + (running per-digit occupancy from
    the slabs before it) and scattering the permutation entries to
    ``base[digit] + offset``. Every pass is a stable counting sort, so
    the composed permutation is the exact stable u64 argsort.
    """
    keys = keys_ref[...].reshape(-1)
    perm = jnp.arange(n, dtype=jnp.int32)
    tri = jnp.tril(jnp.ones((occ_block, occ_block), dtype=jnp.int32), -1)
    n_blocks = n // occ_block
    for p in range(8):  # static unroll: one pass per byte, LSD first
        d = ((keys[perm] >> np.uint64(8 * p)) & jnp.uint64(0xFF)).astype(jnp.int32)
        hist = jnp.zeros(256, dtype=jnp.int32).at[d].add(1)
        base = jnp.cumsum(hist) - hist

        def body(b, carry, d=d, perm=perm, base=base):
            new_perm, run = carry
            db = lax.dynamic_slice(d, (b * occ_block,), (occ_block,))
            pb = lax.dynamic_slice(perm, (b * occ_block,), (occ_block,))
            eq = (db[None, :] == db[:, None]).astype(jnp.int32)
            occ = (eq * tri).sum(axis=1) + run[db]
            new_perm = new_perm.at[base[db] + occ].set(pb, unique_indices=True)
            return new_perm, run.at[db].add(1)

        perm, _ = lax.fori_loop(
            0, n_blocks, body,
            (jnp.zeros(n, dtype=jnp.int32), jnp.zeros(256, dtype=jnp.int32)),
        )
    rank_ref[...] = (
        jnp.zeros((1, n), dtype=jnp.float64)
        .at[0, perm].set(jnp.arange(n, dtype=jnp.float64), unique_indices=True)
    )


def radix_rank_pallas(keys, interpret: bool = True):
    """Float rank matrix from (S, N) monotone u64 keys via the pallas
    histogram radix; N must be a multiple of the occupancy block (any
    power-of-two pool bucket is)."""
    from jax.experimental import pallas as pl

    S, N = keys.shape
    occ_block = min(256, N)
    while N % occ_block:
        occ_block //= 2
    return pl.pallas_call(
        functools.partial(_radix_rank_kernel, n=N, occ_block=occ_block),
        grid=(S,),
        in_specs=[pl.BlockSpec((1, N), lambda s: (s, 0))],
        out_specs=pl.BlockSpec((1, N), lambda s: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((S, N), jnp.float64),
        interpret=interpret,
    )(keys)
