from .workload import EvalResult, Workload, Budget

__all__ = ["EvalResult", "Workload", "Budget"]
