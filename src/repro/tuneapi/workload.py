"""Workload protocol shared by the Spark simulator and the JAX objective.

A workload is a named set of queries evaluated under a configuration; the
tuner only ever interacts with this interface, so MFTune is agnostic to
whether a "query" is a SQL statement (sparksim) or a compiled step program
(jaxwl). Evaluation cost is charged to a Budget whose clock is virtual for
the simulator and real for compiled evaluations.

Two evaluation entry points:

- ``evaluate(config, ...)``       — one configuration.
- ``evaluate_many(configs, ...)`` — a batch of configurations over the same
  query subset / data fraction. The base implementation is a loop over
  ``evaluate`` so every workload supports it; implementations with a
  vectorizable objective (``sparksim.SparkWorkload`` via
  ``SparkCostModel.evaluate_batch``) override it to evaluate the whole
  (configs x queries) grid in one pass. Hyperband rungs feed entire
  survivor sets through this hook.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Union

__all__ = ["EvalResult", "Workload", "Budget"]

Config = Dict[str, Any]


@dataclass
class EvalResult:
    per_query_latency: List[float]          # latency per evaluated query (aligned to subset order)
    per_query_cost: List[float]             # cost charged per evaluated query
    failed: bool = False                    # OOM / error / early-stopped
    failure_reason: str = ""

    @property
    def aggregate(self) -> float:
        return float(sum(self.per_query_latency))

    @property
    def elapsed(self) -> float:
        return float(sum(self.per_query_cost))


class Workload:
    """Interface. Implementations: sparksim.SparkWorkload, jaxwl.CellWorkload."""

    task_id: str = "workload"

    @property
    def queries(self) -> List[str]:
        raise NotImplementedError

    @property
    def space(self):  # -> ConfigSpace
        raise NotImplementedError

    def default_config(self) -> Config:
        return self.space.default()

    def evaluate(
        self,
        config: Config,
        query_indices: Optional[Sequence[int]] = None,
        cost_cap: Optional[float] = None,
        data_fraction: float = 1.0,
    ) -> EvalResult:
        """Run the given queries (None => all) under ``config``.

        ``cost_cap``: abort (failed=True, reason='early_stop') once the
        accumulated cost exceeds the cap — the §6.3 median early-stop hook.
        ``data_fraction``: scale the input data volume (the paper's
        Data-Volume proxy baseline); implementations may ignore it.
        """
        raise NotImplementedError

    def evaluate_many(
        self,
        configs: Sequence[Config],
        query_indices: Optional[Sequence[int]] = None,
        cost_cap: Union[None, float, Sequence[Optional[float]]] = None,
        data_fraction: float = 1.0,
    ) -> List[EvalResult]:
        """Evaluate a batch of configs over the same query subset.

        ``cost_cap`` is either one cap applied to every config independently
        or a per-config sequence. Default: loop over ``evaluate`` —
        override for vectorized backends.
        """
        caps = self._per_config_caps(cost_cap, len(configs))
        return [
            self.evaluate(c, query_indices=query_indices, cost_cap=cap,
                          data_fraction=data_fraction)
            for c, cap in zip(configs, caps)
        ]

    @staticmethod
    def _per_config_caps(
        cost_cap: Union[None, float, Sequence[Optional[float]]], n: int
    ) -> List[Optional[float]]:
        if cost_cap is None or isinstance(cost_cap, (int, float)):
            return [cost_cap] * n  # type: ignore[list-item]
        caps = list(cost_cap)
        if len(caps) != n:
            raise ValueError(f"{len(caps)} cost caps for {n} configs")
        return caps

    def meta_features(self) -> Optional[List[float]]:
        return None


class Budget:
    """Budget accounting on a virtual or real clock."""

    def __init__(self, total: float):
        self.total = float(total)
        self.spent = 0.0
        self.events: List[Dict[str, float]] = []

    def charge(self, seconds: float, label: str = "") -> None:
        self.spent += float(seconds)
        self.events.append({"t": self.spent, "cost": float(seconds), "label": label})

    @property
    def remaining(self) -> float:
        return self.total - self.spent

    @property
    def exhausted(self) -> bool:
        return self.spent >= self.total

    @property
    def now(self) -> float:
        return self.spent
