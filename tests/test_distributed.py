"""Distributed substrate: sharding assignment, pipeline, overlap,
compression, data pipeline, checkpointing (incl. elastic re-mesh)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# multi-device CPU for this module (must precede first jax usage in-proc;
# harmless if jax is already initialized with 1 device — tests that need
# devices skip themselves)
N_DEV = jax.device_count()


def _mesh(shape, axes):
    total = int(np.prod(shape))
    if N_DEV < total:
        pytest.skip(f"needs {total} devices, have {N_DEV}")
    kwargs = {}
    if hasattr(jax.sharding, "AxisType"):  # explicit-sharding API, jax >= 0.5
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kwargs)


# ------------------------------------------------------------------ sharding


def test_assign_pspec_divisibility():
    from repro.distributed.sharding import assign_pspec

    mesh = _mesh((1,), ("model",)) if N_DEV == 1 else _mesh((min(N_DEV, 2),), ("model",))
    rules = {"heads": ("model",), "kv_heads": ("model",), None: ()}
    # kv_heads=3 not divisible by mesh size>1 -> None
    spec = assign_pspec((3, 128), ("kv_heads", None), mesh, rules)
    if mesh.devices.size > 1:
        assert spec == jax.sharding.PartitionSpec()
    spec2 = assign_pspec((4, 128), ("heads", None), mesh, rules)
    if mesh.devices.size > 1:
        assert spec2[0] == "model"


def test_param_rules_cover_model_axes():
    from repro.configs import get_arch, reduced
    from repro.distributed.sharding import make_param_rules, shardings_for_specs
    from repro.models import Runtime, build_param_specs

    mesh = _mesh((1, 1), ("data", "model"))
    cfg = reduced(get_arch("llama3-8b"))
    specs = build_param_specs(cfg, Runtime())
    sh = shardings_for_specs(specs, mesh, make_param_rules(Runtime(), mesh))
    leaves = jax.tree.leaves(sh, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding))
    assert all(isinstance(l, jax.sharding.NamedSharding) for l in leaves)


# ---------------------------------------------------------------- compression


def test_int8_roundtrip_error_bound():
    from repro.distributed.compression import int8_roundtrip

    g = jax.random.normal(jax.random.PRNGKey(0), (512,), jnp.float32)
    q = int8_roundtrip(g)
    scale = float(jnp.abs(g).max()) / 127.0
    assert float(jnp.abs(q - g).max()) <= scale / 2 + 1e-7


def test_topk_keeps_largest():
    from repro.distributed.compression import topk_mask

    g = jnp.asarray(np.arange(100, dtype=np.float32) - 50)
    kept = topk_mask(g, frac=0.1)
    nz = np.nonzero(np.asarray(kept))[0]
    assert len(nz) <= 12
    assert set(nz) <= set(list(range(0, 8)) + list(range(92, 100)) + [0])


def test_error_feedback_conserves_signal():
    from repro.distributed.compression import ErrorFeedback

    ef = ErrorFeedback()
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (64,), jnp.float32)}
    resid = ef.init(g)
    total = jnp.zeros((64,))
    for _ in range(8):
        kept, resid = ef.compress(g, resid, frac=0.2)
        total = total + kept["w"]
    # over many rounds the accumulated sent signal approaches k * g
    err = float(jnp.abs(total / 8 - g["w"]).mean()) / float(jnp.abs(g["w"]).mean())
    assert err < 0.5


# ------------------------------------------------------------- data pipeline


def test_data_pipeline_determinism_and_skip():
    from repro.data import SyntheticTokenPipeline

    p1 = SyntheticTokenPipeline(1024, 64, 8, seed=7)
    batches = [next(p1) for _ in range(5)]
    p2 = SyntheticTokenPipeline(1024, 64, 8, seed=7)
    p2.skip_to(3)
    np.testing.assert_array_equal(next(p2)["tokens"], batches[3]["tokens"])
    # host sharding: two hosts see different slices
    h0 = SyntheticTokenPipeline(1024, 64, 8, seed=7, host_index=0, host_count=2)
    h1 = SyntheticTokenPipeline(1024, 64, 8, seed=7, host_index=1, host_count=2)
    assert h0.local_batch == 4
    assert not np.array_equal(next(h0)["tokens"], next(h1)["tokens"])


def test_labels_shifted():
    from repro.data import SyntheticTokenPipeline

    b = next(SyntheticTokenPipeline(512, 32, 2, seed=0))
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# --------------------------------------------------------------- checkpoints


def test_checkpoint_roundtrip(tmp_path):
    from repro.train.checkpoint import CheckpointManager

    mgr = CheckpointManager(str(tmp_path), keep_k=2, async_save=False)
    state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    for step in (10, 20, 30):
        mgr.save(step, state, extra={"step": step, "data": {"step": step}})
    assert mgr.all_steps() == [20, 30]  # keep_k GC
    restored, extra = mgr.restore(state)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(state["a"]))
    assert extra["step"] == 30


@pytest.mark.slow
def test_trainer_resume_exact(tmp_path):
    from repro.configs import get_arch, reduced
    from repro.models import Runtime
    from repro.train.trainer import Trainer

    cfg = reduced(get_arch("llama3-8b"))
    rt = Runtime(remat="none", attn_chunk=32, act_shard=False)
    kw = dict(seq_len=32, global_batch=2, seed=3, ckpt_dir=str(tmp_path), save_every=5)
    t1 = Trainer(cfg, rt, **kw)
    losses_a = t1.run(10, log_every=100)

    # fresh process-equivalent: restore and continue 5 more steps
    t2 = Trainer(cfg, rt, **kw)
    assert t2.maybe_resume()
    assert t2.step == 10
    # continuous reference run
    t3 = Trainer(cfg, rt, seq_len=32, global_batch=2, seed=3)
    losses_c = t3.run(15, log_every=100)
    losses_b = t2.run(5, log_every=100)
    np.testing.assert_allclose(losses_b, losses_c[10:], rtol=1e-4)


def test_trainer_loss_decreases():
    from repro.configs import get_arch, reduced
    from repro.models import Runtime
    from repro.train.trainer import Trainer

    cfg = reduced(get_arch("llama3-8b"))
    rt = Runtime(remat="none", attn_chunk=32, act_shard=False)
    t = Trainer(cfg, rt, seq_len=32, global_batch=4, lr=3e-3, seed=0)
    losses = t.run(30, log_every=100)
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
