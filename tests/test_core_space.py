"""ConfigSpace: encode/decode, LHS, restrictions (unit + property tests).

The property tests run as seeded ``pytest.mark.parametrize`` cases so the
module passes without ``hypothesis`` installed; a fuzz variant widens the
seed coverage when ``hypothesis`` is available (importorskip-guarded).
"""

import numpy as np
import pytest

from repro.core import BoolKnob, CatKnob, ConfigSpace, FloatKnob, IntKnob, Intervals


def space():
    return ConfigSpace([
        FloatKnob("f", 0.5, 4.0),
        FloatKnob("flog", 1.0, 1024.0, log=True),
        IntKnob("i", 2, 64, log=True, default=8),
        CatKnob("c", ("a", "b", "c"), default="b"),
        BoolKnob("b", default=True),
    ])


def test_encode_decode_roundtrip_default():
    s = space()
    cfg = s.default()
    dec = s.decode(s.encode(cfg))
    assert dec["c"] == "b" and dec["b"] is True
    assert abs(dec["f"] - cfg["f"]) < 1e-9
    assert dec["i"] == cfg["i"]


def _check_sample_within_bounds(seed):
    s = space()
    rng = np.random.default_rng(seed)
    for cfg in s.sample(rng, 5):
        assert 0.5 <= cfg["f"] <= 4.0
        assert 1.0 <= cfg["flog"] <= 1024.0
        assert 2 <= cfg["i"] <= 64
        assert cfg["c"] in ("a", "b", "c")
        u = s.encode(cfg)
        assert np.all((u >= 0) & (u <= 1))


@pytest.mark.parametrize("seed", [0, 1, 7, 1234, 99991, 2**31 - 1])
def test_sample_within_bounds(seed):
    _check_sample_within_bounds(seed)


def test_sample_within_bounds_fuzz():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    settings(max_examples=20, deadline=None)(
        given(st.integers(0, 2**31 - 1))(_check_sample_within_bounds)
    )()


def test_lhs_stratification():
    s = ConfigSpace([FloatKnob("x", 0.0, 1.0)])
    cfgs = s.lhs_sample(np.random.default_rng(0), 10)
    xs = sorted(c["x"] for c in cfgs)
    # exactly one sample per decile
    for i, x in enumerate(xs):
        assert i / 10 <= x <= (i + 1) / 10


def test_intervals_restriction():
    s = space()
    r = s.restrict(keep=["f", "c"], ranges={"f": Intervals([(1.0, 1.5), (3.0, 3.5)])},
                   cat_subsets={"c": ["a", "c"]})
    assert set(r.names) == {"f", "c"}
    rng = np.random.default_rng(0)
    for cfg in r.sample(rng, 50):
        assert (1.0 <= cfg["f"] <= 1.5) or (3.0 <= cfg["f"] <= 3.5)
        assert cfg["c"] in ("a", "c")
    # project clips into the union
    assert r.project({"f": 2.2, "c": "b"})["f"] in (1.5, 3.0)


def test_intervals_algebra():
    iv = Intervals([(0, 1), (0.5, 2), (3, 4)])
    assert iv.intervals == [(0.0, 2.0), (3.0, 4.0)]
    assert iv.total_length == pytest.approx(3.0)
    assert iv.contains(1.9) and not iv.contains(2.5)
    assert iv.clip(2.4) == 2.0 and iv.clip(2.8) == 3.0


def test_complete_fills_defaults():
    s = space()
    full = s.complete({"f": 1.25})
    assert full["f"] == 1.25 and full["i"] == 8 and full["c"] == "b"
