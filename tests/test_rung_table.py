"""Array-native rung table vs the scalar Hyperband reference, plus the
promotion/incumbent/non-finite/trajectory bugfixes (ISSUE 8).

The table backend must replay the fixed scalar loop bit-for-bit: same
survivor sets (stable tie order), same evaluation order, same cost caps,
same final-rung outcomes — in both scalar-evaluate and batched-rung modes —
and the MFTune observation stream + trajectory must be identical across
``hyperband_backend`` values.
"""

import numpy as np
import pytest

from repro.core import (
    Bracket,
    CandidateColumns,
    ConfigBatch,
    ConfigSpace,
    CostColumns,
    FloatKnob,
    HyperbandRunner,
    KnowledgeBase,
    Observation,
    ProbabilisticRandomForest,
    Rung,
    RungTable,
    TaskRecord,
    hb_schedule,
    hyperband_backend,
    set_hyperband_backend,
    sh_schedule,
)
from repro.core.generator import SurrogateSource
from repro.core.similarity import TaskWeights


# --------------------------------------------------------- schedule exactness


def test_hb_schedule_table1_r9_eta3():
    # paper defaults: R=9, eta=3 -> proxy levels 1/9, 1/3, 1
    got = {b.s: [(r.n, round(r.r, 6)) for r in b.rungs] for b in hb_schedule(9, 3)}
    assert got == {
        2: [(9, 1), (3, 3), (1, 9)],
        1: [(5, 3), (1, 9)],
        0: [(3, 9)],
    }
    deltas = sorted({round(r.delta, 6) for b in hb_schedule(9, 3) for r in b.rungs})
    assert deltas == [round(1 / 9, 6), round(1 / 3, 6), 1.0]


def test_hb_schedule_r16_eta4():
    got = {b.s: [(r.n, round(r.r, 6)) for r in b.rungs] for b in hb_schedule(16, 4)}
    assert got == {
        2: [(16, 1), (4, 4), (1, 16)],
        1: [(6, 4), (1, 16)],
        0: [(3, 16)],
    }


def test_sh_schedule_terminal_rung_edge():
    # R=10, eta=3: r_1 = 10/9 accumulates float error; the final rung must
    # still terminate at r ~ R (the r >= R - 1e-9 edge), not loop past it
    rungs = sh_schedule(9, 10 * 3 ** (-2), 10.0, 3)
    assert len(rungs) == 3
    assert abs(rungs[-1].r - 10.0) < 1e-8
    assert rungs[-1].delta == 1.0
    assert all(r.delta <= 1.0 for r in rungs)
    # r_1 == R -> a single full-fidelity rung, no promotion
    only = sh_schedule(4, 9.0, 9.0, 3)
    assert len(only) == 1 and only[0].n == 4 and only[0].delta == 1.0


# ----------------------------------------------------- backend bit-equivalence


def _drive(backend, scores, fail_ids=(), batch_mode=False, R=9, eta=3):
    hb = HyperbandRunner(R=R, eta=eta, seed=0, backend=backend)
    bracket = hb.brackets[0]
    log = []

    def provide(n, rungs):
        return [{"id": i} for i in range(n)]

    def one(cfg):
        i = cfg["id"]
        return float(scores[i]), i in fail_ids, 1.0 + 0.1 * i

    def evaluate(cfg, delta, cap):
        log.append(("eval", cfg["id"], round(delta, 6), cap))
        return one(cfg)

    def evaluate_batch(cfgs, delta, cap):
        log.append(("batch", tuple(c["id"] for c in cfgs), round(delta, 6), cap))
        return [one(c) for c in cfgs]

    hooks = []
    out = hb.run_bracket(
        bracket,
        provide,
        evaluate,
        lambda cfg, d, p, f, e: hooks.append((cfg["id"], round(d, 6), p, f, e)),
        lambda: False,
        evaluate_batch=evaluate_batch if batch_mode else None,
    )
    outcomes = [(o.config["id"], o.performance, o.failed, o.elapsed) for o in out]
    return hb, outcomes, log, hooks


@pytest.mark.parametrize("batch_mode", [False, True])
@pytest.mark.parametrize(
    "case",
    [
        "plain",       # distinct scores, no failures
        "ties",        # duplicated scores: stable order is load-bearing
        "failures",    # failure-heavy rung: promotion quota from len(ok)
        "all_failed",  # rung with zero successes: bracket stops
    ],
)
def test_table_matches_loop_bit_for_bit(batch_mode, case):
    rng = np.random.default_rng(5)
    scores = rng.random(16)
    fail_ids = ()
    if case == "ties":
        scores = np.array([0.5, 0.2, 0.5, 0.2, 0.9, 0.2, 0.5, 0.1, 0.2] + [0.5] * 7)
    elif case == "failures":
        fail_ids = (0, 1, 2, 5)
        scores = np.arange(16, dtype=float)  # low id = better, but 0..2,5 fail
    elif case == "all_failed":
        fail_ids = tuple(range(16))
    ref = _drive("loop", scores, fail_ids, batch_mode)
    got = _drive("table", scores, fail_ids, batch_mode)
    assert ref[1] == got[1]  # final-rung outcomes
    assert ref[2] == got[2]  # evaluation order + fidelities + cost caps
    assert ref[3] == got[3]  # on_result hook stream
    # cost history values identical (list vs vectorized columns)
    hb_ref, hb_got = ref[0], got[0]
    for key, vals in hb_ref._cost_history.items():
        assert np.array_equal(np.asarray(vals), hb_got._cost_history.values(key))


def test_promotion_quota_counts_only_successes():
    # 9-config rung, the 4 best-scoring configs fail: quota must be
    # floor(5 successes / eta) = 1, not floor(9 results / eta) = 3
    scores = np.arange(9, dtype=float)
    fail_ids = (0, 1, 2, 3)
    for backend in ("loop", "table"):
        hb, outcomes, log, _ = _drive(backend, scores, fail_ids)
        evaluated_r1 = [e[1] for e in log if e[0] == "eval" and e[2] == round(1 / 3, 6)]
        assert evaluated_r1 == [4], backend  # only the best *successful* config
    # and the table records the survivor set explicitly
    table = hb.tables[0]
    assert [s.tolist() for s in table.survivors][0] == [4]


def test_all_failed_rung_stops_bracket():
    for backend in ("loop", "table"):
        _, outcomes, log, _ = _drive(backend, np.arange(9.0), tuple(range(16)))
        assert outcomes == []
        assert all(e[2] == round(1 / 9, 6) for e in log), backend  # rung 0 only


# ------------------------------------------------------------ RungTable unit


def _bracket(n, n_rungs=2):
    return Bracket(s=0, rungs=[Rung(n=max(n >> i, 1), r=3.0**i, delta=1.0) for i in range(n_rungs)])


def test_rung_table_promote_stable_ties():
    table = RungTable(_bracket(8), [{"id": i} for i in range(8)])
    scores = np.array([0.3, 0.1, 0.3, 0.1, 0.1, 0.3, 0.2, 0.1])
    table.record(0, np.arange(8), scores, np.zeros(8, bool), np.ones(8))
    surv = table.promote(0, 3)
    # keep = 8 // 3 = 2; ties on 0.1 keep evaluation order -> ids 1, 3
    assert surv.tolist() == [1, 3]
    assert table.survivors[0].tolist() == [1, 3]


def test_rung_table_rejects_nonfinite_success():
    table = RungTable(_bracket(4), [{"id": i} for i in range(4)])
    with pytest.raises(ValueError, match="non-finite"):
        table.record(0, [0, 1], [np.nan, 1.0], [False, False], [1.0, 1.0])
    with pytest.raises(ValueError, match="non-finite"):
        table.record(0, [0], [np.inf], [False], [1.0])
    # inf on a *failed* row is fine (masked out of promotion)
    table.record(0, [0, 1], [np.inf, 1.0], [True, False], [1.0, 1.0])
    assert table.promote(0, 3).tolist() == [1]


def test_rung_table_incremental_record_and_clear_reuses_buffers():
    table = RungTable(_bracket(8), list(range(8)), capacity=4)
    table.record(0, [0, 1, 2], [3.0, 1.0, 2.0], [False] * 3, [1.0] * 3)
    table.record(0, [3, 4], [0.5, 9.0], [False, True], [1.0, 1.0])
    assert len(table) == 5
    assert table.rows(0).tolist() == [0, 1, 2, 3, 4]
    assert table.promote(0, 3).tolist() == [3]  # 4 ok rows -> keep 1, best 0.5
    cap = table.capacity
    table.clear()
    assert len(table) == 0 and table.survivors == [] and table.capacity == cap
    table.record(0, np.arange(5), np.arange(5.0), np.zeros(5, bool), np.ones(5))
    assert table.capacity == cap  # no regrowth on reuse


def test_cost_columns_match_list_medians():
    cc = CostColumns()
    rng = np.random.default_rng(0)
    ref = {}
    for _ in range(200):
        key = float(rng.integers(3))
        v = float(rng.random())
        cc.append(key, v)
        ref.setdefault(key, []).append(v)
    for key, vals in ref.items():
        assert cc.count(key) == len(vals)
        assert np.array_equal(cc.values(key), np.asarray(vals))
        assert cc.median(key) == float(np.median(vals))
    cc.extend(0.0, [1.0, 2.0])
    assert cc.count(0.0) == len(ref[0.0]) + 2
    cc[9.0] = [5.0, 1.0, 3.0]  # dict-style seeding (tests/back-compat)
    assert cc.median(9.0) == 3.0


def test_backend_default_and_context():
    assert HyperbandRunner().backend == "table"
    with hyperband_backend("loop"):
        assert HyperbandRunner().backend == "loop"
    assert HyperbandRunner().backend == "table"
    with pytest.raises(ValueError):
        set_hyperband_backend("bogus")


# --------------------------------------------------- candidate provisioning


def _space():
    return ConfigSpace([FloatKnob(f"x{i}", 0.0, 1.0) for i in range(4)])


def test_candidate_columns_sequence_semantics():
    space = _space()
    head = [{"x0": 0.0, "x1": 0.0, "x2": 0.0, "x3": 0.0}]
    batch = ConfigBatch(space, np.random.default_rng(0).random((5, 4)))
    cols = CandidateColumns(head, batch, limit=4)
    assert len(cols) == 4
    assert cols[0] is head[0]
    assert cols[1] == batch[0]
    assert cols[1] is cols[1]  # batch rows materialize once (memoized)
    assert cols[-1] == batch[2]
    assert cols[1:3] == [batch[0], batch[1]]
    with pytest.raises(IndexError):
        cols[4]
    assert list(CandidateColumns(head, batch)) == head + batch.materialize()


def test_recommend_batch_matches_recommend():
    from repro.core import CandidateGenerator

    space = _space()
    rng = np.random.default_rng(3)
    models = [
        ProbabilisticRandomForest(n_trees=5, seed=s).fit(
            rng.random((20, 4)), rng.random(20)
        )
        for s in range(2)
    ]
    sources = [
        SurrogateSource(name=f"s{i}", model=m, weight=0.5, incumbent=0.4)
        for i, m in enumerate(models)
    ]
    inc = [{"x0": 0.5, "x1": 0.5, "x2": 0.5, "x3": 0.5}]
    ref = CandidateGenerator(space, seed=7).recommend(5, sources, incumbents=inc)
    got = CandidateGenerator(space, seed=7).recommend_batch(5, sources, incumbents=inc)
    assert isinstance(got, ConfigBatch)
    assert got.materialize() == ref
    # no active sources -> random permutation path, same draws
    ref0 = CandidateGenerator(space, seed=7).recommend(3, [])
    got0 = CandidateGenerator(space, seed=7).recommend_batch(3, [])
    assert got0.materialize() == ref0


# ------------------------------------------------- MFTune-level regressions


def _mft(tmp_path=None, **opt_kw):
    from repro.core import MFTune, MFTuneOptions
    from repro.sparksim import SparkWorkload

    wl = SparkWorkload("tpch", 100, "A")
    return MFTune(wl, KnowledgeBase(), MFTuneOptions(seed=0, **opt_kw))


def _result(latencies, failed=False):
    from repro.tuneapi import EvalResult

    return EvalResult(
        per_query_latency=list(latencies),
        per_query_cost=[1.0] * len(latencies),
        failed=failed,
    )


def test_record_coerces_nonfinite_to_failure():
    from repro.tuneapi import Budget

    mft = _mft()
    budget = Budget(100.0)
    cfg = dict(mft.space.default())
    perf, failed, _ = mft._record(budget, cfg, 1.0, None, _result([np.nan, 1.0]))
    assert failed and perf == float("inf")
    obs = mft.target.observations[-1]
    assert obs.failed and obs.performance == float("inf")
    assert obs.per_query_perf is None
    assert mft.target.best() is None  # not poisoned by NaN
    assert mft._trajectory == []
    # a later finite result is unaffected
    perf, failed, _ = mft._record(budget, cfg, 1.0, None, _result([2.0, 1.0]))
    assert not failed and mft.target.best().performance == 3.0


def test_trajectory_strict_improvement_no_tie_duplicates():
    from repro.tuneapi import Budget

    mft = _mft()
    budget = Budget(100.0)
    cfg = dict(mft.space.default())
    mft._record(budget, cfg, 1.0, None, _result([5.0]))
    mft._record(budget, cfg, 1.0, None, _result([5.0]))  # exact tie: no point
    mft._record(budget, cfg, 1.0, None, _result([4.0]))
    mft._record(budget, cfg, 1.0, None, _result([6.0]))
    assert [p.best for p in mft._trajectory] == [5.0, 4.0]


def test_empty_incumbent_config_not_dropped(monkeypatch):
    """A falsy (all-defaults, {}) incumbent must still reach recommend."""
    from repro.tuneapi import Budget

    mft = _mft()
    mft.target.observations.append(
        Observation(config={}, performance=1.0, fidelity=1.0)
    )
    seen = {}

    def fake_recommend(n, sources, incumbents=(), exclude=()):
        seen["incumbents"] = list(incumbents)
        return []

    monkeypatch.setattr(mft.gen, "recommend", fake_recommend)
    mft._run_bo_step(Budget(100.0), TaskWeights(weights={}, similarities={}, used_meta=False))
    assert seen["incumbents"] == [{}]


def test_provide_passes_empty_incumbent_to_recommend_batch(monkeypatch):
    from repro.tuneapi import Budget

    mft = _mft()
    assert mft.hb.backend == "table"
    mft.target.observations.append(
        Observation(config={}, performance=1.0, fidelity=1.0)
    )
    seen = {}

    def fake_recommend_batch(n, sources, incumbents=(), exclude=()):
        seen["incumbents"] = list(incumbents)
        return ConfigBatch(mft.space, np.empty((0, mft.space.dim)))

    monkeypatch.setattr(mft.gen, "recommend_batch", fake_recommend_batch)
    budget = Budget(1.0)
    budget.charge(2.0, label="drain")  # exhausted: provide runs, no evals
    mft._run_mfo_bracket(budget, TaskWeights(weights={}, similarities={}, used_meta=False))
    assert seen["incumbents"] == [{}]


# ------------------------------------------ MFTune identity across backends


def _observations(**opt_kw):
    from repro.core import MFTune, MFTuneOptions
    from repro.sparksim import SparkWorkload, TaskSpec, generate_history
    from repro.tuneapi import Budget

    kb = KnowledgeBase()
    kb.add_task(
        generate_history(
            TaskSpec("tpch", 100, "A").workload(), n_obs=12, n_init=5, seed=3
        ),
        persist=False,
    )
    wl = SparkWorkload("tpch", 100, "A")
    res = MFTune(wl, kb, MFTuneOptions(seed=0, **opt_kw)).run(Budget(8 * 3600.0))
    obs = kb.get(wl.task_id).observations
    sig = [
        (o.performance, o.fidelity, o.failed, tuple(sorted(o.config.items())))
        for o in obs
    ]
    traj = [
        (p.time, p.best, tuple(sorted(p.config.items()))) for p in res.trajectory
    ]
    return sig, traj, res


def test_mftune_identical_across_hyperband_backends():
    ref_sig, ref_traj, ref_res = _observations(hyperband_backend="loop")
    got_sig, got_traj, got_res = _observations(hyperband_backend="table")
    assert ref_res.n_evaluations > 10  # the tuning loop actually ran
    assert ref_sig == got_sig
    assert ref_traj == got_traj
    assert ref_res.best_performance == got_res.best_performance
    # promotion state is exposed without re-deriving it
    assert ref_res.rung_tables == []
    assert got_res.rung_tables and all(
        isinstance(t, RungTable) for t in got_res.rung_tables
    )
    assert any(t.survivors for t in got_res.rung_tables)
