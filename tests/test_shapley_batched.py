"""Batched Shapley/compression plane equivalence suite.

Asserts that (1) the batched masked-evaluation plane reproduces the legacy
per-chain loop bit-for-bit under shared permutation draws, across
dimensionalities, permutation counts (odd included) and background sizes,
(2) the batch explainer equals sequential per-config calls with a shared
rng, (3) the Monte-Carlo error bound against exact enumeration is retained
and additivity holds exactly, (4) the proportional residual correction
keeps surrogate-ignored knobs at phi == 0.0, (5) ``SpaceCompressor``
invalidates stale cached regions and reuses KDE fits across calls, (6) the
bitvector chain kernel (``model=`` opt-in) reproduces the loop bit-for-bit
and falls back to the generic path when a tree overflows its uint64 word,
and (7) MFTune incumbent trajectories are identical across Shapley
backends at a fixed seed.
"""

import numpy as np
import pytest

from repro.core import (
    ConfigSpace,
    FloatKnob,
    KnowledgeBase,
    Observation,
    SpaceCompressor,
    TaskRecord,
    draw_permutations,
    make_forest,
    shapley_values,
    shapley_values_batch,
    shapley_values_exact,
)
from repro.core.compression import extract_promising_regions
from repro.core.similarity import TaskWeights


def _poly(d, seed=0):
    w = np.random.default_rng(seed).normal(size=d)
    return lambda Z: (Z * w).sum(axis=1) + 2.0 * Z[:, 0] * Z[:, 1 % d]


def _forest_f(d, seed=0, n=48):
    rng = np.random.default_rng(seed)
    X = rng.random((n, d))
    y = 3 * X[:, 0] - X[:, 1 % d] ** 2 + 0.1 * rng.normal(size=n)
    m = make_forest(seed=seed).fit(X, y)
    return m.predict_mean


# ----------------------------------------------------------- backend identity


@pytest.mark.parametrize(
    "d,n_perm,nb",
    [(3, 4, 1), (6, 8, 12), (6, 1, 5), (9, 3, 16), (16, 32, 16), (24, 32, 16)],
)
def test_batched_matches_loop_bitwise(d, n_perm, nb):
    rng = np.random.default_rng(d + n_perm)
    x = rng.random(d)
    bg = rng.random((nb, d))
    for f in (_poly(d, seed=1), _forest_f(d, seed=2)):
        a = shapley_values(
            f, x, bg, n_permutations=n_perm, rng=np.random.default_rng(7), backend="loop"
        )
        b = shapley_values(
            f, x, bg, n_permutations=n_perm, rng=np.random.default_rng(7), backend="batched"
        )
        assert np.array_equal(a, b)


def test_batched_invariant_to_chunking():
    d, nb = 8, 6
    rng = np.random.default_rng(0)
    x, bg = rng.random(d), rng.random((nb, d))
    f = _forest_f(d, seed=3)
    perms = draw_permutations(d, 8, np.random.default_rng(1))
    full = shapley_values(f, x, bg, perms=perms, backend="batched")
    tiny = shapley_values(f, x, bg, perms=perms, backend="batched", max_eval_rows=1)
    assert np.array_equal(full, tiny)


def test_batch_matches_sequential_shared_rng():
    d, nb, n_cfg = 7, 10, 9
    rng = np.random.default_rng(3)
    X = rng.random((n_cfg, d))
    bg = rng.random((nb, d))
    f = _forest_f(d, seed=4)
    r = np.random.default_rng(11)
    seq = np.stack(
        [shapley_values(f, xi, bg, n_permutations=6, rng=r, backend="loop") for xi in X]
    )
    bat = shapley_values_batch(
        f, X, bg, n_permutations=6, rng=np.random.default_rng(11), backend="batched"
    )
    assert np.array_equal(seq, bat)
    # the loop backend of the batch explainer is the same pinned path
    lop = shapley_values_batch(
        f, X, bg, n_permutations=6, rng=np.random.default_rng(11), backend="loop"
    )
    assert np.array_equal(seq, lop)


def test_odd_permutation_count_runs_exactly_n_chains():
    d, nb = 5, 4
    rng = np.random.default_rng(0)
    x, bg = rng.random(d), rng.random((nb, d))
    calls = {"rows": 0}

    def f(Z):
        calls["rows"] += len(Z)
        return Z.sum(axis=1)

    shapley_values(f, x, bg, n_permutations=1, rng=np.random.default_rng(1), backend="loop")
    # 1 chain * (d+1) prefixes * nb rows, plus the two residual anchors
    assert calls["rows"] == (d + 1) * nb + 1 + nb
    assert len(draw_permutations(d, 3, np.random.default_rng(0))) == 3


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        shapley_values(lambda Z: Z.sum(1), np.zeros(3), np.zeros((2, 3)), backend="vmap")


# --------------------------------------------------------- estimator quality


def test_mc_matches_exact_batched():
    rng = np.random.default_rng(0)
    d = 4
    w = np.array([2.0, -1.0, 0.5, 0.0])
    f = lambda Z: Z @ w + 3 * Z[:, 0] * Z[:, 1]
    x = rng.random(d)
    bg = rng.random((12, d))
    exact = shapley_values_exact(f, x, bg)
    mc = shapley_values(
        f, x, bg, n_permutations=64, rng=np.random.default_rng(1), backend="batched"
    )
    assert np.abs(exact - mc).max() < 0.05
    assert abs(mc.sum() - (f(x[None])[0] - f(bg).mean())) < 1e-9


@pytest.mark.parametrize("seed", [0, 1, 17, 123])
def test_additivity_property_batched(seed):
    rng = np.random.default_rng(seed)
    d = 6
    A = rng.normal(size=(d, d)) / d
    f = lambda Z: np.einsum("ni,ij,nj->n", Z, A, Z)
    X = rng.random((3, d))
    bg = rng.random((8, d))
    phis = shapley_values_batch(f, X, bg, n_permutations=8, rng=rng, backend="batched")
    for i in range(len(X)):
        assert abs(phis[i].sum() - (f(X[i][None])[0] - f(bg).mean())) < 1e-9


def test_proportional_residual_keeps_ignored_knob_zero():
    """A knob the model ignores must keep phi == 0.0 exactly; the old
    uniform resid/d spread injected spurious attribution into it."""
    d = 6
    rng = np.random.default_rng(2)
    x, bg = rng.random(d), rng.random((9, d))
    f = lambda Z: 3.0 * Z[:, 0] + Z[:, 1] ** 2  # ignores knobs 2..5
    for backend in ("loop", "batched"):
        phi = shapley_values(
            f, x, bg, n_permutations=8, rng=np.random.default_rng(3), backend=backend
        )
        assert all(phi[j] == 0.0 for j in range(2, d))
        # additivity still exact after the proportional distribution
        assert abs(phi.sum() - (f(x[None])[0] - f(bg).mean())) < 1e-9


def test_uniform_fallback_on_all_zero_attribution():
    d = 4
    rng = np.random.default_rng(0)
    x, bg = rng.random(d), rng.random((5, d))
    f = lambda Z: np.full(len(Z), 2.5)  # constant model: every phi exactly 0
    phi = shapley_values(f, x, bg, n_permutations=4, rng=rng)
    assert np.all(np.isfinite(phi)) and np.array_equal(phi, np.zeros(d))


# ----------------------------------------------------- compression integration


def _space(d=6):
    return ConfigSpace([FloatKnob(f"x{i}", 0.0, 1.0) for i in range(d)])


def _record(task_id, space, f, n=40, seed=0):
    rng = np.random.default_rng(seed)
    rec = TaskRecord(task_id=task_id, queries=["q1"])
    for cfg in space.sample(rng, n):
        rec.observations.append(
            Observation(config=cfg, performance=f(cfg), fidelity=1.0)
        )
    return rec


def _space_sig(space):
    sig = []
    for k in space.knobs:
        iv = k.active_intervals() if hasattr(k, "active_intervals") else None
        sig.append((k.name, tuple(iv.intervals) if iv is not None else None))
    return tuple(sig)


def test_extract_identical_across_backends():
    space = _space(5)
    f = lambda c: (c["x0"] - 0.2) ** 2 + (c["x1"] - 0.7) ** 2 + 1.0
    task = _record("s0", space, f, n=40, seed=0)
    regions = [
        extract_promising_regions(space, task, 1.0, seed=3, backend=b)
        for b in ("loop", "batched")
    ]
    assert regions[0] is not None and regions[1] is not None
    assert regions[0].importance == regions[1].importance
    assert regions[0].values == regions[1].values


def test_compression_identical_across_backends():
    space = _space(6)
    f = lambda c: (c["x0"] - 0.1) ** 2 + (c["x1"] - 0.9) ** 2 + 1.0
    tasks = {f"s{i}": _record(f"s{i}", space, f, n=50, seed=i) for i in range(3)}
    weights = TaskWeights(
        weights={k: 1 / 3 for k in tasks}, similarities={}, used_meta=False
    )
    sigs = []
    for backend in ("loop", "batched"):
        comp = SpaceCompressor(space, alpha=0.65, seed=0, backend=backend)
        sigs.append(_space_sig(comp.compress(weights, tasks)))
    assert sigs[0] == sigs[1]


def test_stale_region_cache_invalidated():
    space = _space(4)
    f = lambda c: c["x0"] + 0.5
    comp = SpaceCompressor(space, alpha=0.65, seed=0)
    target = _record("tgt", space, f, n=8, seed=1)
    assert comp._region(target, 1.0) is not None
    assert "tgt" in comp._cache
    # the target briefly drops below 4 full-fidelity observations
    target.observations = target.observations[:3]
    assert comp._region(target, 1.0, refresh=True) is None
    assert "tgt" not in comp._cache  # stale entry must not survive
    assert comp._region(target, 1.0) is None  # and must not be served later


def test_range_cache_reused_across_compress_calls(monkeypatch):
    space = _space(6)
    f = lambda c: (c["x0"] - 0.1) ** 2 + (c["x1"] - 0.9) ** 2 + 1.0
    tasks = {f"s{i}": _record(f"s{i}", space, f, n=50, seed=i) for i in range(2)}
    weights = TaskWeights(
        weights={k: 0.5 for k in tasks}, similarities={}, used_meta=False
    )
    comp = SpaceCompressor(space, alpha=0.65, seed=0)
    fits = {"n": 0}
    import repro.core.compression as cmod

    real_kde = cmod.WeightedKDE

    def counting_kde(*a, **kw):
        fits["n"] += 1
        return real_kde(*a, **kw)

    monkeypatch.setattr(cmod, "WeightedKDE", counting_kde)
    s1 = comp.compress(weights, tasks)
    cold = fits["n"]
    assert cold > 0
    s2 = comp.compress(weights, tasks)  # unchanged weights: all cache hits
    assert fits["n"] == cold
    assert _space_sig(s1) == _space_sig(s2)


def test_extract_deterministic_and_decoupled_streams():
    space = _space(5)
    f = lambda c: (c["x0"] - 0.3) ** 2 + 1.0
    # > 16 observations so the background subsample path is exercised
    task = _record("s0", space, f, n=30, seed=5)
    r1 = extract_promising_regions(space, task, 1.0, seed=9)
    r2 = extract_promising_regions(space, task, 1.0, seed=9)
    assert r1 is not None and r1.values == r2.values and r1.importance == r2.importance


# ----------------------------------------------------- bitvector chain kernel


def _forest(d, seed=0, n=48):
    rng = np.random.default_rng(seed)
    X = rng.random((n, d))
    y = 3 * X[:, 0] - X[:, 1 % d] ** 2 + 0.1 * rng.normal(size=n)
    return make_forest(seed=seed).fit(X, y)


@pytest.mark.parametrize(
    "d,n_perm,nb",
    [(3, 4, 1), (6, 8, 12), (6, 1, 5), (9, 3, 16), (24, 32, 16)],
)
def test_chain_kernel_matches_loop_bitwise(d, n_perm, nb):
    m = _forest(d, seed=2)
    rng = np.random.default_rng(d + nb)
    x, bg = rng.random(d), rng.random((nb, d))
    a = shapley_values(
        m.predict_mean, x, bg, n_permutations=n_perm,
        rng=np.random.default_rng(7), backend="loop",
    )
    b = shapley_values(
        m.predict_mean, x, bg, n_permutations=n_perm,
        rng=np.random.default_rng(7), backend="batched", model=m,
    )
    assert np.array_equal(a, b)


def test_chain_kernel_batch_matches_sequential():
    d, nb, n_cfg = 8, 10, 7
    m = _forest(d, seed=5)
    rng = np.random.default_rng(1)
    X, bg = rng.random((n_cfg, d)), rng.random((nb, d))
    r = np.random.default_rng(11)
    seq = np.stack(
        [
            shapley_values(m.predict_mean, xi, bg, n_permutations=6, rng=r, backend="loop")
            for xi in X
        ]
    )
    bat = shapley_values_batch(
        m.predict_mean, X, bg, n_permutations=6,
        rng=np.random.default_rng(11), backend="batched", model=m,
    )
    assert np.array_equal(seq, bat)


def test_chain_kernel_invariant_to_chunking():
    d, nb = 7, 5
    m = _forest(d, seed=6)
    rng = np.random.default_rng(0)
    x, bg = rng.random(d), rng.random((nb, d))
    perms = draw_permutations(d, 8, np.random.default_rng(1))
    full = shapley_values(m.predict_mean, x, bg, perms=perms, backend="batched", model=m)
    tiny = shapley_values(
        m.predict_mean, x, bg, perms=perms, backend="batched", model=m, max_eval_rows=1
    )
    assert np.array_equal(full, tiny)


def test_chain_plan_cached_on_arena():
    from repro.kernels.forest_eval.chain import build_chain_plan

    m = _forest(6, seed=0)
    p1 = build_chain_plan(m, 6)
    p2 = build_chain_plan(m, 6)
    assert p1 is not None and p1 is p2


def test_chain_plan_fallback_on_large_trees():
    """Trees past 64 leaves don't fit a uint64 word: the plan builder must
    decline and the batched backend must fall back to the generic composite
    path — still bit-identical to the loop."""
    from repro.kernels.forest_eval.chain import build_chain_plan

    d = 6
    rng = np.random.default_rng(0)
    X = rng.random((600, d))
    y = rng.normal(size=600)  # pure noise: splits keep refining to depth 12
    m = make_forest(seed=0).fit(X, y)
    assert build_chain_plan(m, d) is None
    x, bg = rng.random(d), rng.random((8, d))
    a = shapley_values(
        m.predict_mean, x, bg, n_permutations=4,
        rng=np.random.default_rng(3), backend="loop",
    )
    b = shapley_values(
        m.predict_mean, x, bg, n_permutations=4,
        rng=np.random.default_rng(3), backend="batched", model=m,
    )
    assert np.array_equal(a, b)


def test_chain_plan_guards():
    from repro.kernels.forest_eval.chain import build_chain_plan

    m = _forest(5, seed=1)
    assert build_chain_plan(m, 70) is None  # prefix masks need d <= 64
    assert build_chain_plan(object(), 5) is None  # not a packable forest
    # model= on a non-forest callable silently uses the generic path
    f = _poly(5, seed=2)
    rng = np.random.default_rng(4)
    x, bg = rng.random(5), rng.random((6, 5))
    a = shapley_values(f, x, bg, n_permutations=4, rng=np.random.default_rng(5), backend="loop")
    b = shapley_values(
        f, x, bg, n_permutations=4, rng=np.random.default_rng(5),
        backend="batched", model=object(),
    )
    assert np.array_equal(a, b)


# ------------------------------------------------- end-to-end backend identity


def _traj(shapley_backend):
    from repro.core import MFTune, MFTuneOptions
    from repro.sparksim import SparkWorkload, TaskSpec, generate_history
    from repro.tuneapi import Budget

    kb = KnowledgeBase()
    kb.add_task(
        generate_history(TaskSpec("tpch", 100, "A").workload(), n_obs=12, n_init=5, seed=3),
        persist=False,
    )
    wl = SparkWorkload("tpch", 600, "A")
    opts = MFTuneOptions(seed=0, shapley_backend=shapley_backend)
    res = MFTune(wl, kb, opts).run(Budget(6 * 3600.0))
    return [(p.time, p.best, tuple(sorted(p.config.items()))) for p in res.trajectory]


def test_mftune_trajectory_identical_across_shapley_backends():
    assert _traj("batched") == _traj("loop")
