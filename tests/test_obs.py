"""Unified tracing & metrics plane (ISSUE 9).

Invariants pinned here:
  * span nesting/ordering: parent ids resolve through the per-thread
    stack, spans are emitted in close order, ids are unique, nested
    durations fit inside their parents;
  * histogram bins: fixed log-spaced edges, one-searchsorted recording,
    underflow/overflow buckets;
  * the disabled path is a true no-op and tracing alters nothing: the
    observation stream + trajectory of a full MFTune run are bit-identical
    tracer-on vs tracer-off at a fixed seed;
  * exporters: JSONL and Chrome/Perfetto JSON both round-trip back to
    schema-valid canonical events, and the Perfetto file is plain
    ``json.load``-able (what ui.perfetto.dev requires);
  * back-compat: ``TuningResult.overheads`` / ``surrogate_cache`` /
    ``plane_cache`` are now views over the typed Metrics registry but keep
    their historical shapes and dtypes;
  * baselines route through the same tracer vocabulary.
"""

import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.core import KnowledgeBase, MFTune, MFTuneOptions
from repro.obs.metrics import HIST_BINS, HIST_HI, HIST_LO
from repro.sparksim import SparkWorkload, TaskSpec, generate_history
from repro.tuneapi import Budget


@pytest.fixture(autouse=True)
def _no_global_tracer():
    # tests install tracers explicitly; never leak one across tests
    obs.set_tracer(None)
    yield
    obs.set_tracer(None)


def _warm_kb():
    kb = KnowledgeBase()
    kb.add_task(
        generate_history(
            TaskSpec("tpch", 100, "A").workload(), n_obs=12, n_init=5, seed=3
        ),
        persist=False,
    )
    return kb


def _spans(tracer):
    return [e for e in obs.trace_events(tracer) if e["type"] == "span"]


# ------------------------------------------------------------ span invariants


def test_span_nesting_and_ordering():
    tr = obs.Tracer("t")
    obs.set_tracer(tr)
    with obs.span("outer", a=1) as so:
        with obs.span("inner") as si:
            assert si.parent == so.id
        with obs.span("inner2") as s2:
            s2.set(k="v")
    spans = _spans(tr)
    # spans are emitted when they close: inner, inner2, outer
    assert [s["name"] for s in spans] == ["inner", "inner2", "outer"]
    inner, inner2, outer = spans
    assert outer["parent"] == -1
    assert inner["parent"] == outer["id"] and inner2["parent"] == outer["id"]
    assert inner2["args"]["k"] == "v" and outer["args"]["a"] == 1
    ids = [s["id"] for s in spans]
    assert len(set(ids)) == len(ids)
    # children fit inside the parent window
    for ch in (inner, inner2):
        assert ch["ts"] >= outer["ts"]
        assert ch["ts"] + ch["dur"] <= outer["ts"] + outer["dur"] + 1e-9
    assert inner2["ts"] >= inner["ts"] + inner["dur"] - 1e-9  # sequential siblings


def test_span_stack_is_per_thread():
    tr = obs.Tracer("t")
    obs.set_tracer(tr)
    seen = {}

    def worker():
        with obs.span("in_thread") as s:
            seen["parent"] = s.parent

    with obs.span("main_span"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    # the thread's span must NOT adopt the main thread's open span
    assert seen["parent"] == -1
    tids = {s["tid"] for s in _spans(tr)}
    assert len(tids) == 2


def test_disabled_path_is_noop():
    assert obs.get_tracer() is None
    with obs.span("x", a=1) as s:
        s.set(b=2)
        assert s.id == -1
    obs.count("c")
    obs.observe("h", 1.0)
    obs.gauge("g", 3.0)
    obs.instant("i")
    assert obs.get_tracer() is None


def test_mis_nested_close_unwinds():
    tr = obs.Tracer("t")
    obs.set_tracer(tr)
    a = obs.span("a").__enter__()
    obs.span("b").__enter__()  # never exited (leaked)
    a.__exit__(None, None, None)  # closing the outer unwinds past it
    with obs.span("c"):
        pass
    spans = {s["name"]: s for s in _spans(tr)}
    assert set(spans) == {"a", "c"}  # leaked span dropped, not emitted
    assert spans["c"]["parent"] == -1  # stack fully unwound — no stale parent


def test_buffer_cap_drops_not_grows():
    tr = obs.Tracer("t", max_events=5)
    obs.set_tracer(tr)
    for i in range(20):
        obs.instant(f"e{i}")
    assert len(tr.events) == 5
    assert tr.dropped == 15


# ---------------------------------------------------------------- histograms


def test_histogram_log_spaced_edges_and_overflow():
    m = obs.Metrics()
    h = m.histogram("lat")
    assert len(h.edges) == HIST_BINS + 1
    np.testing.assert_allclose(
        h.edges, np.logspace(np.log10(HIST_LO), np.log10(HIST_HI), HIST_BINS + 1)
    )
    # ratio between consecutive edges is constant (log-spaced)
    r = h.edges[1:] / h.edges[:-1]
    np.testing.assert_allclose(r, r[0])
    h.observe(1e-9)   # underflow -> bucket 0
    h.observe(1e9)    # overflow  -> bucket len(edges)
    h.observe(1.0)
    assert h.counts[0] == 1 and h.counts[-1] == 1
    assert h.n == 3 and h.counts.sum() == 3
    snap = h.snapshot()
    assert snap["min"] == 1e-9 and snap["max"] == 1e9
    assert snap["total"] == pytest.approx(1e-9 + 1e9 + 1.0)
    # recorded bucket matches a direct searchsorted
    k = int(np.searchsorted(h.edges, 1.0, side="right"))
    assert h.counts[k] >= 1


def test_metrics_registry_views():
    m = obs.Metrics()
    m.counter("overhead/similarity").add(0.5)
    m.counter("overhead/similarity").add(0.25)
    m.counter("store/hits").add(3)
    assert m.counters_view("overhead/", coerce_int=False) == {"similarity": 0.75}
    view = m.counters_view("store/")
    assert view == {"hits": 3} and isinstance(view["hits"], int)
    m.absorb_counters("pc/", {"hits": 7, "misses": 2})
    assert m.counters_view("pc/") == {"hits": 7, "misses": 2}
    snap = m.snapshot()
    assert set(snap) == {"counters", "gauges", "histograms"}
    assert snap["counters"]["overhead/similarity"] == 0.75


# ------------------------------------------------- tracing alters no numerics


def _identity_run(traced: bool):
    kb = _warm_kb()
    wl = SparkWorkload("tpch", 100, "A")
    tuner = MFTune(wl, kb, MFTuneOptions(seed=0))
    if traced:
        tracer = obs.Tracer("identity")
        with obs.tracing(tracer):
            res = tuner.tune(Budget(8 * 3600.0))
    else:
        tracer = None
        res = tuner.tune(Budget(8 * 3600.0))
    sig = [
        (o.performance, o.fidelity, tuple(sorted(o.config.items())))
        for o in kb.get(wl.task_id).observations
    ]
    traj = [
        (p.time, p.best, p.fidelity, p.rung, tuple(sorted(p.config.items())))
        for p in res.trajectory
    ]
    return sig, traj, res, tracer


def test_tracer_on_off_bit_identical():
    sig_off, traj_off, res_off, _ = _identity_run(traced=False)
    sig_on, traj_on, res_on, tracer = _identity_run(traced=True)
    assert sig_on == sig_off
    assert traj_on == traj_off
    assert res_on.best_performance == res_off.best_performance
    assert res_on.overheads.keys() == res_off.overheads.keys()
    # the traced run actually traced something
    assert len(tracer.events) > 50


def test_trace_covers_tuner_stages_and_rungs():
    _, _, res, tracer = _identity_run(traced=True)
    events = obs.trace_events(tracer)
    assert obs.validate_events(events) == []
    names = {e["name"] for e in events if e["type"] == "span"}
    for required in ("pool_gen", "surrogate_fit", "surrogate_eval", "bo_recommend",
                     "rung_eval", "space_compression", "workload_eval", "evaluate"):
        assert required in names, f"missing span {required}"
    rungs = [e for e in events if e["type"] == "span" and e["name"] == "rung_eval"]
    for r in rungs:
        a = r["args"]
        assert a["evaluated"] >= a.get("survivors", 0)
        assert a["cost"] >= 0
    # per-run metrics exported under the task scope
    scopes = {e.get("scope") for e in events if e["type"] == "counter"}
    assert "tpch-100gb-A" in scopes


def test_trajectory_wall_time_and_rung():
    # cold start: the warm-history recipe seeds the target's own record, so
    # nothing improves on it; with an empty KB the first full eval always does
    wl = SparkWorkload("tpch", 100, "A")
    res = MFTune(wl, KnowledgeBase(), MFTuneOptions(seed=0)).tune(Budget(4 * 3600.0))
    assert res.trajectory
    for p in res.trajectory:
        assert p.wall_time > 1e9  # real epoch seconds
        assert p.fidelity == 1.0 and p.rung is not None


# ------------------------------------------------------------------ back-compat


def test_tuning_result_views_back_compat():
    _, _, res, _ = _identity_run(traced=False)
    assert res.overheads and all(isinstance(v, float) for v in res.overheads.values())
    for key in ("similarity", "space_compression", "bo_recommend"):
        assert key in res.overheads
    for cache in (res.surrogate_cache, res.plane_cache):
        assert cache and all(isinstance(v, int) for v in cache.values())
    assert {"hits", "misses"} <= res.surrogate_cache.keys()
    assert {"hits", "misses"} <= res.plane_cache.keys()
    # the raw registry snapshot is also exposed
    assert res.metrics["counters"]["overhead/similarity"] == pytest.approx(
        res.overheads["similarity"]
    )


def test_rung_table_rows_carry_trace_ids():
    from repro.core import hyperband_backend

    with hyperband_backend("table"):
        kb = _warm_kb()
        wl = SparkWorkload("tpch", 100, "A")
        tuner = MFTune(wl, kb, MFTuneOptions(seed=0))
        tracer = obs.Tracer("rt")
        with obs.tracing(tracer):
            res = tuner.tune(Budget(8 * 3600.0))
    tables = [t for t in res.rung_tables if len(t) > 0]
    assert tables
    span_ids = {e["id"] for e in tracer.events
                if e["type"] == "span" and e["name"] == "rung_eval"}
    for table in tables:
        ids = table.trace_id[: len(table)]
        assert (ids > 0).all()  # every recorded row links to its rung span
        assert set(np.unique(ids)) <= span_ids


# ------------------------------------------------------------------- exporters


def test_perfetto_round_trip(tmp_path):
    _, _, _, tracer = _identity_run(traced=True)
    canonical = obs.trace_events(tracer)
    pf = tmp_path / "trace.json"
    jl = tmp_path / "trace.jsonl"
    obs.export_perfetto(tracer, str(pf))
    obs.export_jsonl(tracer, str(jl))

    with open(pf) as f:
        doc = json.load(f)  # plain JSON, ui.perfetto.dev-openable
    assert isinstance(doc["traceEvents"], list)
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert "X" in phases and "C" in phases  # durations + counters

    back_pf = obs.read_events(str(pf))
    back_jl = obs.read_events(str(jl))
    assert obs.validate_events(back_pf) == []
    assert obs.validate_events(back_jl) == []
    assert len(back_pf) == len(back_jl) == len(canonical)
    # span stream survives both encodings losslessly
    key = lambda e: (e["name"], round(e["ts"], 6), e["id"], e["parent"])
    spans = sorted(key(e) for e in canonical if e["type"] == "span")
    assert sorted(key(e) for e in back_pf if e["type"] == "span") == spans
    assert sorted(key(e) for e in back_jl if e["type"] == "span") == spans


def test_schema_validator_flags_bad_events():
    good = {"type": "instant", "name": "x", "ts": 0.0, "tid": 1, "args": {}}
    assert obs.validate_events([good]) == []
    bad = [
        {"type": "span", "name": "x"},                     # missing required
        {"type": "instant", "name": 3, "ts": 0.0, "tid": 1, "args": {}},  # wrong type
        {"type": "nope", "name": "x"},                     # unknown type
        {"type": "span", "name": "x", "ts": 0.0, "dur": -1.0, "id": 1,
         "parent": -1, "tid": 1, "args": {}},              # negative duration
    ]
    for ev in bad:
        assert obs.validate_events([ev]), f"validator accepted {ev}"


# ------------------------------------------------------------------- baselines


def test_baselines_share_tracer_vocabulary():
    from repro.baselines import LOCAT, VanillaBO

    for cls in (VanillaBO, LOCAT):
        kb = _warm_kb()
        wl = SparkWorkload("tpch", 100, "A")
        tracer = obs.Tracer("bl")
        with obs.tracing(tracer):
            res = cls(wl, kb=kb, seed=0).run(Budget(12 * 3600.0))
        names = {e["name"] for e in tracer.events if e["type"] == "span"}
        assert "bo_recommend" in names and "workload_eval" in names
        assert "bo_recommend" in res.overheads
        assert res.metrics["counters"]["budget/full_fidelity_s"] > 0
        scopes = {e.get("scope") for e in tracer.events if e["type"] == "counter"}
        assert f"{cls.name}:tpch-100gb-A" in scopes
        for p in res.trajectory:
            assert p.wall_time > 1e9 and p.rung is None
