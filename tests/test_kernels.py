"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dt):
    return TOL[dt]


slow = pytest.mark.slow  # larger shapes ride in the slow tier (compile time)


@pytest.mark.parametrize("S,Hkv,G,D", [
    (64, 1, 1, 16),
    pytest.param(128, 2, 2, 32, marks=slow),
    pytest.param(64, 2, 4, 64, marks=slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None), (True, 32)])
def test_flash_attn_sweep(S, Hkv, G, D, dtype, causal, window):
    from repro.kernels.flash_attn.ops import flash_attention
    from repro.kernels.flash_attn.ref import attention_ref

    B = 2
    ks = jax.random.split(jax.random.PRNGKey(hash((S, Hkv, G, D)) % 2**31), 3)
    q = jax.random.normal(ks[0], (B, S, Hkv, G, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    o = flash_attention(q, k, v, causal=causal, window=window, q_block=32, kv_block=32)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_flash_attn_grads_match_ref():
    from repro.kernels.flash_attn.ops import flash_attention
    from repro.kernels.flash_attn.ref import attention_ref

    B, S, Hkv, G, D = 1, 64, 2, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hkv, G, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    g1 = jax.grad(lambda *a: flash_attention(*a, q_block=16, kv_block=16).sum(), (0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: attention_ref(*a).astype(jnp.float32).sum(), (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("S,splits", [
    (128, 2),
    pytest.param(256, 4, marks=slow),
    pytest.param(96, 3, marks=slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(S, splits, dtype):
    from repro.kernels.flash_decode.ops import decode_attention
    from repro.kernels.flash_decode.ref import decode_ref

    B, Hkv, G, D = 2, 2, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(S), 3)
    q = jax.random.normal(ks[0], (B, Hkv, G, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    lens = jnp.array([S // 3, S], jnp.int32)
    o = decode_attention(q, k, v, lens, kv_splits=splits, kv_block=32)
    ref = decode_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("N,D", [(32, 64), (128, 96), (64, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(N, D, dtype):
    from repro.kernels.rmsnorm.ops import rmsnorm
    from repro.kernels.rmsnorm.ref import rmsnorm_ref

    x = jax.random.normal(jax.random.PRNGKey(N), (N, D), dtype)
    w = jax.random.normal(jax.random.PRNGKey(D), (D,), dtype)
    np.testing.assert_allclose(
        np.asarray(rmsnorm(x, w), np.float32),
        np.asarray(rmsnorm_ref(x, w), np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


def test_rmsnorm_grad():
    from repro.kernels.rmsnorm.ops import rmsnorm
    from repro.kernels.rmsnorm.ref import rmsnorm_ref

    x = jax.random.normal(jax.random.PRNGKey(0), (32, 48), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (48,), jnp.float32)
    g1 = jax.grad(lambda x, w: rmsnorm(x, w).sum(), (0, 1))(x, w)
    g2 = jax.grad(lambda x, w: rmsnorm_ref(x, w).sum(), (0, 1))(x, w)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("S,P,N,chunk", [
    (64, 8, 4, 16),
    pytest.param(128, 16, 8, 32, marks=slow),
])
def test_mamba2_ssd_sweep(S, P, N, chunk):
    from repro.kernels.mamba2_ssd.ops import ssd_scan
    from repro.kernels.mamba2_ssd.ref import ssd_ref

    BH = 2
    ks = jax.random.split(jax.random.PRNGKey(S + P), 4)
    x = jax.random.normal(ks[0], (BH, S, P), jnp.float32) * 0.5
    B = jax.random.normal(ks[1], (BH, S, N), jnp.float32) * 0.5
    C = jax.random.normal(ks[2], (BH, S, N), jnp.float32) * 0.5
    a = -jax.nn.softplus(jax.random.normal(ks[3], (BH, S)))
    y = ssd_scan(x, B, C, a, chunk=chunk)
    ref, _ = ssd_ref(x[:, :, None], B[:, :, None], C[:, :, None], a[:, :, None])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref[:, :, 0]), atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("S,K,chunk", [
    (64, 16, 16),
    pytest.param(128, 32, 32, marks=slow),
])
def test_rwkv6_wkv_sweep(S, K, chunk):
    from repro.kernels.rwkv6_wkv.ops import wkv_scan
    from repro.kernels.rwkv6_wkv.ref import wkv_ref

    BH = 2
    ks = jax.random.split(jax.random.PRNGKey(S + K), 5)
    r = jax.random.normal(ks[0], (BH, S, K), jnp.float32) * 0.5
    k = jax.random.normal(ks[1], (BH, S, K), jnp.float32) * 0.5
    v = jax.random.normal(ks[2], (BH, S, K), jnp.float32) * 0.5
    w = jnp.maximum(-jax.nn.softplus(jax.random.normal(ks[3], (BH, S, K))) - 0.1, -2.0)
    u = jax.random.normal(ks[4], (BH, K), jnp.float32) * 0.3

    def one(rr, kx, vx, wx, ux):
        y, _ = wkv_ref(rr[None, :, None], kx[None, :, None], vx[None, :, None],
                       wx[None, :, None], ux[None])
        return y[0, :, 0]

    y = wkv_scan(r, k, v, w, u, chunk=chunk)
    ref = jax.vmap(one)(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("E,C,D,F", [
    (2, 32, 48, 24),
    pytest.param(4, 64, 96, 48, marks=slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm_sweep(E, C, D, F, dtype):
    from repro.kernels.moe_gmm.ops import grouped_matmul
    from repro.kernels.moe_gmm.ref import gmm_ref

    ks = jax.random.split(jax.random.PRNGKey(E * C), 2)
    x = jax.random.normal(ks[0], (E, C, D), dtype)
    w = jax.random.normal(ks[1], (E, D, F), dtype)
    gs = jnp.array([C] + [C // 2] * (E - 1), jnp.int32)
    np.testing.assert_allclose(
        np.asarray(grouped_matmul(x, w, gs), np.float32),
        np.asarray(gmm_ref(x, w, gs), np.float32),
        atol=_tol(dtype) * D, rtol=_tol(dtype),
    )
