"""Packed-forest surrogate plane equivalence suite.

Asserts that (1) ``PackedForest`` / ``ForestPlane`` reproduce the legacy
per-tree loop bit-for-bit on the numpy backend and to <= 1e-9 on the jax /
pallas (interpret) kernel backends, (2) the fused EI / rank acquisition
matches a scalar ``math.erf`` reference and the legacy per-source loop,
(3) the generator's ``SurrogateStore`` reuses fits across Hyperband rungs
and only refits the rung whose observation count changed, (4) tree splits
are SSE-optimal against a brute-force scan, and (5) MFTune incumbent
trajectories are identical across surrogate backends at a fixed seed.
"""

import math

import numpy as np
import pytest

from repro.core import (
    CandidateGenerator,
    ConfigSpace,
    FloatKnob,
    ForestPlane,
    KnowledgeBase,
    Observation,
    SurrogateStore,
    TaskRecord,
    aggregate_ranks,
    expected_improvement,
    make_forest,
    rank_aggregate,
    score_sources,
)
from repro.core.acquisition import ei_scores
from repro.core.similarity import TaskWeights
from repro.core.surrogate import RegressionTree

DELTAS = [1 / 9, 1 / 3, 1.0]


def _forests(n_sources=4, n=48, d=8, seed0=0):
    rng = np.random.default_rng(seed0)
    out = []
    for s in range(n_sources):
        X = rng.random((n, d))
        y = 3 * X[:, 0] - X[:, 1] ** 2 + 0.1 * rng.normal(size=n)
        out.append(make_forest(seed=seed0 + s).fit(X, y))
    return out, rng.random((96, d))


# ---------------------------------------------------------------- packed path


@pytest.mark.parametrize("n,d,seed", [(16, 3, 0), (48, 8, 1), (120, 16, 2), (5, 2, 3)])
def test_packed_matches_loop_bitwise(n, d, seed):
    rng = np.random.default_rng(seed)
    m = make_forest(seed=seed).fit(rng.random((n, d)), rng.random(n))
    X = rng.random((64, d))
    m_loop, v_loop = m.predict_loop(X)
    m_pack, v_pack = m.predict(X)  # default backend: packed numpy
    assert np.array_equal(m_loop, m_pack)
    assert np.array_equal(v_loop, v_pack)


def test_packed_constant_target_single_leaf():
    rng = np.random.default_rng(0)
    m = make_forest(seed=0).fit(rng.random((6, 2)), np.ones(6))  # root-only trees
    X = rng.random((10, 2))
    assert np.array_equal(m.predict(X)[0], m.predict_loop(X)[0])


def test_unfit_forest_predicts_prior():
    m = make_forest(seed=0)
    mean, var = m.predict(np.zeros((3, 2)))
    assert np.array_equal(mean, np.zeros(3)) and np.array_equal(var, np.ones(3))


def test_plane_matches_per_forest_bitwise():
    forests, X = _forests()
    plane = ForestPlane.from_forests([m.pack() for m in forests])
    means, vars_ = plane.predict(X)
    for i, m in enumerate(forests):
        m_ref, v_ref = m.predict_loop(X)
        assert np.array_equal(means[i], m_ref)
        assert np.array_equal(vars_[i], v_ref)


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_kernel_backends_match_loop(backend):
    pytest.importorskip("jax")
    forests, X = _forests(n_sources=2, n=32, d=5)
    for m in forests:
        m_ref, v_ref = m.predict_loop(X)
        m_k, v_k = m.pack().predict(X, backend=backend)
        np.testing.assert_allclose(m_k, m_ref, atol=1e-9, rtol=0)
        np.testing.assert_allclose(v_k, v_ref, atol=1e-9, rtol=0)
    plane = ForestPlane.from_forests([m.pack() for m in forests])
    means, vars_ = plane.predict(X, backend=backend)
    for i, m in enumerate(forests):
        m_ref, v_ref = m.predict_loop(X)
        np.testing.assert_allclose(means[i], m_ref, atol=1e-9, rtol=0)
        np.testing.assert_allclose(vars_[i], v_ref, atol=1e-9, rtol=0)


# ------------------------------------------------------------- acquisition


def test_ei_matches_scalar_erf_reference():
    rng = np.random.default_rng(7)
    mean = rng.normal(size=256)
    var = rng.random(256) + 1e-4
    best = 0.25
    ei = expected_improvement(mean, var, best)
    std = np.sqrt(np.maximum(var, 1e-12))
    z = (best - mean) / std
    phi = np.exp(-0.5 * z**2) / np.sqrt(2 * np.pi)
    Phi = np.array([0.5 * (1.0 + math.erf(v / math.sqrt(2.0))) for v in z])
    ref = np.maximum((best - mean) * Phi + std * phi, 0.0)
    np.testing.assert_allclose(ei, ref, atol=1e-12, rtol=0)


def test_score_sources_matches_per_source_scores():
    forests, X = _forests(n_sources=5)
    incumbents = [0.3, 0.5, 0.2, 0.4, 0.6]
    fused = score_sources(forests, X, incumbents)
    for i, (m, inc) in enumerate(zip(forests, incumbents)):
        assert np.array_equal(fused[i], ei_scores(m, X, inc))


def test_aggregate_ranks_matches_legacy_loop():
    rng = np.random.default_rng(5)
    scores = rng.random((4, 50))
    weights = [0.4, 0.3, 0.2, 0.1]
    agg = aggregate_ranks(scores, weights)
    # the pre-refactor sequential implementation
    ref = np.zeros(50)
    for row, w in zip(scores, weights):
        order = np.argsort(-row, kind="stable")
        ranks = np.empty(50)
        ranks[order] = np.arange(50, dtype=float)
        ref += float(w) * ranks
    assert np.array_equal(agg, ref)
    assert np.array_equal(rank_aggregate(list(scores), weights), ref)
    with pytest.raises(ValueError):
        rank_aggregate([], [])


# ------------------------------------------------------------ surrogate store


def _target_with_rungs(space, rng, counts):
    rec = TaskRecord(task_id="tgt", queries=["q0"])
    for delta, k in zip(DELTAS, counts):
        for cfg in space.sample(rng, k):
            rec.observations.append(
                Observation(config=cfg, performance=float(rng.random()), fidelity=delta)
            )
    return rec


def test_store_cache_hits_across_rungs():
    space = ConfigSpace([FloatKnob(f"x{i}", 0.0, 1.0) for i in range(4)])
    rng = np.random.default_rng(0)
    gen = CandidateGenerator(space, seed=0)
    target = _target_with_rungs(space, rng, counts=(5, 4, 3))
    weights = TaskWeights(weights={"__target__": 1.0}, similarities={}, used_meta=False)

    s1 = gen.build_sources(weights, {}, target, DELTAS)
    assert len(s1) == 3
    assert gen.cache_stats == {"hits": 0, "misses": 3, "evictions": 0, "size": 3}

    # same rung counts (a new Hyperband bracket, no new observations): all hits
    s2 = gen.build_sources(weights, {}, target, DELTAS)
    assert [s.name for s in s2] == [s.name for s in s1]
    assert gen.cache_stats["hits"] == 3 and gen.cache_stats["misses"] == 3

    # one rung gains an observation: only that rung's surrogate is refit
    target.observations.append(
        Observation(config=space.sample(rng, 1)[0], performance=0.5, fidelity=DELTAS[0])
    )
    gen.build_sources(weights, {}, target, DELTAS)
    assert gen.cache_stats["misses"] == 4 and gen.cache_stats["hits"] == 5
    assert gen.cache_stats["size"] == 3  # stale fingerprint replaced, not duplicated


def test_store_caches_source_tasks_and_evicts():
    space = ConfigSpace([FloatKnob("x0", 0.0, 1.0), FloatKnob("x1", 0.0, 1.0)])
    rng = np.random.default_rng(1)
    src = TaskRecord(task_id="s0", queries=["q0"])
    for cfg in space.sample(rng, 6):
        src.observations.append(
            Observation(config=cfg, performance=float(rng.random()), fidelity=1.0)
        )
    gen = CandidateGenerator(space, seed=0)
    weights = TaskWeights(weights={"s0": 1.0}, similarities={}, used_meta=False)
    target = TaskRecord(task_id="tgt", queries=["q0"])
    gen.build_sources(weights, {"s0": src}, target, DELTAS)
    gen.build_sources(weights, {"s0": src}, target, DELTAS)
    assert gen.cache_stats["hits"] == 1 and gen.cache_stats["misses"] == 1

    store = SurrogateStore(max_entries=2)
    for i in range(5):
        store.get(f"n{i}", 0, lambda: (object(), 0.0))
    assert len(store) == 2 and store.evictions == 3


# ------------------------------------------------------------- split property


@pytest.mark.parametrize("seed", [0, 1, 2, 17, 42, 123, 999, 2024])
def test_split_is_sse_optimal(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 24))
    d = 3
    X = rng.random((n, d))
    y = rng.random(n)
    msl = 2
    tree = RegressionTree(
        max_depth=1, min_samples_split=2, min_samples_leaf=msl, max_features=d,
        rng=np.random.default_rng(seed + 1),
    ).fit(X, y)
    # brute-force SSE over every (feature, split between distinct values)
    best_sse = np.inf
    for f in range(d):
        order = np.argsort(X[:, f], kind="stable")
        xs, ys = X[order, f], y[order]
        for p in range(msl, n - msl + 1):
            if not xs[p - 1] < xs[p]:
                continue
            left, right = ys[:p], ys[p:]
            sse = ((left - left.mean()) ** 2).sum() + ((right - right.mean()) ** 2).sum()
            best_sse = min(best_sse, sse)
    root = tree.nodes[0]
    assert root.feature >= 0, "expected a split on continuous random data"
    mask = X[:, root.feature] <= root.threshold
    left, right = y[mask], y[~mask]
    assert len(left) >= msl and len(right) >= msl
    got = ((left - left.mean()) ** 2).sum() + ((right - right.mean()) ** 2).sum()
    assert got <= best_sse + 1e-9


# ------------------------------------------------- end-to-end backend identity


def _traj(backend):
    from repro.core import MFTune, MFTuneOptions
    from repro.sparksim import SparkWorkload, TaskSpec, generate_history
    from repro.tuneapi import Budget

    kb = KnowledgeBase()
    kb.add_task(
        generate_history(TaskSpec("tpch", 100, "A").workload(), n_obs=12, n_init=5, seed=3),
        persist=False,
    )
    wl = SparkWorkload("tpch", 600, "A")
    opts = MFTuneOptions(seed=0, surrogate_backend=backend)
    res = MFTune(wl, kb, opts).run(Budget(6 * 3600.0))
    return [(p.time, p.best, tuple(sorted(p.config.items()))) for p in res.trajectory]


def test_mftune_trajectory_identical_across_backends():
    assert _traj("numpy") == _traj("loop")
