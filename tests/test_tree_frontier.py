"""Frontier (level-synchronous) vs recursive tree-builder equivalence.

The frontier builder must reproduce the recursive reference *bit-for-bit*:
identical per-node feature subsets (traversal-order-independent seed
chain), identical split choices and thresholds (padded-row cumsums replay
the recursion's exact float op sequence, argmins keep its first-strict-min
tie-breaking), identical leaf statistics. Node numbering differs (BFS vs
preorder DFS), so trees are compared in canonical DFS order.
"""

import numpy as np
import pytest

from repro.core import make_forest
from repro.core.surrogate import RegressionTree


def _dfs(tree):
    """Canonical preorder-DFS flattening, numbering-independent."""
    out = []

    def rec(i):
        nd = tree.nodes[i]
        out.append((nd.feature, nd.threshold, nd.mean, nd.var, nd.n))
        if nd.feature >= 0:
            rec(nd.left)
            rec(nd.right)

    rec(0)
    return out


def _fit_pair(X, y, seed, **kw):
    t_rec = RegressionTree(rng=np.random.default_rng(seed), builder="recursive", **kw).fit(X, y)
    t_fro = RegressionTree(rng=np.random.default_rng(seed), builder="frontier", **kw).fit(X, y)
    return t_rec, t_fro


def _check_identical(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(5, 220))
    d = int(rng.integers(2, 14))
    X = rng.random((n, d))
    y = 3 * X[:, 0] - X[:, 1 % d] ** 2 + 0.1 * rng.normal(size=n)
    if seed % 3 == 0:
        X[:, 0] = np.round(X[:, 0] * 5) / 5       # tied feature values
    if seed % 4 == 0:
        idx = rng.integers(0, n, n)               # bootstrap-style duplicates
        X, y = X[idx], y[idx]
    msl = int(rng.integers(1, 3))
    t_rec, t_fro = _fit_pair(X, y, seed + 1, min_samples_leaf=msl, min_samples_split=4)
    assert _dfs(t_rec) == _dfs(t_fro)
    Xq = rng.random((32, d))
    m1, v1 = t_rec.predict(Xq)
    m2, v2 = t_fro.predict(Xq)
    assert np.array_equal(m1, m2) and np.array_equal(v1, v2)


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 17, 42, 123, 999, 2024, 31337])
def test_frontier_matches_recursive_bitwise(seed):
    _check_identical(seed)


def test_frontier_matches_recursive_fuzz():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    settings(max_examples=20, deadline=None)(
        given(st.integers(0, 2**31 - 1))(_check_identical)
    )()


def test_forest_identical_across_builders():
    """make_forest backend selects the builder ("loop" => recursive); the
    fitted forests must still be identical, so backend choice never changes
    predictions."""
    rng = np.random.default_rng(0)
    X, y = rng.random((60, 8)), rng.random(60)
    f_loop = make_forest(seed=3, backend="loop").fit(X, y)
    f_pack = make_forest(seed=3, backend="numpy").fit(X, y)
    for t1, t2 in zip(f_loop.trees, f_pack.trees):
        assert _dfs(t1) == _dfs(t2)
    Xq = rng.random((48, 8))
    assert all(np.array_equal(a, b) for a, b in zip(f_loop.predict(Xq), f_pack.predict(Xq)))


@pytest.mark.parametrize("seed", [0, 5, 77, 4096])
def test_frontier_split_is_sse_optimal(seed):
    """Root split of the frontier builder is SSE-optimal vs brute force."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(10, 28))
    d = 3
    X = rng.random((n, d))
    y = rng.random(n)
    msl = 2
    tree = RegressionTree(
        max_depth=1, min_samples_split=2, min_samples_leaf=msl, max_features=d,
        rng=np.random.default_rng(seed + 1), builder="frontier",
    ).fit(X, y)
    best_sse = np.inf
    for f in range(d):
        order = np.argsort(X[:, f], kind="stable")
        xs, ys = X[order, f], y[order]
        for p in range(msl, n - msl + 1):
            if not xs[p - 1] < xs[p]:
                continue
            left, right = ys[:p], ys[p:]
            sse = ((left - left.mean()) ** 2).sum() + ((right - right.mean()) ** 2).sum()
            best_sse = min(best_sse, sse)
    root = tree.nodes[0]
    assert root.feature >= 0
    mask = X[:, root.feature] <= root.threshold
    left, right = y[mask], y[~mask]
    assert len(left) >= msl and len(right) >= msl
    got = ((left - left.mean()) ** 2).sum() + ((right - right.mean()) ** 2).sum()
    assert got <= best_sse + 1e-9


def test_degenerate_inputs_stay_leaves():
    for builder in ("recursive", "frontier"):
        # constant target: no split possible
        t = RegressionTree(rng=np.random.default_rng(0), builder=builder).fit(
            np.random.default_rng(1).random((12, 3)), np.ones(12)
        )
        assert len(t.nodes) == 1 and t.nodes[0].feature == -1
        # below min_samples_split
        t = RegressionTree(
            min_samples_split=8, rng=np.random.default_rng(0), builder=builder
        ).fit(np.random.default_rng(1).random((4, 2)), np.arange(4.0))
        assert len(t.nodes) == 1
        # max_depth=0
        t = RegressionTree(max_depth=0, rng=np.random.default_rng(0), builder=builder).fit(
            np.random.default_rng(1).random((20, 2)), np.random.default_rng(2).random(20)
        )
        assert len(t.nodes) == 1
        # single sample
        t = RegressionTree(rng=np.random.default_rng(0), builder=builder).fit(
            np.ones((1, 2)), np.array([2.0])
        )
        assert len(t.nodes) == 1 and t.nodes[0].mean == 2.0


def test_unknown_builder_rejected():
    with pytest.raises(ValueError):
        RegressionTree(builder="iterative")
