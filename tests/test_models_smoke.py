"""Per-architecture smoke tests: reduced config, one forward/train step and
one decode step on CPU; output shapes + finiteness (deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_arch, reduced, shape_applicable
from repro.models import (
    Runtime,
    build_param_specs,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
)

RT = Runtime(scan_layers=True, remat="none", attn_chunk=64, act_shard=False)
B, S = 2, 64


def _arch_params(slow_archs):
    """All archs, the CPU-heavy ones carried in the slow tier."""
    return [
        pytest.param(n, marks=pytest.mark.slow) if n in slow_archs else n
        for n in sorted(ARCHS)
    ]


SLOW_SMOKE = {"zamba2-2.7b", "deepseek-v3-671b", "seamless-m4t-medium",
              "rwkv6-7b", "mixtral-8x22b"}
SLOW_TRAIN = SLOW_SMOKE | {"deepseek-coder-33b", "qwen2-vl-72b", "nemotron-4-340b"}


def _batch(cfg):
    batch = {
        "tokens": jnp.zeros((B, S), jnp.int32),
        "labels": jnp.ones((B, S), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["enc_embeds"] = jnp.full((B, S, cfg.d_model), 0.01, jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", _arch_params(SLOW_SMOKE))
def test_arch_smoke(name):
    cfg = reduced(get_arch(name))
    params = init_params(build_param_specs(cfg, RT), jax.random.PRNGKey(0))
    batch = _batch(cfg)

    logits = jax.jit(lambda p, b: forward(
        p, cfg, RT, tokens=b["tokens"], enc_embeds=b.get("enc_embeds")
    ))(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), "non-finite logits"

    loss = jax.jit(lambda p, b: loss_fn(p, cfg, RT, b))(params, batch)
    assert np.isfinite(float(loss))

    cache = init_cache(cfg, RT, B, 32, enc_len=S if cfg.family == "encdec" else 0)
    dl, cache2 = jax.jit(lambda p, c, t: decode_step(p, cfg, RT, c, t))(
        params, cache, jnp.zeros((B, 1), jnp.int32)
    )
    assert dl.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(dl.astype(jnp.float32)).all())
    assert int(cache2["pos"][0]) == 1


@pytest.mark.parametrize("name", _arch_params(SLOW_TRAIN))
def test_train_step_reduces_loss(name):
    """A couple of optimizer steps decrease CE on a repeated batch."""
    from repro.train import make_train_step
    from repro.optim import adamw_init

    cfg = reduced(get_arch(name))
    params = init_params(build_param_specs(cfg, RT), jax.random.PRNGKey(1))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, RT, lr=5e-3))
    batch = _batch(cfg)
    losses = []
    for _ in range(3):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_all_cells_enumerated():
    from repro.configs import all_cells

    cells = all_cells()
    assert len(cells) == 40
    skips = [(a, s) for a, s in cells if not shape_applicable(get_arch(a), SHAPES[s])[0]]
    assert len(skips) == 7  # long_500k for the quadratic-attention archs
    for a, s in skips:
        assert s == "long_500k"
