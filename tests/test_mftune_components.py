"""Similarity, compression, fidelity partitioning, hyperband, warm start."""

import numpy as np
import pytest

from repro.core import (
    CandidateGenerator,
    ConfigSpace,
    FloatKnob,
    HyperbandRunner,
    KnowledgeBase,
    Observation,
    SimilarityEngine,
    SpaceCompressor,
    TaskRecord,
    WarmStartQueue,
    collect_query_stats,
    early_stop_subset,
    greedy_query_subset,
    hb_schedule,
    phase1_config,
    subset_correlation,
)
from repro.core.similarity import TaskWeights


def _space(d=6):
    return ConfigSpace([FloatKnob(f"x{i}", 0.0, 1.0) for i in range(d)])


def _record(task_id, space, f, n=30, seed=0, queries=("q1", "q2")):
    rng = np.random.default_rng(seed)
    rec = TaskRecord(task_id=task_id, queries=list(queries))
    for cfg in space.sample(rng, n):
        perf = f(cfg)
        rec.observations.append(Observation(config=cfg, performance=perf, fidelity=1.0))
    return rec


def test_eq2_similarity_orders_tasks():
    space = _space()
    f = lambda c: 5 * c["x0"] + c["x1"]
    g = lambda c: -5 * c["x0"] - c["x1"]  # anti-correlated
    kb = KnowledgeBase()
    kb.add_task(_record("same", space, f, seed=1), persist=False)
    kb.add_task(_record("anti", space, g, seed=2), persist=False)
    target = _record("target", space, f, n=20, seed=3)
    kb.add_task(target, persist=False)
    eng = SimilarityEngine(space, kb, seed=0)
    w = eng.compute(target)
    assert w.weights.get("same", 0) > 0
    assert "anti" not in w.weights  # negative similarity filtered
    assert not w.used_meta  # enough data for the transition


def test_transition_uses_meta_when_data_sparse():
    space = _space()
    f = lambda c: 5 * c["x0"]
    kb = KnowledgeBase()
    for i in range(3):
        r = _record(f"s{i}", space, f, seed=i)
        r.meta_features = list(np.ones(4) * i)
        kb.add_task(r, persist=False)
    target = _record("t", space, f, n=2, seed=9)  # too few obs for Eq. 2
    target.meta_features = [1.0, 1.0, 1.0, 1.0]
    kb.add_task(target, persist=False)
    eng = SimilarityEngine(space, kb, seed=0)
    w = eng.compute(target)
    assert w.used_meta


def test_space_compression_drops_noise_keeps_signal():
    space = _space(6)
    # only x0/x1 matter; optimum near x0=0.1, x1=0.9
    f = lambda c: (c["x0"] - 0.1) ** 2 + (c["x1"] - 0.9) ** 2 + 1.0
    kb_tasks = {}
    for i in range(3):
        kb_tasks[f"s{i}"] = _record(f"s{i}", space, f, n=60, seed=i)
    comp = SpaceCompressor(space, alpha=0.65, seed=0)
    weights = TaskWeights(weights={k: 1 / 3 for k in kb_tasks}, similarities={}, used_meta=False)
    restricted = comp.compress(weights, kb_tasks)
    assert "x0" in restricted.by_name and "x1" in restricted.by_name
    assert len(restricted) < 6  # some noise knobs dropped
    k0 = restricted.by_name["x0"]
    iv = k0.active_intervals()
    assert iv.total_length < 0.95  # range actually compressed
    # the region concentrates near the optimum (alpha-mass regions can clip
    # the exact optimum when promising samples skew to one side — the
    # paper's own Fig. 6c caveat for small alpha)
    assert abs(iv.clip(0.1) - 0.1) < 0.1


def _query_stats(seed=0, n_cfg=25, m=6):
    """Three queries carry the aggregate signal; three are cheap noise."""
    rng = np.random.default_rng(seed)
    weights = np.array([5.0, 3.0, 2.0, 0.05, 0.05, 0.05])[:m]
    lat = rng.random((n_cfg, 1)) * weights[None, :] + 0.01 * rng.random((n_cfg, m))
    rec = TaskRecord(task_id="src", queries=[f"q{i}" for i in range(m)])
    for i in range(n_cfg):
        rec.observations.append(
            Observation(config={"x": i}, performance=float(lat[i].sum()), fidelity=1.0,
                        per_query_perf=list(lat[i]), per_query_cost=list(lat[i]))
        )
    return collect_query_stats([rec], {"src": 1.0})


def test_greedy_subset_respects_budget_and_correlates():
    stats = _query_stats()
    subset, tau, cost = greedy_query_subset(stats, delta=1 / 3)
    assert cost <= 1 / 3 + 1e-9
    assert subset and tau > 0.8
    # selection beats the early-stop prefix of the same size
    es = early_stop_subset(6, 1 / 3)
    assert subset_correlation(stats, subset) >= subset_correlation(stats, es) - 1e-9


def test_hb_schedule_r9():
    brackets = hb_schedule(R=9, eta=3)
    deltas = sorted({round(r.delta, 4) for b in brackets for r in b.rungs})
    assert deltas == [round(1 / 9, 4), round(1 / 3, 4), 1.0]


def test_hyperband_bracket_promotes_best():
    hb = HyperbandRunner(R=9, eta=3, seed=0)
    bracket = hb.brackets[0]
    evals = []

    def provide(n, rungs):
        return [{"id": i} for i in range(n)]

    def evaluate(cfg, delta, cap):
        evals.append((cfg["id"], delta))
        return float(cfg["id"]), False, 1.0  # lower id = better

    hb.run_bracket(bracket, provide, evaluate, lambda *a: None, lambda: False)
    full = [i for i, d in evals if d >= 1.0]
    assert full and all(i < 3 for i in full)  # only best configs reach full fidelity


def test_median_early_stop_cap():
    hb = HyperbandRunner(R=9, eta=3, early_stop_factor=1.0)
    d = round(1 / 9, 6)
    hb._cost_history[d] = [10.0, 10.0, 10.0]
    assert hb._cost_cap(1 / 9) == pytest.approx(10.0)
    assert hb._cost_cap(1 / 3) is None  # no history yet


def test_two_phase_warmstart():
    space = _space()
    f = lambda c: c["x0"]
    kb_tasks = {"s0": _record("s0", space, f, n=20, seed=0)}
    weights = TaskWeights(weights={"s0": 1.0}, similarities={"s0": 0.9}, used_meta=False)
    cfg1 = phase1_config(weights, kb_tasks)
    best = kb_tasks["s0"].best()
    assert cfg1 == best.config
    q = WarmStartQueue()
    q.rebuild(weights, kb_tasks)
    got = q.take(3)
    assert len(got) == 3
    assert q.take(100) and got[0] != q.take(1)  # no duplicates served
