"""Decode/train-path consistency: teacher-forcing the decode path token by
token must reproduce the parallel forward's logits (catches KV-cache, state
and position bugs across the four sequence-mixing families)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import Runtime, build_param_specs, decode_step, forward, init_cache, init_params

RT = Runtime(scan_layers=True, remat="none", attn_chunk=16, act_shard=False)

SLOW_CASES = {"zamba2-2.7b", "deepseek-v3-671b"}
CASES = [
    pytest.param(n, marks=pytest.mark.slow) if n in SLOW_CASES else n
    for n in ["llama3-8b", "rwkv6-7b", "zamba2-2.7b", "deepseek-v3-671b", "mixtral-8x22b"]
]


@pytest.mark.parametrize("name", CASES)
def test_decode_matches_forward(name):
    cfg = reduced(get_arch(name))
    params = init_params(build_param_specs(cfg, RT), jax.random.PRNGKey(0))
    B, S = 1, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 2, cfg.vocab)

    par = forward(params, cfg, RT, tokens=tokens).astype(jnp.float32)

    cache = init_cache(cfg, RT, B, S)
    dec = []
    step = jax.jit(lambda p, c, t: decode_step(p, cfg, RT, c, t))
    for t in range(S):
        lg, cache = step(params, cache, tokens[:, t:t + 1])
        dec.append(np.asarray(lg[:, 0], np.float32))
    dec = np.stack(dec, axis=1)

    # compare normalized logits (softmax) at every position
    pref = jax.nn.softmax(par, axis=-1)
    pdec = jax.nn.softmax(jnp.asarray(dec), axis=-1)
    err = float(jnp.abs(pref - pdec).max())
    assert err < 5e-2, f"decode/train divergence {err}"
