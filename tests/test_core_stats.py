"""Surrogates, Shapley, KDE, GBM, acquisition (unit + property tests).

The property tests run as seeded ``pytest.mark.parametrize`` cases so the
module passes without ``hypothesis`` installed; a fuzz variant widens the
seed coverage when ``hypothesis`` is available (importorskip-guarded).
"""

import numpy as np
import pytest

from repro.core import (
    GaussianProcess,
    GradientBoostedTrees,
    ProbabilisticRandomForest,
    WeightedKDE,
    alpha_mass_categories,
    alpha_mass_region,
    expected_improvement,
    kendall_tau,
    rank_aggregate,
    shapley_values,
    shapley_values_exact,
    silverman_bandwidth,
)


def _toy(n=80, d=5, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.random((n, d))
    y = 3 * X[:, 0] - 2 * X[:, 1] ** 2 + 0.1 * rng.normal(size=n)
    return X, y


def test_prf_ranks_signal():
    X, y = _toy()
    m = ProbabilisticRandomForest(seed=0).fit(X, y)
    Xt, yt = _toy(seed=1)
    pred, var = m.predict(Xt)
    tau, p = kendall_tau(pred, yt)
    assert tau > 0.6 and p < 1e-6
    assert np.all(var > 0)


def test_gp_interpolates():
    X, y = _toy(n=40)
    m = GaussianProcess().fit(X, y)
    pred, var = m.predict(X)
    assert np.corrcoef(pred, y)[0, 1] > 0.95


def test_gbm_fits():
    X, y = _toy(n=120)
    m = GradientBoostedTrees(seed=0).fit(X, y)
    Xt, yt = _toy(seed=2)
    tau, _ = kendall_tau(m.predict(Xt), yt)
    assert tau > 0.55


def test_shapley_mc_matches_exact():
    rng = np.random.default_rng(0)
    d = 4
    w = np.array([2.0, -1.0, 0.5, 0.0])
    f = lambda Z: Z @ w + 3 * Z[:, 0] * Z[:, 1]
    x = rng.random(d)
    bg = rng.random((12, d))
    exact = shapley_values_exact(f, x, bg)
    mc = shapley_values(f, x, bg, n_permutations=64, rng=np.random.default_rng(1))
    assert np.abs(exact - mc).max() < 0.05
    # additivity (exact by construction after the residual correction)
    fx = f(x[None])[0]
    f0 = f(bg).mean()
    assert abs(mc.sum() - (fx - f0)) < 1e-9


def _check_shapley_additivity(seed):
    rng = np.random.default_rng(seed)
    d = 6
    A = rng.normal(size=(d, d)) / d
    f = lambda Z: np.einsum("ni,ij,nj->n", Z, A, Z)
    x = rng.random(d)
    bg = rng.random((8, d))
    phi = shapley_values(f, x, bg, n_permutations=8, rng=rng)
    assert abs(phi.sum() - (f(x[None])[0] - f(bg).mean())) < 1e-9


@pytest.mark.parametrize("seed", [0, 1, 17, 123, 999])
def test_shapley_additivity_property(seed):
    _check_shapley_additivity(seed)


def test_shapley_additivity_fuzz():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    settings(max_examples=10, deadline=None)(
        given(st.integers(0, 1000))(_check_shapley_additivity)
    )()


def test_alpha_mass_region_bimodal():
    rng = np.random.default_rng(0)
    samples = np.concatenate([rng.normal(0.2, 0.02, 200), rng.normal(0.8, 0.02, 100)])
    kde = WeightedKDE(samples, np.ones_like(samples))
    region = alpha_mass_region(kde, 0.0, 1.0, alpha=0.65)
    # bimodal: region should be a union excluding the middle
    assert region.total_length < 0.5
    assert region.contains(0.2)
    assert not region.contains(0.5)
    # higher alpha => larger region (monotonicity)
    region9 = alpha_mass_region(kde, 0.0, 1.0, alpha=0.9)
    assert region9.total_length >= region.total_length


def test_alpha_mass_region_weights_matter():
    samples = np.array([0.2] * 10 + [0.8] * 10)
    w_left = np.array([10.0] * 10 + [0.1] * 10)
    kde = WeightedKDE(samples, w_left, bandwidth=0.03)
    region = alpha_mass_region(kde, 0.0, 1.0, alpha=0.6)
    assert region.contains(0.2) and not region.contains(0.8)


def test_alpha_mass_categories():
    vals = ["a"] * 5 + ["b"] * 3 + ["c"]
    kept = alpha_mass_categories(vals, [1.0] * len(vals), alpha=0.65)
    assert "a" in kept and "c" not in kept


def test_ei_positive_at_better_mean():
    ei = expected_improvement(np.array([0.0, 10.0]), np.array([1.0, 1.0]), best=5.0)
    assert ei[0] > ei[1] >= 0.0


def test_rank_aggregate_weighting():
    s1 = np.array([3.0, 2.0, 1.0])  # prefers idx 0
    s2 = np.array([1.0, 2.0, 3.0])  # prefers idx 2
    agg = rank_aggregate([s1, s2], [10.0, 0.1])
    assert int(np.argmin(agg)) == 0


def test_silverman_positive():
    assert silverman_bandwidth(np.array([1.0, 2, 3, 4]), np.ones(4)) > 0
