"""Columnar config-space plane equivalence suite.

Asserts that (1) the columnar :class:`SpacePlane` kernels reproduce the
per-element scalar reference bit-for-bit for sample / LHS / mutate /
encode / decode / project across all four knob kinds, with and without
restrictions, under both log-sampling geometries, (2) ``decode`` is
restriction-aware, (3) the log-knob sampling fix is active on the columnar
default and gated off on the scalar reference, (4) :class:`ConfigBatch`
round-trips, lifts and dedups correctly, and (5) MFTune incumbent
trajectories are identical across space backends at a fixed seed.

The property tests run as seeded ``pytest.mark.parametrize`` cases so the
module passes without ``hypothesis`` installed; a fuzz variant widens the
seed coverage when ``hypothesis`` is available (importorskip-guarded).
"""

import numpy as np
import pytest

from repro.core import (
    BoolKnob,
    CatKnob,
    ConfigBatch,
    ConfigSpace,
    FloatKnob,
    IntKnob,
    Intervals,
    get_space_backend,
    log_sampling,
    space_backend,
)


def mixed_space(restricted: bool) -> ConfigSpace:
    s = ConfigSpace([
        FloatKnob("f", 0.5, 4.0),
        FloatKnob("flog", 1.0, 1024.0, log=True),
        IntKnob("i", 2, 64, log=True, default=8),
        IntKnob("iplain", 0, 100),
        CatKnob("c", ("a", "b", "z"), default="b"),
        BoolKnob("b", default=True),
    ])
    if restricted:
        s = s.restrict(
            ranges={
                "f": Intervals([(1.0, 1.5), (3.0, 3.5)]),
                "flog": Intervals([(2.0, 8.0), (100.0, 700.0)]),
                "i": Intervals([(4.0, 4.0), (16.0, 32.0)]),  # incl. a point piece
            },
            cat_subsets={"c": ["a", "z"], "b": [True]},
        )
    return s


def _backend_outputs(backend: str, restricted: bool, geometry: bool, seed: int):
    with log_sampling(geometry), space_backend(backend):
        s = mixed_space(restricted)
        rng = np.random.default_rng(seed)
        pool = s.sample(rng, 48)
        lhs = s.lhs_sample(rng, 24)
        muts = s.mutate_many(pool, rng)
        proj = s.project_many(muts)
        dec = s.decode_many(rng.random((16, s.dim)))
        return {
            "sample": pool.values,
            "sample_unit": pool.unit(),
            "lhs": lhs.values,
            "mutate": muts.values,
            "project": proj.values,
            "decode": dec.values,
        }


def _check_columnar_matches_scalar(restricted, geometry, seed):
    a = _backend_outputs("columnar", restricted, geometry, seed)
    b = _backend_outputs("scalar", restricted, geometry, seed)
    for name in a:
        assert np.array_equal(a[name], b[name]), f"{name} diverged (restricted={restricted}, geometry={geometry}, seed={seed})"


@pytest.mark.parametrize("restricted", [False, True])
@pytest.mark.parametrize("geometry", [False, True])
@pytest.mark.parametrize("seed", [0, 1, 7, 123, 99991])
def test_columnar_matches_scalar_bitwise(restricted, geometry, seed):
    _check_columnar_matches_scalar(restricted, geometry, seed)


def test_columnar_matches_scalar_fuzz():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    settings(max_examples=15, deadline=None)(
        given(
            st.booleans(), st.booleans(), st.integers(0, 2**31 - 1)
        )(_check_columnar_matches_scalar)
    )()


def test_dict_encode_matches_legacy_scalar_loop():
    s = mixed_space(True)
    cfgs = list(s.sample(np.random.default_rng(5), 40))
    with space_backend("columnar"):
        Uc = mixed_space(True).encode_many(cfgs)
    with space_backend("scalar"):
        # the scalar dict path is the original per-knob encode loop
        Us = mixed_space(True).encode_many(cfgs)
    assert np.array_equal(Uc, Us)
    # and the ConfigBatch fast path agrees with the dict path
    batch = ConfigBatch.from_configs(s, cfgs)
    assert np.array_equal(batch.unit(), s.encode_many(cfgs))


def test_decode_is_restriction_aware():
    s = mixed_space(True)
    cfg = s.decode(np.full(s.dim, 0.55))
    assert (1.0 <= cfg["f"] <= 1.5) or (3.0 <= cfg["f"] <= 3.5)
    assert (2.0 <= cfg["flog"] <= 8.0) or (100.0 <= cfg["flog"] <= 700.0)
    assert cfg["i"] == 4 or 16 <= cfg["i"] <= 32
    assert cfg["c"] in ("a", "z")
    assert cfg["b"] is True
    # unrestricted spaces decode exactly as before (pure from_unit)
    s0 = mixed_space(False)
    u = np.full(s0.dim, 0.4)
    cfg0 = s0.decode(u)
    for j, k in enumerate(s0.knobs):
        v = k.from_unit(0.4)
        got = cfg0[k.name]
        assert got == (int(v) if isinstance(k, IntKnob) else v)


@pytest.mark.parametrize("geometry", [False, True])
def test_sample_respects_restrictions(geometry):
    with log_sampling(geometry):
        s = mixed_space(True)
        for cfg in s.sample(np.random.default_rng(0), 64):
            assert (1.0 <= cfg["f"] <= 1.5) or (3.0 <= cfg["f"] <= 3.5)
            assert (2.0 <= cfg["flog"] <= 8.0) or (100.0 <= cfg["flog"] <= 700.0)
            assert cfg["i"] == 4 or 16 <= cfg["i"] <= 32
            assert cfg["c"] in ("a", "z")
            assert cfg["b"] is True
            u = s.encode(cfg)
            assert np.all((u >= 0) & (u <= 1))


def test_log_knob_geometry_gate():
    """Columnar default samples log knobs uniformly in log space (encoded
    coordinate ~ U(0,1)); the scalar reference keeps the legacy raw-unit
    geometry (encoded coordinate skewed high for a 3-decade range)."""
    rng = np.random.default_rng(0)
    u_col = mixed_space(False).sample(rng, 4000).unit()[:, 1]  # flog column
    with space_backend("scalar"):
        u_raw = mixed_space(False).sample(np.random.default_rng(0), 4000).unit()[:, 1]
    assert abs(u_col.mean() - 0.5) < 0.03      # uniform in the encoding geometry
    assert u_raw.mean() > 0.8                   # legacy raw-unit skew preserved
    # the quantiles of the columnar draw are uniform in unit space too
    q = np.quantile(u_col, [0.25, 0.75])
    assert abs(q[0] - 0.25) < 0.04 and abs(q[1] - 0.75) < 0.04


def test_lhs_stratification():
    s = ConfigSpace([FloatKnob("x", 0.0, 1.0)])
    xs = sorted(c["x"] for c in s.lhs_sample(np.random.default_rng(0), 10))
    for i, x in enumerate(xs):
        assert i / 10 <= x <= (i + 1) / 10
    # restriction-aware: stratified over a disconnected union
    r = ConfigSpace([FloatKnob("x", 0.0, 1.0, restriction=Intervals([(0.0, 0.1), (0.9, 1.0)]))])
    vals = [c["x"] for c in r.lhs_sample(np.random.default_rng(0), 20)]
    lo = sum(1 for v in vals if v <= 0.1)
    assert all((v <= 0.1) or (v >= 0.9) for v in vals)
    assert 8 <= lo <= 12  # halves get equal stratified mass


def test_config_batch_roundtrip_and_lift():
    s = mixed_space(False)
    rng = np.random.default_rng(2)
    pool = s.sample(rng, 12)
    cfgs = pool.materialize()
    again = ConfigBatch.from_configs(s, cfgs)
    assert np.array_equal(pool.values, again.values)
    assert pool.row_keys() == again.row_keys()
    # take slices values and cached encodings coherently
    pool.unit()
    sub = pool.take([3, 1])
    assert sub[0] == cfgs[3] and sub[1] == cfgs[1]
    assert np.array_equal(sub.unit(), pool.unit()[[3, 1]])
    # lift from a compressed sub-space: kept knobs transfer, dropped default
    ss = s.restrict(keep=["f", "c"])
    small = ss.sample(rng, 5)
    lifted = s.complete_batch(small)
    for row, src in zip(lifted, small):
        assert row["f"] == src["f"] and row["c"] == src["c"]
        assert row["i"] == 8 and row["b"] is True  # defaults filled in


def test_mutate_stays_in_active_region():
    s = mixed_space(True)
    rng = np.random.default_rng(3)
    pool = s.sample(rng, 32)
    muts = s.mutate_many(pool, rng, scale=0.5, p=1.0)  # mutate every knob
    for cfg in muts:
        assert (1.0 <= cfg["f"] <= 1.5) or (3.0 <= cfg["f"] <= 3.5)
        assert cfg["c"] in ("a", "z") and cfg["b"] is True


def test_backend_switch_restores():
    assert get_space_backend() == "columnar"
    with space_backend("scalar"):
        assert get_space_backend() == "scalar"
    assert get_space_backend() == "columnar"
    with pytest.raises(ValueError):
        space_backend("vectorized").__enter__()


# ------------------------------------------------- end-to-end backend identity


def _traj(backend):
    from repro.core import KnowledgeBase, MFTune, MFTuneOptions
    from repro.sparksim import TaskSpec, SparkWorkload, generate_history
    from repro.tuneapi import Budget

    # pin one sampling geometry so the backends are bit-comparable
    with log_sampling(True), space_backend(backend):
        kb = KnowledgeBase()
        kb.add_task(
            generate_history(TaskSpec("tpch", 100, "A").workload(), n_obs=12, n_init=5, seed=3),
            persist=False,
        )
        wl = SparkWorkload("tpch", 600, "A")
        res = MFTune(wl, kb, MFTuneOptions(seed=0)).run(Budget(24 * 3600.0))
    return [(p.time, p.best, tuple(sorted(p.config.items()))) for p in res.trajectory]


def test_mftune_trajectory_identical_across_space_backends():
    assert _traj("columnar") == _traj("scalar")
