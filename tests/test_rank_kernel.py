"""Rank-kernel identity tests: radix rank == pinned stable argsort, exactly.

The rank aggregation contract is that every fast path (numpy radix, jitted
callback, fused lax.sort, pallas histogram kernel) produces the *same
permutation* ``np.argsort(-scores, kind="stable")`` would — including on the
IEEE-754 edge cases that break float-domain key remaps under FTZ/DAZ:
signed zeros, subnormals, infinities, and fully tied rows.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.kernels.forest_eval import rank as R

jax = pytest.importorskip("jax")


SPECIALS = np.array(
    [
        0.0,
        -0.0,
        5e-324,          # smallest positive subnormal
        -5e-324,
        1e-310,          # mid-range subnormal
        -1e-310,
        np.finfo(np.float64).tiny,      # smallest normal
        -np.finfo(np.float64).tiny,
        np.inf,
        -np.inf,
        np.finfo(np.float64).max,
        -np.finfo(np.float64).max,
        1.0,
        -1.0,
        3.5,
        -3.5,
    ],
    dtype=np.float64,
)


def _special_rows(seed: int = 0, n_rows: int = 6, n: int = 64) -> np.ndarray:
    rng = np.random.default_rng(seed)
    rows = SPECIALS[rng.integers(0, len(SPECIALS), size=(n_rows, n))]
    # splice in ordinary values so ties and specials interleave
    mask = rng.random((n_rows, n)) < 0.5
    rows = np.where(mask, rng.standard_normal((n_rows, n)), rows)
    return np.ascontiguousarray(rows)


def test_monotone_keys_total_order_on_specials():
    # keys are *descending*-order: larger score -> smaller u64 key, so an
    # ascending stable key sort yields the best-first rank permutation.
    v = np.sort(SPECIALS)  # ascending float order (±0 adjacent, order tied)
    k = R.monotone_keys(v[None, :])[0]
    assert np.all(np.diff(k.astype(object)) <= 0)
    # both zeros map to the same key — a genuine tie, resolved stably
    z = R.monotone_keys(np.array([[0.0, -0.0]]))[0]
    assert z[0] == z[1]


def test_radix_argsort_matches_stable_argsort_specials():
    scores = _special_rows(seed=1)
    for row in scores:
        want = np.argsort(-row, kind="stable")
        got = R.radix_argsort(row[None, :])[0]
        np.testing.assert_array_equal(got, want)


def test_rank_rows_radix_matches_reference():
    for seed in range(3):
        scores = _special_rows(seed=seed, n_rows=4, n=97)
        np.testing.assert_array_equal(
            R.rank_rows_radix(scores), R.rank_rows_reference(scores)
        )


def test_rank_rows_all_tied():
    scores = np.zeros((3, 33))
    out = R.rank_rows(scores)
    # every element keeps its original position's rank (stable on full tie)
    want = np.broadcast_to(np.arange(33, dtype=np.float64), (3, 33))
    np.testing.assert_array_equal(out, want)


def test_rank_rows_dispatch_crossover():
    # below RADIX_MIN_N the argsort path runs; above, the radix path — both
    # must agree with the pinned reference regardless.
    small = _special_rows(seed=2, n_rows=2, n=R.RADIX_MIN_N // 4)
    big = _special_rows(seed=3, n_rows=2, n=R.RADIX_MIN_N + 7)
    for scores in (small, big):
        np.testing.assert_array_equal(
            R.rank_rows(scores), R.rank_rows_reference(scores)
        )


@pytest.mark.parametrize("impl", R.RANK_IMPLS)
def test_rank_rows_traced_identity(impl):
    scores = _special_rows(seed=4, n_rows=3, n=129)
    want = R.rank_rows_reference(scores)
    with jax.experimental.enable_x64(True):
        got = np.asarray(R.rank_rows_traced(jax.numpy.asarray(scores), impl))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("impl", R.RANK_IMPLS)
def test_rank_rows_traced_random_property(impl):
    rng = np.random.default_rng(11)
    for _ in range(3):
        n = int(rng.integers(5, 400))
        s = int(rng.integers(1, 6))
        scores = rng.standard_normal((s, n))
        # force tie clusters
        scores[rng.random((s, n)) < 0.3] = 0.25
        want = R.rank_rows_reference(scores)
        with jax.experimental.enable_x64(True):
            got = np.asarray(R.rank_rows_traced(jax.numpy.asarray(scores), impl))
        np.testing.assert_array_equal(got, want)


def test_aggregate_ranks_host_impl_agreement():
    from repro.kernels.forest_eval import propose as P

    scores = _special_rows(seed=5, n_rows=3, n=257)
    w = np.array([0.5, 0.3, 0.2])
    ref = None
    for impl in ("sort", "callback"):
        agg = P.aggregate_ranks_host(scores, w, rank_impl=impl)
        if ref is None:
            ref = agg
        else:
            np.testing.assert_array_equal(agg, ref)
    # and against the pure-numpy aggregation
    ranks = R.rank_rows(scores)
    np.testing.assert_array_equal(ref, (w[:, None] * ranks).sum(axis=0))
