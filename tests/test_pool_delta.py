"""Chain-delta pool scoring: bit-identity with descent, and generator wiring.

``ForestPlane.predict(..., delta=(bases_unit, base_of))`` may factor a
mutation-heavy pool through the bitvector chain plan (shared-coordinate AND
once per base, re-AND only mutated coordinates per candidate). The contract
is bit-identity with the packed gather descent, and that turning the path on
never changes what the generator recommends.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro import obs
from repro.core.space import ConfigBatch, ConfigSpace, FloatKnob, IntKnob
from repro.core.surrogate import ForestPlane, ProbabilisticRandomForest


def _plane(d: int = 6, seed: int = 11, n_trees: int = 4, depth: int = 5):
    rng = np.random.default_rng(seed)
    Xf = rng.random((80, d))
    models = [
        ProbabilisticRandomForest(
            n_trees=n_trees, max_depth=depth, seed=s, backend="numpy"
        ).fit(Xf, rng.random(80))
        for s in range(3)
    ]
    return ForestPlane([m.pack() for m in models]), rng


def _mutation_pool(rng, d: int, n_free: int = 10, n_mut: int = 30, n_bases: int = 4):
    bases = rng.random((n_bases, d))
    N = n_free + n_mut
    base_of = np.concatenate(
        [np.full(n_free, -1), rng.integers(0, n_bases, n_mut)]
    )
    X = np.empty((N, d))
    for i in range(N):
        if base_of[i] < 0:
            X[i] = rng.random(d)
        else:
            X[i] = bases[base_of[i]]
            nm = rng.integers(1, d)
            cols = rng.choice(d, size=nm, replace=False)
            X[i, cols] = rng.random(nm)
    return X, bases, base_of


def test_delta_predict_bit_identical():
    plane, rng = _plane()
    X, bases, base_of = _mutation_pool(rng, d=6)
    m0, v0 = plane.predict(X, backend="numpy")
    m1, v1 = plane.predict(X, backend="numpy", delta=(bases, base_of))
    np.testing.assert_array_equal(m0, m1)
    np.testing.assert_array_equal(v0, v1)


def test_delta_predict_degenerate_pools():
    plane, rng = _plane(seed=12)
    X, bases, base_of = _mutation_pool(rng, d=6)
    N = len(X)
    m0, v0 = plane.predict(X, backend="numpy")
    # all-free: every row scored through the plain chain walk
    m2, v2 = plane.predict(X, backend="numpy", delta=(bases, np.full(N, -1)))
    np.testing.assert_array_equal(m0, m2)
    np.testing.assert_array_equal(v0, v2)
    # all-based on one base, every coordinate mutated: pure re-AND path
    Xall = rng.random((N, X.shape[1]))
    ma, va = plane.predict(Xall, backend="numpy")
    mb, vb = plane.predict(
        Xall, backend="numpy", delta=(bases, np.zeros(N, dtype=np.int64))
    )
    np.testing.assert_array_equal(ma, mb)
    np.testing.assert_array_equal(va, vb)


def test_delta_dispatch_counter():
    plane, rng = _plane(seed=13)
    X, bases, base_of = _mutation_pool(rng, d=6)
    with obs.tracing() as tr:
        plane.predict(X, backend="numpy", delta=(bases, base_of))
        plane.predict(X, backend="numpy")
    view = tr.metrics.counters_view("forest_plane/")
    assert view.get("chain_delta", 0) == 1
    assert view.get("numpy", 0) == 1


def _space(d: int = 5):
    knobs = [FloatKnob(f"f{i}", 0.0, 1.0) for i in range(d - 1)]
    knobs.append(IntKnob("i0", 1, 32))
    return ConfigSpace(knobs)


def test_candidate_pool_sets_delta_provenance():
    from repro.core.generator import CandidateGenerator

    space = _space()
    gen = CandidateGenerator(space, seed=7, pool_size=64)
    incs = [space.sample(np.random.default_rng(3), 1)[0] for _ in range(3)]
    pool = gen._candidate_pool(incs)
    delta = pool.delta
    assert delta is not None
    bases, base_of = delta
    assert base_of.shape == (len(pool),)
    n_mut = int((base_of >= 0).sum())
    assert n_mut > 0 and np.all(base_of[: len(pool) - n_mut] == -1)
    assert bases.shape[0] >= int(base_of.max()) + 1
    # every based row differs from its base only where a mutation landed;
    # at least the shared coordinates must match the base row exactly.
    U = pool.unit()
    for i in np.flatnonzero(base_of >= 0)[:8]:
        shared = U[i] == bases[base_of[i]]
        assert shared.any()  # gate p<1 keeps some coords untouched w.h.p.

    # a pool with no incumbents carries no delta
    assert gen._candidate_pool([]).delta is None


def test_recommend_unchanged_by_delta_path(monkeypatch):
    import repro.core.generator as GEN
    from repro.core.generator import CandidateGenerator, SurrogateSource

    space = _space()
    rng = np.random.default_rng(2)
    X = space.sample(rng, 30).unit()
    models = [
        ProbabilisticRandomForest(n_trees=3, max_depth=4, seed=s).fit(
            X, rng.random(30)
        )
        for s in range(2)
    ]
    srcs = [
        SurrogateSource(name=f"s{i}", model=m, weight=0.5, incumbent=0.4)
        for i, m in enumerate(models)
    ]
    incs = [space.sample(np.random.default_rng(9), 1)[0] for _ in range(3)]

    got_delta = CandidateGenerator(space, seed=5, pool_size=64).recommend(
        4, srcs, incumbents=incs
    )

    orig = GEN.score_sources
    monkeypatch.setattr(
        GEN,
        "score_sources",
        lambda models, X, incs, delta=None: orig(models, X, incs, delta=None),
    )
    got_plain = CandidateGenerator(space, seed=5, pool_size=64).recommend(
        4, srcs, incumbents=incs
    )
    assert got_delta == got_plain
