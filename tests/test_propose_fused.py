"""Fused on-device propose step: bit-equivalence and routing contracts.

The jax path re-implements EI (Cephes exp/ndtr ports behind FMA/FTZ/
reciprocal-rewrite barriers) and weighted rank aggregation inside one jitted
program; these tests pin x64 bit-identity against the numpy reference at
awkward pool sizes (255/256/257 straddle the minimum padding bucket,
65535/65536 the large buckets), degenerate variances, and tied scores — then
check the engine/generator/MFTune layers preserve selection identity in
host-pool mode and stay sane in device-pool mode.
"""

import numpy as np
import pytest

from repro.core import (
    BoolKnob,
    CatKnob,
    ConfigSpace,
    FloatKnob,
    IntKnob,
    Intervals,
    KnowledgeBase,
    ProbabilisticRandomForest,
    ProposeEngine,
    aggregate_ranks,
    aggregate_ranks_jax,
    expected_improvement,
    expected_improvement_jax,
    plane_cache_stats,
    score_sources,
    set_plane_cache_size,
)

jax = pytest.importorskip("jax")


def _space():
    return ConfigSpace([
        FloatKnob("f1", 0.1, 10.0, log=True),
        FloatKnob("f2", -5.0, 5.0),
        IntKnob("i1", 1, 64, log=True),
        IntKnob("i2", 0, 9),
        CatKnob("c1", ["a", "b", "c"]),
        BoolKnob("b1"),
    ])


def _models(space, n_sources=3, n_obs=40, seed0=0):
    rng = np.random.default_rng(seed0)
    D = space.dim
    models = []
    for s in range(n_sources):
        X = rng.random((n_obs, D))
        y = rng.random(n_obs) * 10 + s
        models.append(ProbabilisticRandomForest(n_trees=10, seed=s).fit(X, y))
    return models


# ------------------------------------------------------------ EI bit-identity


@pytest.mark.parametrize("n", [255, 256, 257, 65535, 65536])
def test_ei_bitwise_identical(n):
    rng = np.random.default_rng(n)
    mean = rng.normal(5.0, 3.0, n)
    var = rng.gamma(1.0, 2.0, n)
    best = 4.0
    ref = expected_improvement(mean, var, best)
    got = expected_improvement_jax(mean, var, best)
    assert ref.dtype == got.dtype == np.float64
    assert np.array_equal(ref.view(np.uint64), got.view(np.uint64))


def test_ei_bitwise_degenerate_and_extreme():
    # zero variance hits the floor; huge |z| exercises the erfc tail and the
    # denormal-flush contract; mixed signs cover both ndtr branches
    mean = np.array([0.0, 5.0, -5.0, 1e6, -1e6, 3.0, 3.0, 1e-300])
    var = np.array([0.0, 0.0, 0.0, 1e-8, 1e-8, 1e4, 1e-12, 0.0])
    for best in (-1e6, -37.0, 0.0, 2.9999999, 3.0, 1e6):
        ref = expected_improvement(mean, var, best)
        got = expected_improvement_jax(mean, var, best)
        assert np.array_equal(ref.view(np.uint64), got.view(np.uint64)), best


# ------------------------------------------------- rank-agg bit-identity


@pytest.mark.parametrize("n", [255, 256, 257, 65536])
def test_aggregate_ranks_bitwise_identical(n):
    rng = np.random.default_rng(n)
    scores = rng.random((3, n))
    # force ties so the stable argsort order is load-bearing
    scores[0, : n // 2] = scores[0, 0]
    scores[1] = np.round(scores[1], 1)
    w = np.array([0.5, 0.3, 0.2])
    ref = aggregate_ranks(scores, w)
    got = aggregate_ranks_jax(scores, w)
    assert np.array_equal(
        np.asarray(ref, dtype=np.float64).view(np.uint64),
        np.asarray(got, dtype=np.float64).view(np.uint64),
    )
    # selection order must match exactly even under ties
    assert np.array_equal(
        np.argsort(ref, kind="stable"), np.argsort(got, kind="stable")
    )


def test_aggregate_ranks_all_tied():
    scores = np.ones((2, 300))
    w = np.array([0.7, 0.3])
    ref = aggregate_ranks(scores, w)
    got = aggregate_ranks_jax(scores, w)
    assert np.array_equal(
        np.asarray(ref, dtype=np.float64).view(np.uint64),
        np.asarray(got, dtype=np.float64).view(np.uint64),
    )


# ------------------------------------------------ engine selection identity


def _staged_topk(models, pool, incs, ws, n):
    scores = score_sources(models, pool, incs)
    agg = aggregate_ranks(scores, np.asarray(ws))
    return np.argsort(agg, kind="stable")[:n]


@pytest.mark.parametrize("descent", ["auto", "qs", "jax", "pallas"])
def test_score_topk_matches_staged_numpy(descent):
    space = _space()
    models = _models(space)
    rng = np.random.default_rng(7)
    incs, ws = [5.0, 4.0, 6.0], [0.5, 0.3, 0.2]
    eng = ProposeEngine(space, seed=0)
    assert ProposeEngine.fusable(models)
    for n_pool in (100, 777):
        pool = rng.random((n_pool, space.dim))
        ref = _staged_topk(models, pool, incs, ws, 5)
        got = eng.score_topk(models, pool, incs, ws, 5, descent=descent)
        assert np.array_equal(ref, got)
    if descent == "qs":
        # small fixture trees fit the 64-leaf word: the merged QuickScorer
        # tables must actually route this, not silently fall back
        assert any(sig[-1] == "qs" for sig in eng.compiled)


def test_jit_cache_growth_bounded():
    space = _space()
    models = _models(space)
    eng = ProposeEngine(space, seed=0)
    rng = np.random.default_rng(11)
    # many calls, two shape buckets -> at most two static signatures
    for n_pool in (300, 300, 500, 400, 510):
        pool = rng.random((n_pool, space.dim))
        eng.score_topk(models, pool, [5.0, 4.0, 6.0], [0.5, 0.3, 0.2], 4)
    assert len(eng.compiled) <= 2


# ------------------------------------------------------ device-pool propose


def test_device_propose_valid_configs():
    space = _space()
    models = _models(space)
    eng = ProposeEngine(space, seed=0)
    idx, units, agg = eng.propose(models, [5.0, 4.0, 6.0], [0.5, 0.3, 0.2], 5)
    assert units.shape[1] == space.dim
    assert np.all((units >= 0.0) & (units <= 1.0))
    assert np.all(np.isfinite(agg))
    batch = space.decode_many(units)
    for i in range(len(batch)):
        cfg = batch[i]
        for k in space.knobs:
            v = cfg[k.name]
            if isinstance(k, FloatKnob):
                assert k.lo <= v <= k.hi
            elif isinstance(k, IntKnob):
                assert isinstance(v, (int, np.integer)) and k.lo <= v <= k.hi
            elif isinstance(k, CatKnob):
                assert v in k.choices
            else:
                assert isinstance(v, (bool, np.bool_))


def test_device_propose_respects_restrictions():
    space = _space()
    models = _models(space)
    sub = space.restrict(
        keep=["f1", "i1", "c1", "b1"],
        ranges={"f1": Intervals([(0.5, 1.0), (4.0, 8.0)])},
        cat_subsets={"c1": ["a", "c"]},
    )
    eng = ProposeEngine(space, seed=0, pool_size=512)
    _, units, _ = eng.propose(
        models, [5.0, 4.0, 6.0], [0.5, 0.3, 0.2], 8, sample_space=sub
    )
    batch = space.decode_many(units)
    f2_default = space.by_name["f2"].default_value()
    i2_default = space.by_name["i2"].default_value()
    for i in range(len(batch)):
        cfg = batch[i]
        assert (0.5 <= cfg["f1"] <= 1.0) or (4.0 <= cfg["f1"] <= 8.0)
        assert cfg["c1"] in ("a", "c")
        # dropped knobs pin to full-space defaults
        assert cfg["f2"] == f2_default
        assert cfg["i2"] == i2_default


def test_device_propose_key_threading_deterministic():
    space = _space()
    models = _models(space)
    a = ProposeEngine(space, seed=0)
    b = ProposeEngine(space, seed=0)
    _, ua1, _ = a.propose(models, [5.0], [1.0], 4)
    _, ua2, _ = a.propose(models, [5.0], [1.0], 4)
    _, ub1, _ = b.propose(models, [5.0], [1.0], 4)
    _, ub2, _ = b.propose(models, [5.0], [1.0], 4)
    assert np.array_equal(ua1, ub1) and np.array_equal(ua2, ub2)
    assert not np.array_equal(ua1, ua2)  # the key advances between steps


# --------------------------------------------------------- plane cache LRU


def test_plane_cache_stats_and_resize():
    space = _space()
    models = _models(space)
    eng = ProposeEngine(space, seed=0)
    prev = set_plane_cache_size(2)
    try:
        s0 = plane_cache_stats()
        assert s0["max_entries"] == 2
        pool = np.random.default_rng(0).random((64, space.dim))
        eng.score_topk(models, pool, [5.0, 4.0, 6.0], [0.5, 0.3, 0.2], 3)
        s1 = plane_cache_stats()
        assert s1["misses"] == s0["misses"] + 1
        eng.score_topk(models, pool, [5.0, 4.0, 6.0], [0.5, 0.3, 0.2], 3)
        s2 = plane_cache_stats()
        assert s2["hits"] == s1["hits"] + 1
        assert s2["entries"] <= 2
    finally:
        set_plane_cache_size(prev)


# ----------------------------------------------- MFTune trajectory identity


def _observations(**opt_kw):
    from repro.core import MFTune, MFTuneOptions
    from repro.sparksim import SparkWorkload, TaskSpec, generate_history
    from repro.tuneapi import Budget

    kb = KnowledgeBase()
    kb.add_task(
        generate_history(
            TaskSpec("tpch", 100, "A").workload(), n_obs=12, n_init=5, seed=3
        ),
        persist=False,
    )
    wl = SparkWorkload("tpch", 100, "A")
    res = MFTune(wl, kb, MFTuneOptions(seed=0, **opt_kw)).run(Budget(8 * 3600.0))
    obs = kb.get(wl.task_id).observations
    sig = [
        (o.performance, o.fidelity, tuple(sorted(o.config.items()))) for o in obs
    ]
    traj = [
        (p.time, p.best, tuple(sorted(p.config.items()))) for p in res.trajectory
    ]
    return sig, traj, res


def test_mftune_identical_across_acquisition_backends():
    ref_sig, ref_traj, ref_res = _observations()
    got_sig, got_traj, got_res = _observations(
        acquisition_backend="jax", acquisition_pool="host"
    )
    assert ref_res.n_evaluations > 10  # the BO loop actually ran
    assert got_res.plane_cache["misses"] > 0  # the fused path actually ran
    assert ref_sig == got_sig
    assert ref_traj == got_traj
    assert ref_res.best_performance == got_res.best_performance
