"""Batched-vs-scalar evaluation equivalence.

The vectorized engine (`SparkCostModel.evaluate_batch`,
`Workload.evaluate_many`, Hyperband's rung-level batched path) must
reproduce the scalar reference paths bit-for-bit: latencies, costs,
failure flags/reasons, early-stop charging and noise determinism.
"""

import numpy as np
import pytest

from repro.core import HyperbandRunner
from repro.sparksim import SparkWorkload
from repro.tuneapi import EvalResult, Workload


@pytest.fixture(scope="module")
def wl():
    return SparkWorkload("tpch", 600, "A")


def _configs(wl, n, seed):
    rng = np.random.default_rng(seed)
    return [dict(wl.space.default(), **c) for c in wl.space.sample(rng, n)]


def _assert_rows_equal(ref, row):
    lats, costs, failed, reason = row
    assert [float(x) for x in ref[0]] == lats
    assert [float(x) for x in ref[1]] == costs
    assert ref[2] == failed and ref[3] == reason


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_batch_matches_scalar_full_set(wl, seed):
    cfgs = _configs(wl, 8, seed)
    rows = wl.model.evaluate_batch(cfgs)
    for cfg, row in zip(cfgs, rows):
        _assert_rows_equal(wl.model.evaluate(cfg), row)


@pytest.mark.parametrize("data_fraction", [1.0, 1 / 3, 1 / 27])
def test_batch_matches_scalar_subsets_and_fractions(wl, data_fraction):
    rng = np.random.default_rng(7)
    cfgs = _configs(wl, 6, 7)
    subset = list(rng.choice(len(wl.queries), size=9, replace=False))
    rows = wl.model.evaluate_batch(cfgs, query_indices=subset, data_fraction=data_fraction)
    for cfg, row in zip(cfgs, rows):
        _assert_rows_equal(
            wl.model.evaluate(cfg, query_indices=subset, data_fraction=data_fraction), row
        )


def test_batch_matches_scalar_cost_caps(wl):
    """Early-stop charging (and its precedence over OOM) is identical."""
    cfgs = _configs(wl, 10, 11)
    full = [wl.model.evaluate(c)[0] for c in cfgs]
    # caps chosen to trigger early stops at different depths per config
    caps = [sum(lats) * f for lats, f in zip(full, [0.05, 0.3, 0.9, 1.1, 0.5,
                                                    0.01, 0.7, 2.0, 0.2, 0.6])]
    rows = wl.model.evaluate_batch(cfgs, cost_cap=caps)
    n_early = 0
    for cfg, cap, row in zip(cfgs, caps, rows):
        ref = wl.model.evaluate(cfg, cost_cap=cap)
        _assert_rows_equal(ref, row)
        n_early += row[3] == "early_stop"
    assert n_early >= 5  # the caps actually exercised the early-stop branch
    # one shared scalar cap behaves like a per-config broadcast of it
    rows_scalar_cap = wl.model.evaluate_batch(cfgs, cost_cap=caps[0])
    _assert_rows_equal(wl.model.evaluate(cfgs[1], cost_cap=caps[0]), rows_scalar_cap[1])


def test_batch_reproduces_oom(wl):
    bad = dict(wl.default_config())
    bad.update({"spark.executor.memory": 2, "spark.memory.fraction": 0.3,
                "spark.sql.shuffle.partitions": 20, "spark.executor.cores": 16})
    bad = dict(wl.space.default(), **bad)
    good = dict(wl.space.default(), **wl.default_config())
    rows = wl.model.evaluate_batch([good, bad])
    _assert_rows_equal(wl.model.evaluate(good), rows[0])
    _assert_rows_equal(wl.model.evaluate(bad), rows[1])
    assert rows[1][2] and rows[1][3] == "oom"
    assert len(rows[1][0]) < len(wl.queries)  # aborted at the failing query


def test_batch_deterministic(wl):
    cfgs = _configs(wl, 4, 3)
    a = wl.model.evaluate_batch(cfgs)
    b = wl.model.evaluate_batch(cfgs)
    assert a == b


def test_workload_evaluate_many_matches_evaluate(wl):
    rng = np.random.default_rng(5)
    cfgs = [c for c in wl.space.sample(rng, 5)]  # partial configs: defaults merged inside
    subset = [0, 3, 7, 12]
    many = wl.evaluate_many(cfgs, query_indices=subset, cost_cap=40.0, data_fraction=0.5)
    for cfg, res in zip(cfgs, many):
        ref = wl.evaluate(cfg, query_indices=subset, cost_cap=40.0, data_fraction=0.5)
        assert [float(x) for x in ref.per_query_latency] == res.per_query_latency
        assert [float(x) for x in ref.per_query_cost] == res.per_query_cost
        assert ref.failed == res.failed and ref.failure_reason == res.failure_reason


class _LoopWorkload(Workload):
    """Protocol-only workload: exercises the default evaluate_many fallback."""

    task_id = "loop"

    def __init__(self):
        self.calls = []

    @property
    def queries(self):
        return ["q1", "q2"]

    def evaluate(self, config, query_indices=None, cost_cap=None, data_fraction=1.0):
        self.calls.append((config["x"], cost_cap))
        return EvalResult(per_query_latency=[float(config["x"])], per_query_cost=[1.0])


def test_default_evaluate_many_loops_with_per_config_caps():
    w = _LoopWorkload()
    res = w.evaluate_many([{"x": 1}, {"x": 2}], cost_cap=[5.0, None])
    assert [r.per_query_latency for r in res] == [[1.0], [2.0]]
    assert w.calls == [(1, 5.0), (2, None)]
    with pytest.raises(ValueError):
        w.evaluate_many([{"x": 1}], cost_cap=[1.0, 2.0])


def _toy_eval(cfg, delta, cap):
    # deterministic, lower id better; elapsed constant so the median cap
    # history is identical between the scalar and batched paths
    return float(cfg["id"]) + delta, cfg["id"] == 7, 1.0


def test_hyperband_batched_rungs_match_scalar():
    log_s, log_b = [], []

    def run(use_batch, log):
        hb = HyperbandRunner(R=9, eta=3, seed=0)
        kwargs = {}
        if use_batch:
            kwargs["evaluate_batch"] = lambda cfgs, delta, cap: [
                _toy_eval(c, delta, cap) for c in cfgs
            ]
        return hb.run_bracket(
            hb.brackets[0],
            provide_candidates=lambda n, rungs: [{"id": i} for i in range(n)],
            evaluate=lambda cfg, delta, cap: _toy_eval(cfg, delta, cap),
            on_result=lambda cfg, delta, perf, failed, elapsed: log.append(
                (cfg["id"], round(delta, 6), perf, failed)
            ),
            should_stop=lambda: False,
            **kwargs,
        )

    out_s = run(False, log_s)
    out_b = run(True, log_b)
    assert log_s == log_b  # same configs evaluated at the same fidelities
    assert [(o.config, o.performance, o.failed) for o in out_s] == [
        (o.config, o.performance, o.failed) for o in out_b
    ]


def test_hyperband_batched_prefix_means_budget_out():
    """A short batch result (budget ran out) stops the rung like should_stop."""
    hb = HyperbandRunner(R=9, eta=3, seed=0)
    seen = []

    def batch(cfgs, delta, cap):
        out = [(float(c["id"]), False, 1.0) for c in cfgs]
        return out[:2]  # budget died after two evaluations

    outcomes = hb.run_bracket(
        hb.brackets[0],
        provide_candidates=lambda n, rungs: [{"id": i} for i in range(n)],
        evaluate=lambda cfg, delta, cap: (0.0, False, 1.0),
        on_result=lambda cfg, delta, perf, failed, elapsed: seen.append(cfg["id"]),
        should_stop=lambda: False,
        evaluate_batch=batch,
    )
    assert seen[:2] == [0, 1]
    # survivors of the truncated rung still promote (2 results / eta -> 1)
    assert all(i in (0, 1) for i in seen[2:])
