"""Spark simulator invariants + the paper's structural phenomena.

The property tests run as seeded ``pytest.mark.parametrize`` cases so the
module passes without ``hypothesis`` installed; a fuzz variant widens the
seed coverage when ``hypothesis`` is available (importorskip-guarded).
"""

import numpy as np
import pytest

from repro.core import kendall_tau
from repro.sparksim import SCENARIOS, SparkWorkload, spark_space


@pytest.fixture(scope="module")
def wl():
    return SparkWorkload("tpch", 600, "A")


def test_determinism(wl):
    cfg = wl.default_config()
    a = wl.evaluate(cfg)
    b = wl.evaluate(cfg)
    assert a.per_query_latency == b.per_query_latency


def test_sixty_knobs():
    assert len(spark_space()) == 60


def test_executor_sizing_caps(wl):
    cfg = wl.default_config()
    # absurd memory request -> cluster caps executor count -> slower
    small = dict(cfg, **{"spark.executor.instances": 48, "spark.executor.memory": 8})
    huge = dict(cfg, **{"spark.executor.instances": 48, "spark.executor.memory": 64})
    rs = wl.evaluate(small)
    rh = wl.evaluate(huge)
    assert rh.aggregate > rs.aggregate


def test_oom_channel(wl):
    cfg = dict(wl.default_config())
    cfg["spark.executor.memory"] = 2
    cfg["spark.memory.fraction"] = 0.3
    cfg["spark.sql.shuffle.partitions"] = 20
    cfg["spark.executor.cores"] = 16
    res = wl.evaluate(cfg)
    assert res.failed and res.failure_reason == "oom"


def test_cost_cap_early_stop(wl):
    cfg = wl.default_config()
    full = wl.evaluate(cfg)
    res = wl.evaluate(cfg, cost_cap=full.aggregate / 10)
    assert res.failed and res.failure_reason == "early_stop"
    assert res.elapsed <= full.aggregate / 10 + 1e-6


def test_meta_features_34d(wl):
    mf = wl.meta_features()
    assert len(mf) == 34 and all(np.isfinite(mf))


def _check_latency_positive(seed):
    wl = SparkWorkload("tpch", 100, "B")
    rng = np.random.default_rng(seed)
    for cfg in wl.space.sample(rng, 3):
        res = wl.evaluate(cfg)
        assert all(l > 0 for l in res.per_query_latency)


@pytest.mark.parametrize("seed", [0, 37, 100])
def test_latency_positive(seed):
    _check_latency_positive(seed)


def test_latency_positive_fuzz():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    settings(max_examples=5, deadline=None)(
        given(st.integers(0, 100))(_check_latency_positive)
    )()


def test_data_volume_proxy_decorrelates(wl):
    """Fig. 1b structure: tiny data fractions must rank configs worse than
    the full-data ranking ranks itself (tau(DV 4%) substantially < 1).

    48 samples: log-space sampling of the memory knobs sends more configs
    into the OOM region, so a larger pool keeps the surviving-config tau
    estimate stable.
    """
    rng = np.random.default_rng(0)
    cfgs = [c for c in wl.space.sample(rng, 48)]
    full, tiny = [], []
    for c in cfgs:
        rf = wl.evaluate(c)
        rt = wl.evaluate(c, data_fraction=1 / 27)
        if not rf.failed and not rt.failed:
            full.append(rf.aggregate)
            tiny.append(rt.aggregate)
    tau, _ = kendall_tau(tiny, full)
    assert tau < 0.75  # materially degraded ranking


def test_hardware_scenarios_differ(wl):
    cfg = wl.default_config()
    a = SparkWorkload("tpch", 600, "A").evaluate(cfg).aggregate
    f = SparkWorkload("tpch", 600, "F").evaluate(cfg).aggregate
    assert f > a  # scenario F (2 nodes, 32 cores, 128GB) is strictly smaller
