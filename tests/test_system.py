"""End-to-end behaviour tests for the paper's system (MFTune on sparksim)."""

import numpy as np
import pytest

from repro.core import KnowledgeBase, MFTune, MFTuneOptions
from repro.sparksim import SparkWorkload, TaskSpec, generate_history
from repro.tuneapi import Budget


@pytest.fixture(scope="module")
def mini_kb():
    kb = KnowledgeBase()
    for i, spec in enumerate([TaskSpec("tpch", 600, "B"), TaskSpec("tpch", 100, "A")]):
        kb.add_task(generate_history(spec.workload(), n_obs=16, n_init=6, seed=i), persist=False)
    return kb


def _run(kb, hours=144, **opts):
    # 144 simulated hours: log-space sampling of the memory/parallelism
    # knobs makes random 600GB configs slower (and more OOM-prone) than the
    # old raw-unit draws, so the tuner needs a bigger simulated budget to
    # accumulate the observations that activate MFO.
    wl = SparkWorkload("tpch", 600, "A")
    tuner = MFTune(wl, kb, MFTuneOptions(seed=0, **opts))
    return tuner.run(Budget(hours * 3600.0))


def test_mftune_end_to_end(mini_kb):
    res = _run(mini_kb)
    assert res.best_config is not None
    assert np.isfinite(res.best_performance)
    assert res.n_evaluations > res.n_full_evaluations  # low-fidelity evals happened
    assert res.mfo_activation_time is not None
    # beats the default configuration comfortably
    wl = SparkWorkload("tpch", 600, "A")
    default = wl.evaluate(wl.default_config()).aggregate
    assert res.best_performance < default


@pytest.mark.slow
def test_mftune_multifidelity_explores_more(mini_kb):
    mf = _run(mini_kb, hours=144)
    sf = _run(mini_kb, hours=144, enable_mfo=False)
    # the Fig. 1a phenomenon: MFO evaluates more configurations in-budget
    assert mf.n_evaluations > sf.n_evaluations
    assert sf.n_evaluations == sf.n_full_evaluations


def test_cold_start_degrades_to_bo_then_activates():
    res = _run(KnowledgeBase(), hours=48)
    assert res.best_config is not None
    # no history: MFO can only activate after enough own observations
    assert res.mfo_activation_time is None or res.mfo_activation_time > 0


@pytest.mark.slow
def test_trajectory_monotone(mini_kb):
    res = _run(mini_kb)
    bests = [p.best for p in res.trajectory]
    assert all(b2 <= b1 + 1e-9 for b1, b2 in zip(bests, bests[1:]))


@pytest.mark.slow
def test_budget_respected(mini_kb):
    wl = SparkWorkload("tpch", 600, "A")
    budget = Budget(12 * 3600.0)
    MFTune(wl, mini_kb, MFTuneOptions(seed=1)).run(budget)
    # the final evaluation may overshoot by at most one evaluation's cost
    assert budget.spent < budget.total * 1.5
