"""Chain-plan fallback contract: every decline carries an explicit reason.

``build_chain_plan`` returns None when the bitvector encoding does not
apply; the batched Shapley plane then falls back to the composite-tensor
path. These tests pin the decline reasons (so a silent behavioral change in
the applicability rules shows up as a reason-string diff) and check the
fallback actually produces attributions on a >64-leaf forest.
"""

import numpy as np
import pytest

from repro.core import ProbabilisticRandomForest
from repro.kernels.forest_eval.chain import build_chain_plan, chain_decline_reason


def _fit_prf(n, d, seed=0, **kw):
    rng = np.random.default_rng(seed)
    X = rng.random((n, d))
    y = rng.random(n)
    return ProbabilisticRandomForest(seed=seed, **kw).fit(X, y)


def test_decline_d_over_64():
    m = _fit_prf(30, 4, n_trees=3, max_depth=3)
    assert build_chain_plan(m, 65) is None
    assert "> 64 prefix-mask bits" in chain_decline_reason()


def test_decline_not_packable():
    assert build_chain_plan(object(), 4) is None
    assert chain_decline_reason() == "not a packable forest"


def test_decline_leaf_overflow_and_fallback():
    # deep trees on plentiful data exceed 64 leaves per tree
    m = _fit_prf(600, 4, n_trees=3, max_depth=12, min_samples_split=2)
    leaves = max(
        sum(1 for nd in t.nodes if nd.feature < 0) for t in m.trees
    )
    assert leaves > 64, "fixture failed to grow a >64-leaf tree"
    assert build_chain_plan(m, 4) is None
    assert "leaf word" in chain_decline_reason()

    # the batched plane still attributes via the composite-tensor fallback,
    # bit-identical to the per-chain loop path
    from repro.core import draw_permutations, shapley_values_batch

    rng = np.random.default_rng(1)
    Xq = rng.random((3, 4))
    bg = rng.random((8, 4))
    perms = draw_permutations(4, 4, rng)
    loop = shapley_values_batch(m.predict_mean, Xq, bg, perms=perms, backend="loop")
    batched = shapley_values_batch(m.predict_mean, Xq, bg, perms=perms, model=m)
    assert np.array_equal(loop, batched)


def test_success_clears_reason():
    m = _fit_prf(40, 4, n_trees=3, max_depth=3)
    # force a decline first so a stale reason would be visible
    assert build_chain_plan(m, 65) is None
    assert chain_decline_reason()
    assert build_chain_plan(m, 4) is not None
    assert chain_decline_reason() == ""
