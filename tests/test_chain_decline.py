"""Chain-plan fallback contract: every decline carries an explicit reason.

``build_chain_plan`` returns None when the bitvector encoding does not
apply; the batched Shapley plane then falls back to the composite-tensor
path. These tests pin the decline reasons (so a silent behavioral change in
the applicability rules shows up as a reason-string diff) and check the
fallback actually produces attributions on a >64-leaf forest.
"""

import numpy as np
import pytest

from repro.core import ProbabilisticRandomForest
from repro.kernels.forest_eval.chain import build_chain_plan, chain_decline_reason


def _fit_prf(n, d, seed=0, **kw):
    rng = np.random.default_rng(seed)
    X = rng.random((n, d))
    y = rng.random(n)
    return ProbabilisticRandomForest(seed=seed, **kw).fit(X, y)


def test_decline_d_over_64():
    m = _fit_prf(30, 4, n_trees=3, max_depth=3)
    assert build_chain_plan(m, 65) is None
    assert "> 64 prefix-mask bits" in chain_decline_reason()


def test_decline_not_packable():
    assert build_chain_plan(object(), 4) is None
    assert chain_decline_reason() == "not a packable forest"


def test_decline_leaf_overflow_and_fallback():
    # deep trees on plentiful data exceed 128 leaves per tree — beyond even
    # the two-word (uint64 x 2) leaf encoding
    m = _fit_prf(600, 4, n_trees=3, max_depth=12, min_samples_split=2)
    leaves = max(
        sum(1 for nd in t.nodes if nd.feature < 0) for t in m.trees
    )
    assert leaves > 128, "fixture failed to grow a >128-leaf tree"
    assert build_chain_plan(m, 4) is None
    assert "leaf word" in chain_decline_reason()

    # the batched plane still attributes via the composite-tensor fallback,
    # bit-identical to the per-chain loop path
    from repro.core import draw_permutations, shapley_values_batch

    rng = np.random.default_rng(1)
    Xq = rng.random((3, 4))
    bg = rng.random((8, 4))
    perms = draw_permutations(4, 4, rng)
    loop = shapley_values_batch(m.predict_mean, Xq, bg, perms=perms, backend="loop")
    batched = shapley_values_batch(m.predict_mean, Xq, bg, perms=perms, model=m)
    assert np.array_equal(loop, batched)


def test_two_word_pack_success():
    # 64 < leaves <= 128 packs into two uint64 leaf words per tree; the
    # chain walk must stay bit-identical to the per-chain loop path.
    m = _fit_prf(170, 5, seed=2, n_trees=3, max_depth=14, min_samples_split=2)
    leaves = max(
        sum(1 for nd in t.nodes if nd.feature < 0) for t in m.trees
    )
    assert 64 < leaves <= 128, f"fixture grew {leaves} leaves, want (64, 128]"
    plan = build_chain_plan(m, 5)
    assert plan is not None and plan.n_words == 2
    assert chain_decline_reason() == ""

    from repro.core import draw_permutations, shapley_values_batch

    rng = np.random.default_rng(3)
    Xq = rng.random((3, 5))
    bg = rng.random((8, 5))
    perms = draw_permutations(5, 4, rng)
    loop = shapley_values_batch(m.predict_mean, Xq, bg, perms=perms, backend="loop")
    chained = shapley_values_batch(m.predict_mean, Xq, bg, perms=perms, model=m)
    assert np.array_equal(loop, chained)


def test_plan_carries_decline_reason():
    # satellite of the module-global fix: the reason travels on the
    # (plan, reason) return, not just the legacy last-call slot.
    from repro.kernels.forest_eval.chain import build_chain_plan_ex

    m_small = _fit_prf(40, 4, n_trees=3, max_depth=3)
    plan, reason = build_chain_plan_ex(m_small, 4)
    assert plan is not None and reason == ""
    assert plan.decline_reason == ""

    plan2, reason2 = build_chain_plan_ex(m_small, 65)
    assert plan2 is None and "> 64 prefix-mask bits" in reason2


def test_success_clears_reason():
    m = _fit_prf(40, 4, n_trees=3, max_depth=3)
    # force a decline first so a stale reason would be visible
    assert build_chain_plan(m, 65) is None
    assert chain_decline_reason()
    assert build_chain_plan(m, 4) is not None
    assert chain_decline_reason() == ""
