"""Beyond-paper: MFTune tunes THIS framework's distributed configuration.

The workload's "queries" are (arch x shape) step programs; a query's
latency is the three-term TPU-v5e roofline step time of its compiled HLO
under the candidate runtime configuration (remat policy, sequence
sharding, attention chunking, MoE capacity, optimizer dtype, ...). This is
exactly the regime the paper targets — expensive multi-part evaluations —
with real compiled artifacts as the objective.

Compiles are cached by (cell, config) so repeated evaluations are free.
Expect several minutes of real time for the first few evaluations.

    PYTHONPATH=src python examples/tune_mesh.py [--budget-evals 10]
"""

import argparse
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget-evals", type=int, default=8)
    ap.add_argument("--cells", nargs="+", default=["llama3-8b:train_4k"])
    args = ap.parse_args()

    from repro.jaxwl import CellWorkload
    from repro.core import KnowledgeBase, MFTune, MFTuneOptions
    from repro.tuneapi import Budget

    wl = CellWorkload([tuple(c.split(":")) for c in args.cells])
    base = wl.evaluate(wl.default_config())
    print(f"== baseline roofline step time {base.aggregate * 1e3:.2f} ms "
          f"across {len(wl.queries)} cells")

    # budget = modeled step-seconds; each evaluation charges its step time,
    # so an eval budget of N means roughly N compiles of the cell set
    tuner = MFTune(wl, KnowledgeBase(), MFTuneOptions(
        seed=0, enable_mfo=False, enable_transfer=False, init_lhs=4,
    ))
    budget = Budget(base.aggregate * args.budget_evals)
    res = tuner.run(budget)
    print(f"== best modeled step time {res.best_performance * 1e3:.2f} ms "
          f"({base.aggregate / res.best_performance:.2f}x vs default runtime config)")
    for k, v in sorted(res.best_config.items()):
        print(f"   {k} = {v}")


if __name__ == "__main__":
    main()
