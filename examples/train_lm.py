"""End-to-end driver: train a reduced llama3-family model for a few hundred
steps on CPU with checkpoint/resume fault tolerance.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="llama3-8b")
    args = ap.parse_args()

    from repro.configs import get_arch, reduced
    from repro.models import Runtime
    from repro.train.trainer import Trainer

    cfg = reduced(get_arch(args.arch))
    rt = Runtime(remat="none", scan_layers=True, attn_chunk=64, act_shard=False)
    print(f"== training reduced {args.arch}: {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab} ({sum(1 for _ in range(1))} host)")
    trainer = Trainer(cfg, rt, seq_len=128, global_batch=8, lr=1e-3, seed=0,
                      ckpt_dir=".cache/train_lm_ckpt", save_every=100)
    resumed = trainer.maybe_resume()
    if resumed:
        print(f"   resumed from step {trainer.step}")
    losses = trainer.run(args.steps, log_every=25)
    print(f"== done: loss {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
    assert losses[-1] < losses[0], "loss did not improve"


if __name__ == "__main__":
    main()
