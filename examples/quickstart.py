"""Quickstart: tune a Spark SQL workload with MFTune in 60 seconds.

Creates a small historical knowledge base (2 source tasks), then runs
MFTune against TPC-H/600GB on Hardware A under a 24h *virtual* budget —
the simulator's clock charges evaluation latency, so this finishes in
about a minute of real time.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import KnowledgeBase, MFTune, MFTuneOptions
from repro.sparksim import SparkWorkload, TaskSpec, generate_history
from repro.tuneapi import Budget


def main() -> None:
    print("== building a small knowledge base (2 historical tasks)")
    kb = KnowledgeBase()
    for i, spec in enumerate([TaskSpec("tpch", 600, "B"), TaskSpec("tpch", 100, "A")]):
        rec = generate_history(spec.workload(), n_obs=20, seed=i)
        kb.add_task(rec, persist=False)
        print(f"   {spec.task_id}: {len(rec.observations)} observations, "
              f"best={rec.best().performance / 3600:.2f}h")

    wl = SparkWorkload("tpch", 600, "A")
    default = wl.evaluate(wl.default_config()).aggregate
    print(f"== target {wl.task_id}: default-config latency {default / 3600:.2f}h")

    print("== tuning (24 virtual hours)...")
    tuner = MFTune(wl, kb, MFTuneOptions(seed=0))
    result = tuner.run(Budget(24 * 3600.0))

    print(f"== done: best latency {result.best_performance / 3600:.2f}h "
          f"({default / result.best_performance:.2f}x speedup vs default)")
    print(f"   evaluations: {result.n_evaluations} total, "
          f"{result.n_full_evaluations} full-fidelity "
          f"(MFO activated at t={result.mfo_activation_time / 3600:.1f}h)"
          if result.mfo_activation_time is not None else "")
    print("   convergence:")
    for p in result.trajectory:
        print(f"     t={p.time / 3600:6.2f}h  best={p.best / 3600:6.2f}h")
    top = sorted(result.best_config.items())[:8]
    print("   best config (first 8 knobs):")
    for k, v in top:
        print(f"     {k} = {v}")


if __name__ == "__main__":
    main()
